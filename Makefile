# Convenience targets. `make artifacts` is referenced throughout the
# rust sources: it AOT-lowers the L2 JAX graphs (and their L1 Pallas
# kernels) to the HLO text artifacts the PJRT runtime loads.

.PHONY: artifacts build test bench scenarios clean

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Cross-scenario robustness matrix (every Fig-8 system x every workload
# scenario, incl. the checked-in sample trace) — EXPERIMENTS.md.
scenarios:
	cargo run --release -- experiment scenarios

bench:
	cargo bench

clean:
	cargo clean
	rm -rf out
