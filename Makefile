# Convenience targets. `make artifacts` is referenced throughout the
# rust sources: it AOT-lowers the L2 JAX graphs (and their L1 Pallas
# kernels) to the HLO text artifacts the PJRT runtime loads.

.PHONY: artifacts build test bench clean

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

clean:
	cargo clean
	rm -rf out
