# Convenience targets. `make artifacts` is referenced throughout the
# rust sources: it AOT-lowers the L2 JAX graphs (and their L1 Pallas
# kernels) to the HLO text artifacts the PJRT runtime loads.

.PHONY: artifacts build test lint lint-rules bench bench-scale scenarios overload keepalive adversity replay trace clean

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Two-pass determinism linter (DESIGN.md §Static analysis): token rules
# D001-D005 (hash-ordered collections, wall-clock reads, unsalted RNG
# forks, partial float orders, fallible queue pops) plus the crate-wide
# rules D006-D010 (salt registry, metrics-aggregation coverage, trace
# taxonomy, eviction funnel, RNG-stream hygiene). Non-zero exit on any
# violation.
lint:
	cargo run --release -- lint

# The rule catalog: id, pass (token vs crate), file scope, and contract
# for every D-rule the gate enforces.
lint-rules:
	cargo run --release -- lint --list-rules

# Cross-scenario robustness matrix (every Fig-8 system x every workload
# scenario, incl. the checked-in sample trace) — EXPERIMENTS.md.
scenarios:
	cargo run --release -- experiment scenarios

# Past-saturation rps sweep (4-worker cluster, 4->64 rps): queue-wait /
# shed distributions plus the engine admission invariant (fails if any
# worker ever exceeded its limits); dumps out/overload.json — EXPERIMENTS.md.
overload:
	cargo run --release -- experiment overload

# Keep-alive policy x workload matrix (fixed/histogram/pressure over
# azure-synthetic + diurnal on a small cluster): idle-container-seconds
# vs cold starts per eviction policy, re-verifies the admission
# invariant per replicate; dumps out/keepalive.json — EXPERIMENTS.md.
keepalive:
	cargo run --release -- experiment keepalive

# Adversity matrix (policy x keep-alive x fault profile: none/crash/
# stragglers/hetero/chaos on a small cluster): SLO + failure/requeue
# counters under deterministic fault injection, with the release-mode
# `Cluster::check_invariants` audit per replicate; dumps
# out/adversity.json — EXPERIMENTS.md + DESIGN.md §Faults.
adversity:
	cargo run --release -- experiment adversity

# Real-trace replay (policy x cluster-scaler grid over the --scenario
# trace, or the embedded Azure sample): streaming-ingest mix report,
# scaler:none control column byte-pinned to the fixed cluster, plus the
# fifer scaling timeline; dumps out/replay.json — EXPERIMENTS.md +
# DESIGN.md §Scaler / §Trace ingest.
replay:
	cargo run --release -- experiment replay

# Traced demo run + digest: JSONL lifecycle trace and Chrome trace-event
# timeline (load out/trace.json in Perfetto), then the latency-breakdown /
# utilization report — EXPERIMENTS.md + DESIGN.md §Observability.
trace:
	cargo run --release -- run --policy shabari --rps 4 --seeds 1 \
		--trace out/trace.jsonl --trace-chrome out/trace.json
	cargo run --release -- report out/trace.jsonl

bench:
	cargo bench

# Engine scale benchmark: 64 workers at 4x the fig8 request rate, one
# timed cell per policy; dumps out/BENCH_scale.json (EXPERIMENTS.md §Perf).
# seeds=1/jobs=1 on purpose: the checked-in BENCH_scale.json record and
# its before/after speedup methodology compare single-replicate,
# single-thread wall-clock on an identical grid + seed.
bench-scale:
	cargo run --release -- experiment scale --seeds 1 --jobs 1

clean:
	cargo clean
	rm -rf out
