"""L2 checks: model entrypoints produce correct shapes/values and the AOT
lowering pipeline yields parseable HLO text with stable parameter order."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def rand(seed, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, lo, hi)


class TestEntrypoints:
    def test_predict_matches_ref(self):
        w = rand(0, (model.NUM_CLASSES, model.FEAT_DIM))
        x = rand(1, (model.FEAT_DIM,))
        (scores,) = model.csmc_predict(w, x)
        np.testing.assert_allclose(scores, ref.score_ref(w, x), rtol=1e-5, atol=1e-6)

    def test_update_matches_ref(self):
        w = rand(2, (model.NUM_CLASSES, model.FEAT_DIM))
        x = rand(3, (model.FEAT_DIM,))
        c = rand(4, (model.NUM_CLASSES,), 1.0, 10.0)
        (w2,) = model.csmc_update(w, x, c, jnp.float32(0.05))
        np.testing.assert_allclose(w2, ref.update_ref(w, x, c, 0.05), rtol=1e-5, atol=1e-5)

    def test_predict_batch_shape(self):
        w = rand(5, (model.NUM_CLASSES, model.FEAT_DIM))
        xs = rand(6, (model.BATCH, model.FEAT_DIM))
        (scores,) = model.csmc_predict_batch(w, xs)
        assert scores.shape == (model.BATCH, model.NUM_CLASSES)
        np.testing.assert_allclose(
            scores, ref.score_batch_ref(w, xs), rtol=1e-5, atol=1e-6
        )

    def test_entrypoints_registry_complete(self):
        for entry in model.ENTRYPOINTS:
            fn, args = model.example_args(entry)
            assert callable(fn)
            assert all(hasattr(a, "shape") for a in args)


class TestLowering:
    @pytest.mark.parametrize("entry", model.ENTRYPOINTS)
    def test_lowering_produces_hlo_text(self, entry):
        text = aot.lower_entry(entry)
        assert "HloModule" in text
        assert "ENTRY" in text
        # tuple return convention the rust loader depends on
        assert "tuple(" in text or ") tuple" in text

    def test_predict_param_order(self):
        """Rust passes (W, x); parameter(0) must be the [48,16] weights."""
        text = aot.lower_entry("csmc_predict")
        entry_lines = []
        seen_entry = False
        for line in text.splitlines():
            t = line.strip()
            if t.startswith("ENTRY"):
                seen_entry = True
                continue
            if seen_entry:
                if t.startswith("}"):
                    break
                if "parameter(" in t:
                    entry_lines.append(t)
        assert len(entry_lines) == 2
        p0 = next(l for l in entry_lines if "parameter(0)" in l)
        p1 = next(l for l in entry_lines if "parameter(1)" in l)
        assert f"f32[{model.NUM_CLASSES},{model.FEAT_DIM}]" in p0
        assert f"f32[{model.FEAT_DIM}]" in p1

    def test_update_param_order(self):
        text = aot.lower_entry("csmc_update")
        assert f"f32[{model.NUM_CLASSES},{model.FEAT_DIM}]" in text
        # lr is a scalar parameter
        assert "f32[]" in text

    def test_no_custom_calls(self):
        """interpret=True must lower to plain HLO ops executable on CPU
        PJRT — a mosaic/tpu custom-call would break the rust runtime."""
        for entry in model.ENTRYPOINTS:
            text = aot.lower_entry(entry)
            assert "custom-call" not in text, f"{entry} contains a custom-call"


class TestArtifacts:
    """If artifacts/ exists (make artifacts), verify it is consistent."""

    def _dir(self):
        import os

        here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        return os.path.join(os.path.dirname(here), "artifacts")

    def test_manifest_consistent(self):
        import json
        import os

        d = self._dir()
        if not os.path.exists(os.path.join(d, "manifest.json")):
            pytest.skip("artifacts not built")
        with open(os.path.join(d, "manifest.json")) as f:
            m = json.load(f)
        assert m["num_classes"] == model.NUM_CLASSES
        assert m["feat_dim"] == model.FEAT_DIM
        assert m["batch"] == model.BATCH
        for entry in model.ENTRYPOINTS:
            path = os.path.join(d, f"{entry}.hlo.txt")
            assert os.path.exists(path), f"missing artifact {path}"
            with open(path) as fh:
                assert "HloModule" in fh.read(200)
