"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Fixed-shape smoke tests plus hypothesis sweeps over shapes, block sizes,
and value ranges. Tolerances are tight: the kernels perform the same ops
as the oracle, so only reduction-order noise is allowed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import csmc, ref

RTOL = 1e-4
ATOL = 1e-4


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# Fixed production shapes
# ---------------------------------------------------------------------------

class TestProductionShapes:
    C, F, B = 48, 16, 64

    def test_score(self):
        kw, kx = keys(0, 2)
        w, x = rand(kw, (self.C, self.F)), rand(kx, (self.F,))
        np.testing.assert_allclose(
            csmc.score(w, x), ref.score_ref(w, x), rtol=RTOL, atol=ATOL
        )

    def test_score_tiled(self):
        kw, kx = keys(1, 2)
        w, x = rand(kw, (self.C, self.F)), rand(kx, (self.F,))
        for block_c in (8, 16, 24, 48):
            np.testing.assert_allclose(
                csmc.score(w, x, block_c=block_c),
                ref.score_ref(w, x),
                rtol=RTOL,
                atol=ATOL,
                err_msg=f"block_c={block_c}",
            )

    def test_score_batch(self):
        kw, kx = keys(2, 2)
        w, xs = rand(kw, (self.C, self.F)), rand(kx, (self.B, self.F))
        np.testing.assert_allclose(
            csmc.score_batch(w, xs), ref.score_batch_ref(w, xs), rtol=RTOL, atol=ATOL
        )

    def test_score_batch_tiled(self):
        kw, kx = keys(3, 2)
        w, xs = rand(kw, (self.C, self.F)), rand(kx, (self.B, self.F))
        for bb, bc in [(8, 8), (16, 24), (32, 48), (64, 16)]:
            np.testing.assert_allclose(
                csmc.score_batch(w, xs, block_b=bb, block_c=bc),
                ref.score_batch_ref(w, xs),
                rtol=RTOL,
                atol=ATOL,
                err_msg=f"block=({bb},{bc})",
            )

    def test_update(self):
        kw, kx, kc = keys(4, 3)
        w, x = rand(kw, (self.C, self.F)), rand(kx, (self.F,))
        costs = rand(kc, (self.C,), 1.0, 10.0)
        np.testing.assert_allclose(
            csmc.update(w, x, costs, 0.05),
            ref.update_ref(w, x, costs, 0.05),
            rtol=RTOL,
            atol=ATOL,
        )

    def test_update_tiled(self):
        kw, kx, kc = keys(5, 3)
        w, x = rand(kw, (self.C, self.F)), rand(kx, (self.F,))
        costs = rand(kc, (self.C,), 1.0, 10.0)
        for block_c in (8, 12, 24):
            np.testing.assert_allclose(
                csmc.update(w, x, costs, 0.05, block_c=block_c),
                ref.update_ref(w, x, costs, 0.05),
                rtol=RTOL,
                atol=ATOL,
                err_msg=f"block_c={block_c}",
            )

    def test_update_lr_zero_is_identity(self):
        kw, kx, kc = keys(6, 3)
        w, x = rand(kw, (self.C, self.F)), rand(kx, (self.F,))
        costs = rand(kc, (self.C,))
        np.testing.assert_allclose(csmc.update(w, x, costs, 0.0), w, rtol=0, atol=0)

    def test_update_reduces_loss(self):
        """A small-lr CSOAA step must not increase the squared cost error."""
        kw, kx, kc = keys(7, 3)
        w, x = rand(kw, (self.C, self.F)), rand(kx, (self.F,))
        costs = rand(kc, (self.C,), 1.0, 10.0)

        def loss(wm):
            e = wm @ x - costs
            return float(jnp.sum(e * e))

        w2 = csmc.update(w, x, costs, 0.01)
        assert loss(np.asarray(w2)) <= loss(w) + 1e-6

    def test_score_zero_weights(self):
        x = rand(keys(8, 1)[0], (self.F,))
        out = csmc.score(jnp.zeros((self.C, self.F), jnp.float32), x)
        np.testing.assert_array_equal(np.asarray(out), np.zeros(self.C, np.float32))


# ---------------------------------------------------------------------------
# Hypothesis shape/value sweeps
# ---------------------------------------------------------------------------

@st.composite
def shapes(draw):
    c = draw(st.integers(1, 96))
    f = draw(st.integers(1, 48))
    return c, f


@settings(max_examples=40, deadline=None)
@given(shapes(), st.integers(0, 2**31 - 1))
def test_score_sweep(shape, seed):
    c, f = shape
    kw, kx = keys(seed, 2)
    w, x = rand(kw, (c, f)), rand(kx, (f,))
    np.testing.assert_allclose(csmc.score(w, x), ref.score_ref(w, x), rtol=RTOL, atol=ATOL)


@settings(max_examples=30, deadline=None)
@given(shapes(), st.integers(1, 32), st.integers(0, 2**31 - 1))
def test_score_batch_sweep(shape, b, seed):
    c, f = shape
    kw, kx = keys(seed, 2)
    w, xs = rand(kw, (c, f)), rand(kx, (b, f))
    np.testing.assert_allclose(
        csmc.score_batch(w, xs), ref.score_batch_ref(w, xs), rtol=RTOL, atol=ATOL
    )


@settings(max_examples=30, deadline=None)
@given(
    shapes(),
    st.floats(0.0, 0.5, allow_nan=False),
    st.integers(0, 2**31 - 1),
)
def test_update_sweep(shape, lr, seed):
    c, f = shape
    kw, kx, kc = keys(seed, 3)
    w, x = rand(kw, (c, f)), rand(kx, (f,))
    costs = rand(kc, (c,), 1.0, 10.0)
    np.testing.assert_allclose(
        csmc.update(w, x, costs, lr),
        ref.update_ref(w, x, costs, lr),
        rtol=RTOL,
        atol=1e-5,
    )


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_score_tiled_sweep(c_tiles, block_c, seed):
    """Tiled and untiled scoring agree for any divisible (C, block) combo."""
    c = c_tiles * block_c
    f = 16
    kw, kx = keys(seed, 2)
    w, x = rand(kw, (c, f)), rand(kx, (f,))
    np.testing.assert_allclose(
        csmc.score(w, x, block_c=block_c), csmc.score(w, x), rtol=RTOL, atol=ATOL
    )


# ---------------------------------------------------------------------------
# Extreme values: the cost function emits values in [1, ~2C]; weights stay
# bounded. Check no overflow/NaN creep at the edges.
# ---------------------------------------------------------------------------

def test_large_costs_finite():
    C, F = 48, 16
    kw, kx = keys(100, 2)
    w, x = rand(kw, (C, F)), rand(kx, (F,))
    costs = jnp.full((C,), 96.0, jnp.float32)
    out = csmc.update(w, x, costs, 0.05)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_repeated_updates_converge():
    """Online CSOAA on a fixed example converges to predicting the costs."""
    C, F = 8, 4
    kx, kc = keys(101, 2)
    x = rand(kx, (F,), 0.1, 1.0)
    costs = rand(kc, (C,), 1.0, 8.0)
    w = jnp.zeros((C, F), jnp.float32)
    for _ in range(300):
        w = csmc.update(w, x, costs, 0.2)
    np.testing.assert_allclose(csmc.score(w, x), costs, rtol=1e-3, atol=1e-3)


def test_vmem_estimate_production_fits():
    # 48x16 f32 panel + batch tiles must fit a 16 MiB VMEM budget easily.
    assert csmc.vmem_bytes(48, 16, b=64) < 16 * 1024 * 1024


def test_mxu_utilization_monotone_in_tiles():
    u_small = csmc.mxu_utilization(48, 16, 64, block_b=8, block_c=8)
    u_big = csmc.mxu_utilization(48, 16, 64, block_b=64, block_c=48)
    assert u_big > u_small
