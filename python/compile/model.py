"""Layer-2 JAX graphs for Shabari's online CSMC learner.

These are the computations the rust coordinator executes through PJRT on
the request path (predict) and the feedback path (update). Each function
here calls the Layer-1 Pallas kernels in ``kernels/csmc.py`` so that the
kernel lowers into the same HLO module — one artifact per entrypoint,
compiled once by the rust runtime at startup.

Production shapes (mirrored in ``rust/src/runtime/mod.rs`` and checked via
``artifacts/manifest.json``):

  C = 48  classes  (vCPU classes 1..48; memory classes 128MB * 1..48)
  F = 16  padded feature dimension (Table 2 features + bias + SLO slots)
  B = 64  bulk-predict batch

The argmin / confidence gating / safeguard logic intentionally stays in
rust (Layer 3): it is branchy scalar logic entangled with scheduler state,
not tensor compute.
"""

import jax.numpy as jnp

from .kernels import csmc

# Shape constants baked into the AOT artifacts.
NUM_CLASSES = 48
FEAT_DIM = 16
BATCH = 64
# Default CSOAA learning rate; the rust side passes lr explicitly so this
# is only the value used for documentation/tests.
DEFAULT_LR = 0.05


def csmc_predict(w, x):
    """Predict per-class costs for one invocation.

    w: [C, F] model weights, x: [F] featurized input (+ SLO slot).
    Returns a 1-tuple (scores[C],) — all artifacts return tuples.
    """
    return (csmc.score(w, x),)


def csmc_update(w, x, costs, lr):
    """One online CSOAA update after an invocation completes.

    costs[C] comes from the rust cost function (§4.3.1 of the paper:
    lowest cost 1 at the target class, growing linearly away from it,
    underprediction penalized more than overprediction).
    """
    return (csmc.update(w, x, costs, lr),)


def csmc_predict_batch(w, xs):
    """Bulk predict: xs [B, F] -> scores [B, C] (warm-up, replay, bench)."""
    return (csmc.score_batch(w, xs),)


def reference_predict(w, x):
    """Pure-jnp mirror of csmc_predict (used by pytest only)."""
    from .kernels import ref

    return (ref.score_ref(w, x),)


def example_args(entry):
    """ShapeDtypeStructs to lower each entrypoint with."""
    import jax

    f32 = jnp.float32
    w = jax.ShapeDtypeStruct((NUM_CLASSES, FEAT_DIM), f32)
    x = jax.ShapeDtypeStruct((FEAT_DIM,), f32)
    c = jax.ShapeDtypeStruct((NUM_CLASSES,), f32)
    lr = jax.ShapeDtypeStruct((), f32)
    xs = jax.ShapeDtypeStruct((BATCH, FEAT_DIM), f32)
    return {
        "csmc_predict": (csmc_predict, (w, x)),
        "csmc_update": (csmc_update, (w, x, c, lr)),
        "csmc_predict_batch": (csmc_predict_batch, (w, xs)),
    }[entry]


ENTRYPOINTS = ("csmc_predict", "csmc_update", "csmc_predict_batch")
