# L1: Pallas kernels for the CSMC learner hot-spot + pure-jnp oracle.
from . import csmc, ref  # noqa: F401
