"""Pure-jnp oracle for the CSMC (cost-sensitive multi-class) kernels.

This is the CORE correctness signal for Layer 1: every Pallas kernel in
``csmc.py`` must match these reference implementations (we assert
``allclose`` with tight f32 tolerances in pytest and hypothesis sweeps).

The learner is Vowpal-Wabbit-style CSOAA: one linear regressor per class
predicts the *cost* of choosing that class; prediction = argmin over class
scores (the argmin itself stays in rust, where confidence gating and
safeguards live).
"""

import jax.numpy as jnp


def score_ref(w, x):
    """Per-class cost scores for one example.

    w: [C, F] per-class regressor weights
    x: [F]    feature vector
    returns [C] scores (predicted cost per class)
    """
    return w @ x


def score_batch_ref(w, xs):
    """Batched scores.

    w:  [C, F]
    xs: [B, F]
    returns [B, C]
    """
    return xs @ w.T


def update_ref(w, x, costs, lr):
    """One CSOAA SGD step on squared loss, all classes at once.

    Per class i:  pred_i = w_i . x ;  w_i' = w_i - lr * (pred_i - c_i) * x
    (rank-1 update: W' = W - lr * outer(pred - costs, x))

    w:     [C, F]
    x:     [F]
    costs: [C]  observed cost labels (from the rust cost function)
    lr:    []   scalar learning rate
    returns [C, F] updated weights
    """
    pred = w @ x
    return w - lr * jnp.outer(pred - costs, x)
