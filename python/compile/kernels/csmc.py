"""Layer-1 Pallas kernels for the CSMC (cost-sensitive multi-class) learner.

Shabari's resource allocator trains one CSOAA model per function and per
resource type (vCPU, memory): C per-class linear regressors over an
F-dimensional padded feature vector. The three hot operations are:

  * ``score``        — W[C,F] @ x[F]        -> scores[C]   (predict path)
  * ``score_batch``  — X[B,F] @ W[C,F]^T    -> scores[B,C] (bulk predict)
  * ``update``       — rank-1 CSOAA SGD step on W           (feedback path)

All kernels run with ``interpret=True``: this CPU-PJRT image cannot execute
Mosaic custom-calls, so interpret mode is both the correctness vehicle and
the form that AOT-lowers into plain HLO the rust runtime can run.

TPU mapping (DESIGN.md §Hardware-Adaptation): the weight panel for the
production shape (C=48, F=16, f32) is 3 KiB — it lives comfortably in VMEM,
so the single-example kernels use one grid step with the whole panel
resident (BlockSpec = whole array). The batched kernel tiles over
(block_b x block_c) output tiles with the F dimension kept whole, i.e. an
MXU-friendly ``(block_b, F) @ (F, block_c)`` inner matmul per grid cell.
Block sizes are parameters so the perf pass can sweep them.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# score: W[C,F] @ x[F] -> [C]
# ---------------------------------------------------------------------------

def _score_kernel(w_ref, x_ref, o_ref):
    # Whole-panel matvec: W (block_c, F) against the full feature vector.
    o_ref[...] = w_ref[...] @ x_ref[...]


def score(w, x, *, block_c=None):
    """Per-class cost scores for one example (Pallas).

    w: [C, F] f32, x: [F] f32 -> [C] f32.
    ``block_c`` tiles the class dimension; default = whole panel in one
    grid step (C*F*4B fits VMEM for the production shape).
    """
    c, f = w.shape
    assert x.shape == (f,), (w.shape, x.shape)
    if block_c is None or block_c >= c:
        return pl.pallas_call(
            _score_kernel,
            out_shape=jax.ShapeDtypeStruct((c,), w.dtype),
            interpret=True,
        )(w, x)
    assert c % block_c == 0, f"block_c={block_c} must divide C={c}"
    return pl.pallas_call(
        _score_kernel,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), w.dtype),
        interpret=True,
    )(w, x)


# ---------------------------------------------------------------------------
# score_batch: X[B,F] @ W[C,F]^T -> [B,C]
# ---------------------------------------------------------------------------

def _score_batch_kernel(x_ref, w_ref, o_ref):
    # (block_b, F) @ (F, block_c): contraction kept whole so each grid cell
    # is one MXU-shaped matmul; no cross-step accumulation needed.
    o_ref[...] = x_ref[...] @ w_ref[...].T


def score_batch(w, xs, *, block_b=None, block_c=None):
    """Batched scores (Pallas). w: [C,F], xs: [B,F] -> [B,C]."""
    c, f = w.shape
    b, f2 = xs.shape
    assert f == f2, (w.shape, xs.shape)
    if (block_b is None or block_b >= b) and (block_c is None or block_c >= c):
        return pl.pallas_call(
            _score_batch_kernel,
            out_shape=jax.ShapeDtypeStruct((b, c), w.dtype),
            interpret=True,
        )(xs, w)
    bb = block_b or b
    bc = block_c or c
    assert b % bb == 0 and c % bc == 0, (b, bb, c, bc)
    return pl.pallas_call(
        _score_batch_kernel,
        grid=(b // bb, c // bc),
        in_specs=[
            pl.BlockSpec((bb, f), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), w.dtype),
        interpret=True,
    )(xs, w)


# ---------------------------------------------------------------------------
# update: W' = W - lr * outer(W@x - costs, x)
# ---------------------------------------------------------------------------

def _update_kernel(w_ref, x_ref, c_ref, lr_ref, o_ref):
    x = x_ref[...]
    pred = w_ref[...] @ x           # (block_c,)
    err = pred - c_ref[...]         # (block_c,)
    o_ref[...] = w_ref[...] - lr_ref[0] * err[:, None] * x[None, :]


def update(w, x, costs, lr, *, block_c=None):
    """One CSOAA SGD step (Pallas).

    w: [C,F], x: [F], costs: [C], lr: scalar (passed as a length-1 vector
    internally so the interpret-mode BlockSpec stays rank-1) -> [C,F].
    """
    c, f = w.shape
    assert x.shape == (f,) and costs.shape == (c,)
    lr_vec = jnp.reshape(jnp.asarray(lr, dtype=w.dtype), (1,))
    if block_c is None or block_c >= c:
        return pl.pallas_call(
            _update_kernel,
            out_shape=jax.ShapeDtypeStruct((c, f), w.dtype),
            interpret=True,
        )(w, x, costs, lr_vec)
    assert c % block_c == 0
    return pl.pallas_call(
        _update_kernel,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_c, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, f), w.dtype),
        interpret=True,
    )(w, x, costs, lr_vec)


# ---------------------------------------------------------------------------
# VMEM / MXU estimate used by DESIGN.md §Perf (structure-only: interpret
# mode gives CPU-numpy timings, which are NOT a TPU proxy).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def vmem_bytes(c, f, b=1, dtype_bytes=4, block_b=None, block_c=None):
    """Worst-case VMEM residency of one grid step of score_batch."""
    bb = block_b or b
    bc = block_c or c
    x_tile = bb * f * dtype_bytes
    w_tile = bc * f * dtype_bytes
    o_tile = bb * bc * dtype_bytes
    return x_tile + w_tile + o_tile


def mxu_utilization(c, f, b, block_b=None, block_c=None, mxu=128):
    """Fraction of MXU lanes busy for the inner (bb, F) @ (F, bc) matmul.

    The systolic array processes mxu x mxu tiles; utilization is the product
    of the fill ratios of each dimension (B and C fill the spatial dims, F
    streams through).
    """
    bb = min(block_b or b, mxu)
    bc = min(block_c or c, mxu)
    return (bb / mxu) * (bc / mxu)
