"""AOT compile path: lower the L2 JAX graphs to HLO text artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
resulting ``artifacts/*.hlo.txt`` through the PJRT C API and python never
appears on the request path again.

HLO *text* (not ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(entry: str) -> str:
    fn, args = model.example_args(entry)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Kept for Makefile compatibility: --out <file> writes the predict
    # artifact to that exact path in addition to the standard set.
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "num_classes": model.NUM_CLASSES,
        "feat_dim": model.FEAT_DIM,
        "batch": model.BATCH,
        "entrypoints": list(model.ENTRYPOINTS),
        "jax_version": jax.__version__,
    }
    for entry in model.ENTRYPOINTS:
        text = lower_entry(entry)
        path = os.path.join(args.out_dir, f"{entry}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")

    if args.out:
        # Legacy single-file target (Makefile sentinel).
        text = lower_entry("csmc_predict")
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out} ({len(text)} chars)")


if __name__ == "__main__":
    main()
