//! Per-invocation latency breakdowns over trace spans (DESIGN.md
//! §Observability): the percentile view of *where latency goes* —
//! decision vs queue vs cold start vs execution — that run-level means
//! cannot show (the paper's 6x-variability motivation).

use std::collections::BTreeMap;

use crate::simulator::trace::{verdict_label, InvocationSpans};

use super::histogram::Log2Histogram;

/// Component distributions assembled from a run's invocation spans.
#[derive(Debug, Clone, Default)]
pub struct LatencyBreakdown {
    pub decision: Log2Histogram,
    pub queue: Log2Histogram,
    pub cold_start: Log2Histogram,
    pub exec: Log2Histogram,
    pub e2e: Log2Histogram,
    pub invocations: u64,
    /// Terminal verdicts by label (completed / oom-killed / …), ordered.
    pub verdicts: BTreeMap<String, u64>,
    /// Largest observed `|components_sum - e2e|` — the telescoping
    /// invariant's witness (float residue only; the trace-battery test
    /// bounds it at 1e-9 s).
    pub max_sum_error_s: f64,
}

impl LatencyBreakdown {
    /// `(label, histogram)` rows in report order.
    pub fn components(&self) -> [(&'static str, &Log2Histogram); 5] {
        [
            ("decision", &self.decision),
            ("queue", &self.queue),
            ("cold-start", &self.cold_start),
            ("exec", &self.exec),
            ("e2e", &self.e2e),
        ]
    }
}

/// Fold invocation spans into component histograms.
pub fn breakdown(spans: &[InvocationSpans]) -> LatencyBreakdown {
    let mut b = LatencyBreakdown::default();
    for s in spans {
        b.decision.record(s.decision_s);
        b.queue.record(s.queue_s);
        b.cold_start.record(s.cold_start_s);
        b.exec.record(s.exec_s);
        b.e2e.record(s.e2e_s());
        b.invocations += 1;
        *b.verdicts.entry(verdict_label(s.verdict).to_string()).or_insert(0) += 1;
        let err = (s.components_sum() - s.e2e_s()).abs();
        if err > b.max_sum_error_s {
            b.max_sum_error_s = err;
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::Verdict;

    fn span(decision: f64, queue: f64, cold: f64, exec: f64, verdict: Verdict) -> InvocationSpans {
        InvocationSpans {
            inv: 1,
            func: 0,
            worker: 0,
            arrival: 0.0,
            end: decision + queue + cold + exec,
            verdict,
            decision_s: decision,
            queue_s: queue,
            cold_start_s: cold,
            exec_s: exec,
            episodes: Vec::new(),
        }
    }

    #[test]
    fn breakdown_folds_components_and_verdicts() {
        let spans = vec![
            span(0.01, 0.0, 0.6, 2.0, Verdict::Completed),
            span(0.01, 5.0, 0.0, 1.0, Verdict::Completed),
            span(0.02, 30.0, 0.0, 0.0, Verdict::TimedOut),
        ];
        let b = breakdown(&spans);
        assert_eq!(b.invocations, 3);
        assert_eq!(b.queue.count(), 3);
        assert_eq!(b.queue.max(), 30.0);
        assert_eq!(b.verdicts.get("completed"), Some(&2));
        assert_eq!(b.verdicts.get("timed-out"), Some(&1));
        // spans built to telescope exactly
        assert!(b.max_sum_error_s < 1e-12, "sum error {}", b.max_sum_error_s);
        assert_eq!(b.e2e.count(), 3);
        assert!((b.e2e.mean() - ((2.61 + 6.01 + 30.02) / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn empty_breakdown_is_safe() {
        let b = breakdown(&[]);
        assert_eq!(b.invocations, 0);
        assert_eq!(b.e2e.percentile(99.0), 0.0);
        assert!(b.verdicts.is_empty());
    }
}
