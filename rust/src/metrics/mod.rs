//! Metrics aggregation: the three evaluation lenses of §7.1 (SLO
//! violations, allocated-but-idle resources, per-invocation utilization)
//! plus cold-start and failure accounting, computed from
//! `InvocationRecord`s.

use crate::simulator::engine::{EvictReason, SimResult};
use crate::simulator::{InvocationRecord, Verdict};
use crate::util::stats::{self, Summary};

pub mod histogram;
pub mod spans;

/// Aggregated metrics for one run (one policy at one load).
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub policy: String,
    pub invocations: usize,
    /// % of invocations violating their SLO (failures count as violations).
    pub slo_violation_pct: f64,
    /// Distribution of wasted (allocated-idle) vCPUs per invocation.
    pub wasted_vcpus: Summary,
    /// Distribution of wasted memory (GB) per invocation.
    pub wasted_mem_gb: Summary,
    /// Distribution of per-invocation vCPU utilization (0..1).
    pub vcpu_utilization: Summary,
    /// Distribution of per-invocation memory utilization (0..1).
    pub mem_utilization: Summary,
    /// % of invocations that paid a cold start.
    pub cold_start_pct: f64,
    /// % of SLO-violating invocations whose run had a cold start.
    pub violations_with_cold_start_pct: f64,
    /// % killed by the OOM killer.
    pub oom_pct: f64,
    /// % timed out (no response).
    pub timeout_pct: f64,
    /// Mean end-to-end latency (s).
    pub mean_e2e_s: f64,
    /// Throughput over the simulated window (completed/s).
    pub throughput: f64,
    /// Distribution of admission-queue wait per invocation (s). All-zero
    /// until the cluster saturates; the overload experiment's headline.
    pub queue_wait: Summary,
    /// % of invocations that waited on an admission queue at all.
    pub queued_pct: f64,
    pub containers_created: u64,
    pub background_launches: u64,
    /// Background pre-warms shed because their target worker could not
    /// admit them (see `SimResult::background_shed`).
    pub background_shed: u64,
    /// Highest per-worker vCPU reservation observed anywhere in the run —
    /// the admission invariant's release-build witness
    /// (`peak_alloc_vcpus <= sched_vcpu_limit` must hold; 0 when
    /// aggregated from bare records).
    pub peak_alloc_vcpus: f64,
    /// Highest per-worker memory reservation (MB) observed.
    pub peak_alloc_mem_mb: f64,
    /// Keep-alive TTL-expiry evictions (DESIGN.md §KeepAlive).
    pub evictions: u64,
    /// Demand-driven evictions: idle containers reclaimed to admit
    /// queued work (`--keepalive pressure`).
    pub pressure_evictions: u64,
    /// Warm binds served by a hybrid-histogram pre-warmed container.
    pub prewarm_hits: u64,
    /// Total container-seconds spent idle in the warm pool — the
    /// memory-waste proxy the keepalive experiment minimizes (0 when
    /// aggregated from bare records).
    pub idle_container_s: f64,
    /// % of invocations lost to worker crashes (`Verdict::Failed`).
    pub failed_pct: f64,
    /// Worker crash events that fired (DESIGN.md §Faults; 0 when
    /// aggregated from bare records).
    pub worker_crashes: u64,
    /// Invocations rerouted through another worker's admission path after
    /// a crash.
    pub requeued_on_crash: u64,
    /// Slowest configured worker speed factor (1.0 = no stragglers).
    pub straggler_slowdown: f64,
    /// Extension-worker provisions the cluster scaler started
    /// (DESIGN.md §Scaler; 0 when aggregated from bare records).
    pub scale_up_events: u64,
    /// Idle extension workers the scaler drained back out.
    pub scale_down_events: u64,
    /// Most workers ever serving at once (the configured base count
    /// under `scaler:none`; 0 when aggregated from bare records).
    pub peak_up_workers: usize,
    /// Discrete events the engine processed (0 when aggregated from bare
    /// records). With the harness's wall-clock this yields the
    /// self-throughput numbers (`sim_inv_per_s`, `sim_events_per_s`)
    /// stamped into every experiment artifact.
    pub sim_events: u64,
}

impl RunMetrics {
    /// Field-wise mean across per-seed replicates of the same sweep cell
    /// (`experiments::sweep`). Scalar metrics average directly; the
    /// distribution [`Summary`]s average percentile-wise (see
    /// `stats::average_summaries`); counters round to the nearest integer.
    pub fn mean_of(runs: &[RunMetrics]) -> RunMetrics {
        assert!(!runs.is_empty(), "mean_of needs at least one run");
        let n = runs.len() as f64;
        let avg = |f: fn(&RunMetrics) -> f64| runs.iter().map(f).sum::<f64>() / n;
        let avg_summary = |f: fn(&RunMetrics) -> &Summary| {
            stats::average_summaries(&runs.iter().map(f).collect::<Vec<_>>())
        };
        RunMetrics {
            policy: runs[0].policy.clone(),
            invocations: (runs.iter().map(|r| r.invocations).sum::<usize>() as f64 / n).round()
                as usize,
            slo_violation_pct: avg(|r| r.slo_violation_pct),
            wasted_vcpus: avg_summary(|r| &r.wasted_vcpus),
            wasted_mem_gb: avg_summary(|r| &r.wasted_mem_gb),
            vcpu_utilization: avg_summary(|r| &r.vcpu_utilization),
            mem_utilization: avg_summary(|r| &r.mem_utilization),
            cold_start_pct: avg(|r| r.cold_start_pct),
            violations_with_cold_start_pct: avg(|r| r.violations_with_cold_start_pct),
            oom_pct: avg(|r| r.oom_pct),
            timeout_pct: avg(|r| r.timeout_pct),
            mean_e2e_s: avg(|r| r.mean_e2e_s),
            throughput: avg(|r| r.throughput),
            queue_wait: avg_summary(|r| &r.queue_wait),
            queued_pct: avg(|r| r.queued_pct),
            containers_created: (runs.iter().map(|r| r.containers_created).sum::<u64>() as f64
                / n)
                .round() as u64,
            background_launches: (runs.iter().map(|r| r.background_launches).sum::<u64>() as f64
                / n)
                .round() as u64,
            background_shed: (runs.iter().map(|r| r.background_shed).sum::<u64>() as f64 / n)
                .round() as u64,
            // Peaks take the max, not the mean: they witness that *no*
            // replicate ever exceeded the admission limits.
            // lint:reducer(D007, peak_alloc_vcpus, peak_alloc_mem_mb): max-reduced — an averaged peak would no longer witness the admission invariant
            peak_alloc_vcpus: runs.iter().map(|r| r.peak_alloc_vcpus).fold(0.0, f64::max),
            peak_alloc_mem_mb: runs.iter().map(|r| r.peak_alloc_mem_mb).fold(0.0, f64::max),
            evictions: (runs.iter().map(|r| r.evictions).sum::<u64>() as f64 / n).round()
                as u64,
            pressure_evictions: (runs.iter().map(|r| r.pressure_evictions).sum::<u64>() as f64
                / n)
                .round() as u64,
            prewarm_hits: (runs.iter().map(|r| r.prewarm_hits).sum::<u64>() as f64 / n).round()
                as u64,
            idle_container_s: avg(|r| r.idle_container_s),
            failed_pct: avg(|r| r.failed_pct),
            worker_crashes: (runs.iter().map(|r| r.worker_crashes).sum::<u64>() as f64 / n)
                .round() as u64,
            requeued_on_crash: (runs.iter().map(|r| r.requeued_on_crash).sum::<u64>() as f64
                / n)
                .round() as u64,
            // The slowdown is a configuration echo, identical across
            // replicates of a cell; the min keeps it honest if not.
            // lint:reducer(D007, straggler_slowdown): min-reduced — reports the worst configured straggler factor, never an average
            straggler_slowdown: runs
                .iter()
                .map(|r| r.straggler_slowdown)
                .fold(1.0, f64::min),
            scale_up_events: (runs.iter().map(|r| r.scale_up_events).sum::<u64>() as f64 / n)
                .round() as u64,
            scale_down_events: (runs.iter().map(|r| r.scale_down_events).sum::<u64>() as f64
                / n)
                .round() as u64,
            // The peak takes the max: it witnesses the largest cluster any
            // replicate ever needed, which an average would understate.
            // lint:reducer(D007, peak_up_workers): max-reduced — reports the largest serving pool any replicate reached
            peak_up_workers: runs.iter().map(|r| r.peak_up_workers).max().unwrap_or(0),
            sim_events: (runs.iter().map(|r| r.sim_events).sum::<u64>() as f64 / n).round()
                as u64,
        }
    }
}

/// Compute metrics from raw records.
pub fn aggregate(policy: &str, records: &[InvocationRecord]) -> RunMetrics {
    let n = records.len().max(1);
    let violations: Vec<&InvocationRecord> =
        records.iter().filter(|r| r.slo_violated()).collect();
    // Throughput spans the *observed* window, `max(end) - min(arrival)`:
    // measuring from t=0 deflated throughput for traces whose first
    // arrival is late (`trace-file` replays, `flash-crowd` onsets). The
    // 1e-9 floor guards the empty/degenerate cases.
    let last_end = records.iter().map(|r| r.end).fold(0.0f64, f64::max);
    let first_arrival = records.iter().map(|r| r.arrival).fold(f64::INFINITY, f64::min);
    let span = (last_end - first_arrival).max(1e-9);
    RunMetrics {
        policy: policy.to_string(),
        invocations: records.len(),
        slo_violation_pct: 100.0 * violations.len() as f64 / n as f64,
        wasted_vcpus: stats::summarize(
            &records.iter().map(|r| r.wasted_vcpus()).collect::<Vec<_>>(),
        ),
        wasted_mem_gb: stats::summarize(
            &records.iter().map(|r| r.wasted_mem_gb()).collect::<Vec<_>>(),
        ),
        vcpu_utilization: stats::summarize(
            &records.iter().map(|r| r.vcpu_utilization()).collect::<Vec<_>>(),
        ),
        mem_utilization: stats::summarize(
            &records.iter().map(|r| r.mem_utilization()).collect::<Vec<_>>(),
        ),
        cold_start_pct: stats::percent_where(records, |r| r.had_cold_start),
        violations_with_cold_start_pct: if violations.is_empty() {
            0.0
        } else {
            100.0 * violations.iter().filter(|r| r.had_cold_start).count() as f64
                / violations.len() as f64
        },
        oom_pct: stats::percent_where(records, |r| r.verdict == Verdict::OomKilled),
        timeout_pct: stats::percent_where(records, |r| r.verdict == Verdict::TimedOut),
        mean_e2e_s: stats::mean(&records.iter().map(|r| r.e2e_s).collect::<Vec<_>>()),
        throughput: records
            .iter()
            .filter(|r| r.verdict == Verdict::Completed)
            .count() as f64
            / span,
        queue_wait: stats::summarize(&records.iter().map(|r| r.queue_s).collect::<Vec<_>>()),
        queued_pct: stats::percent_where(records, |r| r.queue_s > 0.0),
        containers_created: 0,
        background_launches: 0,
        background_shed: 0,
        peak_alloc_vcpus: 0.0,
        peak_alloc_mem_mb: 0.0,
        evictions: 0,
        pressure_evictions: 0,
        prewarm_hits: 0,
        idle_container_s: 0.0,
        failed_pct: stats::percent_where(records, |r| r.verdict == Verdict::Failed),
        worker_crashes: 0,
        requeued_on_crash: 0,
        straggler_slowdown: 1.0,
        scale_up_events: 0,
        scale_down_events: 0,
        peak_up_workers: 0,
        sim_events: 0,
    }
}

/// Aggregate straight from a `SimResult` (fills container counters and
/// the admission-invariant peaks too).
pub fn from_result(policy: &str, res: &SimResult) -> RunMetrics {
    let mut m = aggregate(policy, &res.records);
    m.containers_created = res.containers_created;
    m.background_launches = res.background_launches;
    m.background_shed = res.background_shed;
    m.peak_alloc_vcpus = res.cluster.peak_allocated_vcpus();
    m.peak_alloc_mem_mb = res.cluster.peak_allocated_mem_mb();
    m.evictions =
        res.evictions.iter().filter(|e| e.reason == EvictReason::Expired).count() as u64;
    m.pressure_evictions = res.pressure_evictions;
    m.prewarm_hits = res.prewarm_hits;
    m.idle_container_s = res.idle_container_s;
    m.worker_crashes = res.worker_crashes;
    m.requeued_on_crash = res.requeued_on_crash;
    m.straggler_slowdown = res.straggler_slowdown;
    m.scale_up_events = res.scale_ups;
    m.scale_down_events = res.scale_downs;
    m.peak_up_workers = res.peak_up_workers;
    m.sim_events = res.events_processed;
    m
}

/// Records after a warm-up cutoff (learning-phase exclusion used by some
/// sensitivity analyses; the headline E2E numbers include everything,
/// like the paper's). Borrows instead of cloning: `InvocationRecord`
/// carries an owned `InputSpec`, so cloning every record to drop a prefix
/// was pure allocation overhead — filter lazily and collect references
/// only where the caller actually needs a slice.
pub fn after_warmup(
    records: &[InvocationRecord],
    cutoff_s: f64,
) -> impl Iterator<Item = &InvocationRecord> {
    records.iter().filter(move |r| r.arrival >= cutoff_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};

    fn rec(exec: f64, slo: f64, cold: bool, verdict: Verdict) -> InvocationRecord {
        InvocationRecord {
            id: 1,
            func: 0,
            input: InputSpec::new(InputKind::Payload),
            worker: 0,
            vcpus: 8,
            mem_mb: 2048,
            requested_vcpus: 8,
            requested_mem_mb: 2048,
            arrival: 0.0,
            cold_start_s: if cold { 0.5 } else { 0.0 },
            had_cold_start: cold,
            overhead_s: 0.0,
            queue_s: 0.0,
            exec_s: exec,
            e2e_s: exec,
            end: exec,
            slo_s: slo,
            verdict,
            avg_vcpus_used: 4.0,
            peak_vcpus_used: 8.0,
            mem_used_gb: 1.0,
        }
    }

    #[test]
    fn violation_percentage() {
        let recs = vec![
            rec(1.0, 2.0, false, Verdict::Completed),
            rec(3.0, 2.0, true, Verdict::Completed),
            rec(1.0, 2.0, false, Verdict::OomKilled),
            rec(1.0, 2.0, false, Verdict::Completed),
        ];
        let m = aggregate("x", &recs);
        assert!((m.slo_violation_pct - 50.0).abs() < 1e-9);
        assert!((m.oom_pct - 25.0).abs() < 1e-9);
        assert!((m.cold_start_pct - 25.0).abs() < 1e-9);
        // 1 of the 2 violations had a cold start
        assert!((m.violations_with_cold_start_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn waste_distributions() {
        let recs = vec![rec(1.0, 2.0, false, Verdict::Completed)];
        let m = aggregate("x", &recs);
        // peak-based: 8 allocated, peak 8 used -> 0 wasted
        assert!((m.wasted_vcpus.p50 - 0.0).abs() < 1e-9);
        assert!((m.wasted_mem_gb.p50 - 1.0).abs() < 1e-9);
        assert!((m.vcpu_utilization.p50 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn empty_records_safe() {
        let m = aggregate("x", &[]);
        assert_eq!(m.invocations, 0);
        assert_eq!(m.slo_violation_pct, 0.0);
        assert_eq!(m.throughput, 0.0);
        assert_eq!(m.queued_pct, 0.0);
    }

    #[test]
    fn throughput_spans_observed_window_not_t0() {
        // Two completions one second apart. Unshifted: span 2 s from the
        // first arrival. Shifted 1000 s later (a trace-file replay whose
        // first arrival is late): the rate must be identical — the old
        // `max(end)`-from-t=0 span deflated it ~500x.
        let make = |offset: f64| {
            let mut a = rec(1.0, 2.0, false, Verdict::Completed);
            a.arrival = offset;
            a.end = offset + 1.0;
            let mut b = rec(1.0, 2.0, false, Verdict::Completed);
            b.arrival = offset + 1.0;
            b.end = offset + 2.0;
            vec![a, b]
        };
        let base = aggregate("x", &make(0.0));
        let shifted = aggregate("x", &make(1000.0));
        assert!((base.throughput - 1.0).abs() < 1e-9, "2 completions / 2 s");
        assert_eq!(
            shifted.throughput.to_bits(),
            base.throughput.to_bits(),
            "late-starting traces must not deflate throughput: {} vs {}",
            shifted.throughput,
            base.throughput
        );
    }

    #[test]
    fn queue_metrics_aggregate() {
        let mut a = rec(1.0, 2.0, false, Verdict::Completed);
        a.queue_s = 3.0;
        let b = rec(1.0, 2.0, false, Verdict::Completed);
        let m = aggregate("x", &[a, b]);
        assert!((m.queued_pct - 50.0).abs() < 1e-9);
        assert!((m.queue_wait.max - 3.0).abs() < 1e-9);
        // bare-record aggregation carries no cluster peaks
        assert_eq!(m.peak_alloc_vcpus, 0.0);
    }

    #[test]
    fn mean_of_averages_fields() {
        let a = aggregate("x", &[rec(1.0, 2.0, true, Verdict::Completed)]);
        let b = aggregate("x", &[rec(3.0, 2.0, false, Verdict::Completed)]);
        let m = RunMetrics::mean_of(&[a.clone(), b.clone()]);
        assert_eq!(m.policy, "x");
        assert!((m.slo_violation_pct - 50.0).abs() < 1e-9, "100% and 0% average to 50%");
        assert!((m.cold_start_pct - 50.0).abs() < 1e-9);
        assert!(
            (m.wasted_mem_gb.p50 - (a.wasted_mem_gb.p50 + b.wasted_mem_gb.p50) / 2.0).abs()
                < 1e-12
        );
        // single-run mean is the identity on scalar fields
        let one = RunMetrics::mean_of(&[a.clone()]);
        assert_eq!(one.slo_violation_pct.to_bits(), a.slo_violation_pct.to_bits());
    }

    #[test]
    fn keepalive_metrics_average_across_replicates() {
        let mut a = aggregate("x", &[rec(1.0, 2.0, false, Verdict::Completed)]);
        a.evictions = 10;
        a.pressure_evictions = 4;
        a.prewarm_hits = 2;
        a.idle_container_s = 100.0;
        let mut b = a.clone();
        b.evictions = 20;
        b.pressure_evictions = 0;
        b.prewarm_hits = 0;
        b.idle_container_s = 50.0;
        let m = RunMetrics::mean_of(&[a, b]);
        assert_eq!(m.evictions, 15);
        assert_eq!(m.pressure_evictions, 2);
        assert_eq!(m.prewarm_hits, 1);
        assert!((m.idle_container_s - 75.0).abs() < 1e-12);
        // bare-record aggregation starts the counters at zero
        let fresh = aggregate("x", &[rec(1.0, 2.0, false, Verdict::Completed)]);
        assert_eq!(fresh.evictions + fresh.pressure_evictions + fresh.prewarm_hits, 0);
        assert_eq!(fresh.idle_container_s, 0.0);
    }

    #[test]
    fn fault_metrics_aggregate_and_average() {
        let mut a = aggregate(
            "x",
            &[rec(1.0, 2.0, false, Verdict::Completed), rec(0.0, 2.0, false, Verdict::Failed)],
        );
        assert!((a.failed_pct - 50.0).abs() < 1e-9);
        assert!((a.slo_violation_pct - 50.0).abs() < 1e-9, "Failed counts as a violation");
        // bare-record aggregation carries no engine counters
        assert_eq!(a.worker_crashes, 0);
        assert_eq!(a.straggler_slowdown, 1.0);
        a.worker_crashes = 4;
        a.requeued_on_crash = 2;
        a.straggler_slowdown = 0.5;
        let mut b = a.clone();
        b.worker_crashes = 2;
        b.requeued_on_crash = 0;
        b.straggler_slowdown = 1.0;
        let m = RunMetrics::mean_of(&[a, b]);
        assert_eq!(m.worker_crashes, 3);
        assert_eq!(m.requeued_on_crash, 1);
        assert!((m.straggler_slowdown - 0.5).abs() < 1e-12, "slowdown reports the min");
        assert!((m.failed_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn scaling_metrics_average_and_peak_max() {
        let mut a = aggregate("x", &[rec(1.0, 2.0, false, Verdict::Completed)]);
        // bare-record aggregation carries no scaler counters
        assert_eq!(a.scale_up_events + a.scale_down_events, 0);
        assert_eq!(a.peak_up_workers, 0);
        a.scale_up_events = 4;
        a.scale_down_events = 2;
        a.peak_up_workers = 20;
        let mut b = a.clone();
        b.scale_up_events = 2;
        b.scale_down_events = 0;
        b.peak_up_workers = 18;
        let m = RunMetrics::mean_of(&[a, b]);
        assert_eq!(m.scale_up_events, 3);
        assert_eq!(m.scale_down_events, 1);
        assert_eq!(m.peak_up_workers, 20, "peak pool size reports the max");
    }

    #[test]
    fn warmup_filter() {
        let mut a = rec(1.0, 2.0, false, Verdict::Completed);
        a.arrival = 10.0;
        let mut b = rec(1.0, 2.0, false, Verdict::Completed);
        b.arrival = 200.0;
        let records = [a, b];
        // borrowing iterator: no record is cloned to apply the cutoff
        let filtered: Vec<&InvocationRecord> = after_warmup(&records, 100.0).collect();
        assert_eq!(filtered.len(), 1);
        assert!(std::ptr::eq(filtered[0], &records[1]), "borrows, not clones");
        assert_eq!(after_warmup(&records, 0.0).count(), 2);
        assert_eq!(after_warmup(&records, 500.0).count(), 0);
    }
}
