//! Fixed-bucket log2 latency histograms (DESIGN.md §Observability).
//!
//! The span-breakdown report needs percentile *distributions* of latency
//! components, not just means, and it needs them mergeable across seeds
//! and byte-deterministic across platforms. A [`Log2Histogram`] has 44
//! fixed power-of-two buckets from 1 µs up (~17.6 ks at the top — well
//! past any walltime limit), so merging is counter addition and bucket
//! placement never calls a libm function: edges are found by exact f64
//! doubling (an exponent increment), not `log2()`, whose last-bit
//! behavior is platform-dependent.

/// Lower edge of bucket 1: values below this (including 0 and negative
/// float residue) land in bucket 0.
pub const MIN_S: f64 = 1e-6;

/// Bucket count. Bucket 0 is `(-inf, MIN_S)`, bucket `i` (1..BUCKETS-1)
/// is `[MIN_S * 2^(i-1), MIN_S * 2^i)`, and the last bucket is the
/// catch-all up to infinity.
pub const BUCKETS: usize = 44;

/// A mergeable fixed-bucket histogram of nonnegative seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct Log2Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Log2Histogram { counts: [0; BUCKETS], count: 0, sum: 0.0, max: 0.0 }
    }
}

/// Bucket index for a value, by exact repeated doubling of the edge —
/// each step is an f64 exponent increment, so the edges are identical
/// bit-for-bit on every platform.
pub fn bucket_index(v: f64) -> usize {
    if !(v >= MIN_S) {
        // Sub-microsecond, zero, negative residue, NaN: bucket 0.
        return 0;
    }
    let mut edge = MIN_S;
    for i in 1..BUCKETS {
        edge *= 2.0;
        if v < edge {
            return i;
        }
    }
    BUCKETS - 1
}

/// Upper edge of a bucket (`MIN_S * 2^i`); callers display ranges with
/// `upper_edge(i-1)..upper_edge(i)`.
pub fn upper_edge(i: usize) -> f64 {
    let mut edge = MIN_S;
    for _ in 0..i.min(BUCKETS - 1) {
        edge *= 2.0;
    }
    edge
}

impl Log2Histogram {
    pub fn record(&mut self, v: f64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v.max(0.0);
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile estimate: the upper edge of the first bucket where the
    /// cumulative count reaches `ceil(p/100 * count)` — an upper bound
    /// within one bucket width (≤ 2x). The catch-all top bucket reports
    /// the recorded max instead of its (unbounded) edge, as does any
    /// bucket the max itself falls in.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().clamp(1.0, self.count as f64) as u64;
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            cum += self.counts[i];
            if cum >= rank {
                return if i == BUCKETS - 1 { self.max } else { upper_edge(i).min(self.max) };
            }
        }
        self.max
    }

    /// Merge another histogram in (cross-seed aggregation): counts add,
    /// max takes the max.
    pub fn merge(&mut self, other: &Log2Histogram) {
        for i in 0..BUCKETS {
            self.counts[i] += other.counts[i];
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Raw bucket counts (exporters / tests).
    pub fn buckets(&self) -> &[u64; BUCKETS] {
        &self.counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_exact_powers_of_two() {
        // below the first edge -> bucket 0
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(9.9e-7), 0);
        // the edge itself opens the next bucket (half-open intervals)
        assert_eq!(bucket_index(MIN_S), 1);
        assert_eq!(bucket_index(2e-6), 2);
        assert_eq!(bucket_index(4e-6), 3);
        // one ulp under an edge stays in the lower bucket
        assert_eq!(bucket_index(f64::from_bits((2e-6f64).to_bits() - 1)), 1);
        // ~1 s lives where upper_edge brackets it
        let i = bucket_index(1.0);
        assert!(upper_edge(i - 1) <= 1.0 && 1.0 < upper_edge(i));
        // far beyond the range -> catch-all
        assert_eq!(bucket_index(1e12), BUCKETS - 1);
        // upper_edge doubles exactly
        for i in 1..BUCKETS {
            assert_eq!(upper_edge(i), 2.0 * upper_edge(i - 1));
        }
    }

    #[test]
    fn percentile_is_bucket_upper_bound() {
        let mut h = Log2Histogram::default();
        for _ in 0..99 {
            h.record(0.001);
        }
        h.record(10.0);
        assert_eq!(h.count(), 100);
        // p50 falls in 0.001's bucket: its upper edge is within 2x above
        let p50 = h.percentile(50.0);
        assert!((0.001..=0.002048).contains(&p50), "p50 {p50}");
        // p100 reports the exact max
        assert_eq!(h.percentile(100.0), 10.0);
        assert!(h.percentile(99.0) <= 10.0);
        assert!((h.mean() - (99.0 * 0.001 + 10.0) / 100.0).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Log2Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }

    #[test]
    fn merge_adds_counts_and_keeps_max() {
        let mut a = Log2Histogram::default();
        a.record(0.5);
        let mut b = Log2Histogram::default();
        b.record(2.0);
        b.record(8.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 8.0);
        let sum: u64 = a.buckets().iter().sum();
        assert_eq!(sum, 3);
    }
}
