//! Schedulers: Shabari's cold-start-aware, dual-resource scheduler (§5)
//! plus the OpenWhisk default (memory-centric) and Hermod-style packing
//! comparison policies (Fig 7b, Fig 10).

pub mod hermod;
pub mod openwhisk;
pub mod shabari;

use crate::simulator::worker::Cluster;
use crate::simulator::{BackgroundLaunch, ContainerChoice, Request};

/// Scheduler output: where to run and in what container.
#[derive(Debug, Clone)]
pub struct SchedDecision {
    pub worker: usize,
    pub container: ContainerChoice,
    pub background: Option<BackgroundLaunch>,
    /// Scheduling latency on the critical path (Fig 14: 0.5–1.5 ms).
    pub latency_s: f64,
}

/// A container-placement policy. The allocator decides *how much*; the
/// scheduler decides *where* and *in which container*.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    fn schedule(
        &mut self,
        req: &Request,
        vcpus: u32,
        mem_mb: u32,
        cluster: &Cluster,
    ) -> SchedDecision;
}

/// Deterministic "home server" for a function (OpenWhisk-style hashing;
/// reduces cache contention / improves locality, §5).
pub fn home_server(func_name: &str, n_workers: usize) -> usize {
    (crate::util::rng::fnv1a(func_name.as_bytes()) % n_workers as u64) as usize
}

/// First worker at-or-after `start` (wrapping) that can admit the size;
/// falls back to `fallback` when none has capacity.
pub fn probe_from(
    cluster: &Cluster,
    start: usize,
    vcpus: u32,
    mem_mb: u32,
    fallback: usize,
) -> usize {
    let n = cluster.len();
    for off in 0..n {
        let w = (start + off) % n;
        if cluster.worker(w).has_capacity(vcpus, mem_mb) {
            return w;
        }
    }
    fallback
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimConfig;

    #[test]
    fn home_server_stable_and_spread() {
        let a = home_server("matmult", 16);
        assert_eq!(a, home_server("matmult", 16));
        // the 12 catalog functions should not all collide
        let homes: std::collections::BTreeSet<usize> = crate::functions::catalog::CATALOG
            .iter()
            .map(|f| home_server(f.name, 16))
            .collect();
        assert!(homes.len() >= 6, "expected spread, got {homes:?}");
    }

    #[test]
    fn probe_skips_full_workers() {
        let cfg = SimConfig::small();
        let mut cl = Cluster::new(&cfg);
        cl.workers[1].allocated_vcpus = 89.0; // nearly full
        cl.workers[2].allocated_vcpus = 0.0;
        let w = probe_from(&cl, 1, 8, 1024, 0);
        assert_eq!(w, 2, "worker 1 cannot admit 8 vCPUs");
    }

    #[test]
    fn probe_falls_back_when_all_full() {
        let cfg = SimConfig::small();
        let mut cl = Cluster::new(&cfg);
        for w in &mut cl.workers {
            w.allocated_vcpus = 90.0;
        }
        assert_eq!(probe_from(&cl, 0, 8, 1024, 3), 3);
    }
}
