//! Hermod-style packing scheduler (Fig 7b comparison): pack invocations
//! onto the lowest-numbered worker until its capacity is reached before
//! spilling to the next.
//!
//! The paper shows this backfires for Shabari's workload: functions that
//! fetch inputs from an external database (matmult, lrtrain, image
//! functions) saturate the packed worker's NIC, degrading everyone on it
//! (§5). The simulator reproduces that through the NIC fair-sharing
//! model.

use crate::simulator::worker::Cluster;
use crate::simulator::{ContainerChoice, Request};
use crate::util::rng::Rng;

use super::{SchedDecision, Scheduler};

#[derive(Debug)]
pub struct HermodScheduler {
    rng: Rng,
    pub latency_s: f64,
}

/// Salt decorrelating this scheduler's tie-break stream from the other
/// consumers of the run seed.
const SALT_HERMOD_SCHED: u64 = 0x4E58_410D;

impl HermodScheduler {
    pub fn new(seed: u64) -> Self {
        HermodScheduler { rng: Rng::new(seed ^ SALT_HERMOD_SCHED), latency_s: 0.001 }
    }
}

impl Scheduler for HermodScheduler {
    fn name(&self) -> &'static str {
        "hermod-packing"
    }

    fn schedule(
        &mut self,
        req: &Request,
        vcpus: u32,
        mem_mb: u32,
        cluster: &Cluster,
    ) -> SchedDecision {
        // Prefer a warm container on the most-packed admissible worker;
        // otherwise pack: first worker (ascending id) with capacity. A
        // worker with a fitting warm container is probed with the
        // warm-bind-aware check (DESIGN.md §KeepAlive): under
        // reservation-holding keep-alive the candidate's own reservation
        // must not spill packing off the warmth it could reuse
        // capacity-neutrally. With free idle containers the two checks
        // coincide, so fixed-mode behavior is unchanged.
        let mut chosen = None;
        for w in &cluster.workers {
            let warm_fits = w.has_capacity_for_warm(vcpus, mem_mb)
                && w.find_warm_larger(req.func, vcpus, mem_mb).is_some();
            if warm_fits || w.has_capacity(vcpus, mem_mb) {
                chosen = Some(w.id);
                break;
            }
        }
        let worker = chosen.unwrap_or_else(|| self.rng.below(cluster.len()));
        // Index-backed lookup: smallest fitting size, lowest id on ties.
        let container = match cluster.worker(worker).find_warm_larger(req.func, vcpus, mem_mb) {
            Some(c) => ContainerChoice::Warm(c.id),
            None => ContainerChoice::Cold,
        };
        SchedDecision { worker, container, background: None, latency_s: self.latency_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};
    use crate::functions::catalog::index_of;
    use crate::simulator::SimConfig;

    fn req() -> Request {
        Request {
            id: 1,
            func: index_of("qr").unwrap(),
            input: InputSpec::new(InputKind::Payload),
            arrival: 0.0,
            slo_s: 1.0,
        }
    }

    #[test]
    fn packs_first_worker_until_full() {
        let mut cl = Cluster::new(&SimConfig::small());
        let mut s = HermodScheduler::new(1);
        let d = s.schedule(&req(), 8, 1024, &cl);
        assert_eq!(d.worker, 0);
        // fill worker 0
        cl.workers[0].allocated_vcpus = 85.0;
        let d = s.schedule(&req(), 8, 1024, &cl);
        assert_eq!(d.worker, 1, "spill to next worker when full");
    }

    #[test]
    fn warm_ties_resolve_to_lowest_container_id() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req();
        for id in [9u64, 4, 7] {
            let mut c = crate::simulator::container::Container::new(id, r.func, 4, 512, 0.0);
            c.mark_ready(0.0);
            cl.insert_container(0, c);
        }
        let mut s = HermodScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.worker, 0);
        assert_eq!(d.container, ContainerChoice::Warm(4));
    }

    #[test]
    fn queued_demand_spills_packing_to_next_worker() {
        use crate::simulator::worker::QueuedAdmission;
        let mut cl = Cluster::new(&SimConfig::small());
        // worker 0 is nominally empty but has a backlog covering its
        // whole limit: packing must spill to worker 1
        cl.workers[0].push_admission(QueuedAdmission { inv_id: 1, vcpus: 90, mem_mb: 512 });
        let mut s = HermodScheduler::new(1);
        let d = s.schedule(&req(), 8, 1024, &cl);
        assert_eq!(d.worker, 1, "queued demand counts against packing capacity");
    }

    #[test]
    fn pressure_mode_packs_onto_its_own_warmth() {
        use crate::simulator::keepalive::KeepAliveMode;
        // under reservation-holding keep-alive, worker 0's idle warm
        // container fills its whole limit; packing must still choose it
        // (the warm bind is capacity-neutral) instead of spilling
        let cfg = SimConfig {
            workers: 4,
            sched_vcpu_limit: 4.0,
            keepalive: KeepAliveMode::Pressure,
            ..SimConfig::default()
        };
        let mut cl = Cluster::new(&cfg);
        let r = req();
        let mut c = crate::simulator::container::Container::new(5, r.func, 4, 512, 0.0);
        c.mark_ready(0.0);
        cl.insert_container(0, c);
        assert_eq!(cl.workers[0].allocated_vcpus, 4.0, "idle reserves under pressure");
        let mut s = HermodScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.worker, 0, "warmth beats spilling");
        assert_eq!(d.container, ContainerChoice::Warm(5));
    }

    #[test]
    fn random_when_everything_full() {
        let mut cl = Cluster::new(&SimConfig::small());
        for w in &mut cl.workers {
            w.allocated_vcpus = 90.0;
        }
        let mut s = HermodScheduler::new(1);
        let d = s.schedule(&req(), 8, 1024, &cl);
        assert!(d.worker < cl.len());
    }
}
