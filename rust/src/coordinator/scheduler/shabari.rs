//! Shabari's Scheduler (§5): mitigate the cold starts that delayed,
//! per-invocation sizing introduces.
//!
//! Routing order:
//! 1. warm container of the **exact** predicted size (any worker with
//!    admission capacity);
//! 2. warm container **larger but closest** to the prediction — and
//!    proactively launch a perfectly-sized container in the background
//!    for future invocations;
//! 3. **cold** container of the exact size on the function's home server
//!    (hash-based), probing forward when the home server is full, random
//!    when every server is full.
//!
//! Load tracking is dual-resource: a worker admits an invocation only if
//! both its vCPU (`userCpu` limit) and memory loads fit (§6) — and
//! queue-aware: `Worker::has_capacity` subtracts demand already parked
//! on the worker's FIFO admission queue, so probing never piles onto a
//! backlogged worker (the engine enforces the hard limit either way;
//! DESIGN.md §Admission).

use crate::simulator::worker::{Cluster, Worker};
use crate::simulator::{BackgroundLaunch, ContainerChoice, Request};
use crate::util::rng::Rng;

use super::{home_server, probe_from, SchedDecision, Scheduler};

#[derive(Debug)]
pub struct ShabariScheduler {
    rng: Rng,
    /// Modeled critical-path latency (Fig 14: 0.5–1.5 ms).
    pub latency_s: f64,
    /// Counters for the cold-start analysis (Fig 10).
    pub warm_exact_hits: u64,
    pub warm_larger_hits: u64,
    pub cold_routes: u64,
}

/// Salt decorrelating the scheduler's tie-break stream from the other
/// consumers of the run seed (engine, workload, learner).
const SALT_SHABARI_SCHED: u64 = 0x5C4E_D011;

impl ShabariScheduler {
    pub fn new(seed: u64) -> Self {
        ShabariScheduler {
            rng: Rng::new(seed ^ SALT_SHABARI_SCHED),
            latency_s: 0.001,
            warm_exact_hits: 0,
            warm_larger_hits: 0,
            cold_routes: 0,
        }
    }

    fn decide(
        &mut self,
        req: &Request,
        vcpus: u32,
        mem_mb: u32,
        cluster: &Cluster,
    ) -> (usize, ContainerChoice, Option<BackgroundLaunch>) {
        let func_name = crate::functions::catalog::CATALOG[req.func].name;
        let home = home_server(func_name, cluster.len());

        // (1) exact-size warm container, admissible worker.
        if let Some((w, cid)) = self.find_warm(cluster, req.func, vcpus, mem_mb, true) {
            self.warm_exact_hits += 1;
            return (w, ContainerChoice::Warm(cid), None);
        }

        // (2) larger-but-closest warm container; background-launch the
        // perfect size for future invocations.
        if let Some((w, cid)) = self.find_warm(cluster, req.func, vcpus, mem_mb, false) {
            self.warm_larger_hits += 1;
            let bg_worker = if cluster.worker(home).has_capacity(vcpus, mem_mb) {
                home
            } else {
                probe_from(cluster, home, vcpus, mem_mb, w)
            };
            let background = Some(BackgroundLaunch { worker: bg_worker, vcpus, mem_mb });
            return (w, ContainerChoice::Warm(cid), background);
        }

        // (3) cold on the home server, probing forward; random if full.
        self.cold_routes += 1;
        let worker = if cluster.worker(home).has_capacity(vcpus, mem_mb) {
            home
        } else {
            let probed = probe_from(cluster, home, vcpus, mem_mb, usize::MAX);
            if probed == usize::MAX {
                self.rng.below(cluster.len())
            } else {
                probed
            }
        };
        (worker, ContainerChoice::Cold, None)
    }

    /// Cluster-wide warm lookup via the sorted warm index; `exact`
    /// selects mode. Only admissible placements count (the worker must
    /// fit the *container's* size, since that is what gets allocated) —
    /// probed with the warm-bind-aware check: under reservation-holding
    /// keep-alive the candidate's own reservation must not veto its own
    /// reuse (`Worker::has_capacity_for_warm`, DESIGN.md §KeepAlive).
    /// Equal-size candidates resolve to the lowest (worker, container)
    /// id — deterministic, unlike the old per-worker hash-order scan.
    fn find_warm(
        &self,
        cluster: &Cluster,
        func: usize,
        vcpus: u32,
        mem_mb: u32,
        exact: bool,
    ) -> Option<(usize, u64)> {
        let admit = |w: &Worker, cv: u32, cm: u32| w.has_capacity_for_warm(cv, cm);
        if exact {
            cluster.find_warm_exact_where(func, vcpus, mem_mb, admit)
        } else {
            cluster.find_warm_larger_where(func, vcpus, mem_mb, admit)
        }
    }
}

impl Scheduler for ShabariScheduler {
    fn name(&self) -> &'static str {
        "shabari"
    }

    fn schedule(
        &mut self,
        req: &Request,
        vcpus: u32,
        mem_mb: u32,
        cluster: &Cluster,
    ) -> SchedDecision {
        let (worker, container, background) = self.decide(req, vcpus, mem_mb, cluster);
        SchedDecision { worker, container, background, latency_s: self.latency_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::catalog::index_of;
    use crate::featurizer::{InputKind, InputSpec};
    use crate::simulator::container::Container;
    use crate::simulator::SimConfig;

    fn req(func: &str) -> Request {
        Request {
            id: 1,
            func: index_of(func).unwrap(),
            input: InputSpec::new(InputKind::Payload),
            arrival: 0.0,
            slo_s: 1.0,
        }
    }

    fn warm(cl: &mut Cluster, worker: usize, id: u64, func: usize, vcpus: u32, mem: u32) {
        let mut c = Container::new(id, func, vcpus, mem, 0.0);
        c.mark_ready(0.0);
        cl.insert_container(worker, c);
    }

    #[test]
    fn prefers_exact_warm() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("qr");
        warm(&mut cl, 2, 10, r.func, 8, 1024); // larger
        warm(&mut cl, 3, 11, r.func, 4, 512); // exact
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.worker, 3);
        assert_eq!(d.container, ContainerChoice::Warm(11));
        assert!(d.background.is_none(), "exact hits need no background launch");
        assert_eq!(s.warm_exact_hits, 1);
    }

    #[test]
    fn larger_warm_triggers_background_launch() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("qr");
        warm(&mut cl, 1, 10, r.func, 16, 4096);
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.container, ContainerChoice::Warm(10));
        let bg = d.background.expect("must pre-warm the right size");
        assert_eq!(bg.vcpus, 4);
        assert_eq!(bg.mem_mb, 512);
        assert_eq!(s.warm_larger_hits, 1);
    }

    #[test]
    fn equal_size_larger_candidates_have_a_stable_winner() {
        // several identically-sized larger-than-requested warm containers:
        // the winner must be the lowest (worker, container) id, run after
        // run, instead of whatever hash iteration yields first.
        let build = || {
            let mut cl = Cluster::new(&SimConfig::small());
            let r = req("qr");
            for (worker, id) in [(2usize, 71u64), (1, 58), (3, 12), (1, 33)] {
                warm(&mut cl, worker, id, r.func, 8, 1024);
            }
            (cl, r)
        };
        for _ in 0..3 {
            let (cl, r) = build();
            let mut s = ShabariScheduler::new(1);
            let d = s.schedule(&r, 4, 512, &cl);
            assert_eq!(d.worker, 1);
            assert_eq!(d.container, ContainerChoice::Warm(33), "lowest (worker, id) wins");
        }
    }

    #[test]
    fn closest_larger_wins() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("qr");
        warm(&mut cl, 0, 10, r.func, 32, 4096);
        warm(&mut cl, 1, 11, r.func, 6, 1024);
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.container, ContainerChoice::Warm(11), "6 vCPUs closer than 32");
    }

    #[test]
    fn cold_goes_to_home_server() {
        let cl = Cluster::new(&SimConfig::small());
        let r = req("matmult");
        let home = home_server("matmult", cl.len());
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 8, 2048, &cl);
        assert_eq!(d.worker, home);
        assert_eq!(d.container, ContainerChoice::Cold);
        assert_eq!(s.cold_routes, 1);
    }

    #[test]
    fn full_home_probes_forward() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("matmult");
        let home = home_server("matmult", cl.len());
        cl.workers[home].allocated_vcpus = 90.0;
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 8, 2048, &cl);
        assert_ne!(d.worker, home);
    }

    #[test]
    fn queued_demand_steers_cold_route_away() {
        use crate::simulator::worker::QueuedAdmission;
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("matmult");
        let home = home_server("matmult", cl.len());
        // nothing allocated, but 85 vCPUs of demand already waiting: the
        // queue-aware view leaves no room for an 8-vCPU ask
        cl.workers[home].push_admission(QueuedAdmission {
            inv_id: 1,
            vcpus: 85,
            mem_mb: 1024,
        });
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 8, 2048, &cl);
        assert_ne!(d.worker, home, "backlogged home server must be probed past");
    }

    #[test]
    fn smaller_warm_never_reused() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("qr");
        warm(&mut cl, 0, 10, r.func, 2, 256);
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.container, ContainerChoice::Cold, "2-vCPU box can't serve a 4-vCPU ask");
    }

    #[test]
    fn warm_on_full_worker_skipped() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("qr");
        warm(&mut cl, 0, 10, r.func, 4, 512);
        cl.workers[0].allocated_vcpus = 88.0; // 4 vCPUs won't fit under 90
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_ne!(d.worker, 0, "admission control must skip the full worker");
    }

    #[test]
    fn pressure_mode_warm_candidate_not_vetoed_by_its_own_reservation() {
        use crate::simulator::keepalive::KeepAliveMode;
        // under reservation-holding keep-alive an idle container occupies
        // capacity; the probe must not let it veto its own (capacity-
        // neutral) reuse, or every loaded worker's warmth would be
        // skipped and pressure-evicted for the resulting cold route
        let cfg = SimConfig {
            workers: 4,
            sched_vcpu_limit: 8.0,
            keepalive: KeepAliveMode::Pressure,
            ..SimConfig::default()
        };
        let mut cl = Cluster::new(&cfg);
        let r = req("qr");
        warm(&mut cl, 2, 10, r.func, 8, 1024); // fills worker 2 entirely
        assert_eq!(cl.workers[2].allocated_vcpus, 8.0, "idle reserves under pressure");
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 8, 1024, &cl);
        assert_eq!(d.worker, 2);
        assert_eq!(d.container, ContainerChoice::Warm(10), "capacity-neutral reuse");
        // but a backlogged worker still rejects the warm placement
        cl.workers[2].push_admission(crate::simulator::worker::QueuedAdmission {
            inv_id: 9,
            vcpus: 8,
            mem_mb: 1024,
        });
        let d = s.schedule(&r, 8, 1024, &cl);
        assert_eq!(d.container, ContainerChoice::Cold, "queue-aware view still vetoes");
    }

    #[test]
    fn other_functions_warm_pool_ignored() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("qr");
        let other = index_of("encrypt").unwrap();
        warm(&mut cl, 0, 10, other, 4, 512);
        let mut s = ShabariScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.container, ContainerChoice::Cold);
    }
}
