//! The default OpenWhisk scheduler (§5 observation 3): *memory-centric*
//! load balancing. It hashes a function to a home invoker and only checks
//! the invoker's **memory** load when admitting — vCPU allocations are
//! invisible to it, which is exactly why independent per-resource
//! allocations oversubscribe vCPUs under this scheduler (Fig 10's
//! "Shabari-alloc + OW-sched" ablation, static baselines in Fig 8).

use crate::simulator::worker::Cluster;
use crate::simulator::{ContainerChoice, Request};
use crate::util::rng::Rng;

use super::{home_server, SchedDecision, Scheduler};

#[derive(Debug)]
pub struct OpenWhiskScheduler {
    rng: Rng,
    pub latency_s: f64,
}

/// Salt decorrelating this scheduler's tie-break stream from the other
/// consumers of the run seed.
const SALT_OPENWHISK_SCHED: u64 = 0x0111_5C4E;

impl OpenWhiskScheduler {
    pub fn new(seed: u64) -> Self {
        OpenWhiskScheduler { rng: Rng::new(seed ^ SALT_OPENWHISK_SCHED), latency_s: 0.001 }
    }

    /// Memory-only admission (ignores vCPU load entirely). Queue-aware:
    /// memory demand already parked on the worker's admission queue
    /// counts as taken — OpenWhisk's loadbalancer tracks in-flight
    /// activations the same way, so a backlogged invoker stops looking
    /// free the moment a completion frees real memory.
    fn mem_fits(cluster: &Cluster, w: usize, mem_mb: u32) -> bool {
        let w = cluster.worker(w);
        w.free_mem_mb() - w.queued_mem_mb() >= mem_mb as f64
    }
}

impl Scheduler for OpenWhiskScheduler {
    fn name(&self) -> &'static str {
        "openwhisk"
    }

    fn schedule(
        &mut self,
        req: &Request,
        vcpus: u32,
        mem_mb: u32,
        cluster: &Cluster,
    ) -> SchedDecision {
        let _ = vcpus; // memory-centric: vCPUs are not load-balanced
        let func_name = crate::functions::catalog::CATALOG[req.func].name;
        let home = home_server(func_name, cluster.len());
        let n = cluster.len();

        // OpenWhisk reuses warm containers on the chosen invoker only.
        let mut chosen = home;
        for off in 0..n {
            let w = (home + off) % n;
            if Self::mem_fits(cluster, w, mem_mb) {
                chosen = w;
                break;
            }
            if off == n - 1 {
                chosen = self.rng.below(n);
            }
        }

        // same-size warm container on that invoker?
        let container = match cluster.worker(chosen).find_warm_exact(req.func, vcpus, mem_mb) {
            Some(c) => ContainerChoice::Warm(c.id),
            None => ContainerChoice::Cold,
        };
        SchedDecision { worker: chosen, container, background: None, latency_s: self.latency_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};
    use crate::functions::catalog::index_of;
    use crate::simulator::SimConfig;

    fn req(func: &str) -> Request {
        Request {
            id: 1,
            func: index_of(func).unwrap(),
            input: InputSpec::new(InputKind::Payload),
            arrival: 0.0,
            slo_s: 1.0,
        }
    }

    #[test]
    fn ignores_vcpu_load() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("matmult");
        let home = home_server("matmult", cl.len());
        // home is fully vCPU-loaded but has free memory
        cl.workers[home].allocated_vcpus = 90.0;
        let mut s = OpenWhiskScheduler::new(1);
        let d = s.schedule(&r, 16, 1024, &cl);
        assert_eq!(
            d.worker, home,
            "memory-centric OW keeps packing a vCPU-saturated worker"
        );
    }

    #[test]
    fn queued_memory_demand_counts_as_load() {
        use crate::simulator::worker::QueuedAdmission;
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("matmult");
        let home = home_server("matmult", cl.len());
        // plenty of free memory, but a deep admission backlog: the
        // queue-aware view must steer the probe off the home invoker
        for i in 0..125 {
            cl.workers[home].push_admission(QueuedAdmission {
                inv_id: i,
                vcpus: 1,
                mem_mb: 1024,
            });
        }
        let mut s = OpenWhiskScheduler::new(1);
        let d = s.schedule(&r, 16, 1024, &cl);
        assert_ne!(d.worker, home, "backlogged invoker must be skipped");
    }

    #[test]
    fn respects_memory_load() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("matmult");
        let home = home_server("matmult", cl.len());
        cl.workers[home].allocated_mem_mb = 125.0 * 1024.0; // memory full
        let mut s = OpenWhiskScheduler::new(1);
        let d = s.schedule(&r, 16, 1024, &cl);
        assert_ne!(d.worker, home, "memory-full worker must be skipped");
    }

    #[test]
    fn reuses_same_size_warm_on_home_only() {
        let mut cl = Cluster::new(&SimConfig::small());
        let r = req("qr");
        let home = home_server("qr", cl.len());
        let other = (home + 1) % cl.len();
        // warm container on a non-home worker: OW won't look there
        let mut c = crate::simulator::container::Container::new(5, r.func, 4, 512, 0.0);
        c.mark_ready(0.0);
        cl.insert_container(other, c);
        let mut s = OpenWhiskScheduler::new(1);
        let d = s.schedule(&r, 4, 512, &cl);
        assert_eq!(d.worker, home);
        assert_eq!(d.container, ContainerChoice::Cold);
    }
}
