//! Model formulations explored in §4.2 / Figure 6:
//!
//! * **PerFunction** (the winner, Shabari's default): one vCPU + one
//!   memory model per function — customizes to function semantics with no
//!   function-level features.
//! * **OneHot**: a single model per resource across all functions; the
//!   feature vector is the concatenation of per-function blocks with only
//!   the invoked function's block populated (one-hot block encoding).
//!   Needs a wide learner (`DynCsmc`) — the paper found it wastes ~5x p90
//!   vCPUs because the shared model cannot specialize.
//! * **PerInputType**: one model per input *type* (image, video, ...);
//!   functions sharing a type share a model — fast-completing functions
//!   dominate the early updates and starve slower ones (mobilenet's SLO
//!   violations in Fig 6a).

use std::collections::BTreeMap;
use std::fmt;

use crate::featurizer::{FeatureVector, InputKind};
use crate::learner::native::DynCsmc;
use crate::learner::xla::ModelFactory;
use crate::learner::CsmcModel;
use crate::runtime::{FEAT_DIM, NUM_CLASSES};

/// Which formulation the allocator uses (Fig 6 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Formulation {
    PerFunction,
    OneHot,
    PerInputType,
}

impl Formulation {
    pub fn parse(s: &str) -> Option<Formulation> {
        match s {
            "per-function" => Some(Formulation::PerFunction),
            "one-hot" => Some(Formulation::OneHot),
            "per-input-type" => Some(Formulation::PerInputType),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Formulation::PerFunction => "per-function",
            Formulation::OneHot => "one-hot",
            Formulation::PerInputType => "per-input-type",
        }
    }
}

/// Number of functions the one-hot block layout supports.
const ONEHOT_FUNCS: usize = 12;
/// Wide feature dim: one F-block per function + shared bias slot.
const WIDE_DIM: usize = ONEHOT_FUNCS * FEAT_DIM + 1;

/// A bank of CSOAA models keyed per the chosen formulation, one bank per
/// resource type (vCPU / memory — trained separately, §4.3).
pub struct ModelBank {
    formulation: Formulation,
    /// PerFunction: keyed by function index. PerInputType: keyed by
    /// input-kind index.
    models: BTreeMap<usize, Box<dyn CsmcModel>>,
    /// OneHot: single wide model.
    wide: Option<DynCsmc>,
    /// Per-function observation counts (confidence gating is always
    /// per function, regardless of model sharing).
    func_obs: BTreeMap<usize, u64>,
    lr: f32,
    /// Experience replay: ring of recent (x, costs) per model key, plus
    /// how many replayed updates accompany each fresh one. The memory
    /// bank uses replay to converge within its confidence window (the
    /// footprint surface is stationary, so replay is sound); the vCPU
    /// bank keeps replay at 0 so the explore/revert dynamics of Fig 9a
    /// stay responsive.
    replay: usize,
    history: BTreeMap<usize, Vec<([f32; FEAT_DIM], [f32; NUM_CLASSES])>>,
    replay_cursor: u64,
}

/// Manual `Debug`: `models` holds `Box<dyn CsmcModel>` trait objects, so
/// print the bank's shape (formulation, key count, hyperparameters)
/// instead of the weights.
impl fmt::Debug for ModelBank {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ModelBank")
            .field("formulation", &self.formulation)
            .field("models", &self.models.len())
            .field("lr", &self.lr)
            .field("replay", &self.replay)
            .finish_non_exhaustive()
    }
}

/// Capacity of each per-key replay ring.
const REPLAY_RING: usize = 64;

impl ModelBank {
    pub fn new(formulation: Formulation, lr: f32) -> Self {
        Self::with_replay(formulation, lr, 0)
    }

    pub fn with_replay(formulation: Formulation, lr: f32, replay: usize) -> Self {
        let wide = if formulation == Formulation::OneHot {
            Some(DynCsmc::new(NUM_CLASSES, WIDE_DIM, lr))
        } else {
            None
        };
        ModelBank {
            formulation,
            models: BTreeMap::new(),
            wide,
            func_obs: BTreeMap::new(),
            lr,
            replay,
            history: BTreeMap::new(),
            replay_cursor: 0,
        }
    }

    fn key(&self, func: usize, kind: InputKind) -> usize {
        match self.formulation {
            Formulation::PerFunction => func,
            Formulation::PerInputType => kind.index(),
            Formulation::OneHot => 0,
        }
    }

    fn widen(func: usize, x: &FeatureVector) -> Vec<f32> {
        let mut wide = vec![0f32; WIDE_DIM];
        wide[0] = 1.0; // shared bias
        let at = 1 + (func % ONEHOT_FUNCS) * FEAT_DIM;
        wide[at..at + FEAT_DIM].copy_from_slice(x.as_slice());
        wide
    }

    /// Per-class scores for an invocation of `func` with features `x`.
    /// `factory` supplies backend models on first use (per-function /
    /// per-input-type formulations only).
    pub fn scores(
        &mut self,
        factory: &ModelFactory,
        func: usize,
        kind: InputKind,
        x: &FeatureVector,
    ) -> [f32; NUM_CLASSES] {
        if let Some(wide) = &self.wide {
            let s = wide.scores_dyn(&Self::widen(func, x));
            let mut out = [0f32; NUM_CLASSES];
            out.copy_from_slice(&s);
            return out;
        }
        let key = self.key(func, kind);
        let model = self.models.entry(key).or_insert_with(|| factory.make());
        model.scores(&fixed(x))
    }

    /// Absorb feedback for an invocation of `func`.
    pub fn update(
        &mut self,
        factory: &ModelFactory,
        func: usize,
        kind: InputKind,
        x: &FeatureVector,
        costs: &[f32; NUM_CLASSES],
    ) {
        *self.func_obs.entry(func).or_insert(0) += 1;
        if let Some(wide) = &mut self.wide {
            wide.update_dyn(&Self::widen(func, x), costs);
            return;
        }
        let key = self.key(func, kind);
        let model = self.models.entry(key).or_insert_with(|| factory.make());
        model.update(&fixed(x), costs);
        if self.replay > 0 {
            let ring = self.history.entry(key).or_default();
            if ring.len() >= REPLAY_RING {
                ring.remove(0);
            }
            ring.push((fixed(x), *costs));
            for _ in 0..self.replay {
                // deterministic strided walk over the ring
                self.replay_cursor = self.replay_cursor.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let idx = (self.replay_cursor >> 33) as usize % ring.len();
                let (rx, rc) = ring[idx];
                model.update(&rx, &rc);
            }
        }
    }

    /// Observations of this *function* (confidence gating input).
    pub fn observations(&self, func: usize) -> u64 {
        self.func_obs.get(&func).copied().unwrap_or(0)
    }

    /// Discount `n` observations of `func` (saturating): a worker crash
    /// takes the executions it contributed with it, pushing the function
    /// back toward (or into) its exploration window. Model weights are
    /// left as-is — SGD history cannot be surgically unlearned — so this
    /// models Shabari re-verifying confidence after losing a node.
    pub fn forget(&mut self, func: usize, n: u64) {
        if let Some(obs) = self.func_obs.get_mut(&func) {
            *obs = obs.saturating_sub(n);
        }
    }

    pub fn formulation(&self) -> Formulation {
        self.formulation
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Number of distinct underlying models (scalability comparison §4.2).
    pub fn model_count(&self) -> usize {
        if self.wide.is_some() {
            1
        } else {
            self.models.len()
        }
    }
}

fn fixed(x: &FeatureVector) -> [f32; FEAT_DIM] {
    let mut out = [0f32; FEAT_DIM];
    out.copy_from_slice(x.as_slice());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::cost_vector;
    use crate::learner::xla::{Backend, ModelFactory};

    fn factory() -> ModelFactory {
        ModelFactory::new(Backend::Native, "artifacts", 0.1).unwrap()
    }

    fn feats(slot: usize) -> FeatureVector {
        let mut f = FeatureVector::zeros();
        f.0[0] = 1.0;
        f.0[slot] = 1.0;
        f
    }

    #[test]
    fn per_function_isolates_functions() {
        let fac = factory();
        let mut bank = ModelBank::new(Formulation::PerFunction, 0.1);
        let x = feats(1);
        for _ in 0..200 {
            bank.update(&fac, 0, InputKind::Image, &x, &cost_vector(4, 2.0));
            bank.update(&fac, 1, InputKind::Image, &x, &cost_vector(30, 2.0));
        }
        let s0 = bank.scores(&fac, 0, InputKind::Image, &x);
        let s1 = bank.scores(&fac, 1, InputKind::Image, &x);
        assert_eq!(crate::learner::argmin(&s0), 4);
        assert_eq!(crate::learner::argmin(&s1), 30);
        assert_eq!(bank.model_count(), 2);
    }

    #[test]
    fn per_input_type_shares_models() {
        let fac = factory();
        let mut bank = ModelBank::new(Formulation::PerInputType, 0.1);
        let x = feats(2);
        // two functions, same input type -> same model (interference)
        for _ in 0..100 {
            bank.update(&fac, 0, InputKind::Image, &x, &cost_vector(4, 2.0));
        }
        let s1 = bank.scores(&fac, 1, InputKind::Image, &x);
        assert_eq!(
            crate::learner::argmin(&s1),
            4,
            "function 1 inherits function 0's learning through the shared model"
        );
        assert_eq!(bank.model_count(), 1);
    }

    #[test]
    fn one_hot_distinguishes_but_shares_capacity() {
        let fac = factory();
        let mut bank = ModelBank::new(Formulation::OneHot, 0.1);
        let x = feats(1);
        for _ in 0..400 {
            bank.update(&fac, 0, InputKind::Image, &x, &cost_vector(4, 2.0));
            bank.update(&fac, 5, InputKind::Video, &x, &cost_vector(20, 2.0));
        }
        let s0 = bank.scores(&fac, 0, InputKind::Image, &x);
        let s5 = bank.scores(&fac, 5, InputKind::Video, &x);
        assert_eq!(crate::learner::argmin(&s0), 4);
        assert_eq!(crate::learner::argmin(&s5), 20);
        assert_eq!(bank.model_count(), 1);
    }

    #[test]
    fn observations_counted_per_function_in_all_formulations() {
        for f in [Formulation::PerFunction, Formulation::OneHot, Formulation::PerInputType] {
            let fac = factory();
            let mut bank = ModelBank::new(f, 0.1);
            let x = feats(1);
            bank.update(&fac, 3, InputKind::Image, &x, &cost_vector(4, 2.0));
            bank.update(&fac, 3, InputKind::Image, &x, &cost_vector(4, 2.0));
            bank.update(&fac, 7, InputKind::Image, &x, &cost_vector(4, 2.0));
            assert_eq!(bank.observations(3), 2, "{f:?}");
            assert_eq!(bank.observations(7), 1, "{f:?}");
            assert_eq!(bank.observations(9), 0, "{f:?}");
        }
    }

    #[test]
    fn formulation_parsing() {
        assert_eq!(Formulation::parse("per-function"), Some(Formulation::PerFunction));
        assert_eq!(Formulation::parse("one-hot"), Some(Formulation::OneHot));
        assert_eq!(Formulation::parse("nope"), None);
    }
}
