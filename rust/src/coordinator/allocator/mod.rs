//! Shabari's Resource Allocator (paper §4): delayed, input-aware,
//! per-resource-type allocation via online cost-sensitive multi-class
//! learning, with confidence gating and OOM safeguards.

pub mod cost;
pub mod formulation;

use crate::featurizer::{FeatureCache, FeatureVector};
use crate::functions::catalog::CATALOG;
use crate::learner::xla::{Backend, ModelFactory};
use crate::learner::argmin;
use crate::simulator::{InvocationRecord, Request, Verdict};

use cost::{class_mem_mb, class_vcpus, SlackPolicy, MAX_MEM_MB};
use formulation::{Formulation, ModelBank};

/// Allocator hyperparameters (defaults per §6/§7.5).
#[derive(Debug, Clone)]
pub struct AllocatorConfig {
    pub lr: f32,
    /// Invocations a function's model must absorb before vCPU predictions
    /// are trusted (§7.5: 8–12 suffices; default 10).
    pub vcpu_confidence: u64,
    /// Memory confidence = 2x vCPU (§4.3.2 safeguard 1; default 20).
    pub mem_confidence: u64,
    /// Default allocation while learning (§7.5: 16 vCPUs; §7.2: 4 GB).
    pub default_vcpus: u32,
    pub default_mem_mb: u32,
    pub slack: SlackPolicy,
    pub formulation: Formulation,
    /// Modeled critical-path latencies (Fig 14; measured for real by
    /// `cargo bench` / experiment fig14).
    pub predict_latency_s: f64,
    pub learner_backend: Backend,
    pub artifacts_dir: String,
}

impl Default for AllocatorConfig {
    fn default() -> Self {
        AllocatorConfig {
            lr: 0.3,
            vcpu_confidence: 10,
            mem_confidence: 20,
            default_vcpus: 16,
            default_mem_mb: 4096,
            slack: SlackPolicy::absolute_default(),
            formulation: Formulation::PerFunction,
            predict_latency_s: 0.003,
            learner_backend: Backend::Native,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl AllocatorConfig {
    /// Production config: XLA backend over the AOT artifacts.
    pub fn xla(artifacts_dir: &str) -> Self {
        AllocatorConfig {
            learner_backend: Backend::Xla,
            artifacts_dir: artifacts_dir.to_string(),
            ..Default::default()
        }
    }
}

/// The allocation the allocator hands to the scheduler.
#[derive(Debug, Clone, Copy)]
pub struct Allocation {
    pub vcpus: u32,
    pub mem_mb: u32,
    /// Critical-path latency of featurization + prediction.
    pub overhead_s: f64,
    /// Whether the prediction came from the model (vs the learning-phase
    /// default).
    pub vcpus_from_model: bool,
    pub mem_from_model: bool,
}

/// Shabari's Resource Allocator: per-function online models for vCPU and
/// memory, fed by the worker daemon's per-invocation reports.
#[derive(Debug)]
pub struct ResourceAllocator {
    pub cfg: AllocatorConfig,
    factory: ModelFactory,
    vcpu_bank: ModelBank,
    mem_bank: ModelBank,
    pub feature_cache: FeatureCache,
}

impl ResourceAllocator {
    pub fn new(cfg: AllocatorConfig) -> anyhow::Result<Self> {
        let factory = ModelFactory::new(cfg.learner_backend, &cfg.artifacts_dir, cfg.lr)?;
        Ok(ResourceAllocator {
            vcpu_bank: ModelBank::new(cfg.formulation, cfg.lr),
            mem_bank: ModelBank::with_replay(cfg.formulation, cfg.lr, 3),
            feature_cache: FeatureCache::new(),
            factory,
            cfg,
        })
    }

    /// Predict the allocation for a request (§4.3). Featurization latency
    /// lands on the critical path only on cache misses (§7.6).
    pub fn allocate(&mut self, req: &Request) -> Allocation {
        let (features, extract_s) = self.feature_cache.featurize_invocation(&req.input);
        let kind = CATALOG[req.func].input_kind;

        // vCPU model sees the SLO as a feature; memory model does not
        // (§4.3.2: memory does not affect performance).
        let x_vcpu = features.clone().with_slo(req.slo_s);
        let x_mem = features;

        let vcpus_from_model = self.vcpu_bank.observations(req.func) >= self.cfg.vcpu_confidence;
        let vcpus = if vcpus_from_model {
            let scores = self.vcpu_bank.scores(&self.factory, req.func, kind, &x_vcpu);
            class_vcpus(argmin(&scores))
        } else {
            self.cfg.default_vcpus
        };

        let mem_from_model = self.mem_bank.observations(req.func) >= self.cfg.mem_confidence;
        let mem_mb = if mem_from_model {
            let scores = self.mem_bank.scores(&self.factory, req.func, kind, &x_mem);
            // Headroom above the argmin: two classes (256 MB) plus ~12%
            // proportional margin. The cost target is the rounded-up
            // footprint, so a zero margin would OOM on any upward noise or
            // local interpolation error (§4.3.2 aims for <1% kills; the
            // paper accepts Shabari's higher p95 wasted memory for this).
            let a = argmin(&scores);
            let best = (a + 2 + a / 8).min(crate::runtime::NUM_CLASSES - 1);
            let predicted = class_mem_mb(best);
            // Safeguard 2 (§4.3.2): prediction must exceed the input size;
            // otherwise fall back to the largest default.
            let input_mb = (req.input.size_bytes / (1024.0 * 1024.0)).ceil() as u32;
            if predicted <= input_mb {
                self.cfg.default_mem_mb.max(input_mb.min(MAX_MEM_MB))
            } else {
                predicted
            }
        } else {
            self.cfg.default_mem_mb
        };

        Allocation {
            vcpus,
            mem_mb,
            overhead_s: extract_s + self.cfg.predict_latency_s,
            vcpus_from_model,
            mem_from_model,
        }
    }

    /// Close the feedback loop from a finished invocation (§4.3 feedback;
    /// runs off the critical path).
    pub fn feedback(&mut self, rec: &InvocationRecord) {
        let kind = CATALOG[rec.func].input_kind;
        let (features, _) = self.feature_cache.featurize_invocation(&rec.input);
        let x_vcpu = features.clone().with_slo(rec.slo_s);
        let x_mem = features;

        // Timeouts flow through the violation branch of the cost function:
        // the walltime cap means exec >> SLO, so a compute-starved
        // invocation (high utilization) grows aggressively and an
        // infeasible-SLO one (low utilization) anchors at what it used.
        let vc = cost::vcpu_costs(rec, self.cfg.slack);
        self.vcpu_bank.update(&self.factory, rec.func, kind, &x_vcpu, &vc);
        let mc = cost::mem_costs(rec);
        self.mem_bank.update(&self.factory, rec.func, kind, &x_mem, &mc);
    }

    /// Discount `n` of `func`'s observations from both banks (saturating):
    /// a crashed worker takes its contributed executions with it, so the
    /// function may fall back under its confidence thresholds and re-enter
    /// the default-allocation learning phase (DESIGN.md §Faults).
    pub fn forget(&mut self, func: usize, n: u64) {
        self.vcpu_bank.forget(func, n);
        self.mem_bank.forget(func, n);
    }

    /// Observation counters (sensitivity experiments).
    pub fn vcpu_observations(&self, func: usize) -> u64 {
        self.vcpu_bank.observations(func)
    }

    pub fn mem_observations(&self, func: usize) -> u64 {
        self.mem_bank.observations(func)
    }

    /// Direct score access for introspection (fig9 timeline experiment).
    pub fn vcpu_scores_for(&mut self, func: usize, x: &FeatureVector) -> [f32; crate::runtime::NUM_CLASSES] {
        let kind = CATALOG[func].input_kind;
        self.vcpu_bank.scores(&self.factory, func, kind, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};
    use crate::functions::catalog::index_of;

    fn req(func: &str, slo: f64) -> Request {
        let f = index_of(func).unwrap();
        let mut input = InputSpec::new(CATALOG[f].input_kind);
        input.id = 99;
        input.size_bytes = 1e6;
        input.width = 800.0;
        input.height = 600.0;
        input.length = 500.0;
        Request { id: 1, func: f, input, arrival: 0.0, slo_s: slo }
    }

    fn completed(r: &Request, vcpus: u32, mem_mb: u32, exec: f64, used: f64, mem_gb: f64) -> InvocationRecord {
        InvocationRecord {
            id: r.id,
            func: r.func,
            input: r.input.clone(),
            worker: 0,
            vcpus,
            mem_mb,
            requested_vcpus: vcpus,
            requested_mem_mb: mem_mb,
            arrival: 0.0,
            cold_start_s: 0.0,
            had_cold_start: false,
            overhead_s: 0.0,
            queue_s: 0.0,
            exec_s: exec,
            e2e_s: exec,
            end: exec,
            slo_s: r.slo_s,
            verdict: Verdict::Completed,
            avg_vcpus_used: used,
            peak_vcpus_used: used,
            mem_used_gb: mem_gb,
        }
    }

    #[test]
    fn defaults_before_confidence() {
        let mut a = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
        let r = req("imageprocess", 2.0);
        let alloc = a.allocate(&r);
        assert_eq!(alloc.vcpus, 16);
        assert_eq!(alloc.mem_mb, 4096);
        assert!(!alloc.vcpus_from_model);
        assert!(!alloc.mem_from_model);
    }

    #[test]
    fn learns_to_shrink_single_threaded() {
        let mut a = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
        let r = req("imageprocess", 2.0);
        // imageprocess: 1 vCPU used, finishes in 1.0s with slack
        for _ in 0..40 {
            let rec = completed(&r, 16, 4096, 1.0, 1.0, 0.5);
            a.feedback(&rec);
        }
        let alloc = a.allocate(&r);
        assert!(alloc.vcpus_from_model);
        assert!(alloc.vcpus <= 4, "single-threaded must shrink, got {}", alloc.vcpus);
        assert!(alloc.mem_mb < 4096, "memory should track footprint, got {}", alloc.mem_mb);
        assert!(alloc.mem_mb >= 512, "footprint 0.5 GB needs >= 512 MB");
    }

    #[test]
    fn learns_to_grow_on_violations() {
        let mut a = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
        let r = req("matmult", 5.0);
        // fully-utilized 16 vCPUs keep missing the SLO by 2s
        for _ in 0..40 {
            let rec = completed(&r, 16, 4096, 7.0, 15.9, 2.0);
            a.feedback(&rec);
        }
        let alloc = a.allocate(&r);
        assert!(alloc.vcpus_from_model);
        assert!(alloc.vcpus > 16, "high-util violations must grow, got {}", alloc.vcpus);
    }

    #[test]
    fn memory_safeguard_input_size() {
        let mut cfg = AllocatorConfig::default();
        cfg.mem_confidence = 1;
        let mut a = ResourceAllocator::new(cfg).unwrap();
        let mut r = req("compress", 60.0);
        r.input.size_bytes = 1.5e9; // 1.5 GB input
        // teach the model a tiny footprint so its raw prediction is small
        let rec = completed(&r, 16, 4096, 10.0, 10.0, 0.3);
        a.feedback(&rec);
        let alloc = a.allocate(&r);
        // raw prediction (~384 MB) is below the input size -> safeguard
        assert!(
            alloc.mem_mb as f64 >= 1.5e9 / 1024.0 / 1024.0 || alloc.mem_mb == 4096,
            "safeguard must override tiny predictions, got {}",
            alloc.mem_mb
        );
    }

    #[test]
    fn confidence_thresholds_gate_separately() {
        let mut cfg = AllocatorConfig::default();
        cfg.vcpu_confidence = 2;
        cfg.mem_confidence = 4;
        let mut a = ResourceAllocator::new(cfg).unwrap();
        let r = req("qr", 1.0);
        for i in 0..3 {
            let rec = completed(&r, 16, 4096, 0.2, 1.0, 0.1);
            a.feedback(&rec);
            let alloc = a.allocate(&r);
            if i < 1 {
                assert!(!alloc.vcpus_from_model);
            }
        }
        let alloc = a.allocate(&r);
        assert!(alloc.vcpus_from_model, "3 obs >= vcpu threshold 2");
        assert!(!alloc.mem_from_model, "3 obs < mem threshold 4");
    }

    #[test]
    fn forget_discounts_observations_and_regates_confidence() {
        let mut cfg = AllocatorConfig::default();
        cfg.vcpu_confidence = 2;
        cfg.mem_confidence = 2;
        let mut a = ResourceAllocator::new(cfg).unwrap();
        let r = req("qr", 1.0);
        for _ in 0..3 {
            a.feedback(&completed(&r, 16, 4096, 0.2, 1.0, 0.1));
        }
        assert_eq!(a.vcpu_observations(r.func), 3);
        assert!(a.allocate(&r).vcpus_from_model);
        a.forget(r.func, 2);
        assert_eq!(a.vcpu_observations(r.func), 1);
        assert_eq!(a.mem_observations(r.func), 1);
        assert!(
            !a.allocate(&r).vcpus_from_model,
            "forgetting must push the function back under confidence"
        );
        a.forget(r.func, 100);
        assert_eq!(a.vcpu_observations(r.func), 0, "forget saturates at zero");
        a.forget(999, 5); // unknown function: no-op, no panic
    }

    #[test]
    fn overhead_includes_prediction_latency() {
        let mut a = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
        let r = req("imageprocess", 2.0);
        let first = a.allocate(&r);
        // first sight of object 99: featurization on critical path
        assert!(first.overhead_s >= a.cfg.predict_latency_s);
        let second = a.allocate(&r);
        // cached now: only prediction latency
        assert!((second.overhead_s - a.cfg.predict_latency_s).abs() < 1e-12);
    }
}
