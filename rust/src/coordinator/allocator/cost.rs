//! Cost functions that turn an invocation's observed outcome into the
//! CSOAA cost vector (paper §4.3.1 for vCPUs, §4.3.2 for memory).
//!
//! Class encoding: vCPU class `i` = `i + 1` vCPUs; memory class `i` =
//! `(i + 1) * 128` MB. Both use [`NUM_CLASSES`] = 48 classes.

use crate::learner::cost_vector;
use crate::runtime::NUM_CLASSES;
use crate::simulator::{InvocationRecord, Verdict};

/// Memory granularity (one class step).
pub const MEM_STEP_MB: u32 = 128;
/// Largest representable allocations.
pub const MAX_VCPUS: u32 = NUM_CLASSES as u32;
pub const MAX_MEM_MB: u32 = NUM_CLASSES as u32 * MEM_STEP_MB;

/// vCPU count -> class index.
pub fn vcpu_class(vcpus: u32) -> usize {
    (vcpus.clamp(1, MAX_VCPUS) - 1) as usize
}

/// Class index -> vCPU count.
pub fn class_vcpus(class: usize) -> u32 {
    class as u32 + 1
}

/// Memory MB -> class index (rounded up to the next 128 MB step).
pub fn mem_class(mem_mb: u32) -> usize {
    let mb = mem_mb.clamp(1, MAX_MEM_MB);
    ((mb + MEM_STEP_MB - 1) / MEM_STEP_MB - 1) as usize
}

/// Class index -> memory MB.
pub fn class_mem_mb(class: usize) -> u32 {
    (class as u32 + 1) * MEM_STEP_MB
}

/// Slack policy for choosing the target vCPU class (§4.3.1, Fig 7a).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlackPolicy {
    /// For every `x_s` seconds past the SLO add a vCPU; for every `y_s`
    /// of slack below it remove one. Paper-tuned: X=0.5 s, Y=1.5 s.
    Absolute { x_s: f64, y_s: f64 },
    /// Scale the allocation by the exec-time/SLO ratio.
    Proportional,
}

impl SlackPolicy {
    pub fn absolute_default() -> Self {
        SlackPolicy::Absolute { x_s: 0.5, y_s: 1.5 }
    }
}

/// Fraction of the allocation that must be utilized for an SLO violation
/// to be attributed to under-allocation (§4.3.1 case 2: 90%).
pub const HIGH_UTIL_THRESHOLD: f64 = 0.9;

/// Penalty slope multiplier for underprediction (both resources).
pub const UNDER_PENALTY: f32 = 2.0;
/// Memory underprediction risks OOM kills — penalize harder.
pub const MEM_UNDER_PENALTY: f32 = 3.0;

/// Compute the target vCPU class for a completed invocation.
///
/// Mirrors §4.3.1:
/// * SLO met → keep or shrink according to slack;
/// * SLO missed with low utilization → external cause; anchor to the
///   vCPUs actually used;
/// * SLO missed with high utilization → grow past the peak used,
///   scaled by the deficit.
pub fn vcpu_target_class(rec: &InvocationRecord, policy: SlackPolicy) -> usize {
    let alloc = rec.vcpus.max(1);
    let exec = rec.exec_s;
    let slo = rec.slo_s.max(1e-6);
    let met = rec.verdict == Verdict::Completed && exec <= slo;
    if met {
        let slack = slo - exec;
        let down = match policy {
            // The paper tuned Y=1.5s against second-scale runtimes
            // (Y ~ 0.15-0.75x exec). For minute-scale invocations a fixed
            // 1.5s step would shed dozens of classes per update, so the
            // effective step is floored at 22% of the SLO — identical to
            // the paper's constant in its regime, stable outside it.
            SlackPolicy::Absolute { y_s, .. } => {
                (slack / y_s.max(0.22 * slo)).floor() as i64
            }
            SlackPolicy::Proportional => {
                // target ≈ alloc * exec/slo (never below 1)
                let t = (alloc as f64 * exec / slo).ceil() as i64;
                (alloc as i64 - t).max(0)
            }
        };
        // Cap the one-update shrink at a quarter of the allocation: the
        // X/Y absolute steps were tuned for second-scale runtimes (§4.3.1);
        // minute-scale invocations can accumulate enough slack to jump to
        // 1 vCPU in one step, which oscillates through timeouts. The cap
        // keeps the absolute policy's aggressiveness bounded while the
        // model still explores downward over several invocations (Fig 9a).
        let down = down.min((alloc as i64 / 4).max(1));
        let slack_target = (alloc as i64 - down).max(1) as u32;
        // "fewer vCPUs could also meet the SLO" (§4.3.1 case 1): cores the
        // invocation never touched gave zero benefit, so the peak actually
        // used caps the target — this is what lets Shabari settle
        // single-threaded functions at 1-2 vCPUs (Fig 9b) even when the
        // slack alone is below one Y-step.
        let util_cap = rec.peak_vcpus_used.ceil().max(1.0) as u32;
        let target = slack_target.min(util_cap.max(1)).max(1);
        vcpu_class(target)
    } else {
        let util = rec.avg_vcpus_used / alloc as f64;
        if util < HIGH_UTIL_THRESHOLD {
            // Violation not caused by the vCPU allocation (§4.3.1(2)):
            // anchor the model to what the invocation actually used.
            let used = rec.peak_vcpus_used.ceil().max(1.0) as u32;
            vcpu_class(used.min(alloc))
        } else {
            let deficit = (exec - slo).max(0.0);
            let up = match policy {
                // Same regime scaling as the shrink step (X floored at 4%
                // of the SLO) — keeps growth more aggressive than shrink,
                // as the absolute policy intends (Fig 7a).
                SlackPolicy::Absolute { x_s, .. } => {
                    (deficit / x_s.max(0.04 * slo)).floor() as i64 + 1
                }
                SlackPolicy::Proportional => {
                    let t = (alloc as f64 * exec / slo).ceil() as i64;
                    (t - alloc as i64).max(1)
                }
            };
            let base = rec.peak_vcpus_used.ceil().max(alloc as f64) as i64;
            let target = (base + up).clamp(1, MAX_VCPUS as i64) as u32;
            vcpu_class(target)
        }
    }
}

/// CSOAA cost vector for the vCPU model.
pub fn vcpu_costs(rec: &InvocationRecord, policy: SlackPolicy) -> [f32; NUM_CLASSES] {
    cost_vector(vcpu_target_class(rec, policy), UNDER_PENALTY)
}

/// Target memory class: the observed footprint rounded up one step
/// (§4.3.2: "assigns the lowest cost to the class corresponding to the
/// observed memory utilization"); an OOM kill pushes one class above the
/// failed allocation instead.
pub fn mem_target_class(rec: &InvocationRecord) -> usize {
    if rec.verdict == Verdict::OomKilled {
        // the footprint exceeded the allocation; ask for more next time
        let failed = mem_class(rec.mem_mb);
        (failed + 2).min(NUM_CLASSES - 1)
    } else {
        let used_mb = (rec.mem_used_gb * 1024.0).ceil().max(1.0) as u32;
        mem_class(used_mb)
    }
}

/// CSOAA cost vector for the memory model.
pub fn mem_costs(rec: &InvocationRecord) -> [f32; NUM_CLASSES] {
    cost_vector(mem_target_class(rec), MEM_UNDER_PENALTY)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};

    fn rec(vcpus: u32, exec: f64, slo: f64, avg_used: f64, peak: f64) -> InvocationRecord {
        InvocationRecord {
            id: 1,
            func: 0,
            input: InputSpec::new(InputKind::Payload),
            worker: 0,
            vcpus,
            mem_mb: 2048,
            requested_vcpus: vcpus,
            requested_mem_mb: 2048,
            arrival: 0.0,
            cold_start_s: 0.0,
            had_cold_start: false,
            overhead_s: 0.0,
            queue_s: 0.0,
            exec_s: exec,
            e2e_s: exec,
            end: exec,
            slo_s: slo,
            verdict: Verdict::Completed,
            avg_vcpus_used: avg_used,
            peak_vcpus_used: peak,
            mem_used_gb: 1.0,
        }
    }

    #[test]
    fn class_mappings_roundtrip() {
        for v in 1..=MAX_VCPUS {
            assert_eq!(class_vcpus(vcpu_class(v)), v);
        }
        assert_eq!(mem_class(128), 0);
        assert_eq!(mem_class(129), 1, "rounds up");
        assert_eq!(class_mem_mb(mem_class(4096)), 4096);
        assert_eq!(mem_class(MAX_MEM_MB + 999), NUM_CLASSES - 1);
    }

    #[test]
    fn met_with_big_slack_shrinks() {
        // SLO 10s, ran 4s => slack 6s; effective Y = max(1.5, 0.22*10) =
        // 2.2s => floor(6/2.2) = 2 classes down
        let r = rec(16, 4.0, 10.0, 14.0, 16.0);
        let t = vcpu_target_class(&r, SlackPolicy::absolute_default());
        assert_eq!(class_vcpus(t), 14);
    }

    #[test]
    fn met_with_no_slack_keeps() {
        let r = rec(16, 9.8, 10.0, 14.0, 16.0);
        let t = vcpu_target_class(&r, SlackPolicy::absolute_default());
        assert_eq!(class_vcpus(t), 16);
    }

    #[test]
    fn shrink_never_below_one() {
        let r = rec(2, 0.1, 100.0, 1.0, 1.0);
        let t = vcpu_target_class(&r, SlackPolicy::absolute_default());
        assert_eq!(class_vcpus(t), 1);
    }

    #[test]
    fn violated_low_util_anchors_to_used() {
        // 16 allocated, only ~2 used => violation caused elsewhere
        let mut r = rec(16, 12.0, 10.0, 2.0, 2.0);
        r.avg_vcpus_used = 2.0;
        let t = vcpu_target_class(&r, SlackPolicy::absolute_default());
        assert_eq!(class_vcpus(t), 2, "single/low-par functions don't grow");
    }

    #[test]
    fn violated_high_util_grows_past_peak() {
        // fully used 8 vCPUs and missed by 1s => +3 classes at X=0.5 (+1)
        let r = rec(8, 11.0, 10.0, 7.8, 8.0);
        let t = vcpu_target_class(&r, SlackPolicy::absolute_default());
        assert_eq!(class_vcpus(t), 8 + 3);
    }

    #[test]
    fn absolute_more_aggressive_than_proportional_on_violation() {
        let r = rec(8, 11.0, 10.0, 7.9, 8.0);
        let ta = vcpu_target_class(&r, SlackPolicy::absolute_default());
        let tp = vcpu_target_class(&r, SlackPolicy::Proportional);
        assert!(
            class_vcpus(ta) >= class_vcpus(tp),
            "absolute {} vs proportional {}",
            class_vcpus(ta),
            class_vcpus(tp)
        );
    }

    #[test]
    fn growth_clamped_to_max() {
        let r = rec(47, 60.0, 1.0, 47.0, 47.0);
        let t = vcpu_target_class(&r, SlackPolicy::absolute_default());
        assert_eq!(class_vcpus(t), MAX_VCPUS);
    }

    #[test]
    fn mem_target_tracks_footprint() {
        let mut r = rec(8, 5.0, 10.0, 4.0, 8.0);
        r.mem_used_gb = 1.0; // 1024 MB -> class 7 (8*128)
        assert_eq!(class_mem_mb(mem_target_class(&r)), 1024);
        r.mem_used_gb = 1.01;
        assert_eq!(class_mem_mb(mem_target_class(&r)), 1152, "rounds up a step");
    }

    #[test]
    fn oom_pushes_above_failed_allocation() {
        let mut r = rec(8, 5.0, 10.0, 4.0, 8.0);
        r.verdict = Verdict::OomKilled;
        r.mem_mb = 2048;
        r.mem_used_gb = 2.0; // truncated at kill time
        assert!(class_mem_mb(mem_target_class(&r)) > 2048);
    }

    #[test]
    fn cost_vectors_minimize_at_target() {
        let r = rec(8, 11.0, 10.0, 7.9, 8.0);
        let vc = vcpu_costs(&r, SlackPolicy::absolute_default());
        assert_eq!(crate::learner::argmin(&vc), vcpu_target_class(&r, SlackPolicy::absolute_default()));
        let mc = mem_costs(&r);
        assert_eq!(crate::learner::argmin(&mc), mem_target_class(&r));
    }
}
