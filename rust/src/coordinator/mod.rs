//! Shabari's coordinator: the Resource Allocator (§4), the Scheduler
//! (§5), and the router that composes them into a `simulator::Policy`
//! (Figure 5's life cycle: interface → featurizer → allocator →
//! scheduler → worker daemon → metadata store → online update).

pub mod allocator;
pub mod router;
pub mod scheduler;

pub use allocator::{AllocatorConfig, ResourceAllocator};
pub use router::ShabariPolicy;
