//! The router: glues the Resource Allocator and a Scheduler into a
//! `simulator::Policy` — this is the Shabari system the experiments run
//! (Figure 5's invocation life cycle).

use std::collections::BTreeMap;

use crate::simulator::worker::Cluster;
use crate::simulator::{Decision, InvocationRecord, Policy, Request, SimTime, Verdict};

use super::allocator::ResourceAllocator;
use super::scheduler::Scheduler;

/// Shabari (or an ablation of it): allocator + pluggable scheduler.
pub struct ShabariPolicy {
    pub allocator: ResourceAllocator,
    pub scheduler: Box<dyn Scheduler>,
    /// Feedback contributions per `(worker, func)` — the ledger a worker
    /// crash consults to forget what that worker's runs taught the
    /// allocator (DESIGN.md §Faults).
    feedback_counts: BTreeMap<(usize, usize), u64>,
    name: String,
}

/// Manual `Debug`: the scheduler is a `Box<dyn Scheduler>` trait object,
/// so print its registry name alongside the allocator state.
impl std::fmt::Debug for ShabariPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShabariPolicy")
            .field("name", &self.name)
            .field("scheduler", &self.scheduler.name())
            .field("allocator", &self.allocator)
            .field("feedback_entries", &self.feedback_counts.len())
            .finish_non_exhaustive()
    }
}

impl ShabariPolicy {
    pub fn new(allocator: ResourceAllocator, scheduler: Box<dyn Scheduler>) -> Self {
        let name = format!("shabari({})", scheduler.name());
        ShabariPolicy { allocator, scheduler, feedback_counts: BTreeMap::new(), name }
    }

    /// The full system with default config + Shabari scheduler.
    pub fn standard(seed: u64) -> anyhow::Result<Self> {
        let allocator =
            ResourceAllocator::new(super::allocator::AllocatorConfig::default())?;
        let scheduler = Box::new(super::scheduler::shabari::ShabariScheduler::new(seed));
        Ok(Self::new(allocator, scheduler))
    }
}

impl Policy for ShabariPolicy {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
        // 2-3: featurize + predict (§4)
        let alloc = self.allocator.allocate(req);
        // 4: schedule (§5)
        let sched = self
            .scheduler
            .schedule(req, alloc.vcpus, alloc.mem_mb, cluster);
        Decision {
            worker: sched.worker,
            vcpus: alloc.vcpus,
            mem_mb: alloc.mem_mb,
            container: sched.container,
            background: sched.background,
            overhead_s: alloc.overhead_s + sched.latency_s,
        }
    }

    fn on_complete(&mut self, _now: SimTime, rec: &InvocationRecord, _cluster: &Cluster) {
        if rec.verdict == Verdict::Failed {
            // The worker daemon died with the execution: there is no
            // measurement to report, so nothing reaches the learner.
            return;
        }
        *self.feedback_counts.entry((rec.worker, rec.func)).or_insert(0) += 1;
        // 5: daemon -> metadata store -> online update (off critical path)
        self.allocator.feedback(rec);
    }

    fn on_worker_crash(&mut self, _now: SimTime, worker: usize, _cluster: &Cluster) {
        // Per-function observations contributed by the crashed worker's
        // daemon are lost with it: discount them so confidence gating may
        // re-enter the learning phase (DESIGN.md §Faults).
        let lost: Vec<(usize, u64)> = self
            .feedback_counts
            .range((worker, 0)..=(worker, usize::MAX))
            .map(|(&(_, func), &n)| (func, n))
            .collect();
        for (func, n) in lost {
            self.allocator.forget(func, n);
            self.feedback_counts.remove(&(worker, func));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::allocator::AllocatorConfig;
    use crate::coordinator::scheduler::shabari::ShabariScheduler;
    use crate::featurizer::InputSpec;
    use crate::functions::catalog::{index_of, CATALOG};
    use crate::functions::inputs;
    use crate::simulator::engine::simulate;
    use crate::simulator::{SimConfig, Verdict};
    use crate::util::rng::Rng;

    fn requests_for(func: &str, n: usize, gap: f64, slo: f64) -> Vec<Request> {
        let fi = index_of(func).unwrap();
        let mut rng = Rng::new(33);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        (0..n)
            .map(|i| Request {
                id: i as u64 + 1,
                func: fi,
                input: pool[i % pool.len()].clone(),
                arrival: i as f64 * gap,
                slo_s: slo,
            })
            .collect()
    }

    fn policy() -> ShabariPolicy {
        let allocator = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
        ShabariPolicy::new(allocator, Box::new(ShabariScheduler::new(7)))
    }

    #[test]
    fn end_to_end_learning_shrinks_single_threaded() {
        let mut p = policy();
        // imageprocess SLO of 3 s: 1 vCPU suffices; default is 16
        let reqs = requests_for("imageprocess", 60, 4.0, 3.0);
        let res = simulate(SimConfig::small(), &mut p, reqs);
        let recs = res.sorted_records();
        assert_eq!(recs.len(), 60);
        // early invocations use the 16-vCPU default
        assert_eq!(recs[0].requested_vcpus, 16);
        // after the confidence threshold the model shrinks hard
        let late: Vec<u32> = recs[40..].iter().map(|r| r.requested_vcpus).collect();
        let avg: f64 = late.iter().map(|v| *v as f64).sum::<f64>() / late.len() as f64;
        assert!(avg <= 4.0, "single-threaded should settle near 1-2 vCPUs, got {avg} ({late:?})");
    }

    #[test]
    fn feedback_loop_reduces_memory_waste() {
        let mut p = policy();
        let reqs = requests_for("qr", 80, 2.0, 1.0);
        let res = simulate(SimConfig::small(), &mut p, reqs);
        let recs = res.sorted_records();
        let early_waste: f64 = recs[..20].iter().map(|r| r.wasted_mem_gb()).sum::<f64>() / 20.0;
        let late_waste: f64 =
            recs[60..].iter().map(|r| r.wasted_mem_gb()).sum::<f64>() / (recs.len() - 60) as f64;
        assert!(
            late_waste < 0.3 * early_waste,
            "memory waste must collapse after learning: early {early_waste} late {late_waste}"
        );
    }

    #[test]
    fn no_oom_kills_with_default_safeguards() {
        let mut p = policy();
        let reqs = requests_for("sentiment", 80, 2.0, 10.0);
        let res = simulate(SimConfig::small(), &mut p, reqs);
        let ooms = res
            .records
            .iter()
            .filter(|r| r.verdict == Verdict::OomKilled)
            .count();
        let pct = 100.0 * ooms as f64 / res.records.len() as f64;
        assert!(pct <= 2.0, "OOM kill rate must stay ~<1% (§7.5), got {pct}% ({ooms})");
    }

    #[test]
    fn warm_hits_accumulate_over_time() {
        let mut p = policy();
        let reqs = requests_for("encrypt", 60, 1.0, 2.0);
        let res = simulate(SimConfig::small(), &mut p, reqs);
        let cold: usize = res.records.iter().filter(|r| r.had_cold_start).count();
        assert!(
            cold < res.records.len() / 2,
            "stable workload must mostly hit warm containers: {cold}/{}",
            res.records.len()
        );
    }

    #[test]
    fn multi_threaded_gets_more_cores_for_tight_slo() {
        let fi = index_of("matmult").unwrap();
        let mut rng = Rng::new(5);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let input: InputSpec = pool[6].clone(); // larger matrix
        // SLO achievable only with many cores
        let d = (CATALOG[fi].demand)(&input);
        let slo = d.ideal_exec_s(24.0, 10.0) * 1.1;
        let reqs: Vec<Request> = (0..50)
            .map(|i| Request {
                id: i + 1,
                func: fi,
                input: input.clone(),
                arrival: i as f64 * 8.0,
                slo_s: slo,
            })
            .collect();
        let mut p = policy();
        let res = simulate(SimConfig::small(), &mut p, reqs);
        let recs = res.sorted_records();
        let late_alloc: f64 = recs[30..]
            .iter()
            .map(|r| r.requested_vcpus as f64)
            .sum::<f64>()
            / (recs.len() - 30) as f64;
        assert!(
            late_alloc >= 12.0,
            "tight SLO on a parallel function needs many cores, got {late_alloc}"
        );
        let late_viol = recs[30..].iter().filter(|r| r.slo_violated()).count();
        assert!(
            late_viol * 3 <= recs.len() - 30,
            "most late invocations should meet the SLO ({late_viol} violations)"
        );
    }
}
