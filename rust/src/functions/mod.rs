//! The serverless function catalog (paper Table 1) and the ground-truth
//! performance models the simulator executes against.
//!
//! The paper measures 12 real functions (~8K profiling runs); we encode
//! the *structure* those measurements revealed (§2, DESIGN.md §2):
//!
//! * positive but **non-linear** runtime growth with input size (Fig 2);
//! * input properties beyond size matter — `videoprocess` resolution
//!   drives vCPU *down* and memory *up* (Fig 3);
//! * single- vs multi-threaded split with **bounded parallelism** —
//!   extra vCPUs help `compress`/`resnet-50` until a plateau, never help
//!   `imageprocess`/`sentiment`/`encrypt`/`speech2text`/`qr` (Fig 4);
//! * decoupled resource natures: `videoprocess` compute-heavy,
//!   `sentiment` memory-bound (§2.3).

pub mod catalog;
pub mod inputs;

use crate::featurizer::{InputKind, InputSpec};
use crate::util::rng::Rng;

/// The resource demand of one invocation, before runtime noise.
///
/// Execution proceeds in phases (see `simulator::engine`):
/// network fetch (bandwidth-shared) → serial compute (1 vCPU) →
/// parallel compute (`min(alloc, maxpar)` vCPUs, processor-shared).
#[derive(Debug, Clone, PartialEq)]
pub struct Demand {
    /// Bytes fetched from the external datastore before compute starts.
    pub net_bytes: f64,
    /// Serial compute, CPU-seconds on one vCPU.
    pub serial_s: f64,
    /// Parallelizable compute, total CPU-seconds.
    pub parallel_cpu_s: f64,
    /// Maximum exploitable parallelism (bounded; ≥ 1).
    pub maxpar: f64,
    /// Peak memory footprint, GB (allocation-independent, §4.3.2).
    pub mem_gb: f64,
}

impl Demand {
    /// Ideal (contention-free) execution time with `alloc` vCPUs on a
    /// worker with `net_gbps` of free network bandwidth.
    pub fn ideal_exec_s(&self, alloc_vcpus: f64, net_gbps: f64) -> f64 {
        let net_s = if self.net_bytes > 0.0 {
            self.net_bytes * 8.0 / (net_gbps * 1e9)
        } else {
            0.0
        };
        let par = self.effective_parallelism(alloc_vcpus);
        net_s + self.serial_s + self.parallel_cpu_s / par
    }

    /// vCPUs actually exploited during the parallel phase.
    pub fn effective_parallelism(&self, alloc_vcpus: f64) -> f64 {
        alloc_vcpus.max(1.0).min(self.maxpar.max(1.0))
    }

    /// Total CPU-seconds consumed (serial + parallel work).
    pub fn total_cpu_s(&self) -> f64 {
        self.serial_s + self.parallel_cpu_s
    }

    /// Average vCPUs used over an ideal run (the cgroup-style number the
    /// worker daemon reports).
    pub fn avg_vcpus_used(&self, alloc_vcpus: f64, net_gbps: f64) -> f64 {
        let t = self.ideal_exec_s(alloc_vcpus, net_gbps);
        if t <= 0.0 {
            0.0
        } else {
            self.total_cpu_s() / t
        }
    }

    /// Peak vCPUs used (parallel-phase draw).
    pub fn peak_vcpus_used(&self, alloc_vcpus: f64) -> f64 {
        if self.parallel_cpu_s > 0.0 {
            self.effective_parallelism(alloc_vcpus)
        } else {
            1.0f64.min(alloc_vcpus.max(1.0))
        }
    }
}

/// Static description of one catalog function.
pub struct FunctionSpec {
    pub name: &'static str,
    pub input_kind: InputKind,
    /// Whether the function can exploit > 1 vCPU (paper §2.2 split).
    pub multi_threaded: bool,
    /// Whether inputs are fetched from an external database (network
    /// bandwidth matters — the Hermod-packing failure mode, §5).
    pub fetches_from_db: bool,
    /// Ground-truth demand model.
    pub demand: fn(&InputSpec) -> Demand,
    /// Multiplicative lognormal runtime-noise σ (grows with input size
    /// for multi-threaded functions — Fig 2c).
    pub noise_sigma: fn(&InputSpec) -> f64,
}

impl std::fmt::Debug for FunctionSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FunctionSpec")
            .field("name", &self.name)
            .field("input_kind", &self.input_kind)
            .field("multi_threaded", &self.multi_threaded)
            .finish()
    }
}

impl FunctionSpec {
    /// Demand with runtime noise applied (deterministic given the rng).
    pub fn noisy_demand(&self, input: &InputSpec, rng: &mut Rng) -> Demand {
        let base = (self.demand)(input);
        let sigma = (self.noise_sigma)(input);
        if sigma <= 0.0 {
            return base;
        }
        // One multiplicative factor for compute phases (system-level
        // variability affects the whole run), a smaller one for memory.
        let f = rng.lognormal(0.0, sigma);
        let fm = rng.lognormal(0.0, sigma * 0.25);
        Demand {
            net_bytes: base.net_bytes,
            serial_s: base.serial_s * f,
            parallel_cpu_s: base.parallel_cpu_s * f,
            maxpar: base.maxpar,
            mem_gb: base.mem_gb * fm,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demand() -> Demand {
        Demand {
            net_bytes: 1e9, // 1 GB
            serial_s: 1.0,
            parallel_cpu_s: 30.0,
            maxpar: 10.0,
            mem_gb: 1.0,
        }
    }

    #[test]
    fn ideal_exec_components() {
        let d = demand();
        // 1 GB over 10 Gb/s = 0.8 s; serial 1 s; parallel 30/10 = 3 s
        let t = d.ideal_exec_s(16.0, 10.0);
        assert!((t - (0.8 + 1.0 + 3.0)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn parallelism_bounded() {
        let d = demand();
        assert_eq!(d.effective_parallelism(4.0), 4.0);
        assert_eq!(d.effective_parallelism(64.0), 10.0);
        assert_eq!(d.effective_parallelism(0.0), 1.0);
    }

    #[test]
    fn more_vcpus_never_slower() {
        let d = demand();
        let mut prev = f64::INFINITY;
        for k in 1..=32 {
            let t = d.ideal_exec_s(k as f64, 10.0);
            assert!(t <= prev + 1e-12);
            prev = t;
        }
    }

    #[test]
    fn avg_usage_below_alloc() {
        let d = demand();
        for k in [1.0, 4.0, 16.0] {
            let used = d.avg_vcpus_used(k, 10.0);
            assert!(used <= k + 1e-9, "used {used} alloc {k}");
            assert!(used > 0.0);
        }
    }

    #[test]
    fn single_threaded_peak_is_one() {
        let d = Demand { net_bytes: 0.0, serial_s: 2.0, parallel_cpu_s: 0.0, maxpar: 1.0, mem_gb: 0.3 };
        assert_eq!(d.peak_vcpus_used(8.0), 1.0);
    }
}
