//! The 12 functions of Table 1 with calibrated ground-truth models.
//!
//! Calibration targets (paper §2, §7.1): execution times from 100s of ms
//! to a few minutes; single-threaded set {imageprocess, sentiment,
//! encrypt, speech2text, qr}; multi-threaded set {matmult, linpack,
//! videoprocess, mobilenet, lrtrain, compress, resnet-50} with bounded,
//! input-dependent parallelism; `videoprocess` resolution effect (Fig 3);
//! `sentiment` memory-bound, `videoprocess`/`matmult`/`linpack`/`lrtrain`
//! compute-bound (§2.3); `matmult`/`lrtrain`/`imageprocess` (and the other
//! image functions) fetch inputs from an external database (§5).

use super::{Demand, FunctionSpec};
use crate::featurizer::{InputKind, InputSpec};

/// Effective per-vCPU compute throughput used by the analytic models.
const GFLOPS_PER_VCPU: f64 = 0.3e9;

fn pixels(s: &InputSpec) -> f64 {
    (s.width * s.height).max(1.0)
}

// ---------------------------------------------------------------------------
// demand models
// ---------------------------------------------------------------------------

fn matmult_demand(s: &InputSpec) -> Demand {
    let n = s.rows.max(2.0);
    let flops = 2.0 * n * n * n;
    Demand {
        net_bytes: 2.0 * n * n * 8.0, // two operand matrices from the DB
        serial_s: 0.15 + n * n * 8.0 / 2.0e9,
        parallel_cpu_s: flops / GFLOPS_PER_VCPU,
        maxpar: (n / 250.0).clamp(1.0, 48.0).floor(),
        mem_gb: 0.2 + 3.0 * n * n * 8.0 / 1e9,
    }
}

fn linpack_demand(s: &InputSpec) -> Demand {
    // LU solve: 2n^3/3 flops. Input arrives as payload (problem size);
    // the function generates the system locally — no featurization, no
    // network fetch (§7.6: "linpack does not require any featurization").
    let n = s.length.max(2.0);
    let flops = 2.0 * n * n * n / 3.0;
    Demand {
        net_bytes: 0.0,
        serial_s: 0.1 + n * n * 8.0 / 4.0e9,
        parallel_cpu_s: flops / GFLOPS_PER_VCPU,
        maxpar: (n / 500.0).clamp(1.0, 32.0).floor(),
        mem_gb: 0.15 + n * n * 8.0 / 1e9,
    }
}

fn imageprocess_demand(s: &InputSpec) -> Demand {
    // Single-threaded filter chain over the decoded bitmap (Fig 4e: util
    // pinned at ~1 vCPU regardless of allocation).
    let px = pixels(s);
    Demand {
        net_bytes: s.size_bytes,
        serial_s: 0.25 + px / 6.0e6 + s.size_mb() * 0.03,
        parallel_cpu_s: 0.0,
        maxpar: 1.0,
        mem_gb: 0.12 + px * 3.0 * 8.0 / 1e9,
    }
}

fn videoprocess_demand(s: &InputSpec) -> Demand {
    // Transcode: work ∝ frames × pixels. Parallelism is inversely related
    // to per-frame resolution (Fig 3: 1280x720 inputs use *fewer* vCPUs
    // and *more* memory than low-res inputs; low-res streams split into
    // many more independent GOP chunks).
    let px = pixels(s);
    let frames = (s.duration_s * s.fps).max(1.0);
    Demand {
        net_bytes: s.size_bytes,
        serial_s: 0.3 + s.duration_s * 0.012,
        parallel_cpu_s: frames * px / 0.12e8,
        maxpar: (48.0 * (480.0 * 360.0) / px).clamp(6.0, 48.0).floor(),
        mem_gb: 0.18 + px / 1.5e6,
    }
}

fn encrypt_demand(s: &InputSpec) -> Demand {
    // Single-threaded AES over an inline string payload.
    let len = s.length.max(1.0);
    Demand {
        net_bytes: 0.0,
        serial_s: 0.1 + len * 3.0e-5,
        parallel_cpu_s: 0.0,
        maxpar: 1.0,
        mem_gb: 0.12 + len * 2.0e-9,
    }
}

fn mobilenet_demand(s: &InputSpec) -> Demand {
    // Lightweight CNN inference: intra-op parallelism saturates early.
    let px = pixels(s);
    Demand {
        net_bytes: s.size_bytes,
        serial_s: 0.18 + s.size_mb() * 0.01,
        parallel_cpu_s: 1.8 + px / 0.6e6,
        maxpar: 4.0,
        mem_gb: 0.9 + px * 12.0 / 1e9,
    }
}

fn sentiment_demand(s: &InputSpec) -> Demand {
    // Single-threaded, memory-bound (§2.3): the embedding tables + batch
    // dominate memory while compute stays on one core.
    let batch = s.length.max(1.0);
    Demand {
        net_bytes: 0.0,
        serial_s: 0.25 + batch * 1.6e-3,
        parallel_cpu_s: 0.0,
        maxpar: 1.0,
        mem_gb: 0.45 + batch * 1.1e-3,
    }
}

fn speech2text_demand(s: &InputSpec) -> Demand {
    // Single-threaded decode: runtime scales with audio duration, not
    // directly with file size (FLAC inputs are smaller but same length).
    let dur = s.duration_s.max(0.5);
    Demand {
        net_bytes: s.size_bytes,
        serial_s: 0.6 + dur * 0.35,
        parallel_cpu_s: 0.0,
        maxpar: 1.0,
        mem_gb: 0.7 + dur * 1.2e-3,
    }
}

fn qr_demand(s: &InputSpec) -> Demand {
    // QR-code render for a short url payload: fastest function (100s of ms).
    let len = s.length.max(1.0);
    Demand {
        net_bytes: 0.0,
        serial_s: 0.08 + len * 2.5e-4,
        parallel_cpu_s: 0.0,
        maxpar: 1.0,
        mem_gb: 0.1 + len * 1.0e-6,
    }
}

fn lrtrain_demand(s: &InputSpec) -> Demand {
    // Logistic-regression training epochs over a CSV training set pulled
    // from the datastore; data-parallel across cores, saturating at 16.
    let mb = s.size_mb().max(1.0);
    Demand {
        net_bytes: s.size_bytes,
        serial_s: 0.5 + mb * 0.012,
        parallel_cpu_s: mb * 14.0,
        maxpar: 16.0,
        mem_gb: 0.3 + mb / 380.0,
    }
}

fn compress_demand(s: &InputSpec) -> Demand {
    // Block-parallel compressor (zstd-like): parallelism grows with the
    // number of input blocks (Fig 4a/4c: large files scale further and
    // show higher utilization).
    let mb = s.size_mb().max(1.0);
    Demand {
        net_bytes: 0.0,
        serial_s: 0.2 + mb * 0.002,
        parallel_cpu_s: mb * 1.1,
        maxpar: (mb / 64.0).clamp(2.0, 32.0).floor(),
        mem_gb: 0.25 + mb / 1900.0,
    }
}

fn resnet50_demand(s: &InputSpec) -> Demand {
    // Heavier CNN inference than mobilenet; scales to ~8 cores (Fig 4b/4d).
    let px = pixels(s);
    Demand {
        net_bytes: s.size_bytes,
        serial_s: 0.22 + s.size_mb() * 0.012,
        parallel_cpu_s: 9.0 + px / 0.1e6,
        maxpar: 8.0,
        mem_gb: 2.1 + px * 16.0 / 1e9,
    }
}

// ---------------------------------------------------------------------------
// noise models — multi-threaded functions get size-growing variability
// (Fig 2c: compress shows ~50% spread at 2 GB); single-threaded stay tight.
// ---------------------------------------------------------------------------

fn noise_small(_s: &InputSpec) -> f64 {
    0.04
}

fn noise_medium(_s: &InputSpec) -> f64 {
    0.08
}

fn noise_compress(s: &InputSpec) -> f64 {
    0.05 + 0.13 * (s.size_mb() / 2048.0).min(1.0)
}

fn noise_matrix(s: &InputSpec) -> f64 {
    0.05 + 0.08 * (s.rows / 8000.0).min(1.0)
}

fn noise_linpack(s: &InputSpec) -> f64 {
    0.05 + 0.08 * (s.length / 8000.0).min(1.0)
}

fn noise_video(s: &InputSpec) -> f64 {
    0.06 + 0.06 * (s.size_mb() / 6.0).min(1.0)
}

// ---------------------------------------------------------------------------
// catalog
// ---------------------------------------------------------------------------

/// The full Table-1 catalog.
pub static CATALOG: &[FunctionSpec] = &[
    FunctionSpec {
        name: "matmult",
        input_kind: InputKind::Matrix,
        multi_threaded: true,
        fetches_from_db: true,
        demand: matmult_demand,
        noise_sigma: noise_matrix,
    },
    FunctionSpec {
        name: "linpack",
        input_kind: InputKind::Payload,
        multi_threaded: true,
        fetches_from_db: false,
        demand: linpack_demand,
        noise_sigma: noise_linpack,
    },
    FunctionSpec {
        name: "imageprocess",
        input_kind: InputKind::Image,
        multi_threaded: false,
        fetches_from_db: true,
        demand: imageprocess_demand,
        noise_sigma: noise_small,
    },
    FunctionSpec {
        name: "videoprocess",
        input_kind: InputKind::Video,
        multi_threaded: true,
        fetches_from_db: true,
        demand: videoprocess_demand,
        noise_sigma: noise_video,
    },
    FunctionSpec {
        name: "encrypt",
        input_kind: InputKind::Payload,
        multi_threaded: false,
        fetches_from_db: false,
        demand: encrypt_demand,
        noise_sigma: noise_small,
    },
    FunctionSpec {
        name: "mobilenet",
        input_kind: InputKind::Image,
        multi_threaded: true,
        fetches_from_db: true,
        demand: mobilenet_demand,
        noise_sigma: noise_medium,
    },
    FunctionSpec {
        name: "sentiment",
        input_kind: InputKind::Payload,
        multi_threaded: false,
        fetches_from_db: false,
        demand: sentiment_demand,
        noise_sigma: noise_small,
    },
    FunctionSpec {
        name: "speech2text",
        input_kind: InputKind::Audio,
        multi_threaded: false,
        fetches_from_db: true,
        demand: speech2text_demand,
        noise_sigma: noise_small,
    },
    FunctionSpec {
        name: "qr",
        input_kind: InputKind::Payload,
        multi_threaded: false,
        fetches_from_db: false,
        demand: qr_demand,
        noise_sigma: noise_small,
    },
    FunctionSpec {
        name: "lrtrain",
        input_kind: InputKind::Csv,
        multi_threaded: true,
        fetches_from_db: true,
        demand: lrtrain_demand,
        noise_sigma: noise_medium,
    },
    FunctionSpec {
        name: "compress",
        input_kind: InputKind::File,
        multi_threaded: true,
        fetches_from_db: false,
        demand: compress_demand,
        noise_sigma: noise_compress,
    },
    FunctionSpec {
        name: "resnet50",
        input_kind: InputKind::Image,
        multi_threaded: true,
        fetches_from_db: true,
        demand: resnet50_demand,
        noise_sigma: noise_medium,
    },
];

/// Look a function up by name.
pub fn by_name(name: &str) -> Option<&'static FunctionSpec> {
    CATALOG.iter().find(|f| f.name == name)
}

/// Index of a function in the catalog (stable across runs).
pub fn index_of(name: &str) -> Option<usize> {
    CATALOG.iter().position(|f| f.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::inputs;
    use crate::util::rng::Rng;

    #[test]
    fn twelve_functions() {
        assert_eq!(CATALOG.len(), 12);
        assert!(by_name("matmult").is_some());
        assert!(by_name("resnet50").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn single_threaded_set_matches_paper() {
        let st: Vec<&str> = CATALOG
            .iter()
            .filter(|f| !f.multi_threaded)
            .map(|f| f.name)
            .collect();
        assert_eq!(st, vec!["imageprocess", "encrypt", "sentiment", "speech2text", "qr"]);
    }

    #[test]
    fn single_threaded_have_maxpar_one() {
        let mut rng = Rng::new(1);
        for f in CATALOG.iter().filter(|f| !f.multi_threaded) {
            for input in inputs::pool(f, &mut rng) {
                let d = (f.demand)(&input);
                assert_eq!(d.maxpar, 1.0, "{}", f.name);
                assert_eq!(d.parallel_cpu_s, 0.0, "{}", f.name);
            }
        }
    }

    #[test]
    fn runtimes_in_paper_range() {
        // §7.1: execution times span 100s of ms to a few minutes.
        let mut rng = Rng::new(2);
        let mut global_min = f64::INFINITY;
        let mut global_max = 0.0f64;
        for f in CATALOG {
            for input in inputs::pool(f, &mut rng) {
                let d = (f.demand)(&input);
                // best case: 32 vCPUs, idle 10 Gb/s network
                let t = d.ideal_exec_s(32.0, 10.0);
                assert!(t > 0.02, "{} too fast: {t}", f.name);
                assert!(t < 600.0, "{} too slow even at 32 vCPUs: {t}", f.name);
                global_min = global_min.min(t);
                global_max = global_max.max(t);
            }
        }
        assert!(global_min < 0.5, "no sub-second functions: {global_min}");
        assert!(global_max > 30.0, "no multi-ten-second functions: {global_max}");
    }

    #[test]
    fn memory_footprints_reasonable() {
        let mut rng = Rng::new(3);
        for f in CATALOG {
            for input in inputs::pool(f, &mut rng) {
                let d = (f.demand)(&input);
                assert!(d.mem_gb > 0.05, "{}: {}", f.name, d.mem_gb);
                assert!(d.mem_gb < 8.0, "{}: {} GB exceeds class range", f.name, d.mem_gb);
            }
        }
    }

    #[test]
    fn videoprocess_resolution_effect() {
        // Fig 3: same-size videos — high resolution => fewer vCPUs, more
        // memory; low resolution => more vCPUs, less memory.
        let f = by_name("videoprocess").unwrap();
        let mut hi = crate::featurizer::InputSpec::new(InputKind::Video);
        hi.size_bytes = 3.8e6;
        hi.width = 1280.0;
        hi.height = 720.0;
        hi.duration_s = 20.0;
        hi.fps = 30.0;
        hi.bitrate = 8.0 * hi.size_bytes / hi.duration_s;
        let mut lo = hi.clone();
        lo.width = 320.0;
        lo.height = 240.0;
        let dh = (f.demand)(&hi);
        let dl = (f.demand)(&lo);
        assert!(dl.maxpar > 2.0 * dh.maxpar, "low-res must parallelize more: {} vs {}", dl.maxpar, dh.maxpar);
        assert!(dh.mem_gb > 1.5 * dl.mem_gb, "high-res must use more memory");
    }

    #[test]
    fn compress_parallelism_grows_with_size() {
        let f = by_name("compress").unwrap();
        let mut small = crate::featurizer::InputSpec::new(InputKind::File);
        small.size_bytes = 64e6;
        let mut large = small.clone();
        large.size_bytes = 2e9;
        let ds = (f.demand)(&small);
        let dl = (f.demand)(&large);
        assert!(dl.maxpar > ds.maxpar);
        // Fig 4a: more vCPUs keep helping the large input longer
        let t8 = dl.ideal_exec_s(8.0, 10.0);
        let t32 = dl.ideal_exec_s(32.0, 10.0);
        assert!(t32 < 0.6 * t8);
    }

    #[test]
    fn nonlinear_size_runtime_relationship() {
        // Fig 2: matmult runtime grows superlinearly in matrix dim.
        let f = by_name("matmult").unwrap();
        let mk = |n: f64| {
            let mut s = crate::featurizer::InputSpec::new(InputKind::Matrix);
            s.rows = n;
            s.cols = n;
            s.size_bytes = n * n * 8.0;
            (f.demand)(&s).ideal_exec_s(16.0, 10.0)
        };
        let t1 = mk(4000.0);
        let t2 = mk(8000.0);
        // 2x dimension => 8x flops; with allocation capped at 16 vCPUs the
        // runtime must grow far faster than the 2x a linear model predicts.
        assert!(t2 > 3.0 * t1, "superlinear expected: {t1} vs {t2}");
    }

    #[test]
    fn sentiment_memory_bound() {
        // §2.3: sentiment uses ~all memory but only 1 vCPU.
        let f = by_name("sentiment").unwrap();
        let mut s = crate::featurizer::InputSpec::new(InputKind::Payload);
        s.length = 3000.0;
        let d = (f.demand)(&s);
        assert_eq!(d.maxpar, 1.0);
        assert!(d.mem_gb > 3.0, "large batches must be memory-heavy: {}", d.mem_gb);
    }

    #[test]
    fn noise_grows_with_size_for_compress() {
        let f = by_name("compress").unwrap();
        let mut small = crate::featurizer::InputSpec::new(InputKind::File);
        small.size_bytes = 64e6;
        let mut large = small.clone();
        large.size_bytes = 2e9;
        assert!((f.noise_sigma)(&large) > 2.0 * (f.noise_sigma)(&small));
    }

    #[test]
    fn noisy_demand_deterministic_per_seed() {
        let f = by_name("compress").unwrap();
        let mut s = crate::featurizer::InputSpec::new(InputKind::File);
        s.size_bytes = 5e8;
        let d1 = f.noisy_demand(&s, &mut Rng::new(7));
        let d2 = f.noisy_demand(&s, &mut Rng::new(7));
        assert_eq!(d1, d2);
    }
}
