//! Synthetic input pools per function, mirroring Table 1 (#sizes and size
//! ranges) and the Fig-3 `videoprocess` set-1 / set-2 resolution split.
//!
//! Pools are deterministic given an [`Rng`]: experiments fork a stream per
//! function so the same `--seed` regenerates identical inputs.

use crate::featurizer::{InputKind, InputSpec};
use crate::functions::FunctionSpec;
use crate::util::rng::Rng;

/// Fresh unique datastore object ids.
fn next_id(rng: &mut Rng) -> u64 {
    // non-zero: 0 means "inline payload"
    rng.next_u64() | 1
}

/// Geometric interpolation between lo and hi with `n` points.
fn geom_steps(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 1 && hi >= lo && lo > 0.0);
    if n == 1 {
        return vec![lo];
    }
    let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
    (0..n).map(|i| lo * ratio.powi(i as i32)).collect()
}

/// Standard video resolutions used by the set-1 pool (varying) — Fig 3.
const RESOLUTIONS: &[(f64, f64)] = &[
    (320.0, 240.0),
    (480.0, 360.0),
    (640.0, 480.0),
    (960.0, 540.0),
    (1280.0, 720.0),
];

/// Build the input pool for a function per Table 1.
pub fn pool(func: &FunctionSpec, rng: &mut Rng) -> Vec<InputSpec> {
    match func.name {
        "matmult" => matrix_pool(rng, 9, 500.0, 8000.0),
        "linpack" => payload_pool(rng, 11, 500.0, 8000.0),
        "imageprocess" => image_pool(rng, 14, 12.0e3, 4.6e6),
        "videoprocess" => video_pool_set1(rng, 5),
        "encrypt" => payload_pool(rng, 7, 500.0, 50_000.0),
        "mobilenet" => image_pool(rng, 14, 12.0e3, 4.6e6),
        "sentiment" => payload_pool(rng, 12, 50.0, 3000.0),
        "speech2text" => audio_pool(rng, 8, 48.0e3, 12.0e6),
        "qr" => payload_pool(rng, 11, 25.0, 480.0),
        "lrtrain" => csv_pool(rng, 4, 10.0e6, 100.0e6),
        "compress" => file_pool(rng, 7, 64.0e6, 2.0e9),
        "resnet50" => image_pool(rng, 9, 184.0e3, 4.6e6),
        other => panic!("unknown function '{other}'"),
    }
}

pub fn matrix_pool(rng: &mut Rng, n: usize, lo_dim: f64, hi_dim: f64) -> Vec<InputSpec> {
    geom_steps(lo_dim, hi_dim, n)
        .into_iter()
        .map(|dim| {
            let dim = dim.round();
            let mut s = InputSpec::new(InputKind::Matrix);
            s.id = next_id(rng);
            s.rows = dim;
            s.cols = dim;
            s.density = rng.range_f64(0.6, 1.0);
            s.size_bytes = dim * dim * 8.0;
            s
        })
        .collect()
}

pub fn payload_pool(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<InputSpec> {
    geom_steps(lo, hi, n)
        .into_iter()
        .map(|len| {
            let mut s = InputSpec::new(InputKind::Payload);
            s.id = 0; // inline — no datastore object
            s.length = len.round();
            s.size_bytes = len.round();
            s.in_datastore = false;
            let _ = rng.next_u64(); // keep stream alignment with other pools
            s
        })
        .collect()
}

pub fn image_pool(rng: &mut Rng, n: usize, lo_bytes: f64, hi_bytes: f64) -> Vec<InputSpec> {
    geom_steps(lo_bytes, hi_bytes, n)
        .into_iter()
        .map(|bytes| {
            let mut s = InputSpec::new(InputKind::Image);
            s.id = next_id(rng);
            s.size_bytes = bytes;
            // JPEG-ish: ~0.5–2.5 bytes per pixel depending on quality
            let bpp = rng.range_f64(0.5, 2.5);
            let px = (bytes / bpp).max(64.0 * 64.0);
            let aspect = rng.range_f64(0.6, 1.8);
            s.width = (px * aspect).sqrt().round();
            s.height = (px / aspect).sqrt().round();
            s.channels = 3.0;
            s.dpi = *rng.choose(&[72.0, 96.0, 300.0]);
            s
        })
        .collect()
}

/// Fig-3 set-1: sizes span Table 1's 2.2–6.1 MB with *varying* resolution
/// (the property Cypress's size-only view misses).
pub fn video_pool_set1(rng: &mut Rng, n: usize) -> Vec<InputSpec> {
    geom_steps(2.2e6, 6.1e6, n)
        .into_iter()
        .enumerate()
        .map(|(i, bytes)| {
            // deliberately decorrelate resolution from size
            let (w, h) = RESOLUTIONS[(i * 3 + 1) % RESOLUTIONS.len()];
            make_video(rng, bytes, w, h)
        })
        .collect()
}

/// Fig-3 set-2: same size range, *constant* 1280x720 resolution.
pub fn video_pool_set2(rng: &mut Rng, n: usize) -> Vec<InputSpec> {
    geom_steps(2.2e6, 6.1e6, n)
        .into_iter()
        .map(|bytes| make_video(rng, bytes, 1280.0, 720.0))
        .collect()
}

fn make_video(rng: &mut Rng, bytes: f64, w: f64, h: f64) -> InputSpec {
    let mut s = InputSpec::new(InputKind::Video);
    s.id = next_id(rng);
    s.size_bytes = bytes;
    s.width = w;
    s.height = h;
    s.fps = *rng.choose(&[24.0, 30.0]);
    // bitrate scales with resolution; duration follows from size
    s.bitrate = 0.07 * w * h * 1.5; // bits/s, H.264-ish rule of thumb
    s.duration_s = (bytes * 8.0 / s.bitrate).clamp(5.0, 180.0);
    s.encoding = *rng.choose(&[0.0, 1.0]); // mp4 / mpeg4
    s
}

pub fn audio_pool(rng: &mut Rng, n: usize, lo_bytes: f64, hi_bytes: f64) -> Vec<InputSpec> {
    geom_steps(lo_bytes, hi_bytes, n)
        .into_iter()
        .map(|bytes| {
            let mut s = InputSpec::new(InputKind::Audio);
            s.id = next_id(rng);
            s.size_bytes = bytes;
            s.flac = rng.chance(0.3);
            s.channels = *rng.choose(&[1.0, 2.0]);
            s.sample_rate = *rng.choose(&[16_000.0, 44_100.0]);
            // FLAC ~4x denser than wav-ish PCM at same duration
            let bits_per_s = if s.flac { 320_000.0 } else { 128_000.0 };
            s.bitrate = bits_per_s;
            s.duration_s = (bytes * 8.0 / bits_per_s).clamp(1.0, 900.0);
            s
        })
        .collect()
}

pub fn csv_pool(rng: &mut Rng, n: usize, lo_bytes: f64, hi_bytes: f64) -> Vec<InputSpec> {
    geom_steps(lo_bytes, hi_bytes, n)
        .into_iter()
        .map(|bytes| {
            let mut s = InputSpec::new(InputKind::Csv);
            s.id = next_id(rng);
            s.size_bytes = bytes;
            s.cols = rng.range_f64(8.0, 64.0).round();
            // ~10 bytes per numeric cell
            s.rows = (bytes / (s.cols * 10.0)).round();
            s
        })
        .collect()
}

pub fn file_pool(rng: &mut Rng, n: usize, lo_bytes: f64, hi_bytes: f64) -> Vec<InputSpec> {
    geom_steps(lo_bytes, hi_bytes, n)
        .into_iter()
        .map(|bytes| {
            let mut s = InputSpec::new(InputKind::File);
            s.id = next_id(rng);
            s.size_bytes = bytes;
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::catalog::CATALOG;

    #[test]
    fn pool_sizes_match_table1() {
        let expect: &[(&str, usize)] = &[
            ("matmult", 9),
            ("linpack", 11),
            ("imageprocess", 14),
            ("videoprocess", 5),
            ("encrypt", 7),
            ("mobilenet", 14),
            ("sentiment", 12),
            ("speech2text", 8),
            ("qr", 11),
            ("lrtrain", 4),
            ("compress", 7),
            ("resnet50", 9),
        ];
        for (name, n) in expect {
            let f = crate::functions::catalog::by_name(name).unwrap();
            let mut rng = Rng::new(1);
            assert_eq!(pool(f, &mut rng).len(), *n, "{name}");
        }
    }

    #[test]
    fn pools_deterministic() {
        for f in CATALOG {
            let a = pool(f, &mut Rng::new(9));
            let b = pool(f, &mut Rng::new(9));
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id, "{}", f.name);
                assert_eq!(x.size_bytes, y.size_bytes, "{}", f.name);
            }
        }
    }

    #[test]
    fn sizes_within_table1_ranges() {
        let f = crate::functions::catalog::by_name("compress").unwrap();
        let p = pool(f, &mut Rng::new(3));
        assert!(p.iter().all(|s| (64.0e6..=2.01e9).contains(&s.size_bytes)));
        let f = crate::functions::catalog::by_name("speech2text").unwrap();
        let p = pool(f, &mut Rng::new(3));
        assert!(p.iter().all(|s| (48.0e3..=12.1e6).contains(&s.size_bytes)));
    }

    #[test]
    fn geom_steps_cover_range() {
        let v = geom_steps(1.0, 100.0, 3);
        assert!((v[0] - 1.0).abs() < 1e-9);
        assert!((v[1] - 10.0).abs() < 1e-6);
        assert!((v[2] - 100.0).abs() < 1e-6);
    }

    #[test]
    fn set1_resolutions_vary_set2_constant() {
        let mut rng = Rng::new(4);
        let s1 = video_pool_set1(&mut rng, 5);
        let s2 = video_pool_set2(&mut rng, 5);
        let distinct1: std::collections::BTreeSet<u64> =
            s1.iter().map(|v| (v.width * v.height) as u64).collect();
        assert!(distinct1.len() >= 3, "set-1 must vary resolution");
        assert!(s2.iter().all(|v| v.width == 1280.0 && v.height == 720.0));
    }

    #[test]
    fn payload_inputs_are_inline() {
        let f = crate::functions::catalog::by_name("qr").unwrap();
        for s in pool(f, &mut Rng::new(5)) {
            assert_eq!(s.id, 0);
            assert!(!s.in_datastore);
        }
    }

    #[test]
    fn datastore_inputs_have_ids() {
        let f = crate::functions::catalog::by_name("imageprocess").unwrap();
        let p = pool(f, &mut Rng::new(6));
        assert!(p.iter().all(|s| s.id != 0));
        let ids: std::collections::BTreeSet<u64> = p.iter().map(|s| s.id).collect();
        assert_eq!(ids.len(), p.len(), "ids must be unique");
    }
}
