//! `experiment scenarios` — the cross-scenario robustness matrix
//! (DESIGN.md §Scenarios): every Fig-8 system × every registered workload
//! scenario at a fixed load, replicated across `Ctx::seeds` seeds on
//! `Ctx::jobs` threads. Where Fig 8 asks "who wins under the Azure-like
//! shape", this asks whether the ranking *survives* diurnal swing, flash
//! crowds, Zipf-skewed popularity, and real-trace replay — the workload
//! regimes where variance conclusions are known to flip (Wen et al.) and
//! underutilization peaks (Fifer).

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};
use crate::workload::scenario::SCENARIOS;

use super::common::{perf_json, run_cell, Ctx};
use super::e2e::FIG8_POLICIES;
use super::sweep::{self, Cell, CellOutcome};

/// Load for the robustness matrix (mid-range: every system still admits
/// the trace, but allocation quality separates them).
pub const MATRIX_RPS: f64 = 4.0;

/// Cell label carrying the scenario name (salts replicate seeds, so the
/// same policy under two scenarios samples disjoint RNG streams at
/// replicates ≥ 1 while replicate 0 stays grid-wide paired).
fn cell_label(scenario: &str) -> String {
    format!("scenario:{scenario}")
}

fn cell_scenario(cell: &Cell) -> &str {
    cell.label.strip_prefix("scenario:").unwrap_or(&cell.label)
}

/// The matrix's scenario columns: the registered names, with the
/// `trace-file` column honoring a user-supplied `trace-file:<path>` from
/// `--scenario` (the only parameterizable scenario — the matrix spans
/// *all* shapes by design, so any other `--scenario` value is already one
/// of its columns).
fn matrix_scenarios(ctx: &Ctx) -> Vec<String> {
    SCENARIOS
        .iter()
        .map(|s| {
            if *s == "trace-file" && ctx.scenario.starts_with("trace-file:") {
                ctx.scenario.clone()
            } else {
                (*s).to_string()
            }
        })
        .collect()
}

/// Run the full policy × scenario grid; outcome
/// `[pi * SCENARIOS.len() + si]` holds `FIG8_POLICIES[pi]` under
/// `SCENARIOS[si]` with all per-seed metrics.
pub fn run_matrix(ctx: &Ctx, rps: f64) -> Result<Vec<CellOutcome<RunMetrics>>> {
    let scenarios = matrix_scenarios(ctx);
    let cells: Vec<Cell> = FIG8_POLICIES
        .iter()
        .flat_map(|p| {
            scenarios.iter().map(move |s| Cell::labeled(p, rps, &cell_label(s), 0.0))
        })
        .collect();
    sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_cell(&cell.policy, &ctx.with_scenario(cell_scenario(cell)), cell.rps, seed)
    })
}

pub fn scenarios(ctx: &Ctx) -> Result<()> {
    // lint:allow(D002): host wall time for the runner's wall-clock report line only
    let t0 = std::time::Instant::now();
    let outcomes = run_matrix(ctx, MATRIX_RPS)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "(robustness matrix: {} cells x {} seed(s) on {} job(s), {:.1}s wall)",
        outcomes.len(),
        ctx.seeds,
        ctx.jobs,
        wall
    );
    if ctx.scenario.starts_with("trace-file:") {
        println!("(trace-file column replays --scenario {})", ctx.scenario);
    } else if ctx.scenario != "azure-synthetic" {
        println!(
            "(note: the matrix always spans all scenarios — --scenario {} is \
             already one of its columns)",
            ctx.scenario
        );
    }

    let ns = SCENARIOS.len();
    // tables keep the short registry names for width; the JSON artifact
    // records the substituted names (incl. a user trace-file path) so a
    // saved dump stays self-describing
    let scenario_names = matrix_scenarios(ctx);
    let header: Vec<&str> =
        std::iter::once("system").chain(SCENARIOS.iter().copied()).collect();

    let mut t = Table::new(
        &format!("Scenarios — % SLO violations, mean [95% CI] (RPS {MATRIX_RPS})"),
        &header,
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for si in 0..ns {
            row.push(outcomes[pi * ns + si].stat(|m| m.slo_violation_pct).fmt_ci(1));
        }
        t.row(row);
    }
    t.note("CI = percentile bootstrap over seeds; widen --seeds to tighten");
    t.print();

    let mut t = Table::new(
        "Scenarios — wasted memory GB per invocation (p50, cross-seed mean)",
        &header,
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for si in 0..ns {
            row.push(fnum(outcomes[pi * ns + si].mean_metrics().wasted_mem_gb.p50, 2));
        }
        t.row(row);
    }
    t.print();

    let mut t = Table::new("Scenarios — cold starts % (cross-seed mean)", &header);
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for si in 0..ns {
            row.push(fpct(outcomes[pi * ns + si].mean_metrics().cold_start_pct));
        }
        t.row(row);
    }
    t.note("flash-crowd exceeds the nominal RPS by design (step burst is extra load)");
    t.print();

    // machine-readable dump for cross-scenario plotting
    let policies = Json::Arr(
        FIG8_POLICIES
            .iter()
            .enumerate()
            .map(|(pi, name)| {
                Json::obj(vec![
                    ("policy", Json::Str(name.to_string())),
                    (
                        "scenarios",
                        Json::Arr(
                            scenario_names
                                .iter()
                                .enumerate()
                                .map(|(si, s)| {
                                    let out = &outcomes[pi * ns + si];
                                    let viol = out.stat(|m| m.slo_violation_pct);
                                    let m = out.mean_metrics();
                                    Json::obj(vec![
                                        ("scenario", Json::Str(s.clone())),
                                        ("slo_violation_pct_mean", Json::Num(viol.mean)),
                                        ("slo_violation_pct_ci95_lo", Json::Num(viol.ci95.0)),
                                        ("slo_violation_pct_ci95_hi", Json::Num(viol.ci95.1)),
                                        ("wasted_mem_gb_p50", Json::Num(m.wasted_mem_gb.p50)),
                                        ("wasted_vcpus_p50", Json::Num(m.wasted_vcpus.p50)),
                                        ("cold_start_pct", Json::Num(m.cold_start_pct)),
                                        ("invocations", Json::Num(m.invocations as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    let dump =
        Json::obj(vec![("perf", perf_json(wall, &outcomes)), ("policies", policies)]);
    std::fs::create_dir_all("out").ok();
    match std::fs::write("out/scenarios.json", dump.to_pretty()) {
        Ok(()) => println!("(dumped out/scenarios.json)"),
        Err(e) => eprintln!("warning: could not write out/scenarios.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_covers_every_policy_scenario_pair() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let outcomes = run_matrix(&ctx, 2.0).unwrap();
        assert_eq!(outcomes.len(), FIG8_POLICIES.len() * SCENARIOS.len());
        for (pi, policy) in FIG8_POLICIES.iter().enumerate() {
            for (si, scenario) in SCENARIOS.iter().enumerate() {
                let out = &outcomes[pi * SCENARIOS.len() + si];
                assert_eq!(out.cell.policy, *policy);
                assert_eq!(cell_scenario(&out.cell), *scenario);
                assert!(out.per_seed.iter().all(|m| m.invocations > 0));
            }
        }
    }

    #[test]
    fn matrix_honors_a_user_trace_file_path() {
        let ctx = Ctx {
            scenario: "trace-file:data/azure_sample.csv".to_string(),
            ..Default::default()
        };
        let names = matrix_scenarios(&ctx);
        assert_eq!(names.len(), SCENARIOS.len());
        assert!(names.contains(&"trace-file:data/azure_sample.csv".to_string()));
        assert!(!names.contains(&"trace-file".to_string()), "column substituted");
        // non-trace-file --scenario values are already matrix columns
        let plain = matrix_scenarios(&Ctx::default());
        assert_eq!(plain, SCENARIOS.iter().map(|s| s.to_string()).collect::<Vec<_>>());
        let diurnal = matrix_scenarios(&Ctx::default().with_scenario("diurnal"));
        assert_eq!(diurnal, plain);
    }

    #[test]
    fn scenario_cells_occupy_distinct_seed_streams() {
        let a = Cell::labeled("shabari", 4.0, &cell_label("diurnal"), 0.0);
        let b = Cell::labeled("shabari", 4.0, &cell_label("flash-crowd"), 0.0);
        assert_ne!(sweep::cell_seed(42, &a, 1), sweep::cell_seed(42, &b, 1));
        // replicate 0 is the shared paired-comparison world
        assert_eq!(sweep::cell_seed(42, &a, 0), sweep::cell_seed(42, &b, 0));
    }

    #[test]
    fn scenarios_actually_change_outcomes() {
        // the same policy under azure-synthetic vs flash-crowd must not
        // collapse to identical runs (the matrix would be vacuous)
        let ctx = Ctx { duration_s: 120.0, ..Default::default() };
        let outcomes = run_matrix(&ctx, 3.0).unwrap();
        let ns = SCENARIOS.len();
        let azure = &outcomes[0]; // FIG8_POLICIES[0] under azure-synthetic
        let flash = &outcomes[2]; // ... under flash-crowd
        assert_ne!(
            azure.per_seed[0].invocations, flash.per_seed[0].invocations,
            "flash-crowd burst load must differ from the base process"
        );
        assert_eq!(ns, 5, "matrix must span all five registered scenarios");
    }
}
