//! Figure 14 — Shabari's overheads, measured on the real clock (not
//! simulated): input featurization per function, model prediction and
//! update (native + XLA paths), scheduler decision latency.
//!
//! This is the one experiment that deliberately runs its cells at
//! `jobs = 1` through the sweep harness: concurrent cells would contend
//! for cores and corrupt the wall-clock latencies being measured
//! (EXPERIMENTS.md §Perf). Each featurization cell still forks its own
//! deterministic RNG so the grid is order-independent.

use anyhow::Result;

use crate::coordinator::scheduler::shabari::ShabariScheduler;
use crate::coordinator::scheduler::Scheduler;
use crate::featurizer::{self, InputSpec};
use crate::functions::catalog::{index_of, CATALOG};
use crate::functions::inputs;
use crate::learner::xla::{Backend, ModelFactory};
use crate::learner::{cost_vector, CsmcModel};
use crate::runtime::{FEAT_DIM, NUM_CLASSES};
use crate::simulator::worker::Cluster;
use crate::simulator::{Request, SimConfig};
use crate::util::bench;
use crate::util::rng::Rng;
use crate::util::table::Table;

use super::common::Ctx;

fn measure_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    // light warmup
    for _ in 0..iters.min(16) {
        f();
    }
    // lint:allow(D002): host wall time for the runner's wall-clock report line only
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1000.0 / iters as f64
}

/// Real featurization compute (metadata math) per function's input type.
/// The *modeled* critical-path cost (file-open latencies on the paper's
/// testbed) is reported alongside from `featurizer::extract`.
pub fn fig14(ctx: &Ctx) -> Result<()> {
    let mut t = Table::new(
        "Fig 14 — featurization cost per function",
        &["function", "input type", "modeled latency (ms)", "measured compute (µs)"],
    );
    // jobs = 1: wall-clock micro-measurements must not share cores.
    let func_indices: Vec<usize> = (0..CATALOG.len()).collect();
    let rows = crate::experiments::sweep::parallel_map(&func_indices, 1, |_, &fi| {
        let spec = &CATALOG[fi];
        let mut rng = Rng::new(ctx.seed ^ crate::util::rng::fnv1a(spec.name.as_bytes()));
        let pool = inputs::pool(spec, &mut rng);
        let input: InputSpec = pool[pool.len() / 2].clone();
        let modeled = featurizer::featurize(&input).extract_latency_s * 1000.0;
        let measured_us =
            measure_ms(2000, || {
                bench::keep(featurizer::featurize(&input));
            }) * 1000.0;
        vec![
            spec.name.to_string(),
            spec.input_kind.name().to_string(),
            format!("{modeled:.3}"),
            format!("{measured_us:.2}"),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t.note("paper: matmult/lrtrain 20-35ms (file opens); images ~0.13ms; linpack ~0");
    t.print();

    // learner predict / update
    let mut t = Table::new(
        "Fig 14 — model predict/update latency",
        &["backend", "predict (ms)", "update (ms)"],
    );
    let mut x = [0f32; FEAT_DIM];
    for (j, v) in x.iter_mut().enumerate() {
        *v = ((j + 1) as f32 * 0.13).sin();
    }
    let costs = cost_vector(12, 2.0);

    let native_factory = ModelFactory::new(Backend::Native, &ctx.artifacts_dir, 0.3)?;
    let mut nm = native_factory.make();
    let p_native = measure_ms(5000, || {
        bench::keep(nm.scores(&x));
    });
    let u_native = measure_ms(5000, || {
        nm.update(&x, &costs);
    });
    t.row(vec!["native".into(), format!("{p_native:.4}"), format!("{u_native:.4}")]);

    let have_xla = cfg!(feature = "xla")
        && std::path::Path::new(&ctx.artifacts_dir).join("manifest.json").exists();
    if have_xla {
        let xla_factory = ModelFactory::new(Backend::Xla, &ctx.artifacts_dir, 0.3)?;
        let mut xm = xla_factory.make();
        let p_xla = measure_ms(500, || {
            bench::keep(xm.scores(&x));
        });
        let u_xla = measure_ms(500, || {
            xm.update(&x, &costs);
        });
        t.row(vec!["xla/pjrt".into(), format!("{p_xla:.4}"), format!("{u_xla:.4}")]);
    } else {
        t.row(vec!["xla/pjrt".into(), "(needs artifacts + xla feature)".into(), "-".into()]);
    }
    t.note("paper: prediction 2-4ms, update 4-5ms (updates off the critical path)");
    t.print();

    // scheduler decision
    let cfg = SimConfig::default();
    let cluster = Cluster::new(&cfg);
    let mut sched = ShabariScheduler::new(ctx.seed);
    let req = Request {
        id: 1,
        func: index_of("qr").unwrap(),
        input: InputSpec::new(crate::featurizer::InputKind::Payload),
        arrival: 0.0,
        slo_s: 1.0,
    };
    let s_ms = measure_ms(5000, || {
        bench::keep(sched.schedule(&req, 4, 512, &cluster));
    });
    let mut t = Table::new("Fig 14 — scheduler decision latency", &["scheduler", "decision (ms)"]);
    t.row(vec!["shabari".into(), format!("{s_ms:.4}")]);
    t.note("paper: 0.5-1.5 ms on a 16-invoker cluster");
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_overheads_sane() {
        // native predict must be far under a millisecond; scheduler under
        // 1 ms on an empty cluster
        let mut x = [0.1f32; FEAT_DIM];
        x[0] = 1.0;
        let f = ModelFactory::new(Backend::Native, "artifacts", 0.3).unwrap();
        let mut m = f.make();
        let p = measure_ms(2000, || {
            bench::keep(m.scores(&x));
        });
        assert!(p < 1.0, "native predict {p} ms");
        let _ = NUM_CLASSES;
    }
}
