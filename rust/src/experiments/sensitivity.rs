//! Sensitivity analyses (§7.5): Figure 11 (vCPU oversubscription limit),
//! Figure 12 (confidence thresholds), Figure 13 (SLO multiplier).

use anyhow::Result;

use crate::coordinator::allocator::ResourceAllocator;
use crate::coordinator::scheduler::shabari::ShabariScheduler;
use crate::coordinator::ShabariPolicy;
use crate::metrics::from_result;
use crate::simulator::engine::simulate;
use crate::util::table::{fnum, fpct, Table};

use super::common::{sim_config, Ctx};

/// Figure 11: vCPU oversubscription limit (`userCpu`) sweep at RPS 6.
pub fn fig11(ctx: &Ctx) -> Result<()> {
    let workload = ctx.workload();
    let mut t = Table::new(
        "Fig 11 — vCPU oversubscription limit per worker (RPS 6)",
        &["userCpu", "SLO viol %", "timeout %", "p50 util %"],
    );
    for limit in [70.0, 80.0, 90.0, 100.0, 110.0, 120.0, 130.0] {
        let mut cfg = sim_config(ctx);
        cfg.sched_vcpu_limit = limit;
        let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
        let mut policy =
            ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(ctx.seed)));
        let trace = workload.trace(6.0, ctx.duration_s, ctx.seed + 6);
        let res = simulate(cfg, &mut policy, trace);
        let m = from_result("shabari", &res);
        t.row(vec![
            fnum(limit, 0),
            fpct(m.slo_violation_pct),
            fpct(m.timeout_pct),
            fpct(100.0 * m.vcpu_utilization.p50),
        ]);
    }
    t.note("paper: raising above ~#cores stops helping; 130 causes ~5% timeouts");
    t.print();
    Ok(())
}

/// Figure 12: confidence-threshold sweeps — (a) vCPU threshold vs SLO
/// violations, (b) memory threshold vs OOM-kill %.
pub fn fig12(ctx: &Ctx) -> Result<()> {
    let workload = ctx.workload();
    let mut t = Table::new(
        "Fig 12a — vCPU confidence threshold (RPS 4)",
        &["threshold", "SLO viol %", "p95 wasted vCPUs"],
    );
    for threshold in [2u64, 5, 10, 16, 24] {
        let mut acfg = ctx.allocator_cfg();
        acfg.vcpu_confidence = threshold;
        let alloc = ResourceAllocator::new(acfg)?;
        let mut policy =
            ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(ctx.seed)));
        let trace = workload.trace(4.0, ctx.duration_s, ctx.seed + 4);
        let res = simulate(sim_config(ctx), &mut policy, trace);
        let m = from_result("shabari", &res);
        t.row(vec![
            threshold.to_string(),
            fpct(m.slo_violation_pct),
            fnum(m.wasted_vcpus.p95, 1),
        ]);
    }
    t.note("larger thresholds keep more invocations on the 16-vCPU default (interference)");
    t.print();

    let mut t = Table::new(
        "Fig 12b — memory confidence threshold (RPS 4)",
        &["threshold", "OOM-killed %", "p50 wasted mem (GB)"],
    );
    for threshold in [5u64, 10, 20, 30] {
        let mut acfg = ctx.allocator_cfg();
        acfg.mem_confidence = threshold;
        let alloc = ResourceAllocator::new(acfg)?;
        let mut policy =
            ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(ctx.seed)));
        let trace = workload.trace(4.0, ctx.duration_s, ctx.seed + 4);
        let res = simulate(sim_config(ctx), &mut policy, trace);
        let m = from_result("shabari", &res);
        t.row(vec![
            threshold.to_string(),
            fpct(m.oom_pct),
            fnum(m.wasted_mem_gb.p50, 2),
        ]);
    }
    t.note("paper: <1% kills at threshold >= 20");
    t.print();
    Ok(())
}

/// Figure 13: SLO-multiplier sweep (1.2x–1.8x) — violations + idle vCPUs.
pub fn fig13(ctx: &Ctx) -> Result<()> {
    let mut t = Table::new(
        "Fig 13 — SLO multiplier sensitivity (RPS 4)",
        &["multiplier", "SLO viol %", "idle vCPUs p50", "idle vCPUs p95"],
    );
    for mult in [1.2, 1.4, 1.6, 1.8] {
        let mut mctx = ctx.clone();
        mctx.slo_multiplier = mult;
        let workload = mctx.workload();
        let alloc = ResourceAllocator::new(mctx.allocator_cfg())?;
        let mut policy =
            ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(mctx.seed)));
        let trace = workload.trace(4.0, mctx.duration_s, mctx.seed + 4);
        let res = simulate(sim_config(&mctx), &mut policy, trace);
        let m = from_result("shabari", &res);
        t.row(vec![
            format!("{mult:.1}x"),
            fpct(m.slo_violation_pct),
            fnum(m.wasted_vcpus.p50, 1),
            fnum(m.wasted_vcpus.p95, 1),
        ]);
    }
    t.note("stricter SLOs violate more; median idle vCPUs stays flat (§7.5)");
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::allocator::AllocatorConfig;
    use crate::learner::xla::Backend;

    fn quick_ctx() -> Ctx {
        Ctx { duration_s: 240.0, backend: Backend::Native, ..Default::default() }
    }

    #[test]
    fn oversubscription_extremes() {
        // 130 userCpu must produce at least as many timeouts as 90
        let ctx = quick_ctx();
        let workload = ctx.workload();
        let run = |limit: f64| {
            let mut cfg = sim_config(&ctx);
            cfg.sched_vcpu_limit = limit;
            let alloc = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
            let mut policy =
                ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(1)));
            let trace = workload.trace(6.0, ctx.duration_s, 99);
            let res = simulate(cfg, &mut policy, trace);
            from_result("s", &res)
        };
        let m90 = run(90.0);
        let m130 = run(130.0);
        assert!(m130.timeout_pct >= m90.timeout_pct);
    }

    #[test]
    fn stricter_slo_more_violations() {
        let base = quick_ctx();
        let run = |mult: f64| {
            let mut ctx = base.clone();
            ctx.slo_multiplier = mult;
            let w = ctx.workload();
            let alloc = ResourceAllocator::new(ctx.allocator_cfg()).unwrap();
            let mut p = ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(1)));
            let trace = w.trace(4.0, ctx.duration_s, 77);
            let res = simulate(sim_config(&ctx), &mut p, trace);
            from_result("s", &res).slo_violation_pct
        };
        let strict = run(1.2);
        let relaxed = run(1.8);
        assert!(
            strict >= relaxed,
            "stricter SLOs must violate at least as much: 1.2x {strict} vs 1.8x {relaxed}"
        );
    }
}
