//! Sensitivity analyses (§7.5): Figure 11 (vCPU oversubscription limit),
//! Figure 12 (confidence thresholds), Figure 13 (SLO multiplier).
//!
//! These are the config-override grids of the sweep harness (DESIGN.md
//! §4): each cell carries its override in `Cell::param`, the runner
//! applies it to a fresh per-seed context, and the override value salts
//! the derived seeds of replicates ≥ 1 (replicate 0 shares the base seed
//! grid-wide for paired comparison — see `sweep::cell_seed`).

use anyhow::Result;

use crate::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use crate::coordinator::scheduler::shabari::ShabariScheduler;
use crate::coordinator::ShabariPolicy;
use crate::metrics::{from_result, RunMetrics};
use crate::simulator::engine::simulate;
use crate::simulator::SimConfig;
use crate::util::table::{fnum, fpct, Table};

use super::common::{sim_config, trace_seed, Ctx};
use super::sweep::{self, Cell};

/// One Shabari run with a per-cell override hook — the single runner
/// behind all three sensitivity grids. The hook sees the derived
/// context, the simulator config, and the allocator config, so any of
/// the paper's §7.5 knobs can be swept without duplicating the
/// build-workload → build-policy → trace → simulate sequence.
fn run_shabari_cell(
    ctx: &Ctx,
    cell: &Cell,
    seed: u64,
    tweak: impl Fn(&mut Ctx, &mut SimConfig, &mut AllocatorConfig, f64),
) -> Result<RunMetrics> {
    // This runner hardcodes the Shabari policy; a cell naming any other
    // policy would silently simulate the wrong system (use
    // `common::run_cell`/`make_policy` for multi-policy grids).
    anyhow::ensure!(
        cell.policy == "shabari",
        "run_shabari_cell only runs 'shabari' cells, got '{}'",
        cell.policy
    );
    let mut cctx = ctx.with_seed(seed);
    let mut cfg = sim_config(&cctx);
    let mut acfg = cctx.allocator_cfg();
    tweak(&mut cctx, &mut cfg, &mut acfg, cell.param);
    let workload = cctx.workload();
    let alloc = ResourceAllocator::new(acfg)?;
    let mut policy = ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(cctx.seed)));
    let scenario = cctx.build_scenario()?;
    let trace = workload.trace_with(
        scenario.as_ref(),
        cell.rps,
        cctx.duration_s,
        trace_seed(&cctx, cell.rps),
    );
    let res = simulate(cfg, &mut policy, trace);
    Ok(from_result("shabari", &res))
}

/// Figure 11: vCPU oversubscription limit (`userCpu`) sweep at RPS 6.
pub fn fig11(ctx: &Ctx) -> Result<()> {
    let limits = [70.0, 80.0, 90.0, 100.0, 110.0, 120.0, 130.0];
    let cells: Vec<Cell> =
        limits.iter().map(|&l| Cell::labeled("shabari", 6.0, "userCpu", l)).collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_shabari_cell(ctx, cell, seed, |_, cfg, _, limit| cfg.sched_vcpu_limit = limit)
    })?;
    let mut t = Table::new(
        &format!("Fig 11 — vCPU oversubscription limit per worker (RPS 6, {} seed(s))", ctx.seeds),
        &["userCpu", "SLO viol %", "timeout %", "p50 util %"],
    );
    for (out, &limit) in outcomes.iter().zip(&limits) {
        let m = out.mean_metrics();
        t.row(vec![
            fnum(limit, 0),
            fpct(m.slo_violation_pct),
            fpct(m.timeout_pct),
            fpct(100.0 * m.vcpu_utilization.p50),
        ]);
    }
    t.note("paper: raising above ~#cores stops helping; 130 causes ~5% timeouts");
    t.print();
    Ok(())
}

/// Figure 12: confidence-threshold sweeps — (a) vCPU threshold vs SLO
/// violations, (b) memory threshold vs OOM-kill %.
pub fn fig12(ctx: &Ctx) -> Result<()> {
    let vcpu_thresholds = [2.0, 5.0, 10.0, 16.0, 24.0];
    let cells: Vec<Cell> = vcpu_thresholds
        .iter()
        .map(|&th| Cell::labeled("shabari", 4.0, "vcpu-confidence", th))
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_shabari_cell(ctx, cell, seed, |_, _, acfg, th| acfg.vcpu_confidence = th as u64)
    })?;
    let mut t = Table::new(
        &format!("Fig 12a — vCPU confidence threshold (RPS 4, {} seed(s))", ctx.seeds),
        &["threshold", "SLO viol %", "p95 wasted vCPUs"],
    );
    for (out, &th) in outcomes.iter().zip(&vcpu_thresholds) {
        let m = out.mean_metrics();
        t.row(vec![
            fnum(th, 0),
            fpct(m.slo_violation_pct),
            fnum(m.wasted_vcpus.p95, 1),
        ]);
    }
    t.note("larger thresholds keep more invocations on the 16-vCPU default (interference)");
    t.print();

    let mem_thresholds = [5.0, 10.0, 20.0, 30.0];
    let cells: Vec<Cell> = mem_thresholds
        .iter()
        .map(|&th| Cell::labeled("shabari", 4.0, "mem-confidence", th))
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_shabari_cell(ctx, cell, seed, |_, _, acfg, th| acfg.mem_confidence = th as u64)
    })?;
    let mut t = Table::new(
        &format!("Fig 12b — memory confidence threshold (RPS 4, {} seed(s))", ctx.seeds),
        &["threshold", "OOM-killed %", "p50 wasted mem (GB)"],
    );
    for (out, &th) in outcomes.iter().zip(&mem_thresholds) {
        let m = out.mean_metrics();
        t.row(vec![fnum(th, 0), fpct(m.oom_pct), fnum(m.wasted_mem_gb.p50, 2)]);
    }
    t.note("paper: <1% kills at threshold >= 20");
    t.print();
    Ok(())
}

/// Figure 13: SLO-multiplier sweep (1.2x–1.8x) — violations + idle vCPUs.
pub fn fig13(ctx: &Ctx) -> Result<()> {
    let multipliers = [1.2, 1.4, 1.6, 1.8];
    let cells: Vec<Cell> = multipliers
        .iter()
        .map(|&m| Cell::labeled("shabari", 4.0, "slo-multiplier", m))
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_shabari_cell(ctx, cell, seed, |cctx, _, _, mult| cctx.slo_multiplier = mult)
    })?;
    let mut t = Table::new(
        &format!("Fig 13 — SLO multiplier sensitivity (RPS 4, {} seed(s))", ctx.seeds),
        &["multiplier", "SLO viol %", "idle vCPUs p50", "idle vCPUs p95"],
    );
    for (out, &mult) in outcomes.iter().zip(&multipliers) {
        let m = out.mean_metrics();
        t.row(vec![
            format!("{mult:.1}x"),
            fpct(m.slo_violation_pct),
            fnum(m.wasted_vcpus.p50, 1),
            fnum(m.wasted_vcpus.p95, 1),
        ]);
    }
    t.note("stricter SLOs violate more; median idle vCPUs stays flat (§7.5)");
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::allocator::AllocatorConfig;
    use crate::learner::xla::Backend;

    fn quick_ctx() -> Ctx {
        Ctx { duration_s: 240.0, backend: Backend::Native, ..Default::default() }
    }

    #[test]
    fn oversubscription_extremes() {
        // 130 userCpu must produce at least as many timeouts as 90
        let ctx = quick_ctx();
        let workload = ctx.workload();
        let run = |limit: f64| {
            let mut cfg = sim_config(&ctx);
            cfg.sched_vcpu_limit = limit;
            let alloc = ResourceAllocator::new(AllocatorConfig::default()).unwrap();
            let mut policy =
                ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(1)));
            let trace = workload.trace(6.0, ctx.duration_s, 99);
            let res = simulate(cfg, &mut policy, trace);
            from_result("s", &res)
        };
        let m90 = run(90.0);
        let m130 = run(130.0);
        assert!(m130.timeout_pct >= m90.timeout_pct);
    }

    #[test]
    fn stricter_slo_more_violations() {
        let base = quick_ctx();
        let run = |mult: f64| {
            let mut ctx = base.clone();
            ctx.slo_multiplier = mult;
            let w = ctx.workload();
            let alloc = ResourceAllocator::new(ctx.allocator_cfg()).unwrap();
            let mut p = ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(1)));
            let trace = w.trace(4.0, ctx.duration_s, 77);
            let res = simulate(sim_config(&ctx), &mut p, trace);
            from_result("s", &res).slo_violation_pct
        };
        let strict = run(1.2);
        let relaxed = run(1.8);
        assert!(
            strict >= relaxed,
            "stricter SLOs must violate at least as much: 1.2x {strict} vs 1.8x {relaxed}"
        );
    }

    #[test]
    fn override_cells_apply_their_param() {
        // A tiny two-point userCpu grid must run and stay deterministic
        // across job counts.
        let ctx = Ctx { duration_s: 60.0, seeds: 2, jobs: 4, ..Default::default() };
        // userCpu = 8 cannot admit the 16-vCPU learning-phase default
        // anywhere (every placement falls back), so its outcomes must
        // diverge from an unconstrained 130-vCPU cluster.
        let cells = vec![
            Cell::labeled("shabari", 4.0, "userCpu", 8.0),
            Cell::labeled("shabari", 4.0, "userCpu", 130.0),
        ];
        let run = |jobs: usize| {
            sweep::run_cells(&cells, ctx.seed, ctx.seeds, jobs, |cell, seed| {
                run_shabari_cell(&ctx, cell, seed, |_, cfg, _, l| cfg.sched_vcpu_limit = l)
            })
            .unwrap()
            .iter()
            .map(|o| {
                let m = o.mean_metrics();
                (m.slo_violation_pct.to_bits(), m.mean_e2e_s.to_bits())
            })
            .collect::<Vec<_>>()
        };
        let sequential = run(1);
        assert_eq!(sequential, run(4), "aggregates identical at any job count");
        // and the override must actually reach the simulator: an over- vs
        // under-subscribed cluster cannot behave identically
        assert_ne!(sequential[0], sequential[1], "userCpu override had no effect");
    }
}
