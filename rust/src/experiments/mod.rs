//! Experiment registry: one runner per paper figure/table (DESIGN.md §4).
//! `shabari experiment <id>` regenerates the corresponding rows/series.
//!
//! Every runner is built on the [`sweep`] harness: it declares a grid of
//! (policy × load × config-override) cells, replicates each cell across
//! `Ctx::seeds` deterministic seeds, and executes the grid on
//! `Ctx::jobs` worker threads. Tables report cross-seed means; headline
//! tables add p50/p99 and bootstrap CIs (EXPERIMENTS.md).

pub mod ablations;
pub mod adversity;
pub mod analysis;
pub mod characterize;
pub mod common;
pub mod e2e;
pub mod keepalive;
pub mod overheads;
pub mod overload;
pub mod replay;
pub mod scale;
pub mod scenarios;
pub mod sensitivity;
pub mod sweep;
pub mod tables;

use anyhow::{bail, Result};

pub use common::Ctx;

/// All experiment ids: the paper's figures/tables in paper order, then
/// this reproduction's own additions (`scenarios`, the cross-scenario
/// robustness matrix — DESIGN.md §Scenarios; `scale`, the 64-worker
/// engine-throughput benchmark — DESIGN.md §Perf; `overload`, the
/// past-saturation sweep proving the admission invariant — DESIGN.md
/// §Admission; `keepalive`, the keep-alive policy × workload matrix —
/// DESIGN.md §KeepAlive; `adversity`, the policy × keep-alive ×
/// fault-profile matrix — DESIGN.md §Faults; `replay`, the real-trace
/// policy × cluster-scaler grid — DESIGN.md §Scaler).
pub const EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig6", "fig7a", "fig7b", "fig8", "fig9", "fig10",
    "fig11", "fig12", "fig13", "fig14", "table1", "table2", "table3", "scenarios", "scale",
    "overload", "keepalive", "adversity", "replay",
];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    match id {
        "fig1" => characterize::fig1(ctx),
        "fig2" => characterize::fig2(ctx),
        "fig3" => characterize::fig3(ctx),
        "fig4" => characterize::fig4(ctx),
        "fig6" => ablations::fig6(ctx),
        "fig7a" => ablations::fig7a(ctx),
        "fig7b" => ablations::fig7b(ctx),
        "fig8" => e2e::fig8(ctx),
        "fig9" => analysis::fig9(ctx),
        "fig10" => analysis::fig10(ctx),
        "fig11" => sensitivity::fig11(ctx),
        "fig12" => sensitivity::fig12(ctx),
        "fig13" => sensitivity::fig13(ctx),
        "fig14" => overheads::fig14(ctx),
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx),
        "table3" => tables::table3(ctx),
        "scenarios" => scenarios::scenarios(ctx),
        "scale" => scale::scale(ctx),
        "overload" => overload::overload(ctx),
        "keepalive" => keepalive::keepalive(ctx),
        "adversity" => adversity::adversity(ctx),
        "replay" => replay::replay(ctx),
        "all" => {
            // Benchmark-style grids skipped under `all`: `scale` is a
            // wall-clock benchmark with its own pinned methodology
            // (seeds=1/jobs=1 via `make bench-scale` — session defaults
            // would overwrite out/BENCH_scale.json with non-comparable
            // numbers), and `overload` deliberately drives 64 rps past
            // saturation — orders of magnitude more work than the
            // figure grids.
            const SKIPPED_UNDER_ALL: &[(&str, &str)] =
                &[("scale", "make bench-scale"), ("overload", "make overload")];
            for id in EXPERIMENTS {
                if let Some((_, how)) =
                    SKIPPED_UNDER_ALL.iter().find(|(skip, _)| skip == id)
                {
                    println!("\n(skipping '{id}' under 'all': run `{how}`)\n");
                    continue;
                }
                println!("\n================ {id} ================\n");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (known: {EXPERIMENTS:?} or 'all')"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_covers_every_table_and_figure() {
        // the paper's evaluation (figures 1-4, 6-14, tables 1-3) plus the
        // repo's own cross-scenario robustness matrix, the engine scale
        // benchmark, the past-saturation overload sweep, the keep-alive
        // policy matrix, the fault-injection adversity matrix, and the
        // real-trace replay grid
        for id in super::EXPERIMENTS {
            assert!(
                id.starts_with("fig")
                    || id.starts_with("table")
                    || *id == "scenarios"
                    || *id == "scale"
                    || *id == "overload"
                    || *id == "keepalive"
                    || *id == "adversity"
                    || *id == "replay"
            );
        }
        assert_eq!(super::EXPERIMENTS.len(), 23);
    }

    #[test]
    fn unknown_id_rejected() {
        let ctx = super::Ctx::default();
        assert!(super::run("fig99", &ctx).is_err());
    }
}
