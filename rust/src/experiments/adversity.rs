//! `experiment adversity` — the policy × keep-alive × fault-profile
//! matrix (DESIGN.md §Faults): scheduling policies crossed with retention
//! policies under every registered fault profile, replicated across
//! `Ctx::seeds` seeds on `Ctx::jobs` threads, on a deliberately small
//! cluster (`--adversity-workers`) so one crashed worker is a real
//! fraction of capacity.
//!
//! The question it answers: Shabari's headline claim is SLO attainment
//! under real-world conditions, yet every other experiment runs on an
//! immortal, uniform cluster. This matrix scores each policy when the
//! cluster itself misbehaves — crash/restart cycles, straggler workers,
//! heterogeneous capacity classes, and all three at once. Expected shape
//! (EXPERIMENTS.md §Adversity): Shabari degrades gracefully (its feedback
//! loop re-learns after losing observations, and right-sizing leaves
//! slack for rerouted work), while static baselines lose SLO attainment
//! under stragglers and crashes because their fixed sizes cannot absorb
//! slower or scarcer capacity.
//!
//! Unlike overload/keepalive, the invariant check here is the first-class
//! [`Cluster::check_invariants`] hook called per replicate — plain
//! `assert!`s that fire in release builds, checked against each worker's
//! *own* (possibly heterogeneous) limits. The global-limit
//! `ensure_admission_invariant` would be wrong under `hetero`.
//!
//! Emits `out/adversity.json` (`make adversity`; CI runs a shrunk smoke).

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::simulator::faults;
use crate::simulator::keepalive as ka;
use crate::simulator::SimConfig;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};

use super::common::{self, Ctx};
use super::sweep::{self, Cell, CellOutcome};

/// Scheduling policies crossed with the fault axis: the full stack and
/// the biggest static baseline (the paper's main foil).
pub const ADV_POLICIES: &[&str] = &["shabari", "static-large"];

/// Retention axis: the legacy fixed default and demand-driven pressure
/// eviction (whose reservation-holding ledger is the one a crash must
/// not corrupt).
pub const ADV_KEEPALIVE: &[&str] = &["fixed:600", "pressure"];

/// The fault axis: every registered profile, including the `none`
/// control column.
pub const ADV_FAULTS: &[&str] = &["none", "crash", "stragglers", "hetero", "chaos"];

/// Load on the small `--adversity-workers` cluster: busy enough that a
/// crash displaces real in-flight work, below the overload meltdown.
pub const ADV_RPS: f64 = 12.0;

/// Cell label carrying both non-policy axes (salts replicate seeds so
/// the same policy under two profiles samples disjoint RNG streams at
/// replicates ≥ 1, while replicate 0 stays grid-wide paired).
fn cell_label(fault: &str, keepalive: &str) -> String {
    format!("faults:{fault}|keepalive:{keepalive}")
}

/// Recover (fault, keepalive) from a cell label.
fn cell_parts(cell: &Cell) -> (&str, &str) {
    let rest = cell.label.strip_prefix("faults:").unwrap_or(&cell.label);
    match rest.split_once("|keepalive:") {
        Some((fault, keepalive)) => (fault, keepalive),
        None => (rest, "fixed:600"),
    }
}

/// Run the policy × fault × keepalive grid; outcome index is
/// `(pi * ADV_FAULTS.len() + fi) * ADV_KEEPALIVE.len() + ki`. Every
/// replicate runs `Cluster::check_invariants()` — release-mode
/// reservation/warm-index/peak checks against per-worker limits.
pub fn run_adversity(ctx: &Ctx, rps: f64) -> Result<Vec<CellOutcome<RunMetrics>>> {
    let workers = ctx.adversity_workers;
    let cells: Vec<Cell> = ADV_POLICIES
        .iter()
        .flat_map(|p| {
            ADV_FAULTS.iter().flat_map(move |f| {
                ADV_KEEPALIVE
                    .iter()
                    .map(move |k| Cell::labeled(p, rps, &cell_label(f, k), workers as f64))
            })
        })
        .collect();
    sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        let (fault, keepalive) = cell_parts(cell);
        let fspec = faults::parse(fault)?;
        let kspec = ka::parse(keepalive)?;
        let cctx = ctx.with_seed(seed).with_keepalive(kspec).with_faults(fspec);
        let workload = cctx.workload();
        let cfg = SimConfig { workers, ..common::sim_config(&cctx) };
        let (res, metrics) = common::run_one(&cell.policy, &cctx, &workload, cell.rps, &cfg)?;
        // First-class invariant hook (ISSUE 6): fires in release builds,
        // hetero-safe (each worker audited against its own limits).
        res.cluster.check_invariants();
        Ok(metrics)
    })
}

pub fn adversity(ctx: &Ctx) -> Result<()> {
    // lint:allow(D002): host wall time for the runner's wall-clock report line only
    let t0 = std::time::Instant::now();
    let outcomes = run_adversity(ctx, ADV_RPS)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "(adversity matrix: {} cells x {} seed(s) on {} job(s), {wall:.1}s wall; \
         cluster invariants held on every replicate)",
        outcomes.len(),
        ctx.seeds,
        ctx.jobs
    );

    let mut t = Table::new(
        &format!(
            "adversity: {} workers @ {} rps, {}s trace (cross-seed means; \
             failed = invocations lost to crashes)",
            ctx.adversity_workers, ADV_RPS, ctx.duration_s
        ),
        &[
            "system",
            "faults",
            "keepalive",
            "SLO viol [95% CI]",
            "failed",
            "crashes",
            "requeued",
            "slowdown",
            "cold",
            "queue p99 s",
        ],
    );
    for out in &outcomes {
        let (fault, keepalive) = cell_parts(&out.cell);
        let m = out.mean_metrics();
        t.row(vec![
            out.cell.policy.clone(),
            fault.to_string(),
            keepalive.to_string(),
            out.stat(|m| m.slo_violation_pct).fmt_ci(1),
            fpct(m.failed_pct),
            m.worker_crashes.to_string(),
            m.requeued_on_crash.to_string(),
            fnum(m.straggler_slowdown, 2),
            fpct(m.cold_start_pct),
            fnum(m.queue_wait.p99, 2),
        ]);
    }
    t.note(
        "expected shape: Shabari degrades gracefully under every profile; static \
         baselines lose SLO attainment under stragglers/chaos (fixed sizes cannot \
         absorb slower capacity) and pay more failed work under crash",
    );
    t.print();

    let dump = Json::obj(vec![
        ("perf", common::perf_json(wall, &outcomes)),
        (
            "config",
            Json::obj(vec![
                ("workers", Json::Num(ctx.adversity_workers as f64)),
                ("rps", Json::Num(ADV_RPS)),
                ("duration_s", Json::Num(ctx.duration_s)),
                ("seeds", Json::Num(ctx.seeds as f64)),
                ("jobs", Json::Num(ctx.jobs as f64)),
                ("seed", Json::Num(ctx.seed as f64)),
            ]),
        ),
        (
            "rows",
            Json::Arr(
                outcomes
                    .iter()
                    .map(|out| {
                        let (fault, keepalive) = cell_parts(&out.cell);
                        let m = out.mean_metrics();
                        let viol = out.stat(|m| m.slo_violation_pct);
                        Json::obj(vec![
                            ("policy", Json::Str(out.cell.policy.clone())),
                            ("faults", Json::Str(fault.to_string())),
                            ("keepalive", Json::Str(keepalive.to_string())),
                            ("slo_violation_pct_mean", Json::Num(viol.mean)),
                            ("slo_violation_pct_ci95_lo", Json::Num(viol.ci95.0)),
                            ("slo_violation_pct_ci95_hi", Json::Num(viol.ci95.1)),
                            ("failed_pct", Json::Num(m.failed_pct)),
                            ("worker_crashes", Json::Num(m.worker_crashes as f64)),
                            ("requeued_on_crash", Json::Num(m.requeued_on_crash as f64)),
                            ("straggler_slowdown", Json::Num(m.straggler_slowdown)),
                            ("cold_start_pct", Json::Num(m.cold_start_pct)),
                            ("timeout_pct", Json::Num(m.timeout_pct)),
                            ("queue_p99_s", Json::Num(m.queue_wait.p99)),
                            ("queued_pct", Json::Num(m.queued_pct)),
                            ("mean_e2e_s", Json::Num(m.mean_e2e_s)),
                            ("idle_container_s", Json::Num(m.idle_container_s)),
                            ("peak_alloc_vcpus", Json::Num(m.peak_alloc_vcpus)),
                            ("invocations", Json::Num(m.invocations as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::create_dir_all("out").ok();
    match std::fs::write("out/adversity.json", dump.to_pretty()) {
        Ok(()) => println!("(dumped out/adversity.json)"),
        Err(e) => eprintln!("warning: could not write out/adversity.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_labels_round_trip_both_axes() {
        let c = Cell::labeled("shabari", ADV_RPS, &cell_label("chaos", "pressure"), 4.0);
        assert_eq!(cell_parts(&c), ("chaos", "pressure"));
        // distinct fault profiles occupy distinct seed streams at rep >= 1
        let a = Cell::labeled("shabari", 12.0, &cell_label("none", "fixed:600"), 4.0);
        let b = Cell::labeled("shabari", 12.0, &cell_label("crash", "fixed:600"), 4.0);
        assert_ne!(sweep::cell_seed(42, &a, 1), sweep::cell_seed(42, &b, 1));
        assert_eq!(sweep::cell_seed(42, &a, 0), sweep::cell_seed(42, &b, 0));
    }

    /// Tiny-parameter smoke mirroring the CI job: the grid covers every
    /// (policy, fault, keepalive) triple, is deterministic across thread
    /// counts, and the fault counters land where the profile says they
    /// must. `run_adversity` also exercises `check_invariants` on every
    /// replicate — including the heterogeneous cells, where the global
    /// admission-limit check would be meaningless.
    #[test]
    fn adversity_grid_covers_axes_and_is_jobs_invariant() {
        let ctx = Ctx { duration_s: 30.0, adversity_workers: 2, seeds: 1, ..Default::default() };
        let seq = run_adversity(&Ctx { jobs: 1, ..ctx.clone() }, ADV_RPS).unwrap();
        let par = run_adversity(&Ctx { jobs: 4, ..ctx }, ADV_RPS).unwrap();
        assert_eq!(seq.len(), ADV_POLICIES.len() * ADV_FAULTS.len() * ADV_KEEPALIVE.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.cell.id(), b.cell.id());
            let (ma, mb) = (a.mean_metrics(), b.mean_metrics());
            assert_eq!(ma.invocations, mb.invocations);
            assert_eq!(
                ma.slo_violation_pct.to_bits(),
                mb.slo_violation_pct.to_bits(),
                "{} diverged across --jobs",
                a.cell.id()
            );
            assert_eq!(ma.worker_crashes, mb.worker_crashes);
            assert_eq!(ma.requeued_on_crash, mb.requeued_on_crash);
            assert_eq!(ma.failed_pct.to_bits(), mb.failed_pct.to_bits());
            // profile => counter shape
            let (fault, _) = cell_parts(&a.cell);
            match fault {
                "crash" | "chaos" => {
                    assert!(ma.worker_crashes > 0, "{}: no crash fired", a.cell.id())
                }
                "stragglers" => assert!(
                    ma.straggler_slowdown < 1.0,
                    "{}: no straggler configured",
                    a.cell.id()
                ),
                "none" | "hetero" => {
                    assert_eq!(ma.worker_crashes, 0);
                    assert_eq!(ma.failed_pct, 0.0);
                    assert_eq!(ma.straggler_slowdown, 1.0);
                }
                other => panic!("unregistered profile {other}"),
            }
        }
    }
}
