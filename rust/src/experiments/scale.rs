//! `experiment scale` — the engine-throughput benchmark behind the
//! indexed-simulator refactor (DESIGN.md §Perf): a 64-worker cluster
//! driven at ≥4× the fig8 request rate, one cell per policy, measuring
//! wall-clock and simulated-invocations-per-second for the full stack
//! (trace → coordinator → DES cluster → metrics).
//!
//! Emits `out/BENCH_scale.json` so before/after engine comparisons are
//! machine-readable (`make bench-scale`; EXPERIMENTS.md §Perf records the
//! measured numbers). The grid runs through the sweep harness, so the
//! usual `--seeds`/`--jobs` determinism contract applies; shrink it for
//! smoke runs with `--scale-workers`/`--scale-rps`/`--duration`.

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::simulator::SimConfig;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};

use super::common::{self, Ctx};
use super::sweep::{self, Cell};

/// Systems timed by the scale grid: the cheapest baseline, a mid-cost
/// baseline, and the full Shabari stack (learner + scheduler feedback).
pub const SCALE_POLICIES: &[&str] = &["static-large", "cypress", "shabari"];

/// One timed row of the scale grid.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    pub policy: String,
    /// Wall-clock for all `seeds` replicates of the cell.
    pub wall_s: f64,
    /// Simulated invocations across all replicates.
    pub invocations: usize,
    /// Engine events processed across all replicates.
    pub sim_events: u64,
    /// Simulated invocations per wall-second (the headline number).
    pub sim_inv_per_s: f64,
    /// Engine events per wall-second (finer-grained than invocations:
    /// insensitive to how much queueing/prewarm churn a policy causes).
    pub sim_events_per_s: f64,
    /// Cross-seed mean metrics (sanity: the grid simulates real work).
    pub metrics: RunMetrics,
}

/// One sweep cell at an explicit cluster size (the `workers` override
/// rides in the cell label so seed derivation stays collision-free).
fn run_scale_cell(
    policy: &str,
    ctx: &Ctx,
    rps: f64,
    workers: usize,
    seed: u64,
) -> Result<RunMetrics> {
    let cctx = ctx.with_seed(seed);
    let workload = cctx.workload();
    let cfg = SimConfig { workers, ..common::sim_config(&cctx) };
    let (_, metrics) = common::run_one(policy, &cctx, &workload, rps, &cfg)?;
    Ok(metrics)
}

/// Run the scale grid, timing each policy's cell (all replicates).
pub fn run_scale(ctx: &Ctx) -> Result<Vec<ScaleRow>> {
    let workers = ctx.scale_workers;
    let rps = ctx.scale_rps;
    let mut rows = Vec::with_capacity(SCALE_POLICIES.len());
    for policy in SCALE_POLICIES {
        let cells = [Cell::labeled(policy, rps, "workers", workers as f64)];
        // lint:allow(D002): host wall time for the bench throughput figure only
        let t0 = std::time::Instant::now();
        let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
            run_scale_cell(&cell.policy, ctx, cell.rps, workers, seed)
        })?;
        let wall_s = t0.elapsed().as_secs_f64();
        let out = &outcomes[0];
        let invocations: usize = out.per_seed.iter().map(|m| m.invocations).sum();
        let sim_events: u64 = out.per_seed.iter().map(|m| m.sim_events).sum();
        rows.push(ScaleRow {
            policy: policy.to_string(),
            wall_s,
            invocations,
            sim_events,
            sim_inv_per_s: invocations as f64 / wall_s.max(1e-9),
            sim_events_per_s: sim_events as f64 / wall_s.max(1e-9),
            metrics: out.mean_metrics(),
        });
    }
    Ok(rows)
}

pub fn scale(ctx: &Ctx) -> Result<()> {
    let rows = run_scale(ctx)?;
    let mut t = Table::new(
        &format!(
            "engine scale: {} workers @ {} rps, {}s trace, {} seed(s) x {} job(s)",
            ctx.scale_workers, ctx.scale_rps, ctx.duration_s, ctx.seeds, ctx.jobs
        ),
        &["system", "invocations", "wall s", "sim inv/s", "sim ev/s", "SLO viol", "containers"],
    );
    for r in &rows {
        t.row(vec![
            r.policy.clone(),
            r.invocations.to_string(),
            fnum(r.wall_s, 2),
            fnum(r.sim_inv_per_s, 0),
            fnum(r.sim_events_per_s, 0),
            fpct(r.metrics.slo_violation_pct),
            r.metrics.containers_created.to_string(),
        ]);
    }
    t.note("wall-clock varies by machine; sim results are byte-deterministic per --seed");
    t.print();

    let dump = Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("workers", Json::Num(ctx.scale_workers as f64)),
                ("rps", Json::Num(ctx.scale_rps)),
                ("duration_s", Json::Num(ctx.duration_s)),
                ("seeds", Json::Num(ctx.seeds as f64)),
                ("jobs", Json::Num(ctx.jobs as f64)),
                ("seed", Json::Num(ctx.seed as f64)),
            ]),
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("policy", Json::Str(r.policy.clone())),
                            ("invocations", Json::Num(r.invocations as f64)),
                            ("sim_events", Json::Num(r.sim_events as f64)),
                            ("wall_s", Json::Num(r.wall_s)),
                            ("sim_inv_per_s", Json::Num(r.sim_inv_per_s)),
                            ("sim_events_per_s", Json::Num(r.sim_events_per_s)),
                            ("slo_violation_pct", Json::Num(r.metrics.slo_violation_pct)),
                            (
                                "containers_created",
                                Json::Num(r.metrics.containers_created as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::create_dir_all("out").ok();
    match std::fs::write("out/BENCH_scale.json", dump.to_pretty()) {
        Ok(()) => println!("(dumped out/BENCH_scale.json)"),
        Err(e) => eprintln!("warning: could not write out/BENCH_scale.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny-parameter smoke: the scale path must simulate real work and
    /// stay deterministic across thread counts (the CI smoke runs the
    /// same grid through the CLI).
    #[test]
    fn scale_grid_runs_and_is_jobs_invariant() {
        let ctx = Ctx {
            duration_s: 30.0,
            scale_workers: 8,
            scale_rps: 4.0,
            seeds: 2,
            ..Default::default()
        };
        let seq = run_scale(&Ctx { jobs: 1, ..ctx.clone() }).unwrap();
        let par = run_scale(&Ctx { jobs: 4, ..ctx }).unwrap();
        assert_eq!(seq.len(), SCALE_POLICIES.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.policy, b.policy);
            assert!(a.invocations > 50, "{}: {} invocations", a.policy, a.invocations);
            assert_eq!(a.invocations, b.invocations);
            // every invocation costs several engine events (arrival,
            // ready, complete, evictions...), so the self-throughput
            // counter must outrun the invocation count
            assert!(a.sim_events > a.invocations as u64, "{}: {} events", a.policy, a.sim_events);
            assert_eq!(a.sim_events, b.sim_events);
            assert_eq!(
                a.metrics.slo_violation_pct.to_bits(),
                b.metrics.slo_violation_pct.to_bits(),
                "{} diverged across --jobs",
                a.policy
            );
            assert_eq!(a.metrics.mean_e2e_s.to_bits(), b.metrics.mean_e2e_s.to_bits());
        }
    }
}
