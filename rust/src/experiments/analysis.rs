//! Behaviour analyses: Figure 9 (allocation timeline / response to SLO
//! violations) and Figure 10 (cold-start mitigation). Fig 10 is a
//! (system × rps) sweep grid; Fig 9 is inherently a single-seed zoom-in
//! (it narrates one allocation timeline), so it runs one cell per
//! function through the same harness and renders off-thread (DESIGN.md §4).

use anyhow::Result;

use crate::coordinator::allocator::ResourceAllocator;
use crate::coordinator::scheduler::shabari::ShabariScheduler;
use crate::coordinator::ShabariPolicy;
use crate::functions::catalog::{index_of, CATALOG};
use crate::functions::inputs;
use crate::simulator::engine::simulate;
use crate::simulator::Request;
use crate::util::rng::Rng;
use crate::util::table::{fnum, fpct, Table};

use super::common::{run_cell, sim_config, Ctx};
use super::sweep::{self, Cell};

/// Figure 9: zoomed-in timeline of allocated vs utilized cores for one
/// input of matmult (multi-threaded) and sentiment (single-threaded).
/// Workers render their table to a string; printing stays in grid order.
pub fn fig9(ctx: &Ctx) -> Result<()> {
    let fnames = ["matmult", "sentiment"];
    let rendered = sweep::parallel_map(&fnames, ctx.jobs, |_, fname| -> Result<String> {
        let fi = index_of(fname).unwrap();
        let mut rng = Rng::new(ctx.seed);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let input = pool[pool.len() / 2].clone();
        // SLO: 1.4x the 16-vCPU isolated time for matmult (meetable with
        // enough cores); 1.05x the flat time for sentiment (often missed,
        // but more vCPUs can't help)
        let d = (CATALOG[fi].demand)(&input);
        let slo = if *fname == "matmult" {
            d.ideal_exec_s(16.0, 10.0) * 1.4
        } else {
            d.ideal_exec_s(1.0, 10.0) * 1.05
        };
        let reqs: Vec<Request> = (0..30)
            .map(|i| Request {
                id: i + 1,
                func: fi,
                input: input.clone(),
                arrival: i as f64 * 20.0,
                slo_s: slo,
            })
            .collect();
        let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
        let mut policy = ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(ctx.seed)));
        let res = simulate(sim_config(ctx), &mut policy, reqs);

        let mut t = Table::new(
            &format!("Fig 9 — {fname} timeline (one input, SLO {slo:.2}s)"),
            &["#", "allocated vCPUs", "peak used", "exec (s)", "SLO violated"],
        );
        for (i, r) in res.sorted_records().iter().enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                r.requested_vcpus.to_string(),
                fnum(r.peak_vcpus_used, 1),
                fnum(r.exec_s, 2),
                if r.slo_violated() { "X".into() } else { "".into() },
            ]);
        }
        t.note(if *fname == "matmult" {
            "explores lower allocations, reverts on violations (multi-threaded)"
        } else {
            "does not grow on violations: function cannot use more vCPUs"
        });
        Ok(t.render())
    });
    for table in rendered {
        print!("{}", table?);
    }
    Ok(())
}

/// Figure 10: % invocations with cold starts and % of SLO violations that
/// had cold starts — Shabari vs Shabari+OW-sched vs static/Parrotfish.
pub fn fig10(ctx: &Ctx) -> Result<()> {
    const SYSTEMS: &[&str] = &[
        "shabari",
        "shabari-ow-sched",
        "static-medium",
        "static-large",
        "parrotfish",
    ];
    let rps_list = [4.0, 6.0];
    let cells: Vec<Cell> = rps_list
        .iter()
        .flat_map(|&rps| SYSTEMS.iter().map(move |p| Cell::new(p, rps)))
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_cell(&cell.policy, ctx, cell.rps, seed)
    })?;
    for (ri, &rps) in rps_list.iter().enumerate() {
        let mut t = Table::new(
            &format!("Fig 10 — cold starts at RPS {rps} ({} seed(s))", ctx.seeds),
            &["system", "% invocations w/ cold start", "% violations w/ cold start"],
        );
        for (si, name) in SYSTEMS.iter().enumerate() {
            let m = outcomes[ri * SYSTEMS.len() + si].mean_metrics();
            t.row(vec![
                name.to_string(),
                fpct(m.cold_start_pct),
                fpct(m.violations_with_cold_start_pct),
            ]);
        }
        t.note("Shabari's scheduler halves cold-start fraction vs the OW scheduler");
        t.print();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::common::run_one;
    use super::*;
    use crate::simulator::SimConfig;

    #[test]
    fn shabari_scheduler_reduces_cold_starts_vs_ow() {
        let ctx = Ctx { duration_s: 420.0, ..Default::default() };
        let w = ctx.workload();
        let cfg = SimConfig { seed: 7, ..Default::default() };
        let (_, shabari) = run_one("shabari", &ctx, &w, 5.0, &cfg).unwrap();
        let (_, ow) = run_one("shabari-ow-sched", &ctx, &w, 5.0, &cfg).unwrap();
        assert!(
            shabari.cold_start_pct < ow.cold_start_pct,
            "shabari {} vs ow {}",
            shabari.cold_start_pct,
            ow.cold_start_pct
        );
    }

    #[test]
    fn fig9_sentiment_stays_single_core() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let fi = index_of("sentiment").unwrap();
        let mut rng = Rng::new(1);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let input = pool[4].clone();
        let d = (CATALOG[fi].demand)(&input);
        let slo = d.ideal_exec_s(1.0, 10.0) * 1.05;
        let reqs: Vec<Request> = (0..30)
            .map(|i| Request {
                id: i + 1,
                func: fi,
                input: input.clone(),
                arrival: i as f64 * 10.0,
                slo_s: slo,
            })
            .collect();
        let alloc = ResourceAllocator::new(ctx.allocator_cfg()).unwrap();
        let mut policy = ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(3)));
        let res = simulate(sim_config(&ctx), &mut policy, reqs);
        let recs = res.sorted_records();
        // after learning, allocation settles at 1-2 vCPUs despite
        // borderline SLO violations
        let late_max = recs[20..].iter().map(|r| r.requested_vcpus).max().unwrap();
        assert!(late_max <= 2, "sentiment settles at 1-2 vCPUs, got {late_max}");
    }
}
