//! Design-exploration ablations: Figure 6 (ML formulation), Figure 7a
//! (cost function), Figure 7b (scheduler placement policy).

use anyhow::Result;

use crate::util::table::{fnum, fpct, Table};

use super::common::{run_one, sim_config, Ctx};

/// Figure 6: per-function vs one-hot vs per-input-type formulations —
/// SLO violations and idle (wasted) vCPU distribution.
pub fn fig6(ctx: &Ctx) -> Result<()> {
    let workload = ctx.workload();
    let cfg = sim_config(ctx);
    let mut t = Table::new(
        "Fig 6 — ML formulations for the online allocator (RPS 4)",
        &["formulation", "SLO viol %", "idle vCPUs p50", "idle vCPUs p90", "idle mem p50 (GB)"],
    );
    for name in ["shabari", "shabari-onehot", "shabari-per-input-type"] {
        let (_, m) = run_one(name, ctx, &workload, 4.0, &cfg)?;
        let label = match name {
            "shabari" => "per-function",
            "shabari-onehot" => "one-hot",
            _ => "per-input-type",
        };
        t.row(vec![
            label.to_string(),
            fpct(m.slo_violation_pct),
            fnum(m.wasted_vcpus.p50, 1),
            fnum(m.wasted_vcpus.p90, 1),
            fnum(m.wasted_mem_gb.p50, 2),
        ]);
    }
    t.note("paper: per-function wins on both compliance and utilization; one-hot ~5x p90 idle vCPUs");
    t.print();
    Ok(())
}

/// Figure 7a: Absolute vs Proportional cost function — SLO violations.
pub fn fig7a(ctx: &Ctx) -> Result<()> {
    let workload = ctx.workload();
    let cfg = sim_config(ctx);
    let mut t = Table::new(
        "Fig 7a — cost function: Absolute (X=0.5s, Y=1.5s) vs Proportional",
        &["rps", "absolute viol %", "proportional viol %"],
    );
    for rps in [4.0, 5.0, 6.0] {
        let (_, ma) = run_one("shabari", ctx, &workload, rps, &cfg)?;
        let (_, mp) = run_one("shabari-proportional", ctx, &workload, rps, &cfg)?;
        t.row(vec![
            fnum(rps, 0),
            fpct(ma.slo_violation_pct),
            fpct(mp.slo_violation_pct),
        ]);
    }
    t.note("paper: absolute ~25% fewer violations (more aggressive on misses)");
    t.print();
    Ok(())
}

/// Figure 7b: hashing-based placement vs Hermod packing at high load.
pub fn fig7b(ctx: &Ctx) -> Result<()> {
    let workload = ctx.workload();
    let cfg = sim_config(ctx);
    let mut t = Table::new(
        "Fig 7b — scheduler placement: hashing vs Hermod packing",
        &["rps", "hashing viol %", "hermod-packing viol %"],
    );
    for rps in [5.0, 6.0] {
        let (_, mh) = run_one("shabari", ctx, &workload, rps, &cfg)?;
        let (_, mp) = run_one("shabari-hermod", ctx, &workload, rps, &cfg)?;
        t.row(vec![
            fnum(rps, 0),
            fpct(mh.slo_violation_pct),
            fpct(mp.slo_violation_pct),
        ]);
    }
    t.note("packing makes NIC the bottleneck for DB-fetching functions (§5)");
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7a_absolute_no_worse() {
        // Short run; the qualitative shape (absolute <= proportional + eps)
        // must hold.
        let ctx = Ctx { duration_s: 240.0, ..Default::default() };
        let w = ctx.workload();
        let cfg = sim_config(&ctx);
        let (_, ma) = run_one("shabari", &ctx, &w, 5.0, &cfg).unwrap();
        let (_, mp) = run_one("shabari-proportional", &ctx, &w, 5.0, &cfg).unwrap();
        assert!(
            ma.slo_violation_pct <= mp.slo_violation_pct + 6.0,
            "absolute {} vs proportional {}",
            ma.slo_violation_pct,
            mp.slo_violation_pct
        );
    }
}
