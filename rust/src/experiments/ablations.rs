//! Design-exploration ablations: Figure 6 (ML formulation), Figure 7a
//! (cost function), Figure 7b (scheduler placement policy) — each a small
//! sweep grid replicated across `Ctx::seeds` (DESIGN.md §4).

use anyhow::Result;

use crate::util::table::{fnum, fpct, Table};

use super::common::{run_cell, Ctx};
use super::sweep::{self, Cell};

/// Figure 6: per-function vs one-hot vs per-input-type formulations —
/// SLO violations and idle (wasted) vCPU distribution.
pub fn fig6(ctx: &Ctx) -> Result<()> {
    const VARIANTS: &[(&str, &str)] = &[
        ("shabari", "per-function"),
        ("shabari-onehot", "one-hot"),
        ("shabari-per-input-type", "per-input-type"),
    ];
    let cells: Vec<Cell> = VARIANTS.iter().map(|(p, _)| Cell::new(p, 4.0)).collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_cell(&cell.policy, ctx, cell.rps, seed)
    })?;
    let mut t = Table::new(
        &format!("Fig 6 — ML formulations for the online allocator (RPS 4, {} seed(s))", ctx.seeds),
        &[
            "formulation",
            "SLO viol % [95% CI]",
            "idle vCPUs p50",
            "idle vCPUs p90",
            "idle mem p50 (GB)",
        ],
    );
    for ((_, label), out) in VARIANTS.iter().zip(&outcomes) {
        let m = out.mean_metrics();
        t.row(vec![
            label.to_string(),
            out.stat(|m| m.slo_violation_pct).fmt_ci(1),
            fnum(m.wasted_vcpus.p50, 1),
            fnum(m.wasted_vcpus.p90, 1),
            fnum(m.wasted_mem_gb.p50, 2),
        ]);
    }
    t.note("paper: per-function wins on both compliance and utilization; one-hot ~5x p90 idle vCPUs");
    t.print();
    Ok(())
}

/// Figure 7a: Absolute vs Proportional cost function — SLO violations.
pub fn fig7a(ctx: &Ctx) -> Result<()> {
    let rps_list = [4.0, 5.0, 6.0];
    let cells: Vec<Cell> = rps_list
        .iter()
        .flat_map(|&rps| {
            ["shabari", "shabari-proportional"].into_iter().map(move |p| Cell::new(p, rps))
        })
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_cell(&cell.policy, ctx, cell.rps, seed)
    })?;
    let mut t = Table::new(
        "Fig 7a — cost function: Absolute (X=0.5s, Y=1.5s) vs Proportional",
        &["rps", "absolute viol %", "proportional viol %"],
    );
    for (ri, &rps) in rps_list.iter().enumerate() {
        let abs = outcomes[ri * 2].mean_metrics();
        let prop = outcomes[ri * 2 + 1].mean_metrics();
        t.row(vec![
            fnum(rps, 0),
            fpct(abs.slo_violation_pct),
            fpct(prop.slo_violation_pct),
        ]);
    }
    t.note("paper: absolute ~25% fewer violations (more aggressive on misses)");
    t.print();
    Ok(())
}

/// Figure 7b: hashing-based placement vs Hermod packing at high load.
pub fn fig7b(ctx: &Ctx) -> Result<()> {
    let rps_list = [5.0, 6.0];
    let cells: Vec<Cell> = rps_list
        .iter()
        .flat_map(|&rps| ["shabari", "shabari-hermod"].into_iter().map(move |p| Cell::new(p, rps)))
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_cell(&cell.policy, ctx, cell.rps, seed)
    })?;
    let mut t = Table::new(
        "Fig 7b — scheduler placement: hashing vs Hermod packing",
        &["rps", "hashing viol %", "hermod-packing viol %"],
    );
    for (ri, &rps) in rps_list.iter().enumerate() {
        let hash = outcomes[ri * 2].mean_metrics();
        let pack = outcomes[ri * 2 + 1].mean_metrics();
        t.row(vec![
            fnum(rps, 0),
            fpct(hash.slo_violation_pct),
            fpct(pack.slo_violation_pct),
        ]);
    }
    t.note("packing makes NIC the bottleneck for DB-fetching functions (§5)");
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::common::{run_one, sim_config};
    use super::*;

    #[test]
    fn fig7a_absolute_no_worse() {
        // Short run; the qualitative shape (absolute <= proportional + eps)
        // must hold.
        let ctx = Ctx { duration_s: 240.0, ..Default::default() };
        let w = ctx.workload();
        let cfg = sim_config(&ctx);
        let (_, ma) = run_one("shabari", &ctx, &w, 5.0, &cfg).unwrap();
        let (_, mp) = run_one("shabari-proportional", &ctx, &w, 5.0, &cfg).unwrap();
        assert!(
            ma.slo_violation_pct <= mp.slo_violation_pct + 6.0,
            "absolute {} vs proportional {}",
            ma.slo_violation_pct,
            mp.slo_violation_pct
        );
    }

    #[test]
    fn fig6_grid_runs_on_threads() {
        // The formulation grid must produce one outcome per variant with
        // the requested number of replicates, identically at any job count.
        let ctx = Ctx { duration_s: 60.0, seeds: 2, jobs: 4, ..Default::default() };
        fig6(&ctx).unwrap();
    }
}
