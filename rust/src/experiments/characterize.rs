//! Measurement-study experiments (paper §2): Figures 1–4.
//! These probe the ground-truth function models in isolation, exactly as
//! the paper's ~8K profiling runs do on the real testbed. The probe
//! grids run through `sweep::parallel_map` with a fresh RNG built inside
//! each cell — forked as `seed ^ fnv1a(cell-id)` where cells of one grid
//! need independent streams (fig1's per-memory-size cells), plain
//! `Rng::new(seed)` where each cell intentionally replays the same pool
//! draws (fig2/fig4's per-function cells) — so output is deterministic
//! at any `--jobs` and the figures saturate the machine like every other
//! experiment (DESIGN.md §4).

use anyhow::Result;

use crate::baselines::profiling;
use crate::featurizer::InputKind;
use crate::functions::catalog::{index_of, CATALOG};
use crate::functions::inputs;
use crate::util::rng::{fnv1a, Rng};
use crate::util::stats;
use crate::util::table::{fnum, fpct, Table};

use super::common::Ctx;
use super::sweep;

/// Deterministic per-cell RNG: independent of how cells are scheduled.
fn cell_rng(seed: u64, tag: &str) -> Rng {
    Rng::new(seed ^ fnv1a(tag.as_bytes()))
}

/// Figure 1: (a) slowdown w.r.t. best runtime across coupled memory
/// sizes; (b) max memory utilized vs allocated — for `videoprocess`.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    let fi = index_of("videoprocess").unwrap();
    let mut rng = Rng::new(ctx.seed);
    let pool = inputs::pool(&CATALOG[fi], &mut rng);

    // OpenWhisk/Lambda-style coupled sizing: vCPUs proportional to memory.
    let mem_ladder_mb: &[u32] = &[1024, 2048, 3072, 4096, 5120, 6144, 8192, 10240];
    let coupled_vcpus = |mem_mb: u32| ((mem_mb as f64 / 1769.0).ceil() as u32).max(1);

    let mut t = Table::new(
        "Fig 1a — videoprocess slowdown vs best, per coupled memory size (100 invocations)",
        &["mem", "vcpus", "median exec (s)", "slowdown p50", "slowdown p95"],
    );
    // 100 invocations spread over the pool per memory size; one sweep
    // cell per size, each with its own forked RNG stream.
    let per_mem: Vec<Vec<f64>> = sweep::parallel_map(mem_ladder_mb, ctx.jobs, |_, &mem| {
        let vcpus = coupled_vcpus(mem);
        let mut rng = cell_rng(ctx.seed, &format!("fig1a:{mem}"));
        (0..100)
            .map(|i| {
                let input = &pool[i % pool.len()];
                let d = CATALOG[fi].noisy_demand(input, &mut rng);
                d.ideal_exec_s(vcpus as f64, 10.0)
            })
            .collect()
    });
    // best runtime per invocation index across memory sizes
    let best: Vec<f64> = (0..100)
        .map(|i| per_mem.iter().map(|v| v[i]).fold(f64::INFINITY, f64::min))
        .collect();
    for (mi, &mem) in mem_ladder_mb.iter().enumerate() {
        let slowdowns: Vec<f64> =
            (0..100).map(|i| per_mem[mi][i] / best[i]).collect();
        let s = stats::summarize(&slowdowns);
        let med = stats::median(&per_mem[mi]);
        t.row(vec![
            format!("{:.1}GB", mem as f64 / 1024.0),
            coupled_vcpus(mem).to_string(),
            fnum(med, 2),
            fnum(s.p50, 2),
            fnum(s.p95, 2),
        ]);
    }
    t.note("paper: up to 6x performance variability across sizes/inputs");
    t.print();

    let mut t2 = Table::new(
        "Fig 1b — videoprocess max memory utilized vs allocated",
        &["alloc", "max used (GB)", "p50 used (GB)", "util % (p50)"],
    );
    let used_per_mem: Vec<Vec<f64>> = sweep::parallel_map(mem_ladder_mb, ctx.jobs, |_, &mem| {
        let mut rng = cell_rng(ctx.seed, &format!("fig1b:{mem}"));
        (0..100)
            .map(|i| CATALOG[fi].noisy_demand(&pool[i % pool.len()], &mut rng).mem_gb)
            .collect()
    });
    for (&mem, used) in mem_ladder_mb.iter().zip(&used_per_mem) {
        let s = stats::summarize(used);
        let alloc_gb = mem as f64 / 1024.0;
        t2.row(vec![
            format!("{alloc_gb:.1}GB"),
            fnum(s.max, 2),
            fnum(s.p50, 2),
            fpct(100.0 * s.p50 / alloc_gb),
        ]);
    }
    t2.note("paper: up to 80% of allocated memory idle (compute-bound function)");
    t2.print();
    Ok(())
}

/// Figure 2: input size vs execution time for three functions at several
/// vCPU allocations — positive but *non-linear* correlation; variability
/// grows with size for multi-threaded functions.
pub fn fig2(ctx: &Ctx) -> Result<()> {
    let fnames = ["imageprocess", "speech2text", "compress"];
    // One cell per function; workers render and the caller prints in order.
    let rendered = sweep::parallel_map(&fnames, ctx.jobs, |_, fname| {
        let fi = index_of(fname).unwrap();
        let mut rng = Rng::new(ctx.seed);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let mut t = Table::new(
            &format!("Fig 2 — {fname}: input size vs execution time"),
            &["size (MB)", "t@4vcpu (s)", "t@8vcpu (s)", "t@16vcpu (s)", "spread %@16"],
        );
        for input in &pool {
            let mut cols = vec![fnum(input.size_mb(), 2)];
            let mut spread = 0.0;
            for vcpus in [4u32, 8, 16] {
                let times: Vec<f64> = (0..10)
                    .map(|_| {
                        CATALOG[fi]
                            .noisy_demand(input, &mut rng)
                            .ideal_exec_s(vcpus as f64, 10.0)
                    })
                    .collect();
                let s = stats::summarize(&times);
                if vcpus == 16 {
                    spread = 100.0 * (s.max - s.min) / s.p50.max(1e-9);
                }
                cols.push(fnum(s.p50, 2));
            }
            cols.push(fpct(spread));
            t.row(cols);
        }
        t.note("positive but non-linear growth; spread grows with size for multi-threaded");
        t.render()
    });
    for table in rendered {
        print!("{table}");
    }
    Ok(())
}

/// Figure 3: videoprocess vCPU / memory utilization vs video size for
/// set-1 (varying resolution) vs set-2 (constant 1280x720).
pub fn fig3(ctx: &Ctx) -> Result<()> {
    let fi = index_of("videoprocess").unwrap();
    let mut rng = Rng::new(ctx.seed);
    let set1 = inputs::video_pool_set1(&mut rng, 5);
    let set2 = inputs::video_pool_set2(&mut rng, 5);
    for (label, set) in [("set-1 (varying resolution)", &set1), ("set-2 (1280x720)", &set2)] {
        let mut t = Table::new(
            &format!("Fig 3 — videoprocess {label}"),
            &["size (MB)", "resolution", "vCPUs used (48 alloc)", "mem used (GB)"],
        );
        for input in set.iter() {
            let d = (CATALOG[fi].demand)(input);
            t.row(vec![
                fnum(input.size_mb(), 2),
                format!("{}x{}", input.width as u32, input.height as u32),
                fnum(d.avg_vcpus_used(48.0, 10.0), 1),
                fnum(d.mem_gb, 2),
            ]);
        }
        t.note("same-sized inputs differ ~70% in vCPUs when resolution varies");
        t.print();
    }
    Ok(())
}

/// Figure 4: execution time (top) and vCPU utilization (bottom) vs vCPU
/// allocation for compress, resnet-50, imageprocess — bounded parallelism.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let fnames = ["compress", "resnet50", "imageprocess"];
    let rendered = sweep::parallel_map(&fnames, ctx.jobs, |_, fname| {
        let fi = index_of(fname).unwrap();
        let mut rng = Rng::new(ctx.seed);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let small = &pool[1];
        let large = &pool[pool.len() - 1];
        let mut t = Table::new(
            &format!("Fig 4 — {fname}: exec time & vCPU utilization vs allocation"),
            &["vcpus", "t small (s)", "t large (s)", "used small", "used large"],
        );
        for vcpus in [1u32, 2, 4, 8, 16, 24, 32] {
            let ds = (CATALOG[fi].demand)(small);
            let dl = (CATALOG[fi].demand)(large);
            t.row(vec![
                vcpus.to_string(),
                fnum(ds.ideal_exec_s(vcpus as f64, 10.0), 2),
                fnum(dl.ideal_exec_s(vcpus as f64, 10.0), 2),
                fnum(ds.avg_vcpus_used(vcpus as f64, 10.0), 1),
                fnum(dl.avg_vcpus_used(vcpus as f64, 10.0), 1),
            ]);
        }
        t.note("gains saturate at bounded parallelism; imageprocess pinned at ~1 vCPU");
        t.render()
    });
    for table in rendered {
        print!("{table}");
    }
    Ok(())
}

/// Sanity helper used by integration tests: the Fig-3 resolution effect
/// as numbers (set-1 vCPU spread at same size vs set-2).
pub fn fig3_vcpu_spread(seed: u64) -> (f64, f64) {
    let fi = index_of("videoprocess").unwrap();
    let mut rng = Rng::new(seed);
    let spread = |set: &[crate::featurizer::InputSpec]| {
        let used: Vec<f64> =
            set.iter().map(|i| (CATALOG[fi].demand)(i).avg_vcpus_used(48.0, 10.0)).collect();
        let s = stats::summarize(&used);
        (s.max - s.min) / s.max.max(1e-9)
    };
    let s1 = inputs::video_pool_set1(&mut rng, 5);
    let s2 = inputs::video_pool_set2(&mut rng, 5);
    (spread(&s1), spread(&s2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figures_run_without_error() {
        let ctx = Ctx::default();
        fig1(&ctx).unwrap();
        fig3(&ctx).unwrap();
        fig4(&ctx).unwrap();
    }

    #[test]
    fn resolution_effect_shape_holds() {
        let (s1, s2) = fig3_vcpu_spread(1);
        assert!(s1 > 0.5, "set-1 spans a wide vCPU range: {s1}");
        assert!(s2 < 0.2, "set-2 nearly constant: {s2}");
    }

    #[test]
    fn fig4_imageprocess_flat() {
        let fi = index_of("imageprocess").unwrap();
        let mut rng = Rng::new(1);
        let pool = inputs::pool(&CATALOG[fi], &mut rng);
        let d = (CATALOG[fi].demand)(&pool[5]);
        let t1 = d.ideal_exec_s(1.0, 10.0);
        let t32 = d.ideal_exec_s(32.0, 10.0);
        assert!((t1 - t32).abs() < 1e-9, "single-threaded is allocation-flat");
    }

    #[test]
    fn input_kind_unused_guard() {
        // compile-time usage of InputKind in this module's imports
        let _ = InputKind::Video;
        let _ = profiling::representative_inputs;
    }
}
