//! Figure 8 — the headline end-to-end comparison: SLO violations, wasted
//! vCPUs/memory, and utilization for Shabari vs all baselines across
//! RPS 2–6.

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};

use super::common::{run_one, sim_config, Ctx};

/// The six systems of Fig 8, in the paper's order.
pub const FIG8_POLICIES: &[&str] = &[
    "static-medium",
    "static-large",
    "parrotfish",
    "cypress",
    "aquatope",
    "shabari",
];

/// Run the full sweep; returns metrics[policy][rps_idx].
pub fn run_sweep(ctx: &Ctx, rps_list: &[f64]) -> Result<Vec<Vec<RunMetrics>>> {
    let workload = ctx.workload();
    let cfg = sim_config(ctx);
    let mut all = Vec::new();
    for name in FIG8_POLICIES {
        let mut per_rps = Vec::new();
        for &rps in rps_list {
            let (_, m) = run_one(name, ctx, &workload, rps, &cfg)?;
            per_rps.push(m);
        }
        all.push(per_rps);
    }
    Ok(all)
}

pub fn fig8(ctx: &Ctx) -> Result<()> {
    let rps_list = [2.0, 3.0, 4.0, 5.0, 6.0];
    let all = run_sweep(ctx, &rps_list)?;

    let mut t = Table::new(
        "Fig 8a — % SLO violations",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| fpct(m.slo_violation_pct)));
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8b — wasted vCPUs per invocation (p50 / p95)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(
            all[pi]
                .iter()
                .map(|m| format!("{}/{}", fnum(m.wasted_vcpus.p50, 1), fnum(m.wasted_vcpus.p95, 1))),
        );
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8c — wasted memory GB per invocation (p50 / p95)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| {
            format!("{}/{}", fnum(m.wasted_mem_gb.p50, 2), fnum(m.wasted_mem_gb.p95, 2))
        }));
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8d — vCPU utilization per invocation (p50)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| fpct(100.0 * m.vcpu_utilization.p50)));
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8e — memory utilization per invocation (p50)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| fpct(100.0 * m.mem_utilization.p50)));
        t.row(row);
    }
    t.print();

    // machine-readable dump for EXPERIMENTS.md bookkeeping
    let dump = Json::Arr(
        FIG8_POLICIES
            .iter()
            .enumerate()
            .map(|(pi, name)| {
                Json::obj(vec![
                    ("policy", Json::Str(name.to_string())),
                    (
                        "slo_violation_pct",
                        Json::arr_f64(
                            &all[pi].iter().map(|m| m.slo_violation_pct).collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "wasted_vcpus_p50",
                        Json::arr_f64(&all[pi].iter().map(|m| m.wasted_vcpus.p50).collect::<Vec<_>>()),
                    ),
                    (
                        "wasted_mem_p50",
                        Json::arr_f64(
                            &all[pi].iter().map(|m| m.wasted_mem_gb.p50).collect::<Vec<_>>(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    std::fs::create_dir_all("out").ok();
    std::fs::write("out/fig8.json", dump.to_pretty()).ok();
    println!("(dumped out/fig8.json)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline shapes on a scaled-down sweep (one RPS, shorter trace).
    #[test]
    fn fig8_shapes_hold_at_high_load() {
        let ctx = Ctx { duration_s: 300.0, ..Default::default() };
        let all = run_sweep(&ctx, &[6.0]).unwrap();
        let get = |name: &str| {
            &all[FIG8_POLICIES.iter().position(|p| *p == name).unwrap()][0]
        };
        let shabari = get("shabari");
        let cypress = get("cypress");
        let parrotfish = get("parrotfish");

        // Shabari beats the input-agnostic/size-only systems at high load
        assert!(
            shabari.slo_violation_pct < cypress.slo_violation_pct,
            "shabari {} vs cypress {}",
            shabari.slo_violation_pct,
            cypress.slo_violation_pct
        );
        // Shabari wastes less memory than Parrotfish (median)
        assert!(
            shabari.wasted_mem_gb.p50 < parrotfish.wasted_mem_gb.p50 + 0.1,
            "shabari {} vs parrotfish {}",
            shabari.wasted_mem_gb.p50,
            parrotfish.wasted_mem_gb.p50
        );
        // Shabari's median wasted vCPUs ~0 (headline claim)
        assert!(
            shabari.wasted_vcpus.p50 <= 1.0,
            "median wasted vCPUs ~0, got {}",
            shabari.wasted_vcpus.p50
        );
    }
}
