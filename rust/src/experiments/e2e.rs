//! Figure 8 — the headline end-to-end comparison: SLO violations, wasted
//! vCPUs/memory, and utilization for Shabari vs all baselines across
//! RPS 2–6, as a (policy × rps) sweep grid replicated over `Ctx::seeds`
//! seeds on `Ctx::jobs` threads (DESIGN.md §4).

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};

use super::common::{perf_json, run_cell, Ctx};
use super::sweep::{self, Cell, CellOutcome};

/// The six systems of Fig 8, in the paper's order.
pub const FIG8_POLICIES: &[&str] = &[
    "static-medium",
    "static-large",
    "parrotfish",
    "cypress",
    "aquatope",
    "shabari",
];

/// Run the full grid; outcome `[pi * rps_list.len() + ri]` holds policy
/// `FIG8_POLICIES[pi]` at `rps_list[ri]` with all per-seed metrics.
pub fn run_sweep_outcomes(
    ctx: &Ctx,
    rps_list: &[f64],
) -> Result<Vec<CellOutcome<RunMetrics>>> {
    let cells: Vec<Cell> = FIG8_POLICIES
        .iter()
        .flat_map(|p| rps_list.iter().map(move |&rps| Cell::new(p, rps)))
        .collect();
    sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_cell(&cell.policy, ctx, cell.rps, seed)
    })
}

/// Reduce the flat outcome grid to cross-seed means `[policy][rps_idx]`
/// — the one reduction both `run_sweep` and `fig8`'s tables use.
fn mean_matrix(outcomes: &[CellOutcome<RunMetrics>], rps_count: usize) -> Vec<Vec<RunMetrics>> {
    outcomes
        .chunks(rps_count)
        .map(|per_policy| per_policy.iter().map(|o| o.mean_metrics()).collect())
        .collect()
}

/// Run the full sweep; returns cross-seed mean metrics[policy][rps_idx]
/// (with `Ctx::seeds == 1` this is exactly the single-run result).
pub fn run_sweep(ctx: &Ctx, rps_list: &[f64]) -> Result<Vec<Vec<RunMetrics>>> {
    Ok(mean_matrix(&run_sweep_outcomes(ctx, rps_list)?, rps_list.len()))
}

pub fn fig8(ctx: &Ctx) -> Result<()> {
    let rps_list = [2.0, 3.0, 4.0, 5.0, 6.0];
    // lint:allow(D002): host wall time for the runner's wall-clock report line only
    let t0 = std::time::Instant::now();
    let outcomes = run_sweep_outcomes(ctx, &rps_list)?;
    let wall = t0.elapsed().as_secs_f64();
    let all = mean_matrix(&outcomes, rps_list.len());
    println!(
        "(sweep: {} cells x {} seed(s) on {} job(s), {:.1}s wall)",
        outcomes.len(),
        ctx.seeds,
        ctx.jobs,
        wall
    );

    let mut t = Table::new(
        "Fig 8a — % SLO violations",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| fpct(m.slo_violation_pct)));
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8b — wasted vCPUs per invocation (p50 / p95)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(
            all[pi]
                .iter()
                .map(|m| format!("{}/{}", fnum(m.wasted_vcpus.p50, 1), fnum(m.wasted_vcpus.p95, 1))),
        );
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8c — wasted memory GB per invocation (p50 / p95)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| {
            format!("{}/{}", fnum(m.wasted_mem_gb.p50, 2), fnum(m.wasted_mem_gb.p95, 2))
        }));
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8d — vCPU utilization per invocation (p50)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| fpct(100.0 * m.vcpu_utilization.p50)));
        t.row(row);
    }
    t.print();

    let mut t = Table::new(
        "Fig 8e — memory utilization per invocation (p50)",
        &["system", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        row.extend(all[pi].iter().map(|m| fpct(100.0 * m.mem_utilization.p50)));
        t.row(row);
    }
    t.print();

    // Cross-seed dispersion at the highest load: mean/p50/p99 + bootstrap
    // 95% CI over the per-seed replicates (EXPERIMENTS.md describes the
    // aggregation; degenerate at --seeds 1).
    let hi = rps_list.len() - 1;
    let mut t = Table::new(
        &format!(
            "Fig 8 — cross-seed statistics @ RPS {} ({} seeds)",
            rps_list[hi], ctx.seeds
        ),
        &[
            "system",
            "viol% mean [95% CI]",
            "viol% p50",
            "viol% p99",
            "waste mem p50 GB [95% CI]",
        ],
    );
    for (pi, name) in FIG8_POLICIES.iter().enumerate() {
        let out = &outcomes[pi * rps_list.len() + hi];
        let viol = out.stat(|m| m.slo_violation_pct);
        let mem = out.stat(|m| m.wasted_mem_gb.p50);
        t.row(vec![
            name.to_string(),
            viol.fmt_ci(1),
            fnum(viol.p50, 1),
            fnum(viol.p99, 1),
            mem.fmt_ci(2),
        ]);
    }
    t.note("CI = percentile bootstrap over seeds; widen --seeds to tighten");
    t.print();

    // machine-readable dump for EXPERIMENTS.md bookkeeping
    let policies = Json::Arr(
        FIG8_POLICIES
            .iter()
            .enumerate()
            .map(|(pi, name)| {
                Json::obj(vec![
                    ("policy", Json::Str(name.to_string())),
                    (
                        "slo_violation_pct",
                        Json::arr_f64(
                            &all[pi].iter().map(|m| m.slo_violation_pct).collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "wasted_vcpus_p50",
                        Json::arr_f64(&all[pi].iter().map(|m| m.wasted_vcpus.p50).collect::<Vec<_>>()),
                    ),
                    (
                        "wasted_mem_p50",
                        Json::arr_f64(
                            &all[pi].iter().map(|m| m.wasted_mem_gb.p50).collect::<Vec<_>>(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    let dump =
        Json::obj(vec![("perf", perf_json(wall, &outcomes)), ("policies", policies)]);
    std::fs::create_dir_all("out").ok();
    match std::fs::write("out/fig8.json", dump.to_pretty()) {
        Ok(()) => println!("(dumped out/fig8.json)"),
        Err(e) => eprintln!("warning: could not write out/fig8.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline shapes on a scaled-down sweep (one RPS, shorter trace).
    #[test]
    fn fig8_shapes_hold_at_high_load() {
        let ctx = Ctx { duration_s: 300.0, ..Default::default() };
        let all = run_sweep(&ctx, &[6.0]).unwrap();
        let get = |name: &str| {
            &all[FIG8_POLICIES.iter().position(|p| *p == name).unwrap()][0]
        };
        let shabari = get("shabari");
        let cypress = get("cypress");
        let parrotfish = get("parrotfish");

        // Shabari beats the input-agnostic/size-only systems at high load
        assert!(
            shabari.slo_violation_pct < cypress.slo_violation_pct,
            "shabari {} vs cypress {}",
            shabari.slo_violation_pct,
            cypress.slo_violation_pct
        );
        // Shabari wastes less memory than Parrotfish (median)
        assert!(
            shabari.wasted_mem_gb.p50 < parrotfish.wasted_mem_gb.p50 + 0.1,
            "shabari {} vs parrotfish {}",
            shabari.wasted_mem_gb.p50,
            parrotfish.wasted_mem_gb.p50
        );
        // Shabari's median wasted vCPUs ~0 (headline claim)
        assert!(
            shabari.wasted_vcpus.p50 <= 1.0,
            "median wasted vCPUs ~0, got {}",
            shabari.wasted_vcpus.p50
        );
    }
}
