//! `experiment replay` — real-trace replay made first-class: the
//! streaming Azure-schema ingest (DESIGN.md §Trace ingest) characterized
//! up front, then a policy × cluster-scaler grid replayed over the trace
//! (DESIGN.md §Scaler), with the scaling timeline of one replicate
//! exported alongside the cross-seed means.
//!
//! The question it answers: every other experiment drives synthetic
//! arrival shapes on a fixed-size cluster. Shabari's deployment story is
//! a real trace on an elastic pool — so this runner replays the
//! configured trace (`--scenario trace-file:<path>`, or the embedded
//! sample) and scores each policy twice: on the frozen cluster
//! (`scaler:none`, byte-identical to the other experiments) and under
//! Fifer-style reactive scaling (`scaler:fifer`), where capacity chases
//! the trace's minute-scale bursts with a provisioning lag.
//!
//! Report sections (`out/replay.json`): `replay_mix` (functions, skew,
//! burstiness, ingest residency), `rows` (the grid), `scaling_timeline`
//! (timestamped provision/ready/drain events from replicate 0 of the
//! first policy under `fifer`), `config`, `perf`.
//!
//! Emits `out/replay.json` (`make replay`; CI runs a shrunk smoke).

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::simulator::scaler;
use crate::simulator::SimConfig;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};
use crate::workload::scenario::trace_file::{TraceFile, TOP_K};

use super::common::{self, Ctx};
use super::sweep::{self, Cell, CellOutcome};

/// Policies on the replay grid: the full stack and the biggest static
/// baseline (the paper's main foil) — the pair whose gap the scaler axis
/// is expected to shrink.
pub const REPLAY_POLICIES: &[&str] = &["shabari", "static-large"];

/// The scaler axis: frozen cluster (control, byte-pinned) vs Fifer-style
/// reactive whole-worker scaling.
pub const REPLAY_SCALERS: &[&str] = &["none", "fifer"];

/// Replay load: busy enough on the small base pool that trace bursts
/// queue (giving the scaler a real signal), below the overload meltdown.
pub const REPLAY_RPS: f64 = 12.0;

/// Base pool for the replay grid: small, so one scaled-up worker is a
/// real fraction of capacity and the `fifer` column visibly diverges.
pub const REPLAY_WORKERS: usize = 4;

/// How many retained functions the `replay_mix` section lists by name.
const MIX_TOP_LISTED: usize = 8;

/// The scenario this replay drives: the context's own trace when one was
/// configured, otherwise the embedded sample trace.
fn replay_scenario(ctx: &Ctx) -> String {
    if ctx.scenario == "trace-file" || ctx.scenario.starts_with("trace-file:") {
        ctx.scenario.clone()
    } else {
        "trace-file".to_string()
    }
}

/// Parse the replay scenario's trace through the streaming ingest (the
/// same parser the scenario registry uses — the memoized path cache makes
/// this free for on-disk traces the grid also loads).
fn load_trace(scenario: &str) -> Result<TraceFile> {
    match scenario.strip_prefix("trace-file:") {
        Some(path) => TraceFile::from_path(path),
        None => TraceFile::sample(),
    }
}

/// Cell label carrying the scaler axis (distinct labels salt replicate
/// seeds, so `none` and `fifer` sample disjoint streams at replicates
/// ≥ 1 while replicate 0 stays grid-wide paired).
fn cell_label(scaler: &str) -> String {
    format!("scaler:{scaler}")
}

/// Recover the scaler name from a cell label.
fn cell_scaler(cell: &Cell) -> &str {
    cell.label.strip_prefix("scaler:").unwrap_or(&cell.label)
}

/// Run the policy × scaler grid over the replay trace; outcome index is
/// `pi * REPLAY_SCALERS.len() + si`. Every replicate runs
/// `Cluster::check_invariants()` — the release-mode audit covers scaled-up
/// extension workers exactly like base ones.
pub fn run_replay(ctx: &Ctx, rps: f64) -> Result<Vec<CellOutcome<RunMetrics>>> {
    let scenario = replay_scenario(ctx);
    let cells: Vec<Cell> = REPLAY_POLICIES
        .iter()
        .flat_map(|p| {
            REPLAY_SCALERS
                .iter()
                .map(move |s| Cell::labeled(p, rps, &cell_label(s), REPLAY_WORKERS as f64))
        })
        .collect();
    sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        let spec = scaler::parse(cell_scaler(cell))?;
        let cctx = ctx.with_seed(seed).with_scenario(&scenario).with_scaler(spec);
        let workload = cctx.workload();
        let cfg = SimConfig { workers: REPLAY_WORKERS, ..common::sim_config(&cctx) };
        let (res, metrics) = common::run_one(&cell.policy, &cctx, &workload, cell.rps, &cfg)?;
        res.cluster.check_invariants();
        Ok(metrics)
    })
}

/// The `replay_mix` characterization: what the ingest retained and how
/// bursty / skewed the replayed trace is. Pure function of the parsed
/// trace — no RNG, no simulation.
fn mix_json(trace: &TraceFile) -> Json {
    let ingest = trace.ingest();
    let per_minute = trace.per_minute();
    let total: u64 = per_minute.iter().sum();
    let mean = total as f64 / per_minute.len().max(1) as f64;
    let max = per_minute.iter().copied().max().unwrap_or(0) as f64;
    let top_share = |k: usize| -> f64 {
        let head: u64 = ingest.top.iter().take(k).map(|p| p.total).sum();
        if total > 0 {
            100.0 * head as f64 / total as f64
        } else {
            0.0
        }
    };
    Json::obj(vec![
        ("minutes", Json::Num(ingest.minutes as f64)),
        ("rows", Json::Num(ingest.rows as f64)),
        ("functions_retained", Json::Num(ingest.top.len() as f64)),
        ("tail_rows", Json::Num(ingest.tail_rows as f64)),
        ("top_k", Json::Num(TOP_K as f64)),
        ("peak_resident_profiles", Json::Num(ingest.peak_resident as f64)),
        ("invocations_total", Json::Num(total as f64)),
        ("tail_invocations", Json::Num(ingest.tail_total() as f64)),
        ("per_minute_mean", Json::Num(mean)),
        ("per_minute_max", Json::Num(max)),
        // minute-scale burstiness: how far the worst minute sits above
        // the average (1.0 = perfectly flat)
        ("burstiness_max_over_mean", Json::Num(if mean > 0.0 { max / mean } else { 0.0 })),
        ("top1_share_pct", Json::Num(top_share(1))),
        ("top8_share_pct", Json::Num(top_share(8))),
        (
            "top",
            Json::Arr(
                ingest
                    .top
                    .iter()
                    .take(MIX_TOP_LISTED)
                    .map(|p| {
                        Json::obj(vec![
                            ("name", Json::Str(p.name.clone())),
                            ("total", Json::Num(p.total as f64)),
                            (
                                "share_pct",
                                Json::Num(if total > 0 {
                                    100.0 * p.total as f64 / total as f64
                                } else {
                                    0.0
                                }),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One extra replicate-0 run of the first policy under `fifer`, kept for
/// its event-level scaling timeline (the grid only keeps aggregated
/// metrics). Same seed and config as the grid's replicate 0, so the
/// timeline matches the reported cell.
fn timeline_json(ctx: &Ctx, scenario: &str, rps: f64) -> Result<Json> {
    let spec = scaler::parse("fifer")?;
    let cctx = ctx.with_scenario(scenario).with_scaler(spec);
    let workload = cctx.workload();
    let cfg = SimConfig { workers: REPLAY_WORKERS, ..common::sim_config(&cctx) };
    let (res, _) = common::run_one(REPLAY_POLICIES[0], &cctx, &workload, rps, &cfg)?;
    Ok(Json::obj(vec![
        ("policy", Json::Str(REPLAY_POLICIES[0].to_string())),
        ("scaler", Json::Str(spec.label())),
        ("base_workers", Json::Num(REPLAY_WORKERS as f64)),
        ("scale_ups", Json::Num(res.scale_ups as f64)),
        ("scale_downs", Json::Num(res.scale_downs as f64)),
        ("peak_up_workers", Json::Num(res.peak_up_workers as f64)),
        (
            "events",
            Json::Arr(
                res.scaling
                    .iter()
                    .map(|e| {
                        Json::obj(vec![
                            ("at_s", Json::Num(e.at)),
                            ("worker", Json::Num(e.worker as f64)),
                            ("action", Json::Str(e.action.label().to_string())),
                            ("up_workers", Json::Num(e.up_workers as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]))
}

pub fn replay(ctx: &Ctx) -> Result<()> {
    // lint:allow(D002): host wall time for the runner's wall-clock report line only
    let t0 = std::time::Instant::now();
    let scenario = replay_scenario(ctx);
    let trace = load_trace(&scenario)?;
    let outcomes = run_replay(ctx, REPLAY_RPS)?;
    let timeline = timeline_json(ctx, &scenario, REPLAY_RPS)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "(replay: scenario {scenario}, {} cells x {} seed(s) on {} job(s), {wall:.1}s wall; \
         cluster invariants held on every replicate)",
        outcomes.len(),
        ctx.seeds,
        ctx.jobs
    );

    let ingest = trace.ingest();
    println!(
        "(trace mix: {} rows -> {} retained + {} tail over {} minutes; \
         peak resident profiles {} <= top-K+1 = {})",
        ingest.rows,
        ingest.top.len(),
        ingest.tail_rows,
        ingest.minutes,
        ingest.peak_resident,
        TOP_K + 1
    );

    let mut t = Table::new(
        &format!(
            "replay: {} base workers @ {} rps, {}s trace (cross-seed means; \
             peak = largest serving pool any replicate reached)",
            REPLAY_WORKERS, REPLAY_RPS, ctx.duration_s
        ),
        &[
            "system",
            "scaler",
            "SLO viol [95% CI]",
            "cold",
            "queue p99 s",
            "scale-ups",
            "scale-downs",
            "peak workers",
        ],
    );
    for out in &outcomes {
        let m = out.mean_metrics();
        t.row(vec![
            out.cell.policy.clone(),
            cell_scaler(&out.cell).to_string(),
            out.stat(|m| m.slo_violation_pct).fmt_ci(1),
            fpct(m.cold_start_pct),
            fnum(m.queue_wait.p99, 2),
            m.scale_up_events.to_string(),
            m.scale_down_events.to_string(),
            m.peak_up_workers.to_string(),
        ]);
    }
    t.note(
        "expected shape: scaler:none reproduces the fixed-cluster streams byte-for-byte; \
         fifer trades extra (cold) capacity during trace bursts for lower queueing, \
         and drains back to the base pool between them",
    );
    t.print();

    let dump = Json::obj(vec![
        ("perf", common::perf_json(wall, &outcomes)),
        (
            "config",
            Json::obj(vec![
                ("scenario", Json::Str(scenario.clone())),
                ("base_workers", Json::Num(REPLAY_WORKERS as f64)),
                ("rps", Json::Num(REPLAY_RPS)),
                ("duration_s", Json::Num(ctx.duration_s)),
                ("seeds", Json::Num(ctx.seeds as f64)),
                ("jobs", Json::Num(ctx.jobs as f64)),
                ("seed", Json::Num(ctx.seed as f64)),
            ]),
        ),
        ("replay_mix", mix_json(&trace)),
        ("scaling_timeline", timeline),
        (
            "rows",
            Json::Arr(
                outcomes
                    .iter()
                    .map(|out| {
                        let m = out.mean_metrics();
                        let viol = out.stat(|m| m.slo_violation_pct);
                        Json::obj(vec![
                            ("policy", Json::Str(out.cell.policy.clone())),
                            ("scaler", Json::Str(cell_scaler(&out.cell).to_string())),
                            ("slo_violation_pct_mean", Json::Num(viol.mean)),
                            ("slo_violation_pct_ci95_lo", Json::Num(viol.ci95.0)),
                            ("slo_violation_pct_ci95_hi", Json::Num(viol.ci95.1)),
                            ("cold_start_pct", Json::Num(m.cold_start_pct)),
                            ("queue_p99_s", Json::Num(m.queue_wait.p99)),
                            ("queued_pct", Json::Num(m.queued_pct)),
                            ("mean_e2e_s", Json::Num(m.mean_e2e_s)),
                            ("scale_up_events", Json::Num(m.scale_up_events as f64)),
                            ("scale_down_events", Json::Num(m.scale_down_events as f64)),
                            ("peak_up_workers", Json::Num(m.peak_up_workers as f64)),
                            ("idle_container_s", Json::Num(m.idle_container_s)),
                            ("peak_alloc_vcpus", Json::Num(m.peak_alloc_vcpus)),
                            ("invocations", Json::Num(m.invocations as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::create_dir_all("out").ok();
    match std::fs::write("out/replay.json", dump.to_pretty()) {
        Ok(()) => println!("(dumped out/replay.json)"),
        Err(e) => eprintln!("warning: could not write out/replay.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_labels_round_trip_and_salt_replicate_seeds() {
        let c = Cell::labeled("shabari", REPLAY_RPS, &cell_label("fifer"), 4.0);
        assert_eq!(cell_scaler(&c), "fifer");
        // distinct scaler modes occupy distinct seed streams at rep >= 1,
        // but replicate 0 stays paired for the byte-pin comparison
        let a = Cell::labeled("shabari", REPLAY_RPS, &cell_label("none"), 4.0);
        let b = Cell::labeled("shabari", REPLAY_RPS, &cell_label("fifer"), 4.0);
        assert_ne!(sweep::cell_seed(42, &a, 1), sweep::cell_seed(42, &b, 1));
        assert_eq!(sweep::cell_seed(42, &a, 0), sweep::cell_seed(42, &b, 0));
    }

    #[test]
    fn replay_scenario_keeps_trace_files_and_overrides_everything_else() {
        let ctx = Ctx::default();
        assert_eq!(replay_scenario(&ctx), "trace-file");
        assert_eq!(replay_scenario(&ctx.with_scenario("trace-file")), "trace-file");
        assert_eq!(
            replay_scenario(&ctx.with_scenario("trace-file:/tmp/azure.csv")),
            "trace-file:/tmp/azure.csv"
        );
        assert_eq!(replay_scenario(&ctx.with_scenario("diurnal")), "trace-file");
    }

    #[test]
    fn mix_section_characterizes_the_sample_trace() {
        let trace = TraceFile::sample().unwrap();
        let text = mix_json(&trace).to_pretty();
        // the sample: 8 rows over 10 minutes, all retained, no tail, and
        // a visible burst (minute 5 carries ~2.6x the mean)
        assert!(text.contains("\"minutes\": 10"), "{text}");
        assert!(text.contains("\"rows\": 8"), "{text}");
        assert!(text.contains("\"functions_retained\": 8"), "{text}");
        assert!(text.contains("\"tail_invocations\": 0"), "{text}");
        let mix = mix_json(&trace);
        let burst = match mix.get("burstiness_max_over_mean") {
            Some(Json::Num(n)) => *n,
            other => panic!("burstiness missing or non-numeric: {other:?}"),
        };
        assert!(burst > 1.5, "sample trace should read bursty, got {burst}");
    }

    /// Tiny-parameter smoke mirroring the CI job: the grid covers every
    /// (policy, scaler) pair, is deterministic across thread counts (the
    /// ISSUE's scaler determinism pin), and the frozen-cluster control
    /// column reports zero scaling activity at exactly the base pool.
    #[test]
    fn replay_grid_covers_axes_and_is_jobs_invariant() {
        let ctx = Ctx { duration_s: 30.0, seeds: 1, ..Default::default() };
        let seq = run_replay(&Ctx { jobs: 1, ..ctx.clone() }, REPLAY_RPS).unwrap();
        let par = run_replay(&Ctx { jobs: 4, ..ctx }, REPLAY_RPS).unwrap();
        assert_eq!(seq.len(), REPLAY_POLICIES.len() * REPLAY_SCALERS.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.cell.id(), b.cell.id());
            let (ma, mb) = (a.mean_metrics(), b.mean_metrics());
            assert_eq!(ma.invocations, mb.invocations);
            assert_eq!(
                ma.slo_violation_pct.to_bits(),
                mb.slo_violation_pct.to_bits(),
                "{} diverged across --jobs",
                a.cell.id()
            );
            assert_eq!(ma.scale_up_events, mb.scale_up_events);
            assert_eq!(ma.scale_down_events, mb.scale_down_events);
            assert_eq!(ma.peak_up_workers, mb.peak_up_workers);
            match cell_scaler(&a.cell) {
                "none" => {
                    assert_eq!(ma.scale_up_events, 0, "{}", a.cell.id());
                    assert_eq!(ma.scale_down_events, 0, "{}", a.cell.id());
                    assert_eq!(ma.peak_up_workers, REPLAY_WORKERS, "{}", a.cell.id());
                }
                "fifer" => {
                    assert!(ma.peak_up_workers >= REPLAY_WORKERS, "{}", a.cell.id());
                    assert!(
                        ma.peak_up_workers <= REPLAY_WORKERS * scaler::MAX_SCALE_FACTOR,
                        "{}: peak {} above the scale cap",
                        a.cell.id(),
                        ma.peak_up_workers
                    );
                }
                other => panic!("unregistered scaler {other}"),
            }
        }
    }
}
