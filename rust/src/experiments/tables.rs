//! Tables 1–3: the function/input catalog, the per-type feature lists,
//! and the number of unique container sizes Shabari creates per function.

use anyhow::Result;

use crate::functions::catalog::CATALOG;
use crate::functions::inputs;
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

use super::common::{run_one, sim_config, Ctx};
use super::sweep::{self, Cell};

/// Table 1: the function catalog (encoded in `functions::catalog`).
pub fn table1(ctx: &Ctx) -> Result<()> {
    let mut rng = Rng::new(ctx.seed);
    let mut t = Table::new(
        "Table 1 — serverless functions studied",
        &["function", "input type", "#sizes", "size range", "threading", "db fetch"],
    );
    for spec in CATALOG {
        let pool = inputs::pool(spec, &mut rng);
        let lo = pool.iter().map(|i| i.size_bytes).fold(f64::INFINITY, f64::min);
        let hi = pool.iter().map(|i| i.size_bytes).fold(0.0f64, f64::max);
        t.row(vec![
            spec.name.to_string(),
            spec.input_kind.name().to_string(),
            pool.len().to_string(),
            format!("{} - {}", human_bytes(lo), human_bytes(hi)),
            if spec.multi_threaded { "multi".into() } else { "single".into() },
            if spec.fetches_from_db { "yes".into() } else { "no".into() },
        ]);
    }
    t.print();
    Ok(())
}

/// Table 2: features extracted per input type (Appendix A).
pub fn table2(_ctx: &Ctx) -> Result<()> {
    let mut t = Table::new("Table 2 — features per input type", &["input type", "features"]);
    let rows: &[(&str, &str)] = &[
        ("image", "width, height, channels, x-dpi, y-dpi, filesize, raw-px"),
        ("matrix", "rows, cols, density, filesize, raw-elems"),
        ("video", "width, height, duration, bitrate, fps, encoding, filesize, raw-px"),
        ("csv", "rows, cols, filesize, raw-size"),
        ("json", "outer-object length, filesize, raw-size"),
        ("audio", "channels, sample rate, duration, bitrate, FLAC flag, filesize, raw-dur"),
        ("payload", "length, size, raw-length"),
        ("file", "filesize, raw-size"),
    ];
    for (k, f) in rows {
        t.row(vec![k.to_string(), f.to_string()]);
    }
    t.note("raw-* features are normalized linear terms added for the linear CSOAA basis");
    t.print();
    Ok(())
}

/// Table 3: number of unique container sizes Shabari creates per function
/// across RPS 2–6 — a five-cell sweep whose per-seed result is the
/// per-function unique-size count (cross-seed mean when `--seeds > 1`).
pub fn table3(ctx: &Ctx) -> Result<()> {
    let rps_list = [2.0, 3.0, 4.0, 5.0, 6.0];
    let cells: Vec<Cell> = rps_list.iter().map(|&rps| Cell::new("shabari", rps)).collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        let cctx = ctx.with_seed(seed);
        let workload = cctx.workload();
        let cfg = sim_config(&cctx);
        let (res, _) = run_one(&cell.policy, &cctx, &workload, cell.rps, &cfg)?;
        Ok((0..CATALOG.len()).map(|fi| res.unique_container_sizes(fi)).collect::<Vec<_>>())
    })?;
    let mut t = Table::new(
        &format!("Table 3 — unique container sizes per function ({} seed(s))", ctx.seeds),
        &["function", "rps2", "rps3", "rps4", "rps5", "rps6"],
    );
    for (fi, spec) in CATALOG.iter().enumerate() {
        let mut row = vec![spec.name.to_string()];
        for out in &outcomes {
            let mean = out.stat_by(|sizes| sizes[fi] as f64).mean;
            row.push(fnum(mean, 1));
        }
        t.row(row);
    }
    t.note("multi-threaded functions explore more sizes as load grows (§7.3)");
    t.print();
    Ok(())
}

fn human_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.1}G", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1}M", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.0}K", b / 1e3)
    } else {
        format!("{b:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_print() {
        let ctx = Ctx::default();
        table1(&ctx).unwrap();
        table2(&ctx).unwrap();
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(500.0), "500");
        assert_eq!(human_bytes(12_000.0), "12K");
        assert_eq!(human_bytes(4.6e6), "4.6M");
        assert_eq!(human_bytes(2e9), "2.0G");
    }
}
