//! `experiment overload` — drive the cluster past saturation and verify
//! the engine's admission invariant end-to-end (DESIGN.md §Admission).
//!
//! An rps sweep from comfortable load to several times cluster capacity,
//! on a deliberately small cluster (`--overload-workers`, default 4), for
//! three systems with very different admission pressure: the full Shabari
//! stack, Shabari's allocator under the memory-centric OpenWhisk
//! scheduler (the §5 oversubscriber), and Static-Large (big fixed asks).
//! Past saturation the expected shape is: throughput plateaus at cluster
//! capacity, queue waits grow from zero through seconds to walltime
//! scale, and the tail converts into `TimedOut` sheds — while
//! `peak_alloc_vcpus` stays pinned at or under `sched_vcpu_limit` on
//! every worker of every replicate (the run *fails* otherwise; before
//! this invariant existed, the engine silently allocated past the limit
//! on exactly these grids).
//!
//! Emits `out/overload.json` (`make overload`; CI runs a shrunk smoke).

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::simulator::SimConfig;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};

use super::common::{self, Ctx};
use super::sweep::{self, Cell, CellOutcome};

/// Systems swept past saturation (admission-pressure extremes).
pub const OVERLOAD_POLICIES: &[&str] = &["shabari", "shabari-ow-sched", "static-large"];

/// The load axis: from comfortably under capacity to far past it.
pub const OVERLOAD_RPS: &[f64] = &[4.0, 8.0, 16.0, 32.0, 64.0];

/// One sweep cell at the overload cluster size (the `workers` override
/// rides in the cell label so seed derivation stays collision-free with
/// other grids at the same policy × rps).
fn run_overload_cell(
    policy: &str,
    ctx: &Ctx,
    rps: f64,
    workers: usize,
    seed: u64,
) -> Result<RunMetrics> {
    let cctx = ctx.with_seed(seed);
    let workload = cctx.workload();
    let cfg = SimConfig { workers, ..common::sim_config(&cctx) };
    let (_, metrics) = common::run_one(policy, &cctx, &workload, rps, &cfg)?;
    Ok(metrics)
}

/// Run the policy × rps grid and enforce the admission invariant on
/// every replicate of every cell: no worker's reservations ever exceeded
/// `sched_vcpu_limit` vCPUs or its memory — checked against the
/// per-worker lifetime peaks, which are maintained on every charge, so
/// this witnesses "at every event" even in release builds (debug builds
/// additionally assert the bound after each event inside the engine).
pub fn run_overload(ctx: &Ctx, rps_list: &[f64]) -> Result<Vec<CellOutcome<RunMetrics>>> {
    let workers = ctx.overload_workers;
    let cells: Vec<Cell> = OVERLOAD_POLICIES
        .iter()
        .flat_map(|p| {
            rps_list
                .iter()
                .map(move |&rps| Cell::labeled(p, rps, "overload-workers", workers as f64))
        })
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        run_overload_cell(&cell.policy, ctx, cell.rps, workers, seed)
    })?;
    common::ensure_admission_invariant(&outcomes, &common::sim_config(ctx))?;
    Ok(outcomes)
}

pub fn overload(ctx: &Ctx) -> Result<()> {
    // lint:allow(D002): host wall time for the runner's wall-clock report line only
    let t0 = std::time::Instant::now();
    let outcomes = run_overload(ctx, OVERLOAD_RPS)?;
    let wall = t0.elapsed().as_secs_f64();
    let limits = common::sim_config(ctx);
    println!(
        "(overload sweep: {} cells x {} seed(s) on {} job(s), {wall:.1}s wall; \
         invariant peak_alloc <= {} vCPUs held on every replicate)",
        outcomes.len(),
        ctx.seeds,
        ctx.jobs,
        limits.sched_vcpu_limit
    );

    let mut t = Table::new(
        &format!(
            "overload: {} workers, {}s trace (queue waits are cross-seed means)",
            ctx.overload_workers, ctx.duration_s
        ),
        &[
            "system",
            "rps",
            "inv",
            "queued",
            "queue p50 s",
            "queue p99 s",
            "timeout",
            "SLO viol",
            "tput/s",
            "peak vCPU",
        ],
    );
    for out in &outcomes {
        let m = out.mean_metrics();
        t.row(vec![
            out.cell.policy.clone(),
            fnum(out.cell.rps, 0),
            m.invocations.to_string(),
            fpct(m.queued_pct),
            fnum(m.queue_wait.p50, 2),
            fnum(m.queue_wait.p99, 2),
            fpct(m.timeout_pct),
            fpct(m.slo_violation_pct),
            fnum(m.throughput, 1),
            fnum(m.peak_alloc_vcpus, 0),
        ]);
    }
    t.note(
        "past saturation: throughput plateaus, queue waits explode, the tail times \
         out — and peak vCPU stays pinned at the admission limit",
    );
    t.print();

    let dump = Json::obj(vec![
        ("perf", common::perf_json(wall, &outcomes)),
        (
            "config",
            Json::obj(vec![
                ("workers", Json::Num(ctx.overload_workers as f64)),
                ("duration_s", Json::Num(ctx.duration_s)),
                ("seeds", Json::Num(ctx.seeds as f64)),
                ("jobs", Json::Num(ctx.jobs as f64)),
                ("seed", Json::Num(ctx.seed as f64)),
                ("sched_vcpu_limit", Json::Num(limits.sched_vcpu_limit)),
                ("mem_limit_mb", Json::Num(limits.mem_gb * 1024.0)),
            ]),
        ),
        (
            "rows",
            Json::Arr(
                outcomes
                    .iter()
                    .map(|out| {
                        let m = out.mean_metrics();
                        Json::obj(vec![
                            ("policy", Json::Str(out.cell.policy.clone())),
                            ("rps", Json::Num(out.cell.rps)),
                            ("invocations", Json::Num(m.invocations as f64)),
                            ("queued_pct", Json::Num(m.queued_pct)),
                            ("queue_p50_s", Json::Num(m.queue_wait.p50)),
                            ("queue_p99_s", Json::Num(m.queue_wait.p99)),
                            ("timeout_pct", Json::Num(m.timeout_pct)),
                            ("slo_violation_pct", Json::Num(m.slo_violation_pct)),
                            ("throughput", Json::Num(m.throughput)),
                            ("peak_alloc_vcpus", Json::Num(m.peak_alloc_vcpus)),
                            ("peak_alloc_mem_mb", Json::Num(m.peak_alloc_mem_mb)),
                            ("background_shed", Json::Num(m.background_shed as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::create_dir_all("out").ok();
    match std::fs::write("out/overload.json", dump.to_pretty()) {
        Ok(()) => println!("(dumped out/overload.json)"),
        Err(e) => eprintln!("warning: could not write out/overload.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny-parameter smoke mirroring the CI job: one under-capacity and
    /// one far-past-capacity load on a single worker. Pins the three
    /// acceptance properties — the invariant holds (run_overload errors
    /// otherwise), saturation produces real queue waits, and the grid is
    /// deterministic across thread counts.
    #[test]
    fn overload_grid_saturates_and_is_jobs_invariant() {
        let ctx = Ctx { duration_s: 30.0, overload_workers: 1, seeds: 2, ..Default::default() };
        let rps = [2.0, 48.0];
        let seq = run_overload(&Ctx { jobs: 1, ..ctx.clone() }, &rps).unwrap();
        let par = run_overload(&Ctx { jobs: 4, ..ctx }, &rps).unwrap();
        assert_eq!(seq.len(), OVERLOAD_POLICIES.len() * rps.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.cell.id(), b.cell.id());
            let (ma, mb) = (a.mean_metrics(), b.mean_metrics());
            assert_eq!(ma.invocations, mb.invocations);
            assert_eq!(
                ma.queue_wait.p99.to_bits(),
                mb.queue_wait.p99.to_bits(),
                "{} queue waits diverged across --jobs",
                a.cell.id()
            );
            assert_eq!(ma.timeout_pct.to_bits(), mb.timeout_pct.to_bits());
        }
        // static-large at 48 rps on one worker is ~10x past capacity:
        // queueing must be real, and some of the tail must die in queue
        let sl = seq
            .iter()
            .find(|o| o.cell.policy == "static-large" && o.cell.rps == 48.0)
            .unwrap()
            .mean_metrics();
        assert!(sl.queued_pct > 10.0, "saturation must queue: {}%", sl.queued_pct);
        assert!(sl.queue_wait.p99 > 0.0);
        // and the invariant witness is non-trivial: the worker really was
        // driven to its limit
        assert!(
            sl.peak_alloc_vcpus >= 80.0,
            "overload must push reservations near the 90-vCPU limit, got {}",
            sl.peak_alloc_vcpus
        );
    }
}
