//! Parallel multi-seed sweep harness (DESIGN.md §4).
//!
//! Shabari's headline numbers (SLO-violation and wasted-resource
//! reductions) are statistical claims over stochastic workloads, so every
//! experiment runner expresses its work as a *grid* of [`Cell`]s —
//! (policy × load × config-override) points — replicated across `--seeds`
//! independent seeds and executed on a bounded pool of `--jobs` worker
//! threads ([`parallel_map`]).
//!
//! Determinism contract:
//! * every (cell, replicate) derives its seed via [`cell_seed`]:
//!   replicate 0 is the base seed itself (grid-wide paired comparison +
//!   single-run compatibility), replicates ≥ 1 are
//!   `base ^ fnv1a(cell-id ‖ replicate)` — stable across runs, machines,
//!   and thread counts;
//! * a cell's runner must build **all** mutable state (workload pools,
//!   trace RNGs, learner models, scheduler counters, cluster RNGs) from
//!   that derived seed *inside* the call — nothing mutable is shared
//!   between cells, which is what makes the closure `Sync` and the
//!   results independent of scheduling (`experiments::common::run_cell`
//!   is the canonical runner);
//! * results are reduced in grid order, and the cross-seed statistics
//!   ([`stats::seed_stats`]: mean/p50/p99 + bootstrap 95% CI) use a
//!   fixed-seed bootstrap — so aggregates are byte-identical at
//!   `--jobs 1` and `--jobs 8` (pinned by `rust/tests/test_sweep.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::util::rng::fnv1a;
use crate::util::stats::{self, SeedStats};

/// One point of a sweep grid. `label`/`param` carry config overrides
/// (e.g. `userCpu = 110` for Fig 11) so distinct cells never collide in
/// seed space even when policy and load match.
#[derive(Debug, Clone)]
pub struct Cell {
    pub policy: String,
    pub rps: f64,
    /// Override name for sensitivity grids ("" when unused).
    pub label: String,
    /// Override value for sensitivity grids (0.0 when unused).
    pub param: f64,
}

impl Cell {
    pub fn new(policy: &str, rps: f64) -> Cell {
        Cell { policy: policy.to_string(), rps, label: String::new(), param: 0.0 }
    }

    /// A cell carrying a named config override.
    pub fn labeled(policy: &str, rps: f64, label: &str, param: f64) -> Cell {
        Cell { policy: policy.to_string(), rps, label: label.to_string(), param }
    }

    /// Stable identity string (seed derivation + display).
    pub fn id(&self) -> String {
        if self.label.is_empty() {
            format!("{}@{}", self.policy, self.rps)
        } else {
            format!("{}@{}|{}={}", self.policy, self.rps, self.label, self.param)
        }
    }
}

/// Deterministic seed for one (cell, replicate) pair.
///
/// Replicate 0 runs at the base seed for *every* cell: cells of one grid
/// then share their replicate-0 stochastic world (common-random-numbers
/// pairing, which tightens policy comparisons), and a `--seeds 1` sweep
/// reproduces the pre-harness single-run outputs bit-for-bit. Replicates
/// ≥ 1 get per-cell streams, `base ^ fnv1a(cell-id ‖ replicate)`.
pub fn cell_seed(base: u64, cell: &Cell, replicate: usize) -> u64 {
    if replicate == 0 {
        return base;
    }
    let tag = format!("{}#{replicate}", cell.id());
    base ^ fnv1a(tag.as_bytes())
}

/// Default worker count: every available core.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(index, item)` over `items` on up to `jobs` scoped worker
/// threads and return the results **in input order** regardless of how
/// the items were scheduled. `jobs <= 1` runs inline on the caller's
/// thread (the two paths produce identical results for deterministic
/// `f`). Workers pull indices from a shared atomic counter, so uneven
/// cell runtimes still keep every core busy.
pub fn parallel_map<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let jobs = jobs.max(1).min(items.len().max(1));
    if jobs <= 1 {
        return items.iter().enumerate().map(|(i, item)| f(i, item)).collect();
    }
    let next = AtomicUsize::new(0);
    // One slot per item: each worker locks only the slot it fills, so
    // there is no contention and no reordering.
    let slots: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().expect("slot lock") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("slot lock").expect("worker filled every slot"))
        .collect()
}

/// All per-seed results of one grid cell, in replicate order.
#[derive(Debug, Clone)]
pub struct CellOutcome<R> {
    pub cell: Cell,
    pub per_seed: Vec<R>,
}

impl<R> CellOutcome<R> {
    /// Cross-seed statistics of any scalar projection of the result.
    pub fn stat_by(&self, metric: impl Fn(&R) -> f64) -> SeedStats {
        let values: Vec<f64> = self.per_seed.iter().map(metric).collect();
        stats::seed_stats(&values)
    }
}

impl CellOutcome<RunMetrics> {
    /// Cross-seed statistics of one metric (mean/p50/p99 + 95% CI).
    pub fn stat(&self, metric: impl Fn(&RunMetrics) -> f64) -> SeedStats {
        self.stat_by(metric)
    }

    /// Field-wise cross-seed mean (drop-in for single-run table code).
    pub fn mean_metrics(&self) -> RunMetrics {
        RunMetrics::mean_of(&self.per_seed)
    }
}

/// Execute a grid: every (cell, replicate) pair becomes one task on the
/// thread pool — a 7-cell × 5-seed sweep exposes 35 units of parallelism,
/// not 7. Results come back grouped per cell in grid order; the first
/// cell error (if any) propagates after the sweep drains.
pub fn run_cells<R, F>(
    cells: &[Cell],
    base_seed: u64,
    seeds: usize,
    jobs: usize,
    run: F,
) -> Result<Vec<CellOutcome<R>>>
where
    R: Send,
    F: Fn(&Cell, u64) -> Result<R> + Sync,
{
    let seeds = seeds.max(1);
    let tasks: Vec<(usize, usize)> = (0..cells.len())
        .flat_map(|c| (0..seeds).map(move |r| (c, r)))
        .collect();
    let results = parallel_map(&tasks, jobs, |_, &(c, r)| {
        run(&cells[c], cell_seed(base_seed, &cells[c], r))
    });
    let mut it = results.into_iter();
    let mut out = Vec::with_capacity(cells.len());
    for cell in cells {
        let mut per_seed = Vec::with_capacity(seeds);
        for _ in 0..seeds {
            per_seed.push(it.next().expect("one result per task")?);
        }
        out.push(CellOutcome { cell: cell.clone(), per_seed });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let seq = parallel_map(&items, 1, |i, x| i * 1000 + x * x);
        let par = parallel_map(&items, 8, |i, x| i * 1000 + x * x);
        assert_eq!(seq, par);
        assert_eq!(seq[3], 3 * 1000 + 9);
    }

    #[test]
    fn parallel_map_runs_every_item_once() {
        use std::sync::atomic::AtomicU64;
        let calls = AtomicU64::new(0);
        let items: Vec<u64> = (0..57).collect();
        let out = parallel_map(&items, 4, |_, x| {
            calls.fetch_add(1, Ordering::Relaxed);
            *x
        });
        assert_eq!(calls.load(Ordering::Relaxed), 57);
        assert_eq!(out, items);
    }

    #[test]
    fn parallel_map_empty_and_oversubscribed() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 8, |_, x| *x).is_empty());
        let one = [7u32];
        assert_eq!(parallel_map(&one, 64, |_, x| *x), vec![7]);
    }

    #[test]
    fn cell_seeds_deterministic_and_distinct() {
        let a = Cell::new("shabari", 4.0);
        assert_eq!(cell_seed(42, &a, 1), cell_seed(42, &a, 1));
        assert_ne!(cell_seed(42, &a, 1), cell_seed(42, &a, 2), "replicates differ");
        let b = Cell::new("cypress", 4.0);
        assert_ne!(cell_seed(42, &a, 1), cell_seed(42, &b, 1), "policies differ");
        let c = Cell::labeled("shabari", 4.0, "userCpu", 110.0);
        assert_ne!(cell_seed(42, &a, 1), cell_seed(42, &c, 1), "overrides differ");
        assert_ne!(cell_seed(42, &a, 1), cell_seed(43, &a, 1), "base seed differs");
        // replicate 0 = base seed for every cell (single-run compatibility
        // + common-random-numbers pairing across a grid)
        assert_eq!(cell_seed(42, &a, 0), 42);
        assert_eq!(cell_seed(42, &b, 0), 42);
        assert_ne!(cell_seed(42, &a, 1), 42, "derived replicates leave the base");
    }

    #[test]
    fn run_cells_groups_by_cell_in_grid_order() {
        let cells = vec![Cell::new("a", 1.0), Cell::new("b", 2.0)];
        let out = run_cells(&cells, 7, 3, 4, |cell, seed| Ok((cell.policy.clone(), seed)))
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].per_seed.len(), 3);
        assert!(out[0].per_seed.iter().all(|(p, _)| p == "a"));
        assert!(out[1].per_seed.iter().all(|(p, _)| p == "b"));
        // replicate order = seed derivation order
        assert_eq!(out[0].per_seed[1].1, cell_seed(7, &cells[0], 1));
    }

    #[test]
    fn run_cells_propagates_errors() {
        let cells = vec![Cell::new("ok", 1.0), Cell::new("bad", 1.0)];
        let res = run_cells(&cells, 1, 2, 2, |cell, _| {
            if cell.policy == "bad" {
                anyhow::bail!("cell failed")
            }
            Ok(0u32)
        });
        assert!(res.is_err());
    }

    #[test]
    fn stat_by_aggregates_across_seeds() {
        let outcome = CellOutcome { cell: Cell::new("x", 1.0), per_seed: vec![1.0, 2.0, 3.0] };
        let s = outcome.stat_by(|v| *v);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!(s.ci95.0 <= 2.0 && 2.0 <= s.ci95.1);
    }

    #[test]
    fn default_jobs_positive() {
        assert!(default_jobs() >= 1);
    }
}
