//! Shared experiment harness: build policies by name, run traces, and
//! collect paper-style metrics.

use anyhow::{bail, ensure, Result};

use crate::baselines::{AquatopePolicy, CypressPolicy, ParrotfishPolicy, StaticPolicy};
use crate::coordinator::allocator::cost::SlackPolicy;
use crate::coordinator::allocator::formulation::Formulation;
use crate::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use crate::coordinator::scheduler::hermod::HermodScheduler;
use crate::coordinator::scheduler::openwhisk::OpenWhiskScheduler;
use crate::coordinator::scheduler::shabari::ShabariScheduler;
use crate::coordinator::ShabariPolicy;
use crate::learner::xla::Backend;
use crate::metrics::{from_result, RunMetrics};
use crate::simulator::engine::{simulate, SimResult};
use crate::simulator::faults::FaultsSpec;
use crate::simulator::keepalive::KeepAliveSpec;
use crate::simulator::{Policy, SimConfig};
use crate::workload::scenario::{self, Scenario};
use crate::workload::Workload;

/// Experiment context, filled from CLI flags.
#[derive(Debug, Clone)]
pub struct Ctx {
    /// Base seed; sweep cells derive theirs as `seed ^ hash(cell)`.
    pub seed: u64,
    /// Learner backend for Shabari variants (XLA = production path).
    pub backend: Backend,
    /// Simulated trace length, seconds (paper: a 10-minute window).
    pub duration_s: f64,
    pub slo_multiplier: f64,
    pub artifacts_dir: String,
    /// Replicates per sweep cell (`--seeds`; CLI default 5). Tests and
    /// library callers default to 1, which reproduces single-run output.
    pub seeds: usize,
    /// Sweep worker threads (`--jobs`; CLI default = all cores).
    pub jobs: usize,
    /// Workload scenario (`--scenario`; see `workload::scenario::by_name`).
    /// The default, `azure-synthetic`, reproduces the pre-scenario traces
    /// byte-for-byte.
    pub scenario: String,
    /// Cluster size of the `experiment scale` grid (`--scale-workers`).
    pub scale_workers: usize,
    /// Request rate of the `experiment scale` grid (`--scale-rps`;
    /// default 24 = 4x the highest fig8 load).
    pub scale_rps: f64,
    /// Cluster size of the `experiment overload` sweep
    /// (`--overload-workers`; deliberately small so the fixed rps axis
    /// crosses saturation).
    pub overload_workers: usize,
    /// Keep-alive/eviction policy (`--keepalive`, parsed at the CLI
    /// boundary like `--scenario`; `simulator::keepalive::parse`). The
    /// default reproduces the legacy fixed-600 s behavior byte-for-byte.
    pub keepalive: KeepAliveSpec,
    /// Cluster size of the `experiment keepalive` matrix
    /// (`--keepalive-workers`; small so admission queues form and
    /// demand-driven eviction has demand to serve).
    pub keepalive_workers: usize,
    /// Fault-injection profile (`--faults`, parsed at the CLI boundary
    /// like `--keepalive`; `simulator::faults::parse`). The default,
    /// `none`, reproduces the immortal-cluster streams byte-for-byte.
    pub faults: FaultsSpec,
    /// Cluster size of the `experiment adversity` matrix
    /// (`--adversity-workers`; small so a single crash is a real fraction
    /// of capacity).
    pub adversity_workers: usize,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            seed: 42,
            backend: Backend::Native,
            duration_s: 600.0,
            slo_multiplier: 1.4,
            artifacts_dir: "artifacts".to_string(),
            seeds: 1,
            jobs: 1,
            scenario: "azure-synthetic".to_string(),
            scale_workers: 64,
            scale_rps: 24.0,
            overload_workers: 4,
            keepalive: KeepAliveSpec::default(),
            keepalive_workers: 4,
            faults: FaultsSpec::default(),
            adversity_workers: 4,
        }
    }
}

impl Ctx {
    pub fn allocator_cfg(&self) -> AllocatorConfig {
        AllocatorConfig {
            learner_backend: self.backend,
            artifacts_dir: self.artifacts_dir.clone(),
            ..Default::default()
        }
    }

    pub fn workload(&self) -> Workload {
        Workload::build(self.seed, self.slo_multiplier)
    }

    /// The same context re-based on a sweep-derived seed. Everything a
    /// cell runs (workload pools, traces, policies, cluster RNG) keys off
    /// `seed`, so this is the only hook replication needs.
    pub fn with_seed(&self, seed: u64) -> Ctx {
        Ctx { seed, ..self.clone() }
    }

    /// The same context under a different workload scenario (the hook the
    /// policy × scenario robustness grid uses per cell).
    pub fn with_scenario(&self, scenario: &str) -> Ctx {
        Ctx { scenario: scenario.to_string(), ..self.clone() }
    }

    /// The same context under a different keep-alive policy (the hook
    /// the keepalive matrix uses per cell).
    pub fn with_keepalive(&self, keepalive: KeepAliveSpec) -> Ctx {
        Ctx { keepalive, ..self.clone() }
    }

    /// The same context under a different fault profile (the hook the
    /// adversity matrix uses per cell).
    pub fn with_faults(&self, faults: FaultsSpec) -> Ctx {
        Ctx { faults, ..self.clone() }
    }

    /// Build this context's scenario from the registry.
    pub fn build_scenario(&self) -> Result<Box<dyn Scenario>> {
        scenario::by_name(&self.scenario)
    }
}

/// All policy names `make_policy` accepts (fig8's six systems + ablations).
pub const POLICIES: &[&str] = &[
    "shabari",
    "shabari-ow-sched", // Shabari allocator + OpenWhisk scheduler (fig10)
    "shabari-hermod",   // Shabari allocator + Hermod packing (fig7b)
    "static-medium",
    "static-large",
    "parrotfish",
    "aquatope",
    "cypress",
];

/// Build a policy by name.
pub fn make_policy(name: &str, ctx: &Ctx, workload: &Workload) -> Result<Box<dyn Policy>> {
    let seed = ctx.seed;
    Ok(match name {
        "shabari" => {
            let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "shabari-ow-sched" => {
            let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
            Box::new(ShabariPolicy::new(alloc, Box::new(OpenWhiskScheduler::new(seed))))
        }
        "shabari-hermod" => {
            let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
            Box::new(ShabariPolicy::new(alloc, Box::new(HermodScheduler::new(seed))))
        }
        "shabari-proportional" => {
            let mut cfg = ctx.allocator_cfg();
            cfg.slack = SlackPolicy::Proportional;
            let alloc = ResourceAllocator::new(cfg)?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "shabari-onehot" => {
            let mut cfg = ctx.allocator_cfg();
            cfg.formulation = Formulation::OneHot;
            cfg.learner_backend = Backend::Native; // wide model is native-only
            let alloc = ResourceAllocator::new(cfg)?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "shabari-per-input-type" => {
            let mut cfg = ctx.allocator_cfg();
            cfg.formulation = Formulation::PerInputType;
            let alloc = ResourceAllocator::new(cfg)?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "static-medium" => Box::new(StaticPolicy::medium(seed)),
        "static-large" => Box::new(StaticPolicy::large(seed)),
        "parrotfish" => Box::new(ParrotfishPolicy::offline(seed)),
        "aquatope" => {
            let slos = workload.slos.clone();
            Box::new(AquatopePolicy::offline(seed, move |f, i| slos[f][i]))
        }
        "cypress" => Box::new(CypressPolicy::new(seed)),
        other => bail!("unknown policy '{other}' (known: {POLICIES:?})"),
    })
}

/// The one trace-seed derivation every runner shares: replicate pairing
/// (`sweep::cell_seed`) relies on all grids salting traces identically.
pub fn trace_seed(ctx: &Ctx, rps: f64) -> u64 {
    ctx.seed.wrapping_add(rps as u64)
}

/// Run one policy over a trace at `rps` under `Ctx::scenario`; returns
/// raw result + metrics.
pub fn run_one(
    name: &str,
    ctx: &Ctx,
    workload: &Workload,
    rps: f64,
    sim_cfg: &SimConfig,
) -> Result<(SimResult, RunMetrics)> {
    let mut policy = make_policy(name, ctx, workload)?;
    let scenario = ctx.build_scenario()?;
    let trace =
        workload.trace_with(scenario.as_ref(), rps, ctx.duration_s, trace_seed(ctx, rps));
    let res = simulate(sim_cfg.clone(), &mut policy, trace);
    let metrics = from_result(name, &res);
    Ok((res, metrics))
}

/// Default testbed config with the experiment seed and the context's
/// keep-alive and fault specs applied.
pub fn sim_config(ctx: &Ctx) -> SimConfig {
    let mut cfg = SimConfig { seed: ctx.seed ^ 0x51AB, ..Default::default() };
    ctx.keepalive.apply(&mut cfg);
    ctx.faults.apply(&mut cfg);
    cfg
}

/// Re-verify the engine's admission invariant on every replicate of a
/// sweep (shared by `experiment overload` and `experiment keepalive`):
/// no worker's reservations ever exceeded the per-worker limits,
/// witnessed by the lifetime peaks carried in [`RunMetrics`] — valid in
/// release builds, where the engine's per-event debug asserts are
/// compiled out.
pub fn ensure_admission_invariant(
    outcomes: &[crate::experiments::sweep::CellOutcome<RunMetrics>],
    limits: &SimConfig,
) -> Result<()> {
    for out in outcomes {
        for (rep, m) in out.per_seed.iter().enumerate() {
            ensure!(
                m.peak_alloc_vcpus <= limits.sched_vcpu_limit + 1e-9,
                "admission invariant violated: {} replicate {rep} peaked at {} vCPUs \
                 (limit {})",
                out.cell.id(),
                m.peak_alloc_vcpus,
                limits.sched_vcpu_limit
            );
            ensure!(
                m.peak_alloc_mem_mb <= limits.mem_gb * 1024.0 + 1e-9,
                "admission invariant violated: {} replicate {rep} peaked at {} MB \
                 (limit {})",
                out.cell.id(),
                m.peak_alloc_mem_mb,
                limits.mem_gb * 1024.0
            );
        }
    }
    Ok(())
}

/// Canonical sweep-cell runner: rebuild *everything* stochastic (workload
/// pools, trace, policy with its learner models and scheduler RNGs,
/// cluster RNG) from the derived `seed`, run once, and reduce to metrics.
/// The trace is generated under `Ctx::scenario`, so any grid runs under
/// any workload shape (`--scenario`, DESIGN.md §Scenarios). No state
/// crosses cells, which is what lets `sweep::run_cells` execute cells on
/// any thread in any order with byte-identical results.
pub fn run_cell(name: &str, ctx: &Ctx, rps: f64, seed: u64) -> Result<RunMetrics> {
    let cctx = ctx.with_seed(seed);
    let workload = cctx.workload();
    let cfg = sim_config(&cctx);
    let (_, metrics) = run_one(name, &cctx, &workload, rps, &cfg)?;
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_policies_constructible() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let w = ctx.workload();
        for name in POLICIES {
            let p = make_policy(name, &ctx, &w).unwrap();
            assert!(!p.name().is_empty());
        }
    }

    #[test]
    fn unknown_policy_rejected() {
        let ctx = Ctx::default();
        let w = Workload::build(1, 1.4);
        assert!(make_policy("nope", &ctx, &w).is_err());
    }

    #[test]
    fn run_one_produces_metrics() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let w = ctx.workload();
        let cfg = sim_config(&ctx);
        let (res, m) = run_one("static-medium", &ctx, &w, 2.0, &cfg).unwrap();
        assert!(m.invocations > 50, "2 rps over 60 s");
        assert_eq!(res.records.len(), m.invocations);
    }

    #[test]
    fn run_cell_honors_the_ctx_scenario() {
        let base = Ctx { duration_s: 60.0, ..Default::default() };
        let azure = run_cell("static-medium", &base, 2.0, 7).unwrap();
        // flash-crowd adds burst load on top of the base rate, so the two
        // scenarios cannot simulate the same number of invocations
        let flash =
            run_cell("static-medium", &base.with_scenario("flash-crowd"), 2.0, 7).unwrap();
        assert_ne!(azure.invocations, flash.invocations, "scenario did not reach the trace");
        // and naming the default explicitly is a no-op
        let explicit =
            run_cell("static-medium", &base.with_scenario("azure-synthetic"), 2.0, 7).unwrap();
        assert_eq!(azure.invocations, explicit.invocations);
        assert_eq!(azure.slo_violation_pct.to_bits(), explicit.slo_violation_pct.to_bits());
    }

    #[test]
    fn unknown_scenario_surfaces_as_error() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        assert!(run_cell("static-medium", &ctx.with_scenario("nope"), 2.0, 7).is_err());
    }

    #[test]
    fn sim_config_applies_the_ctx_keepalive_spec() {
        use crate::simulator::keepalive::{self, KeepAliveMode};
        let base = Ctx::default();
        let cfg = sim_config(&base);
        assert_eq!(cfg.keepalive, KeepAliveMode::Fixed);
        assert_eq!(cfg.keep_alive_s, 600.0, "default spec leaves the legacy TTL");
        let cfg = sim_config(&base.with_keepalive(keepalive::parse("pressure:90").unwrap()));
        assert_eq!(cfg.keepalive, KeepAliveMode::Pressure);
        assert_eq!(cfg.keep_alive_s, 90.0);
        // the explicit fixed:600 spec is byte-identical config-wise to
        // the default (the PR's stream-compatibility guarantee)
        let explicit = sim_config(&base.with_keepalive(keepalive::parse("fixed:600").unwrap()));
        assert_eq!(explicit.keepalive, KeepAliveMode::Fixed);
        assert_eq!(explicit.keep_alive_s, 600.0);
    }

    #[test]
    fn sim_config_applies_the_ctx_faults_spec() {
        use crate::simulator::faults::{self, FaultsMode};
        let base = Ctx::default();
        let cfg = sim_config(&base);
        assert_eq!(cfg.faults.mode, FaultsMode::None, "default ctx injects nothing");
        let cfg = sim_config(&base.with_faults(faults::parse("crash:30").unwrap()));
        assert_eq!(cfg.faults.mode, FaultsMode::Crash);
        assert_eq!(cfg.faults.param, Some(30.0));
        // naming `none` explicitly is config-identical to the default
        // (the byte-stream pin in test_determinism.rs rides on this)
        let explicit = sim_config(&base.with_faults(faults::parse("none").unwrap()));
        assert_eq!(explicit.faults, cfg_default_faults());
    }

    fn cfg_default_faults() -> crate::simulator::faults::FaultsSpec {
        sim_config(&Ctx::default()).faults
    }

    #[test]
    fn run_cell_rebuilds_from_derived_seed() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let a = run_cell("static-medium", &ctx, 2.0, 1234).unwrap();
        let b = run_cell("static-medium", &ctx, 2.0, 1234).unwrap();
        assert_eq!(a.slo_violation_pct.to_bits(), b.slo_violation_pct.to_bits());
        assert_ne!(a.invocations, 0, "sanity: the cell simulated something");
        // a different derived seed must rebuild a different stochastic world
        let c = run_cell("static-medium", &ctx, 2.0, 5678).unwrap();
        assert!(
            a.invocations != c.invocations
                || a.mean_e2e_s.to_bits() != c.mean_e2e_s.to_bits(),
            "seed 5678 must not reproduce seed 1234's run"
        );
    }
}
