//! Shared experiment harness: build policies by name, run traces, and
//! collect paper-style metrics.

use anyhow::{bail, ensure, Result};

use crate::baselines::{AquatopePolicy, CypressPolicy, ParrotfishPolicy, StaticPolicy};
use crate::coordinator::allocator::cost::SlackPolicy;
use crate::coordinator::allocator::formulation::Formulation;
use crate::coordinator::allocator::{AllocatorConfig, ResourceAllocator};
use crate::coordinator::scheduler::hermod::HermodScheduler;
use crate::coordinator::scheduler::openwhisk::OpenWhiskScheduler;
use crate::coordinator::scheduler::shabari::ShabariScheduler;
use crate::coordinator::ShabariPolicy;
use crate::learner::xla::Backend;
use crate::metrics::{from_result, RunMetrics};
use crate::simulator::engine::{simulate, SimResult};
use crate::simulator::faults::FaultsSpec;
use crate::simulator::keepalive::KeepAliveSpec;
use crate::simulator::scaler::ScalerSpec;
use crate::simulator::trace::{TraceConfig, TraceLog};
use crate::simulator::{Policy, SimConfig};
use crate::util::rng::fnv1a;
use crate::workload::scenario::{self, Scenario};
use crate::workload::Workload;

/// Trace-output request carried on [`Ctx`] (`--trace`/`--trace-chrome`,
/// DESIGN.md §Observability). `None` on `Ctx` — the default — means the
/// engine's tracing stays off and every stream is byte-identical to an
/// untraced build.
#[derive(Debug, Clone)]
pub struct TraceOut {
    /// JSONL event-log destination (`--trace PATH`).
    pub jsonl: Option<String>,
    /// Chrome trace-event destination (`--trace-chrome PATH`).
    pub chrome: Option<String>,
    /// Timeline sampling interval, simulated seconds (`--trace-interval`).
    pub interval_s: f64,
    /// `true` for single `run` invocations: write to the paths verbatim.
    /// Experiment grids run many (policy × load × override) cells, so
    /// they leave this `false` and each cell's files get a
    /// `-<policy>-<rps>-<hash8>` suffix before the extension instead
    /// ([`trace_paths`]) — deterministic, collision-free names.
    pub exact: bool,
}

impl Default for TraceOut {
    fn default() -> Self {
        TraceOut { jsonl: None, chrome: None, interval_s: 10.0, exact: false }
    }
}

/// Experiment context, filled from CLI flags.
#[derive(Debug, Clone)]
pub struct Ctx {
    /// Base seed; sweep cells derive theirs as `seed ^ hash(cell)`.
    pub seed: u64,
    /// Learner backend for Shabari variants (XLA = production path).
    pub backend: Backend,
    /// Simulated trace length, seconds (paper: a 10-minute window).
    pub duration_s: f64,
    pub slo_multiplier: f64,
    pub artifacts_dir: String,
    /// Replicates per sweep cell (`--seeds`; CLI default 5). Tests and
    /// library callers default to 1, which reproduces single-run output.
    pub seeds: usize,
    /// Sweep worker threads (`--jobs`; CLI default = all cores).
    pub jobs: usize,
    /// Workload scenario (`--scenario`; see `workload::scenario::by_name`).
    /// The default, `azure-synthetic`, reproduces the pre-scenario traces
    /// byte-for-byte.
    pub scenario: String,
    /// Cluster size of the `experiment scale` grid (`--scale-workers`).
    pub scale_workers: usize,
    /// Request rate of the `experiment scale` grid (`--scale-rps`;
    /// default 24 = 4x the highest fig8 load).
    pub scale_rps: f64,
    /// Cluster size of the `experiment overload` sweep
    /// (`--overload-workers`; deliberately small so the fixed rps axis
    /// crosses saturation).
    pub overload_workers: usize,
    /// Keep-alive/eviction policy (`--keepalive`, parsed at the CLI
    /// boundary like `--scenario`; `simulator::keepalive::parse`). The
    /// default reproduces the legacy fixed-600 s behavior byte-for-byte.
    pub keepalive: KeepAliveSpec,
    /// Cluster size of the `experiment keepalive` matrix
    /// (`--keepalive-workers`; small so admission queues form and
    /// demand-driven eviction has demand to serve).
    pub keepalive_workers: usize,
    /// Fault-injection profile (`--faults`, parsed at the CLI boundary
    /// like `--keepalive`; `simulator::faults::parse`). The default,
    /// `none`, reproduces the immortal-cluster streams byte-for-byte.
    pub faults: FaultsSpec,
    /// Cluster size of the `experiment adversity` matrix
    /// (`--adversity-workers`; small so a single crash is a real fraction
    /// of capacity).
    pub adversity_workers: usize,
    /// Cluster-scaling profile (`--scaler`, parsed at the CLI boundary
    /// like `--faults`; `simulator::scaler::parse`). The default, `none`,
    /// reproduces the fixed-cluster streams byte-for-byte.
    pub scaler: ScalerSpec,
    /// Lifecycle-trace output request (`--trace`/`--trace-chrome`;
    /// DESIGN.md §Observability). `None` — the default — keeps tracing
    /// compiled in but dormant: byte-identical streams, zero extra RNG
    /// draws. Sweeps trace replicate 0 only (see [`Ctx::with_seed`]).
    pub trace: Option<TraceOut>,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            seed: 42,
            backend: Backend::Native,
            duration_s: 600.0,
            slo_multiplier: 1.4,
            artifacts_dir: "artifacts".to_string(),
            seeds: 1,
            jobs: 1,
            scenario: "azure-synthetic".to_string(),
            scale_workers: 64,
            scale_rps: 24.0,
            overload_workers: 4,
            keepalive: KeepAliveSpec::default(),
            keepalive_workers: 4,
            faults: FaultsSpec::default(),
            adversity_workers: 4,
            scaler: ScalerSpec::default(),
            trace: None,
        }
    }
}

impl Ctx {
    pub fn allocator_cfg(&self) -> AllocatorConfig {
        AllocatorConfig {
            learner_backend: self.backend,
            artifacts_dir: self.artifacts_dir.clone(),
            ..Default::default()
        }
    }

    pub fn workload(&self) -> Workload {
        Workload::build(self.seed, self.slo_multiplier)
    }

    /// The same context re-based on a sweep-derived seed. Everything a
    /// cell runs (workload pools, traces, policies, cluster RNG) keys off
    /// `seed`, so this is the only hook replication needs.
    ///
    /// Tracing survives the re-base only at the *base* seed: replicate 0
    /// of every sweep cell runs at exactly `ctx.seed`
    /// (`sweep::cell_seed`), so this gate traces one replicate per cell
    /// and leaves replicates ≥ 1 untraced — one timeline per cell, no
    /// file-name races across replicates.
    pub fn with_seed(&self, seed: u64) -> Ctx {
        let trace = if seed == self.seed { self.trace.clone() } else { None };
        Ctx { seed, trace, ..self.clone() }
    }

    /// The same context under a different workload scenario (the hook the
    /// policy × scenario robustness grid uses per cell).
    pub fn with_scenario(&self, scenario: &str) -> Ctx {
        Ctx { scenario: scenario.to_string(), ..self.clone() }
    }

    /// The same context under a different keep-alive policy (the hook
    /// the keepalive matrix uses per cell).
    pub fn with_keepalive(&self, keepalive: KeepAliveSpec) -> Ctx {
        Ctx { keepalive, ..self.clone() }
    }

    /// The same context under a different fault profile (the hook the
    /// adversity matrix uses per cell).
    pub fn with_faults(&self, faults: FaultsSpec) -> Ctx {
        Ctx { faults, ..self.clone() }
    }

    /// The same context under a different cluster-scaling profile (the
    /// hook the replay experiment's scaler axis uses per cell).
    pub fn with_scaler(&self, scaler: ScalerSpec) -> Ctx {
        Ctx { scaler, ..self.clone() }
    }

    /// Build this context's scenario from the registry.
    pub fn build_scenario(&self) -> Result<Box<dyn Scenario>> {
        scenario::by_name(&self.scenario)
    }
}

/// All policy names `make_policy` accepts (fig8's six systems + ablations).
pub const POLICIES: &[&str] = &[
    "shabari",
    "shabari-ow-sched", // Shabari allocator + OpenWhisk scheduler (fig10)
    "shabari-hermod",   // Shabari allocator + Hermod packing (fig7b)
    "static-medium",
    "static-large",
    "parrotfish",
    "aquatope",
    "cypress",
];

/// Build a policy by name.
pub fn make_policy(name: &str, ctx: &Ctx, workload: &Workload) -> Result<Box<dyn Policy>> {
    let seed = ctx.seed;
    Ok(match name {
        "shabari" => {
            let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "shabari-ow-sched" => {
            let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
            Box::new(ShabariPolicy::new(alloc, Box::new(OpenWhiskScheduler::new(seed))))
        }
        "shabari-hermod" => {
            let alloc = ResourceAllocator::new(ctx.allocator_cfg())?;
            Box::new(ShabariPolicy::new(alloc, Box::new(HermodScheduler::new(seed))))
        }
        "shabari-proportional" => {
            let mut cfg = ctx.allocator_cfg();
            cfg.slack = SlackPolicy::Proportional;
            let alloc = ResourceAllocator::new(cfg)?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "shabari-onehot" => {
            let mut cfg = ctx.allocator_cfg();
            cfg.formulation = Formulation::OneHot;
            cfg.learner_backend = Backend::Native; // wide model is native-only
            let alloc = ResourceAllocator::new(cfg)?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "shabari-per-input-type" => {
            let mut cfg = ctx.allocator_cfg();
            cfg.formulation = Formulation::PerInputType;
            let alloc = ResourceAllocator::new(cfg)?;
            Box::new(ShabariPolicy::new(alloc, Box::new(ShabariScheduler::new(seed))))
        }
        "static-medium" => Box::new(StaticPolicy::medium(seed)),
        "static-large" => Box::new(StaticPolicy::large(seed)),
        "parrotfish" => Box::new(ParrotfishPolicy::offline(seed)),
        "aquatope" => {
            let slos = workload.slos.clone();
            Box::new(AquatopePolicy::offline(seed, move |f, i| slos[f][i]))
        }
        "cypress" => Box::new(CypressPolicy::new(seed)),
        other => bail!("unknown policy '{other}' (known: {POLICIES:?})"),
    })
}

/// The one trace-seed derivation every runner shares: replicate pairing
/// (`sweep::cell_seed`) relies on all grids salting traces identically.
pub fn trace_seed(ctx: &Ctx, rps: f64) -> u64 {
    ctx.seed.wrapping_add(rps as u64)
}

/// Run one policy over a trace at `rps` under `Ctx::scenario`; returns
/// raw result + metrics. When the context requests tracing
/// (`Ctx::trace`), the run's lifecycle trace is exported to disk here —
/// the one place every runner (single runs and sweep cells alike)
/// funnels through.
pub fn run_one(
    name: &str,
    ctx: &Ctx,
    workload: &Workload,
    rps: f64,
    sim_cfg: &SimConfig,
) -> Result<(SimResult, RunMetrics)> {
    let mut policy = make_policy(name, ctx, workload)?;
    let scenario = ctx.build_scenario()?;
    let trace =
        workload.trace_with(scenario.as_ref(), rps, ctx.duration_s, trace_seed(ctx, rps));
    let res = simulate(sim_cfg.clone(), &mut policy, trace);
    if let (Some(out), Some(log)) = (&ctx.trace, &res.trace) {
        write_trace(out, log, name, rps, ctx, sim_cfg)?;
    }
    let metrics = from_result(name, &res);
    Ok((res, metrics))
}

/// Resolve the on-disk names for one traced run. Exact mode returns the
/// requested paths verbatim; grid mode suffixes each with the cell tag
/// and an FNV-1a hash of the full cell descriptor (scenario, keep-alive,
/// faults, cluster size, seeds) so overridden cells sharing a
/// (policy, rps) pair still get distinct files.
pub fn trace_paths(
    out: &TraceOut,
    name: &str,
    rps: f64,
    ctx: &Ctx,
    cfg: &SimConfig,
) -> (Option<String>, Option<String>) {
    if out.exact {
        return (out.jsonl.clone(), out.chrome.clone());
    }
    let desc = format!(
        "{name}@{rps}|scenario={}|keepalive={}|faults={}|scaler={}|workers={}|seed={}|sim_seed={}|dur={}",
        ctx.scenario,
        ctx.keepalive.label(),
        ctx.faults.label(),
        ctx.scaler.label(),
        cfg.workers,
        ctx.seed,
        cfg.seed,
        ctx.duration_s,
    );
    let tag = sanitize_tag(&format!("{name}-{rps}"));
    let suffix = format!("-{tag}-{:08x}", fnv1a(desc.as_bytes()) & 0xffff_ffff);
    (
        out.jsonl.as_deref().map(|p| suffixed(p, &suffix)),
        out.chrome.as_deref().map(|p| suffixed(p, &suffix)),
    )
}

/// Keep path-safe characters; everything else becomes `-`.
fn sanitize_tag(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '-' })
        .collect()
}

/// Insert `suffix` before the file extension (`out/t.jsonl` + `-x` →
/// `out/t-x.jsonl`); appended verbatim when there is no extension.
fn suffixed(path: &str, suffix: &str) -> String {
    match path.rsplit_once('.') {
        Some((stem, ext)) if !ext.contains('/') => format!("{stem}{suffix}.{ext}"),
        _ => format!("{path}{suffix}"),
    }
}

fn write_trace(
    out: &TraceOut,
    log: &TraceLog,
    name: &str,
    rps: f64,
    ctx: &Ctx,
    cfg: &SimConfig,
) -> Result<()> {
    let (jsonl, chrome) = trace_paths(out, name, rps, ctx, cfg);
    crate::log_trace!(
        "trace export for {name}@{rps}: {} events, {} samples",
        log.events.len(),
        log.samples.len()
    );
    if let Some(path) = jsonl {
        write_file(&path, &log.to_jsonl())?;
        crate::log_debug!("wrote lifecycle trace (JSONL) to {path}");
    }
    if let Some(path) = chrome {
        write_file(&path, &log.to_chrome())?;
        crate::log_debug!("wrote Chrome trace-event timeline to {path}");
    }
    Ok(())
}

fn write_file(path: &str, contents: &str) -> Result<()> {
    use anyhow::Context;
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating trace directory for {path}"))?;
        }
    }
    std::fs::write(path, contents).with_context(|| format!("writing trace file {path}"))
}

/// Default testbed config with the experiment seed and the context's
/// keep-alive, fault, and trace specs applied.
pub fn sim_config(ctx: &Ctx) -> SimConfig {
    let mut cfg = SimConfig { seed: ctx.seed ^ 0x51AB, ..Default::default() };
    ctx.keepalive.apply(&mut cfg);
    ctx.faults.apply(&mut cfg);
    ctx.scaler.apply(&mut cfg);
    cfg.trace =
        ctx.trace.as_ref().map(|t| TraceConfig { sample_interval_s: t.interval_s });
    cfg
}

/// Engine self-throughput summary for `out/*.json` experiment artifacts:
/// wall-clock, total simulated invocations and engine events across every
/// (cell, replicate), and the derived per-wall-second rates — so any
/// saved artifact doubles as a perf record for before/after comparisons.
pub fn perf_json(
    wall_s: f64,
    outcomes: &[crate::experiments::sweep::CellOutcome<RunMetrics>],
) -> crate::util::json::Json {
    use crate::util::json::Json;
    let invocations: usize =
        outcomes.iter().flat_map(|o| &o.per_seed).map(|m| m.invocations).sum();
    let sim_events: u64 =
        outcomes.iter().flat_map(|o| &o.per_seed).map(|m| m.sim_events).sum();
    Json::obj(vec![
        ("wall_s", Json::Num(wall_s)),
        ("invocations", Json::Num(invocations as f64)),
        ("sim_events", Json::Num(sim_events as f64)),
        ("sim_inv_per_s", Json::Num(invocations as f64 / wall_s.max(1e-9))),
        ("sim_events_per_s", Json::Num(sim_events as f64 / wall_s.max(1e-9))),
    ])
}

/// Re-verify the engine's admission invariant on every replicate of a
/// sweep (shared by `experiment overload` and `experiment keepalive`):
/// no worker's reservations ever exceeded the per-worker limits,
/// witnessed by the lifetime peaks carried in [`RunMetrics`] — valid in
/// release builds, where the engine's per-event debug asserts are
/// compiled out.
pub fn ensure_admission_invariant(
    outcomes: &[crate::experiments::sweep::CellOutcome<RunMetrics>],
    limits: &SimConfig,
) -> Result<()> {
    for out in outcomes {
        for (rep, m) in out.per_seed.iter().enumerate() {
            ensure!(
                m.peak_alloc_vcpus <= limits.sched_vcpu_limit + 1e-9,
                "admission invariant violated: {} replicate {rep} peaked at {} vCPUs \
                 (limit {})",
                out.cell.id(),
                m.peak_alloc_vcpus,
                limits.sched_vcpu_limit
            );
            ensure!(
                m.peak_alloc_mem_mb <= limits.mem_gb * 1024.0 + 1e-9,
                "admission invariant violated: {} replicate {rep} peaked at {} MB \
                 (limit {})",
                out.cell.id(),
                m.peak_alloc_mem_mb,
                limits.mem_gb * 1024.0
            );
        }
    }
    Ok(())
}

/// Canonical sweep-cell runner: rebuild *everything* stochastic (workload
/// pools, trace, policy with its learner models and scheduler RNGs,
/// cluster RNG) from the derived `seed`, run once, and reduce to metrics.
/// The trace is generated under `Ctx::scenario`, so any grid runs under
/// any workload shape (`--scenario`, DESIGN.md §Scenarios). No state
/// crosses cells, which is what lets `sweep::run_cells` execute cells on
/// any thread in any order with byte-identical results.
pub fn run_cell(name: &str, ctx: &Ctx, rps: f64, seed: u64) -> Result<RunMetrics> {
    let cctx = ctx.with_seed(seed);
    let workload = cctx.workload();
    let cfg = sim_config(&cctx);
    let (_, metrics) = run_one(name, &cctx, &workload, rps, &cfg)?;
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_policies_constructible() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let w = ctx.workload();
        for name in POLICIES {
            let p = make_policy(name, &ctx, &w).unwrap();
            assert!(!p.name().is_empty());
        }
    }

    #[test]
    fn unknown_policy_rejected() {
        let ctx = Ctx::default();
        let w = Workload::build(1, 1.4);
        assert!(make_policy("nope", &ctx, &w).is_err());
    }

    #[test]
    fn run_one_produces_metrics() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let w = ctx.workload();
        let cfg = sim_config(&ctx);
        let (res, m) = run_one("static-medium", &ctx, &w, 2.0, &cfg).unwrap();
        assert!(m.invocations > 50, "2 rps over 60 s");
        assert_eq!(res.records.len(), m.invocations);
    }

    #[test]
    fn run_cell_honors_the_ctx_scenario() {
        let base = Ctx { duration_s: 60.0, ..Default::default() };
        let azure = run_cell("static-medium", &base, 2.0, 7).unwrap();
        // flash-crowd adds burst load on top of the base rate, so the two
        // scenarios cannot simulate the same number of invocations
        let flash =
            run_cell("static-medium", &base.with_scenario("flash-crowd"), 2.0, 7).unwrap();
        assert_ne!(azure.invocations, flash.invocations, "scenario did not reach the trace");
        // and naming the default explicitly is a no-op
        let explicit =
            run_cell("static-medium", &base.with_scenario("azure-synthetic"), 2.0, 7).unwrap();
        assert_eq!(azure.invocations, explicit.invocations);
        assert_eq!(azure.slo_violation_pct.to_bits(), explicit.slo_violation_pct.to_bits());
    }

    #[test]
    fn unknown_scenario_surfaces_as_error() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        assert!(run_cell("static-medium", &ctx.with_scenario("nope"), 2.0, 7).is_err());
    }

    #[test]
    fn sim_config_applies_the_ctx_keepalive_spec() {
        use crate::simulator::keepalive::{self, KeepAliveMode};
        let base = Ctx::default();
        let cfg = sim_config(&base);
        assert_eq!(cfg.keepalive, KeepAliveMode::Fixed);
        assert_eq!(cfg.keep_alive_s, 600.0, "default spec leaves the legacy TTL");
        let cfg = sim_config(&base.with_keepalive(keepalive::parse("pressure:90").unwrap()));
        assert_eq!(cfg.keepalive, KeepAliveMode::Pressure);
        assert_eq!(cfg.keep_alive_s, 90.0);
        // the explicit fixed:600 spec is byte-identical config-wise to
        // the default (the PR's stream-compatibility guarantee)
        let explicit = sim_config(&base.with_keepalive(keepalive::parse("fixed:600").unwrap()));
        assert_eq!(explicit.keepalive, KeepAliveMode::Fixed);
        assert_eq!(explicit.keep_alive_s, 600.0);
    }

    #[test]
    fn sim_config_applies_the_ctx_faults_spec() {
        use crate::simulator::faults::{self, FaultsMode};
        let base = Ctx::default();
        let cfg = sim_config(&base);
        assert_eq!(cfg.faults.mode, FaultsMode::None, "default ctx injects nothing");
        let cfg = sim_config(&base.with_faults(faults::parse("crash:30").unwrap()));
        assert_eq!(cfg.faults.mode, FaultsMode::Crash);
        assert_eq!(cfg.faults.param, Some(30.0));
        // naming `none` explicitly is config-identical to the default
        // (the byte-stream pin in test_determinism.rs rides on this)
        let explicit = sim_config(&base.with_faults(faults::parse("none").unwrap()));
        assert_eq!(explicit.faults, cfg_default_faults());
    }

    fn cfg_default_faults() -> crate::simulator::faults::FaultsSpec {
        sim_config(&Ctx::default()).faults
    }

    #[test]
    fn sim_config_applies_the_ctx_scaler_spec() {
        use crate::simulator::scaler::{self, ScalerMode};
        let base = Ctx::default();
        let cfg = sim_config(&base);
        assert_eq!(cfg.scaler.mode, ScalerMode::None, "default ctx scales nothing");
        let cfg = sim_config(&base.with_scaler(scaler::parse("fifer:0.6").unwrap()));
        assert_eq!(cfg.scaler.mode, ScalerMode::Fifer);
        assert_eq!(cfg.scaler.headroom, Some(0.6));
        // naming `none` explicitly is config-identical to the default
        // (the byte-stream pin in test_determinism.rs rides on this)
        let explicit = sim_config(&base.with_scaler(scaler::parse("none").unwrap()));
        assert_eq!(explicit.scaler, sim_config(&Ctx::default()).scaler);
    }

    #[test]
    fn with_seed_traces_only_the_base_replicate() {
        let traced = Ctx {
            trace: Some(TraceOut { jsonl: Some("out/t.jsonl".into()), ..Default::default() }),
            ..Default::default()
        };
        assert!(traced.with_seed(traced.seed).trace.is_some(), "replicate 0 keeps the trace");
        assert!(traced.with_seed(traced.seed ^ 99).trace.is_none(), "replicates >= 1 drop it");
        // and a traced ctx flips the engine's trace config on
        assert!(sim_config(&traced).trace.is_some());
        assert!(sim_config(&Ctx::default()).trace.is_none(), "default stays dormant");
    }

    #[test]
    fn trace_paths_exact_vs_grid_suffix() {
        let ctx = Ctx::default();
        let cfg = sim_config(&ctx);
        let out = TraceOut {
            jsonl: Some("out/t.jsonl".into()),
            chrome: Some("out/t.json".into()),
            interval_s: 10.0,
            exact: true,
        };
        assert_eq!(
            trace_paths(&out, "shabari", 4.0, &ctx, &cfg),
            (Some("out/t.jsonl".into()), Some("out/t.json".into())),
            "exact mode passes paths through verbatim"
        );
        let grid = TraceOut { exact: false, ..out };
        let (j, c) = trace_paths(&grid, "shabari", 4.0, &ctx, &cfg);
        let j = j.unwrap();
        assert!(j.starts_with("out/t-shabari-4") && j.ends_with(".jsonl"), "{j}");
        assert!(c.unwrap().ends_with(".json"));
        // distinct cells never collide, same cell is stable
        let (j2, _) = trace_paths(&grid, "cypress", 4.0, &ctx, &cfg);
        assert_ne!(j, j2.unwrap());
        let (k, _) =
            trace_paths(&grid, "shabari", 4.0, &ctx.with_scenario("flash-crowd"), &cfg);
        assert_ne!(j, k.unwrap(), "config overrides reach the hash");
        let (again, _) = trace_paths(&grid, "shabari", 4.0, &ctx, &cfg);
        assert_eq!(j, again.unwrap(), "names are deterministic");
    }

    #[test]
    fn run_cell_rebuilds_from_derived_seed() {
        let ctx = Ctx { duration_s: 60.0, ..Default::default() };
        let a = run_cell("static-medium", &ctx, 2.0, 1234).unwrap();
        let b = run_cell("static-medium", &ctx, 2.0, 1234).unwrap();
        assert_eq!(a.slo_violation_pct.to_bits(), b.slo_violation_pct.to_bits());
        assert_ne!(a.invocations, 0, "sanity: the cell simulated something");
        // a different derived seed must rebuild a different stochastic world
        let c = run_cell("static-medium", &ctx, 2.0, 5678).unwrap();
        assert!(
            a.invocations != c.invocations
                || a.mean_e2e_s.to_bits() != c.mean_e2e_s.to_bits(),
            "seed 5678 must not reproduce seed 1234's run"
        );
    }
}
