//! `experiment keepalive` — the keep-alive policy × workload matrix
//! (DESIGN.md §KeepAlive): scheduling policies crossed with every
//! registered keep-alive variant over (at least) the azure-synthetic and
//! diurnal scenarios, replicated across `Ctx::seeds` seeds on
//! `Ctx::jobs` threads, on a deliberately small cluster
//! (`--keepalive-workers`) so admission queues form and demand-driven
//! eviction has demand to serve.
//!
//! The question it answers: how much of the fixed-TTL warm pool's
//! idle-container-seconds (the memory-waste proxy behind the paper's
//! 64–94% wasted-memory reductions) can a smarter eviction policy
//! recover, and at what cold-start/latency price? Expected shape
//! (EXPERIMENTS.md): `histogram` and `pressure` cut idle
//! container-seconds sharply vs `fixed:600` at equal or better tail
//! latency; `fixed:120` sits between, trading idle seconds for cold
//! starts without any per-function signal.
//!
//! Like `experiment overload`, every replicate re-verifies the admission
//! invariant — under `pressure` the reservation ledger changes shape
//! (idle containers hold capacity), so the peaks are re-witnessed here.
//!
//! Emits `out/keepalive.json` (`make keepalive`; CI runs a shrunk smoke).

use anyhow::Result;

use crate::metrics::RunMetrics;
use crate::simulator::keepalive as ka;
use crate::simulator::SimConfig;
use crate::util::json::Json;
use crate::util::table::{fnum, fpct, Table};

use super::common::{self, Ctx};
use super::sweep::{self, Cell, CellOutcome};

/// Scheduling policies crossed with the keep-alive axis: the full stack
/// and the biggest static hoarder (demand-driven eviction's natural
/// prey).
pub const KA_POLICIES: &[&str] = &["shabari", "static-large"];

/// The keep-alive axis: legacy default, a short fixed TTL, the hybrid
/// histogram, and demand-driven pressure eviction.
pub const KA_VARIANTS: &[&str] = &["fixed:600", "fixed:120", "histogram", "pressure"];

/// Workload shapes (idle-gap distributions differ sharply between them).
pub const KA_SCENARIOS: &[&str] = &["azure-synthetic", "diurnal"];

/// Load on the small `--keepalive-workers` cluster: high enough that
/// queues form under hoarding, below the overload meltdown regime.
pub const KA_RPS: f64 = 12.0;

/// Cell label carrying both matrix axes (salts replicate seeds so the
/// same scheduling policy under two keep-alive variants samples
/// disjoint RNG streams at replicates ≥ 1, while replicate 0 stays
/// grid-wide paired).
fn cell_label(variant: &str, scenario: &str) -> String {
    format!("keepalive:{variant}|scenario:{scenario}")
}

/// Recover (variant, scenario) from a cell label.
fn cell_parts(cell: &Cell) -> (&str, &str) {
    let rest = cell.label.strip_prefix("keepalive:").unwrap_or(&cell.label);
    match rest.split_once("|scenario:") {
        Some((variant, scenario)) => (variant, scenario),
        None => (rest, "azure-synthetic"),
    }
}

/// Run the policy × variant × scenario grid; outcome index is
/// `(pi * KA_VARIANTS.len() + vi) * KA_SCENARIOS.len() + si`. Every
/// replicate re-verifies the admission invariant against the per-worker
/// lifetime peaks (the run errors otherwise).
pub fn run_keepalive(ctx: &Ctx, rps: f64) -> Result<Vec<CellOutcome<RunMetrics>>> {
    let workers = ctx.keepalive_workers;
    let cells: Vec<Cell> = KA_POLICIES
        .iter()
        .flat_map(|p| {
            KA_VARIANTS.iter().flat_map(move |v| {
                KA_SCENARIOS
                    .iter()
                    .map(move |s| Cell::labeled(p, rps, &cell_label(v, s), workers as f64))
            })
        })
        .collect();
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        let (variant, scenario) = cell_parts(cell);
        let spec = ka::parse(variant)?;
        let cctx = ctx.with_seed(seed).with_scenario(scenario).with_keepalive(spec);
        let workload = cctx.workload();
        let cfg = SimConfig { workers, ..common::sim_config(&cctx) };
        let (_, metrics) = common::run_one(&cell.policy, &cctx, &workload, cell.rps, &cfg)?;
        Ok(metrics)
    })?;
    common::ensure_admission_invariant(&outcomes, &common::sim_config(ctx))?;
    Ok(outcomes)
}

pub fn keepalive(ctx: &Ctx) -> Result<()> {
    // lint:allow(D002): host wall time for the runner's wall-clock report line only
    let t0 = std::time::Instant::now();
    let outcomes = run_keepalive(ctx, KA_RPS)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "(keepalive matrix: {} cells x {} seed(s) on {} job(s), {wall:.1}s wall; \
         admission invariant held on every replicate)",
        outcomes.len(),
        ctx.seeds,
        ctx.jobs
    );

    let mut t = Table::new(
        &format!(
            "keepalive: {} workers @ {} rps, {}s trace (cross-seed means; \
             idle-s = container-seconds idle in the warm pool)",
            ctx.keepalive_workers, KA_RPS, ctx.duration_s
        ),
        &[
            "system",
            "keepalive",
            "scenario",
            "SLO viol [95% CI]",
            "cold",
            "idle-s",
            "evict ttl",
            "evict press",
            "prewarm hit",
            "queue p99 s",
        ],
    );
    for out in &outcomes {
        let (variant, scenario) = cell_parts(&out.cell);
        let m = out.mean_metrics();
        t.row(vec![
            out.cell.policy.clone(),
            variant.to_string(),
            scenario.to_string(),
            out.stat(|m| m.slo_violation_pct).fmt_ci(1),
            fpct(m.cold_start_pct),
            fnum(m.idle_container_s, 0),
            m.evictions.to_string(),
            m.pressure_evictions.to_string(),
            m.prewarm_hits.to_string(),
            fnum(m.queue_wait.p99, 2),
        ]);
    }
    t.note(
        "expected shape: histogram/pressure cut idle container-seconds vs fixed:600 \
         at equal-or-better tail latency; fixed:120 trades idle-s for cold starts blindly",
    );
    t.print();

    let limits = common::sim_config(ctx);
    let dump = Json::obj(vec![
        ("perf", common::perf_json(wall, &outcomes)),
        (
            "config",
            Json::obj(vec![
                ("workers", Json::Num(ctx.keepalive_workers as f64)),
                ("rps", Json::Num(KA_RPS)),
                ("duration_s", Json::Num(ctx.duration_s)),
                ("seeds", Json::Num(ctx.seeds as f64)),
                ("jobs", Json::Num(ctx.jobs as f64)),
                ("seed", Json::Num(ctx.seed as f64)),
                ("sched_vcpu_limit", Json::Num(limits.sched_vcpu_limit)),
            ]),
        ),
        (
            "rows",
            Json::Arr(
                outcomes
                    .iter()
                    .map(|out| {
                        let (variant, scenario) = cell_parts(&out.cell);
                        let m = out.mean_metrics();
                        let viol = out.stat(|m| m.slo_violation_pct);
                        Json::obj(vec![
                            ("policy", Json::Str(out.cell.policy.clone())),
                            ("keepalive", Json::Str(variant.to_string())),
                            ("scenario", Json::Str(scenario.to_string())),
                            ("slo_violation_pct_mean", Json::Num(viol.mean)),
                            ("slo_violation_pct_ci95_lo", Json::Num(viol.ci95.0)),
                            ("slo_violation_pct_ci95_hi", Json::Num(viol.ci95.1)),
                            ("cold_start_pct", Json::Num(m.cold_start_pct)),
                            ("idle_container_s", Json::Num(m.idle_container_s)),
                            ("evictions", Json::Num(m.evictions as f64)),
                            ("pressure_evictions", Json::Num(m.pressure_evictions as f64)),
                            ("prewarm_hits", Json::Num(m.prewarm_hits as f64)),
                            ("queue_p99_s", Json::Num(m.queue_wait.p99)),
                            ("queued_pct", Json::Num(m.queued_pct)),
                            ("timeout_pct", Json::Num(m.timeout_pct)),
                            ("peak_alloc_vcpus", Json::Num(m.peak_alloc_vcpus)),
                            ("invocations", Json::Num(m.invocations as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::create_dir_all("out").ok();
    match std::fs::write("out/keepalive.json", dump.to_pretty()) {
        Ok(()) => println!("(dumped out/keepalive.json)"),
        Err(e) => eprintln!("warning: could not write out/keepalive.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_labels_round_trip_both_axes() {
        let c = Cell::labeled("shabari", KA_RPS, &cell_label("pressure", "diurnal"), 4.0);
        assert_eq!(cell_parts(&c), ("pressure", "diurnal"));
        // distinct variants/scenarios occupy distinct seed streams
        let a = Cell::labeled("shabari", 12.0, &cell_label("fixed:600", "diurnal"), 4.0);
        let b = Cell::labeled("shabari", 12.0, &cell_label("histogram", "diurnal"), 4.0);
        assert_ne!(sweep::cell_seed(42, &a, 1), sweep::cell_seed(42, &b, 1));
        assert_eq!(sweep::cell_seed(42, &a, 0), sweep::cell_seed(42, &b, 0));
    }

    /// Tiny-parameter smoke mirroring the CI job: the grid covers every
    /// (policy, variant, scenario) triple, is deterministic across
    /// thread counts, and the smarter policies do not *hoard more* than
    /// the legacy fixed default.
    #[test]
    fn keepalive_grid_covers_axes_and_is_jobs_invariant() {
        let ctx = Ctx { duration_s: 30.0, keepalive_workers: 1, seeds: 1, ..Default::default() };
        let seq = run_keepalive(&Ctx { jobs: 1, ..ctx.clone() }, KA_RPS).unwrap();
        let par = run_keepalive(&Ctx { jobs: 4, ..ctx }, KA_RPS).unwrap();
        assert_eq!(seq.len(), KA_POLICIES.len() * KA_VARIANTS.len() * KA_SCENARIOS.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.cell.id(), b.cell.id());
            let (ma, mb) = (a.mean_metrics(), b.mean_metrics());
            assert_eq!(ma.invocations, mb.invocations);
            assert_eq!(
                ma.idle_container_s.to_bits(),
                mb.idle_container_s.to_bits(),
                "{} idle accounting diverged across --jobs",
                a.cell.id()
            );
            assert_eq!(ma.evictions, mb.evictions);
            assert_eq!(ma.pressure_evictions, mb.pressure_evictions);
        }
        // paired replicate-0 worlds: for the same policy × scenario, the
        // histogram variant must not idle *more* container-seconds than
        // the fixed default it specializes (its TTLs are clamped to it)
        let find = |variant: &str| {
            seq.iter()
                .find(|o| {
                    o.cell.policy == "static-large"
                        && cell_parts(&o.cell) == (variant, "azure-synthetic")
                })
                .unwrap()
                .mean_metrics()
        };
        let fixed = find("fixed:600");
        let hist = find("histogram");
        assert!(fixed.idle_container_s > 0.0, "fixed must leave an idle warm pool");
        assert!(
            hist.idle_container_s <= fixed.idle_container_s,
            "histogram hoarded more idle-s ({}) than fixed:600 ({})",
            hist.idle_container_s,
            fixed.idle_container_s
        );
    }
}
