//! Mini property-testing harness (no `proptest` in the offline build).
//!
//! `check(seed_base, cases, |rng| ...)` runs a closure over `cases`
//! independently-seeded RNGs and reports the failing seed so a failure is
//! reproducible with `check_one(seed, ...)`. Generators live on [`Rng`]
//! itself (uniform/exp/normal/...) plus the helpers here for common
//! simulation inputs.

use super::rng::Rng;

/// Run `body` for `cases` deterministic seeds. Panics with the seed on the
/// first failing case (the body panics to signal failure, like a test).
pub fn check(seed_base: u64, cases: u64, body: impl Fn(&mut Rng)) {
    for i in 0..cases {
        let seed = seed_base.wrapping_add(i).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_one(seed: u64, body: impl Fn(&mut Rng)) {
    let mut rng = Rng::new(seed);
    body(&mut rng);
}

/// A random vector of f64 in [lo, hi).
pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| rng.range_f64(lo, hi)).collect()
}

/// A random vector of f32 in [lo, hi).
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len)
        .map(|_| rng.range_f64(lo as f64, hi as f64) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0u64);
        check(1, 50, |_rng| {
            counter.set(counter.get() + 1);
        });
        assert_eq!(counter.get(), 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check(2, 100, |rng| {
                // fails eventually
                assert!(rng.f64() < 0.95, "drew a large value");
            });
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("seed 0x"), "{msg}");
    }

    #[test]
    fn vec_generators_in_range() {
        check(3, 20, |rng| {
            let v = vec_f64(rng, 32, -1.0, 1.0);
            assert_eq!(v.len(), 32);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        });
    }
}
