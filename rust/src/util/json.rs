//! Minimal JSON: a writer for experiment dumps and a recursive-descent
//! parser for manifests/configs. No third-party JSON crate exists in the
//! offline build; this subset (no surrogate escapes, f64 numbers) is all
//! the repo needs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so dumps are stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at offset {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at offset {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos),
        }
    }

    fn keyword(&mut self, kw: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(val)
        } else {
            bail!("invalid keyword at offset {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 character
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E') | Some(b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = Json::obj(vec![
            ("name", Json::Str("shabari".into())),
            ("rps", Json::Num(6.0)),
            ("ok", Json::Bool(true)),
            ("tags", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        let s = j.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": {"b": [1, 2, {"c": null}]}, "d": -3.5e2}"#).unwrap();
        assert_eq!(j.get("d").unwrap().as_f64(), Some(-350.0));
        let b = j.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn parse_escapes() {
        let j = parse(r#""line\nbreak \"quoted\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nbreak \"quoted\" A"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} extra").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn integers_serialize_clean() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn pretty_parses_back() {
        let j = Json::obj(vec![
            ("arr", Json::arr_f64(&[1.0, 2.0])),
            ("obj", Json::obj(vec![("k", Json::Str("v".into()))])),
            ("empty", Json::Arr(vec![])),
        ]);
        let back = parse(&j.to_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_content() {
        let j = parse(r#""héllo → wörld""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo → wörld"));
    }
}
