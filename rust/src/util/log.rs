//! Minimal leveled logger to stderr, controlled by `SHABARI_LOG`
//! (error|warn|info|debug|trace; default warn). Experiments print their
//! results to stdout; the logger is for operational messages only.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("SHABARI_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (tests, CLI flag).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
