//! Minimal leveled logger to stderr, controlled by `SHABARI_LOG`
//! (error|warn|info|debug|trace; default warn). Experiments print their
//! results to stdout; the logger is for operational messages only.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("SHABARI_LOG").as_deref() {
        Ok(name) => parse_level(name).unwrap_or_else(|| {
            // One-time, since the result is cached in LEVEL below: a typo
            // like SHABARI_LOG=dbug should not silently mean "warn".
            eprintln!(
                "[WARN ] unrecognized SHABARI_LOG value '{name}' \
                 (expected error|warn|info|debug|trace); using warn"
            );
            Level::Warn
        }),
        Err(_) => Level::Warn,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Parse a level name (the `SHABARI_LOG` / `--log-level` vocabulary).
pub fn parse_level(name: &str) -> Option<Level> {
    match name {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Override the level programmatically (tests, CLI flag).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }

    #[test]
    fn parse_level_covers_the_vocabulary() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("dbug"), None);
        assert_eq!(parse_level(""), None);
    }
}
