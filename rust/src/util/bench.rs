//! Micro-benchmark harness (no `criterion` in the offline build).
//!
//! Provides warmup + timed iterations with mean/p50/p95/p99 per-iteration
//! latency and a simple comparison printer. The `rust/benches/*.rs` targets
//! (declared with `harness = false`) drive this directly; `cargo bench`
//! runs them like normal binaries.

use std::hint::black_box;
use std::time::Instant;

use super::stats;

/// Result of one benchmark: per-iteration latencies in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub total_s: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
/// Each iteration is timed individually (fine for >= ~1 µs bodies; for
/// nanosecond bodies use [`run_batched`]).
pub fn run(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut lat = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        lat.push(t0.elapsed().as_nanos() as f64);
    }
    let total_s = start.elapsed().as_secs_f64();
    finish(name, lat, total_s)
}

/// Time `f` in batches of `batch` calls per clock read — for very short
/// bodies where a per-call `Instant::now()` would dominate.
pub fn run_batched(
    name: &str,
    warmup: usize,
    batches: usize,
    batch: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut lat = Vec::with_capacity(batches);
    let start = Instant::now();
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        lat.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    let total_s = start.elapsed().as_secs_f64();
    finish(name, lat, total_s)
}

fn finish(name: &str, mut lat: Vec<f64>, total_s: f64) -> BenchResult {
    lat.sort_by(f64::total_cmp);
    let r = BenchResult {
        name: name.to_string(),
        iters: lat.len(),
        mean_ns: stats::mean(&lat),
        p50_ns: stats::percentile(&lat, 50.0),
        p95_ns: stats::percentile(&lat, 95.0),
        p99_ns: stats::percentile(&lat, 99.0),
        total_s,
    };
    println!("{}", format_result(&r));
    r
}

/// Human-readable one-liner: `name  mean±  p50  p95  p99  rate`.
pub fn format_result(r: &BenchResult) -> String {
    format!(
        "{:<44} {:>12}/iter  p50 {:>10}  p95 {:>10}  p99 {:>10}  ({:.1}/s, n={})",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        fmt_ns(r.p99_ns),
        r.throughput_per_s(),
        r.iters
    )
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn keep<T>(value: T) -> T {
    black_box(value)
}

/// Print a section header so `cargo bench` output groups cleanly.
pub fn section(title: &str) {
    println!("\n### {title}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = run("noop-ish", 5, 50, || {
            keep((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }

    #[test]
    fn batched_reports_per_call() {
        let r = run_batched("batched", 2, 10, 100, || {
            keep(1u64 + 1);
        });
        assert_eq!(r.iters, 10);
        // per-call latency of an add must be far under 10µs
        assert!(r.mean_ns < 10_000.0, "mean {}", r.mean_ns);
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
    }
}
