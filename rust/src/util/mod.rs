//! Self-contained substrates (no third-party deps available offline):
//! PRNG + distributions, statistics, JSON, config parsing, tables,
//! property testing, micro-benchmarking, logging.

pub mod bench;
pub mod conf;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
