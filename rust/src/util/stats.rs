//! Streaming-friendly statistics: percentiles, summaries, histograms, CDFs.
//!
//! Every figure in the paper reports distributions (p50/p75/p90/p95 wasted
//! resources, SLO-violation fractions, utilization CDFs); this module is
//! the single implementation the metrics and experiment layers share.

/// Summary of a sample: count, mean, std, min/max and key percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn empty() -> Self {
        Summary {
            count: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p75: 0.0,
            p90: 0.0,
            p95: 0.0,
            p99: 0.0,
        }
    }
}

/// Percentile with linear interpolation between closest ranks
/// (NIST method R-7, matching numpy's default).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
    match sorted.len() {
        0 => 0.0,
        1 => sorted[0],
        n => {
            let rank = p / 100.0 * (n - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            let frac = rank - lo as f64;
            sorted[lo] + (sorted[hi] - sorted[lo]) * frac
        }
    }
}

/// Compute a [`Summary`] of a sample (copies + sorts internally).
pub fn summarize(values: &[f64]) -> Summary {
    if values.is_empty() {
        return Summary::empty();
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        count: n,
        mean,
        std: var.sqrt(),
        min: v[0],
        max: v[n - 1],
        p50: percentile(&v, 50.0),
        p75: percentile(&v, 75.0),
        p90: percentile(&v, 90.0),
        p95: percentile(&v, 95.0),
        p99: percentile(&v, 99.0),
    }
}

/// Median of a sample (convenience).
pub fn median(values: &[f64]) -> f64 {
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
    percentile(&v, 50.0)
}

/// Mean of a sample.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    }
}

/// Fraction of values satisfying a predicate, as a percentage 0..100.
pub fn percent_where<T>(values: &[T], pred: impl Fn(&T) -> bool) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    100.0 * values.iter().filter(|v| pred(v)).count() as f64 / values.len() as f64
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            n - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize
        };
        self.bins[idx.min(n - 1)] += 1;
        self.total += 1;
    }

    /// Fraction of mass at or below bin `i` (inclusive CDF).
    pub fn cdf(&self) -> Vec<f64> {
        let mut acc = 0u64;
        self.bins
            .iter()
            .map(|&b| {
                acc += b;
                if self.total == 0 { 0.0 } else { acc as f64 / self.total as f64 }
            })
            .collect()
    }
}

/// Empirical CDF points (x, F(x)) from a sample — used by figure dumps.
pub fn ecdf(values: &[f64]) -> Vec<(f64, f64)> {
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
    let n = v.len();
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n as f64))
        .collect()
}

/// Online mean/variance (Welford). Used by the worker utilization daemon
/// where we cannot afford to buffer every 10 ms sample.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    max: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if self.n == 1 || x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(summarize(&[]).count, 0);
    }

    #[test]
    fn percent_where_counts() {
        let v = [1, 2, 3, 4];
        assert!((percent_where(&v, |x| *x > 2) - 50.0).abs() < 1e-12);
        assert_eq!(percent_where::<i32>(&[], |_| true), 0.0);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-5.0); // clamps to bin 0
        h.add(0.5);
        h.add(9.9);
        h.add(50.0); // clamps to last
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total, 4);
        let cdf = h.cdf();
        assert!((cdf[9] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone() {
        let pts = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(pts[0].0, 1.0);
        assert!((pts[2].1 - 1.0).abs() < 1e-12);
        assert!(pts.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 4.0, 2.0, 8.0, 5.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        let s = summarize(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.var().sqrt() - s.std).abs() < 1e-9);
        assert_eq!(w.max(), 8.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[1.0, 2.0, 3.0, 4.0]) - 2.5).abs() < 1e-12);
    }
}
