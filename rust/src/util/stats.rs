//! Streaming-friendly statistics: percentiles, summaries, histograms, CDFs.
//!
//! Every figure in the paper reports distributions (p50/p75/p90/p95 wasted
//! resources, SLO-violation fractions, utilization CDFs); this module is
//! the single implementation the metrics and experiment layers share.

/// Summary of a sample: count, mean, std, min/max and key percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn empty() -> Self {
        Summary {
            count: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p75: 0.0,
            p90: 0.0,
            p95: 0.0,
            p99: 0.0,
        }
    }
}

/// Percentile with linear interpolation between closest ranks
/// (NIST method R-7, matching numpy's default).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
    match sorted.len() {
        0 => 0.0,
        1 => sorted[0],
        n => {
            let rank = p / 100.0 * (n - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            let frac = rank - lo as f64;
            sorted[lo] + (sorted[hi] - sorted[lo]) * frac
        }
    }
}

/// Compute a [`Summary`] of a sample (copies + sorts internally).
pub fn summarize(values: &[f64]) -> Summary {
    if values.is_empty() {
        return Summary::empty();
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        count: n,
        mean,
        std: var.sqrt(),
        min: v[0],
        max: v[n - 1],
        p50: percentile(&v, 50.0),
        p75: percentile(&v, 75.0),
        p90: percentile(&v, 90.0),
        p95: percentile(&v, 95.0),
        p99: percentile(&v, 99.0),
    }
}

/// Median of a sample (convenience).
pub fn median(values: &[f64]) -> f64 {
    let mut v = values.to_vec();
    v.sort_by(f64::total_cmp);
    percentile(&v, 50.0)
}

/// Mean of a sample.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    }
}

/// Fraction of values satisfying a predicate, as a percentage 0..100.
pub fn percent_where<T>(values: &[T], pred: impl Fn(&T) -> bool) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    100.0 * values.iter().filter(|v| pred(v)).count() as f64 / values.len() as f64
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            n - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize
        };
        self.bins[idx.min(n - 1)] += 1;
        self.total += 1;
    }

    /// Fraction of mass at or below bin `i` (inclusive CDF).
    pub fn cdf(&self) -> Vec<f64> {
        let mut acc = 0u64;
        self.bins
            .iter()
            .map(|&b| {
                acc += b;
                if self.total == 0 { 0.0 } else { acc as f64 / self.total as f64 }
            })
            .collect()
    }
}

/// Empirical CDF points (x, F(x)) from a sample — used by figure dumps.
pub fn ecdf(values: &[f64]) -> Vec<(f64, f64)> {
    let mut v = values.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n as f64))
        .collect()
}

/// Cross-replicate aggregate of one scalar metric: the sweep harness
/// (`experiments::sweep`, DESIGN.md §4) reports every headline number as
/// mean/p50/p99 over seeds plus a percentile-bootstrap 95% CI of the mean.
#[derive(Debug, Clone)]
pub struct SeedStats {
    /// Number of replicates aggregated.
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    /// 95% bootstrap confidence interval of the mean (lo, hi).
    pub ci95: (f64, f64),
}

impl SeedStats {
    /// Render as `mean [lo, hi]` with the given decimal places.
    pub fn fmt_ci(&self, digits: usize) -> String {
        format!(
            "{:.d$} [{:.d$}, {:.d$}]",
            self.mean,
            self.ci95.0,
            self.ci95.1,
            d = digits
        )
    }
}

/// Aggregate per-seed values into a [`SeedStats`]. Deterministic: the
/// bootstrap RNG is seeded from a fixed constant, so the same value list
/// yields byte-identical statistics regardless of thread count.
pub fn seed_stats(values: &[f64]) -> SeedStats {
    let s = summarize(values);
    SeedStats {
        n: s.count,
        mean: s.mean,
        p50: s.p50,
        p99: s.p99,
        ci95: bootstrap_ci_mean(values, 1000, 0x5EED_C1AA),
    }
}

/// Percentile-bootstrap 95% confidence interval for the mean: resample
/// `values` with replacement `resamples` times and take the 2.5/97.5
/// percentiles of the resampled means. Deterministic for a given seed.
pub fn bootstrap_ci_mean(values: &[f64], resamples: usize, seed: u64) -> (f64, f64) {
    match values.len() {
        0 => return (0.0, 0.0),
        1 => return (values[0], values[0]),
        _ => {}
    }
    let mut rng = super::rng::Rng::new(seed);
    let n = values.len();
    let mut means = Vec::with_capacity(resamples);
    for _ in 0..resamples {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += values[rng.below(n)];
        }
        means.push(acc / n as f64);
    }
    means.sort_by(f64::total_cmp);
    (percentile(&means, 2.5), percentile(&means, 97.5))
}

/// Field-wise mean of several [`Summary`]s (cross-seed reduction of a
/// distribution summary; averaging percentiles over replicates is the
/// standard way the paper-style tables are aggregated across trials).
pub fn average_summaries(items: &[&Summary]) -> Summary {
    if items.is_empty() {
        return Summary::empty();
    }
    let n = items.len() as f64;
    let avg = |f: fn(&Summary) -> f64| items.iter().map(|s| f(s)).sum::<f64>() / n;
    Summary {
        count: (items.iter().map(|s| s.count).sum::<usize>() as f64 / n).round() as usize,
        mean: avg(|s| s.mean),
        std: avg(|s| s.std),
        min: items.iter().map(|s| s.min).fold(f64::INFINITY, f64::min),
        max: items.iter().map(|s| s.max).fold(f64::NEG_INFINITY, f64::max),
        p50: avg(|s| s.p50),
        p75: avg(|s| s.p75),
        p90: avg(|s| s.p90),
        p95: avg(|s| s.p95),
        p99: avg(|s| s.p99),
    }
}

/// Online mean/variance (Welford). Used by the worker utilization daemon
/// where we cannot afford to buffer every 10 ms sample.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    max: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if self.n == 1 || x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(summarize(&[]).count, 0);
    }

    #[test]
    fn percent_where_counts() {
        let v = [1, 2, 3, 4];
        assert!((percent_where(&v, |x| *x > 2) - 50.0).abs() < 1e-12);
        assert_eq!(percent_where::<i32>(&[], |_| true), 0.0);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-5.0); // clamps to bin 0
        h.add(0.5);
        h.add(9.9);
        h.add(50.0); // clamps to last
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total, 4);
        let cdf = h.cdf();
        assert!((cdf[9] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone() {
        let pts = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(pts[0].0, 1.0);
        assert!((pts[2].1 - 1.0).abs() < 1e-12);
        assert!(pts.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 4.0, 2.0, 8.0, 5.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        let s = summarize(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.var().sqrt() - s.std).abs() < 1e-9);
        assert_eq!(w.max(), 8.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[1.0, 2.0, 3.0, 4.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_ci_brackets_the_mean() {
        let v = [4.0, 5.0, 6.0, 5.5, 4.5, 5.2, 4.8, 5.9];
        let (lo, hi) = bootstrap_ci_mean(&v, 500, 7);
        let m = mean(&v);
        assert!(lo <= m && m <= hi, "CI [{lo}, {hi}] must bracket mean {m}");
        assert!(lo >= 4.0 && hi <= 6.0, "CI stays within the sample range");
    }

    #[test]
    fn bootstrap_ci_deterministic() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(bootstrap_ci_mean(&v, 200, 9), bootstrap_ci_mean(&v, 200, 9));
    }

    #[test]
    fn bootstrap_ci_degenerate_cases() {
        assert_eq!(bootstrap_ci_mean(&[], 100, 1), (0.0, 0.0));
        assert_eq!(bootstrap_ci_mean(&[3.5], 100, 1), (3.5, 3.5));
    }

    #[test]
    fn seed_stats_reports_all_views() {
        let v = [2.0, 4.0, 6.0];
        let s = seed_stats(&v);
        assert_eq!(s.n, 3);
        assert!((s.mean - 4.0).abs() < 1e-12);
        assert_eq!(s.p50, 4.0);
        assert!(s.ci95.0 <= s.mean && s.mean <= s.ci95.1);
        assert!(s.fmt_ci(1).starts_with("4.0 ["));
    }

    #[test]
    fn average_summaries_fieldwise() {
        let a = summarize(&[1.0, 2.0, 3.0]);
        let b = summarize(&[3.0, 4.0, 5.0]);
        let m = average_summaries(&[&a, &b]);
        assert_eq!(m.count, 3);
        assert!((m.mean - 3.0).abs() < 1e-12);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.max, 5.0);
        assert!((m.p50 - 3.0).abs() < 1e-12);
        assert_eq!(average_summaries(&[]).count, 0);
    }
}
