//! ASCII table rendering for experiment output — every `shabari experiment`
//! runner prints its figure/table as rows the way the paper reports them.

/// A simple column-aligned table with a title and optional footnote.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub note: Option<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            note: None,
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {} in table '{}'",
            cells.len(),
            self.headers.len(),
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, note: &str) -> &mut Self {
        self.note = Some(note.to_string());
        self
    }

    /// Render with column alignment: first column left, rest right.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * (ncols.saturating_sub(1));
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str(" | ");
                }
                let pad = widths[i].saturating_sub(cell.chars().count());
                if i == 0 {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                } else {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        if let Some(note) = &self.note {
            out.push_str(&format!("note: {note}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `digits` decimals, trimming to an int when exact.
pub fn fnum(x: f64, digits: usize) -> String {
    if x.fract() == 0.0 && x.abs() < 1e12 && digits <= 6 {
        format!("{}", x as i64)
    } else {
        format!("{x:.digits$}")
    }
}

/// Format a percentage.
pub fn fpct(x: f64) -> String {
    format!("{x:.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Fig X", &["system", "slo viol", "waste"]);
        t.row(vec!["shabari".into(), "4.2%".into(), "0".into()]);
        t.row(vec!["static-large".into(), "12.9%".into(), "11".into()]);
        let r = t.render();
        assert!(r.contains("== Fig X =="));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines have the same width
        assert_eq!(lines[1].len(), lines[3].len());
        assert!(r.contains("shabari"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn number_formatting() {
        assert_eq!(fnum(3.0, 2), "3");
        assert_eq!(fnum(3.14159, 2), "3.14");
        assert_eq!(fpct(12.3456), "12.3%");
    }

    #[test]
    fn note_rendered() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into()]).note("lower is better");
        assert!(t.render().contains("note: lower is better"));
    }
}
