//! Deterministic PRNG + distributions.
//!
//! The offline build has no `rand` crate, so we implement a small,
//! well-understood generator (splitmix64-seeded xoshiro256**) plus the
//! distributions the simulator needs: uniform, exponential (Poisson
//! arrivals), normal (runtime noise), lognormal (service-time skew),
//! Pareto (Azure-like burst sizes), and categorical sampling.
//!
//! Every simulation component takes an explicit `Rng` so whole experiments
//! are reproducible from a single seed (`--seed` on the CLI).

/// xoshiro256** — public-domain generator by Blackman & Vigna.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used for Poisson
    /// inter-arrival times.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0,1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (polar form avoided; simplicity wins).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Pareto with scale `xm` and shape `alpha` (heavy-tailed bursts).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical with zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

/// Stable 64-bit FNV-1a hash — used for "home server" hashing in the
/// scheduler (must be deterministic across runs, unlike `DefaultHasher`
/// which is seeded per-process).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn categorical_proportions() {
        let mut r = Rng::new(5);
        let w = [1.0, 3.0];
        let n = 40_000;
        let ones = (0..n).filter(|_| r.categorical(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn pareto_lower_bound() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a(b"matmult"), fnv1a(b"matmult"));
        assert_ne!(fnv1a(b"matmult"), fnv1a(b"linpack"));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
