//! TOML-subset configuration parser (no `toml`/`serde` offline).
//!
//! Supports what the launcher's config files use: `[section]` headers,
//! `key = value` with string/number/bool/array values, `#` comments.
//! Typed getters return helpful errors naming the section and key.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed configuration: `section -> key -> value`. Keys outside any
/// section land in the "" section.
#[derive(Debug, Clone, Default)]
pub struct Conf {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Conf {
    pub fn parse(text: &str) -> Result<Conf> {
        let mut conf = Conf::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                conf.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value for '{}'", lineno + 1, key.trim()))?;
            conf.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(conf)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Conf> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }

    pub fn f64(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| anyhow!("[{section}] {key} must be a number, got {v:?}")),
        }
    }

    pub fn usize(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        let f = self.f64(section, key, default as f64)?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("[{section}] {key} must be a non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn string(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("[{section}] {key} must be a string, got {v:?}")),
        }
    }

    pub fn bool(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_bool()
                .ok_or_else(|| anyhow!("[{section}] {key} must be a bool, got {v:?}")),
        }
    }

    pub fn f64_list(&self, section: &str, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(section, key) {
            None => Ok(default.to_vec()),
            Some(Value::Arr(items)) => items
                .iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| anyhow!("[{section}] {key}: non-numeric array element"))
                })
                .collect(),
            Some(v) => bail!("[{section}] {key} must be an array, got {v:?}"),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value> {
    let t = text.trim();
    if t.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    t.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow!("cannot parse '{t}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster shape
[cluster]
workers = 16
vcpus_per_worker = 90   # Borg-style limit
mem_gb = 125.0
name = "testbed"
debug = false

[workload]
rps_sweep = [2, 3, 4, 5, 6]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Conf::parse(SAMPLE).unwrap();
        assert_eq!(c.usize("cluster", "workers", 0).unwrap(), 16);
        assert_eq!(c.f64("cluster", "mem_gb", 0.0).unwrap(), 125.0);
        assert_eq!(c.string("cluster", "name", "").unwrap(), "testbed");
        assert!(!c.bool("cluster", "debug", true).unwrap());
        assert_eq!(
            c.f64_list("workload", "rps_sweep", &[]).unwrap(),
            vec![2.0, 3.0, 4.0, 5.0, 6.0]
        );
    }

    #[test]
    fn defaults_apply() {
        let c = Conf::parse("").unwrap();
        assert_eq!(c.usize("cluster", "workers", 7).unwrap(), 7);
        assert_eq!(c.string("a", "b", "x").unwrap(), "x");
    }

    #[test]
    fn type_errors_name_the_key() {
        let c = Conf::parse("[s]\nk = \"str\"").unwrap();
        let err = c.f64("s", "k", 0.0).unwrap_err().to_string();
        assert!(err.contains("[s] k"), "{err}");
    }

    #[test]
    fn comments_inside_strings_kept() {
        let c = Conf::parse("[s]\nk = \"a # b\"").unwrap();
        assert_eq!(c.string("s", "k", "").unwrap(), "a # b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Conf::parse("[unterminated").is_err());
        assert!(Conf::parse("keyonly").is_err());
        assert!(Conf::parse("k = ").is_err());
        assert!(Conf::parse("k = [1, 2").is_err());
    }

    #[test]
    fn integer_validation() {
        let c = Conf::parse("[s]\nk = 1.5\nn = -2").unwrap();
        assert!(c.usize("s", "k", 0).is_err());
        assert!(c.usize("s", "n", 0).is_err());
    }
}
