//! Lifecycle tracing & utilization timelines (DESIGN.md §Observability).
//!
//! A zero-cost-when-off trace sink the engine threads through every
//! lifecycle transition: per-invocation events (arrival, decision,
//! queueing, cold start, bind, exec, terminal verdict), per-container
//! events (launch, idle, evict, pre-warm), and worker crash/restart —
//! plus a simulated-time timeline sampler that snapshots per-worker
//! utilization (allocated/busy vCPUs, memory, admission-queue depth,
//! warm-pool size) at a fixed interval.
//!
//! Determinism contract: recording is purely observational. The engine
//! draws no extra RNG values and pushes no extra events whether tracing
//! is on or off — the sampler rides the run loop at interval boundaries
//! instead of scheduling heap events, so event sequence numbers are
//! untouched. `InvocationRecord` streams are byte-identical either way
//! (pinned in `tests/test_determinism.rs`), and trace files contain only
//! simulated time — never wall clock — so they are byte-identical at any
//! `--jobs` (pinned in `tests/test_trace.rs`).
//!
//! Two exporters: line-delimited JSON ([`TraceLog::to_jsonl`], one event
//! or sample per line, parsed back by [`TraceLog::from_jsonl`] for the
//! `report` subcommand) and the Chrome trace-event format
//! ([`TraceLog::to_chrome`], loadable in Perfetto / `chrome://tracing`:
//! workers are process tracks, invocations are spans on per-invocation
//! threads, utilization samples are counter series).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

use super::engine::EvictReason;
use super::worker::Cluster;
use super::{SimTime, Verdict};

/// Trace-sink configuration (`SimConfig::trace`; `None` = tracing off).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Fixed interval of the cluster utilization timeline, simulated
    /// seconds. Cluster state is piecewise-constant between events, so
    /// boundary sampling is exact, not an approximation.
    pub sample_interval_s: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { sample_interval_s: 10.0 }
    }
}

/// One timestamped lifecycle event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at: SimTime,
    pub kind: TraceEventKind,
}

/// The event taxonomy (DESIGN.md §Observability). Per-invocation events
/// carry `inv`; container events carry `container`; all carry the worker
/// they happened on.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEventKind {
    /// A request arrived and entered the platform.
    Arrival { inv: u64, func: usize },
    /// The policy routed it: predicted size, target worker, warm intent.
    Decision { inv: u64, worker: usize, vcpus: u32, mem_mb: u32, warm: bool, overhead_s: f64 },
    /// Parked on the worker's FIFO admission queue (`depth` after push).
    QueueEnter { inv: u64, worker: usize, depth: usize },
    /// Popped off the admission queue after `waited_s`.
    QueueAdmit { inv: u64, worker: usize, waited_s: f64 },
    /// A cold start began for this invocation (container launching).
    ColdStartBegin { inv: u64, worker: usize, container: u64 },
    /// A launching container finished its cold start.
    ContainerReady { worker: usize, container: u64 },
    /// The invocation bound a ready container (its effective size; `warm`
    /// = served from the warm pool rather than its own cold start).
    Bind { inv: u64, worker: usize, container: u64, vcpus: u32, mem_mb: u32, warm: bool },
    /// Phased execution started.
    ExecBegin { inv: u64, worker: usize, container: u64 },
    /// Terminal verdict (completed / OOM-killed / timed-out / failed).
    End { inv: u64, worker: usize, verdict: Verdict },
    /// A container was created (cold start or proactive background).
    ContainerLaunch { worker: usize, container: u64, func: usize, vcpus: u32, mem_mb: u32, background: bool },
    /// A container went idle with a keep-alive TTL (`prewarm` = the
    /// policy attached a pre-warm intent to this idle period).
    ContainerIdle { worker: usize, container: u64, ttl_s: f64, prewarm: bool },
    /// A container was evicted (TTL expiry or demand-driven pressure).
    ContainerEvict { worker: usize, container: u64, reason: EvictReason },
    /// A keep-alive pre-warm fired and passed admission.
    PrewarmFired { worker: usize, func: usize, vcpus: u32, mem_mb: u32 },
    /// A proactive launch (policy background or keep-alive pre-warm) was
    /// cancelled by queue-aware admission — shed, never queued.
    PrewarmShed { worker: usize },
    /// Fault injection: the worker died (DESIGN.md §Faults).
    WorkerCrash { worker: usize },
    /// The crashed worker came back empty.
    WorkerRestart { worker: usize },
}

/// Per-worker utilization gauge at one timeline instant.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSample {
    pub worker: usize,
    /// Reserved vCPUs (`Starting` + `Busy` containers — the admission view).
    pub allocated_vcpus: f64,
    /// vCPU allocations of running invocations (the interference basis).
    pub busy_vcpus: f64,
    pub vcpu_limit: f64,
    pub allocated_mem_mb: f64,
    pub mem_limit_mb: f64,
    /// FIFO admission-queue depth.
    pub queue_depth: usize,
    /// Idle warm containers parked on the worker.
    pub warm_pool: usize,
    pub down: bool,
}

/// One fixed-interval snapshot of every worker.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineSample {
    pub at: SimTime,
    pub workers: Vec<WorkerSample>,
}

impl TimelineSample {
    /// Snapshot current cluster state at `at`. State is piecewise-constant
    /// between events, so sampling at a boundary that falls between two
    /// events reads the exact value that held over the whole gap.
    pub fn capture(at: SimTime, cluster: &Cluster) -> Self {
        TimelineSample {
            at,
            workers: cluster
                .workers
                .iter()
                .map(|w| WorkerSample {
                    worker: w.id,
                    allocated_vcpus: w.allocated_vcpus,
                    busy_vcpus: w.busy_vcpus,
                    vcpu_limit: w.sched_vcpu_limit,
                    allocated_mem_mb: w.allocated_mem_mb,
                    mem_limit_mb: w.mem_limit_mb(),
                    queue_depth: w.admission_queue_len(),
                    warm_pool: w.warm_index().len(),
                    down: w.down,
                })
                .collect(),
        }
    }
}

/// The in-memory trace: run metadata, the event stream in engine
/// processing order (chronological; same-timestamp events in the order
/// the engine handled them), and the utilization timeline.
#[derive(Debug, Clone)]
pub struct TraceLog {
    pub cfg: TraceConfig,
    /// Run description (policy, keep-alive, faults, workers, seed) — all
    /// strings so the JSONL meta line stays schema-free.
    pub meta: BTreeMap<String, String>,
    pub events: Vec<TraceEvent>,
    pub samples: Vec<TimelineSample>,
    /// Next unemitted timeline boundary (sampler bookkeeping).
    next_sample: SimTime,
}

impl TraceLog {
    pub fn new(cfg: TraceConfig, meta: BTreeMap<String, String>) -> Self {
        TraceLog { cfg, meta, events: Vec::new(), samples: Vec::new(), next_sample: 0.0 }
    }

    pub fn record(&mut self, at: SimTime, kind: TraceEventKind) {
        self.events.push(TraceEvent { at, kind });
    }

    /// Next timeline boundary the sampler owes a snapshot for.
    pub fn next_sample_at(&self) -> SimTime {
        self.next_sample
    }

    /// Emit a boundary snapshot and advance to the next boundary.
    pub fn push_sample(&mut self, s: TimelineSample) {
        self.samples.push(s);
        self.next_sample += self.cfg.sample_interval_s.max(1e-9);
    }

    /// Closing snapshot of the end-of-run state (skipped when the last
    /// boundary already sampled this exact instant).
    pub fn close(&mut self, at: SimTime, cluster: &Cluster) {
        if self.samples.last().is_some_and(|s| s.at == at) {
            return;
        }
        self.samples.push(TimelineSample::capture(at, cluster));
    }

    /// Workers covered by the run (meta first, data as fallback).
    pub fn worker_count(&self) -> usize {
        if let Some(n) = self.meta.get("workers").and_then(|s| s.parse::<usize>().ok()) {
            return n;
        }
        let from_samples = self.samples.iter().flat_map(|s| &s.workers).map(|w| w.worker + 1);
        let from_events = self.events.iter().filter_map(|e| e.kind.worker()).map(|w| w + 1);
        from_samples.chain(from_events).max().unwrap_or(0)
    }

    /// Assemble the per-invocation latency spans (see [`assemble_spans`]).
    pub fn spans(&self) -> Vec<InvocationSpans> {
        assemble_spans(&self.events)
    }
}

impl TraceEventKind {
    /// The worker an event happened on (`None` only for `Arrival`,
    /// which precedes the routing decision).
    pub fn worker(&self) -> Option<usize> {
        use TraceEventKind::*;
        match *self {
            Arrival { .. } => None,
            Decision { worker, .. }
            | QueueEnter { worker, .. }
            | QueueAdmit { worker, .. }
            | ColdStartBegin { worker, .. }
            | ContainerReady { worker, .. }
            | Bind { worker, .. }
            | ExecBegin { worker, .. }
            | End { worker, .. }
            | ContainerLaunch { worker, .. }
            | ContainerIdle { worker, .. }
            | ContainerEvict { worker, .. }
            | PrewarmFired { worker, .. }
            | PrewarmShed { worker }
            | WorkerCrash { worker }
            | WorkerRestart { worker } => Some(worker),
        }
    }

    /// The invocation an event belongs to, if any.
    pub fn inv(&self) -> Option<u64> {
        use TraceEventKind::*;
        match *self {
            Arrival { inv, .. }
            | Decision { inv, .. }
            | QueueEnter { inv, .. }
            | QueueAdmit { inv, .. }
            | ColdStartBegin { inv, .. }
            | Bind { inv, .. }
            | ExecBegin { inv, .. }
            | End { inv, .. } => Some(inv),
            _ => None,
        }
    }
}

pub fn verdict_label(v: Verdict) -> &'static str {
    match v {
        Verdict::Completed => "completed",
        Verdict::OomKilled => "oom-killed",
        Verdict::TimedOut => "timed-out",
        Verdict::Failed => "failed",
    }
}

fn verdict_from(label: &str) -> Result<Verdict> {
    Ok(match label {
        "completed" => Verdict::Completed,
        "oom-killed" => Verdict::OomKilled,
        "timed-out" => Verdict::TimedOut,
        "failed" => Verdict::Failed,
        other => bail!("unknown verdict '{other}'"),
    })
}

pub fn evict_reason_label(r: EvictReason) -> &'static str {
    match r {
        EvictReason::Expired => "expired",
        EvictReason::Pressure => "pressure",
    }
}

fn evict_reason_from(label: &str) -> Result<EvictReason> {
    Ok(match label {
        "expired" => EvictReason::Expired,
        "pressure" => EvictReason::Pressure,
        other => bail!("unknown evict reason '{other}'"),
    })
}

// ---------------------------------------------------------------------
// Span assembly
// ---------------------------------------------------------------------

/// Latency component an instant of an invocation's life is attributed to.
/// Exactly one is active from arrival to the terminal verdict, so the
/// per-kind sums telescope to end-to-end latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Decision overhead and any other platform residue (e.g. the gap
    /// between two episodes after a crash re-route).
    Decision,
    /// Parked on a FIFO admission queue.
    Queue,
    /// Waiting on a container cold start.
    ColdStart,
    /// Phased execution.
    Exec,
}

pub fn span_label(k: SpanKind) -> &'static str {
    match k {
        SpanKind::Decision => "decision",
        SpanKind::Queue => "queue",
        SpanKind::ColdStart => "cold-start",
        SpanKind::Exec => "exec",
    }
}

/// One contiguous attributed interval of an invocation's life.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Episode {
    pub kind: SpanKind,
    pub start: SimTime,
    pub end: SimTime,
    pub worker: usize,
}

/// Per-invocation latency breakdown assembled from trace events. The
/// component sums cover the invocation's whole life:
/// `decision_s + queue_s + cold_start_s + exec_s == e2e_s` up to float
/// rounding — including deaths in queue or mid-cold-start, where the
/// open episode is closed at the terminal event (unlike
/// `InvocationRecord`, whose `queue_s`/`cold_start_s` only count closed
/// episodes and can under-report for unbound deaths).
#[derive(Debug, Clone, PartialEq)]
pub struct InvocationSpans {
    pub inv: u64,
    pub func: usize,
    /// Worker of the final episode (crash re-routes move invocations).
    pub worker: usize,
    pub arrival: SimTime,
    pub end: SimTime,
    pub verdict: Verdict,
    pub decision_s: f64,
    pub queue_s: f64,
    pub cold_start_s: f64,
    pub exec_s: f64,
    /// The contiguous intervals the sums above were accumulated from.
    pub episodes: Vec<Episode>,
}

impl InvocationSpans {
    pub fn e2e_s(&self) -> f64 {
        self.end - self.arrival
    }

    pub fn components_sum(&self) -> f64 {
        self.decision_s + self.queue_s + self.cold_start_s + self.exec_s
    }
}

/// Walk the event stream and attribute every instant of every
/// invocation's life to exactly one [`SpanKind`]: a cursor starts at
/// arrival in `Decision`, and each transition event closes the open
/// episode at its timestamp and opens the next. Invocations without a
/// terminal event (never possible in a completed run) are dropped.
pub fn assemble_spans(events: &[TraceEvent]) -> Vec<InvocationSpans> {
    struct St {
        func: usize,
        arrival: SimTime,
        cursor: SimTime,
        active: SpanKind,
        worker: usize,
        episodes: Vec<Episode>,
        done: Option<(SimTime, Verdict)>,
    }
    impl St {
        fn switch(&mut self, t: SimTime, next: SpanKind, worker: usize) {
            if t > self.cursor {
                self.episodes.push(Episode {
                    kind: self.active,
                    start: self.cursor,
                    end: t,
                    worker: self.worker,
                });
            }
            self.cursor = t;
            self.active = next;
            self.worker = worker;
        }
    }
    let mut by_inv: BTreeMap<u64, St> = BTreeMap::new();
    for ev in events {
        let t = ev.at;
        use TraceEventKind::*;
        match ev.kind {
            Arrival { inv, func } => {
                by_inv.insert(
                    inv,
                    St {
                        func,
                        arrival: t,
                        cursor: t,
                        active: SpanKind::Decision,
                        worker: 0,
                        episodes: Vec::new(),
                        done: None,
                    },
                );
            }
            Decision { inv, worker, .. } => {
                // Same timestamp as Arrival: just pin the worker.
                if let Some(st) = by_inv.get_mut(&inv) {
                    st.worker = worker;
                }
            }
            QueueEnter { inv, worker, .. } => {
                if let Some(st) = by_inv.get_mut(&inv) {
                    st.switch(t, SpanKind::Queue, worker);
                }
            }
            QueueAdmit { inv, worker, .. } => {
                // Admission leads straight into a bind or cold start at
                // the same timestamp; the residual bucket is Decision.
                if let Some(st) = by_inv.get_mut(&inv) {
                    st.switch(t, SpanKind::Decision, worker);
                }
            }
            ColdStartBegin { inv, worker, .. } => {
                if let Some(st) = by_inv.get_mut(&inv) {
                    st.switch(t, SpanKind::ColdStart, worker);
                }
            }
            Bind { inv, worker, .. } => {
                // Closes a cold-start episode (or nothing, for a warm
                // bind at the cursor's timestamp); ExecBegin follows at
                // the same instant.
                if let Some(st) = by_inv.get_mut(&inv) {
                    st.switch(t, SpanKind::Decision, worker);
                }
            }
            ExecBegin { inv, worker, .. } => {
                if let Some(st) = by_inv.get_mut(&inv) {
                    st.switch(t, SpanKind::Exec, worker);
                }
            }
            End { inv, worker, verdict } => {
                if let Some(st) = by_inv.get_mut(&inv) {
                    st.switch(t, SpanKind::Decision, worker);
                    st.done = Some((t, verdict));
                }
            }
            // lint:covers(D008, ContainerLaunch, ContainerReady, ContainerIdle, ContainerEvict, PrewarmFired, PrewarmShed, WorkerCrash, WorkerRestart): container/worker lifecycle events carry no invocation id, so span assembly reads only the per-invocation transitions
            _ => {}
        }
    }
    by_inv
        .into_iter()
        .filter_map(|(inv, st)| {
            let (end, verdict) = st.done?;
            let mut spans = InvocationSpans {
                inv,
                func: st.func,
                worker: st.worker,
                arrival: st.arrival,
                end,
                verdict,
                decision_s: 0.0,
                queue_s: 0.0,
                cold_start_s: 0.0,
                exec_s: 0.0,
                episodes: st.episodes,
            };
            for ep in &spans.episodes {
                let d = ep.end - ep.start;
                match ep.kind {
                    SpanKind::Decision => spans.decision_s += d,
                    SpanKind::Queue => spans.queue_s += d,
                    SpanKind::ColdStart => spans.cold_start_s += d,
                    SpanKind::Exec => spans.exec_s += d,
                }
            }
            Some(spans)
        })
        .collect()
}

// ---------------------------------------------------------------------
// JSONL exporter / parser
// ---------------------------------------------------------------------

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        use TraceEventKind::*;
        let mut pairs: Vec<(&str, Json)> = vec![("type", Json::Str("event".into()))];
        let num = |x: f64| Json::Num(x);
        pairs.push(("t", num(self.at)));
        match &self.kind {
            Arrival { inv, func } => {
                pairs.push(("ev", Json::Str("arrival".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("func", num(*func as f64)));
            }
            Decision { inv, worker, vcpus, mem_mb, warm, overhead_s } => {
                pairs.push(("ev", Json::Str("decision".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("vcpus", num(*vcpus as f64)));
                pairs.push(("mem_mb", num(*mem_mb as f64)));
                pairs.push(("warm", Json::Bool(*warm)));
                pairs.push(("overhead_s", num(*overhead_s)));
            }
            QueueEnter { inv, worker, depth } => {
                pairs.push(("ev", Json::Str("queue-enter".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("depth", num(*depth as f64)));
            }
            QueueAdmit { inv, worker, waited_s } => {
                pairs.push(("ev", Json::Str("queue-admit".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("waited_s", num(*waited_s)));
            }
            ColdStartBegin { inv, worker, container } => {
                pairs.push(("ev", Json::Str("cold-start".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("container", num(*container as f64)));
            }
            ContainerReady { worker, container } => {
                pairs.push(("ev", Json::Str("container-ready".into())));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("container", num(*container as f64)));
            }
            Bind { inv, worker, container, vcpus, mem_mb, warm } => {
                pairs.push(("ev", Json::Str("bind".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("container", num(*container as f64)));
                pairs.push(("vcpus", num(*vcpus as f64)));
                pairs.push(("mem_mb", num(*mem_mb as f64)));
                pairs.push(("warm", Json::Bool(*warm)));
            }
            ExecBegin { inv, worker, container } => {
                pairs.push(("ev", Json::Str("exec-begin".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("container", num(*container as f64)));
            }
            End { inv, worker, verdict } => {
                pairs.push(("ev", Json::Str("end".into())));
                pairs.push(("inv", num(*inv as f64)));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("verdict", Json::Str(verdict_label(*verdict).into())));
            }
            ContainerLaunch { worker, container, func, vcpus, mem_mb, background } => {
                pairs.push(("ev", Json::Str("launch".into())));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("container", num(*container as f64)));
                pairs.push(("func", num(*func as f64)));
                pairs.push(("vcpus", num(*vcpus as f64)));
                pairs.push(("mem_mb", num(*mem_mb as f64)));
                pairs.push(("background", Json::Bool(*background)));
            }
            ContainerIdle { worker, container, ttl_s, prewarm } => {
                pairs.push(("ev", Json::Str("idle".into())));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("container", num(*container as f64)));
                pairs.push(("ttl_s", num(*ttl_s)));
                pairs.push(("prewarm", Json::Bool(*prewarm)));
            }
            ContainerEvict { worker, container, reason } => {
                pairs.push(("ev", Json::Str("evict".into())));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("container", num(*container as f64)));
                pairs.push(("reason", Json::Str(evict_reason_label(*reason).into())));
            }
            PrewarmFired { worker, func, vcpus, mem_mb } => {
                pairs.push(("ev", Json::Str("prewarm".into())));
                pairs.push(("worker", num(*worker as f64)));
                pairs.push(("func", num(*func as f64)));
                pairs.push(("vcpus", num(*vcpus as f64)));
                pairs.push(("mem_mb", num(*mem_mb as f64)));
            }
            PrewarmShed { worker } => {
                pairs.push(("ev", Json::Str("prewarm-shed".into())));
                pairs.push(("worker", num(*worker as f64)));
            }
            WorkerCrash { worker } => {
                pairs.push(("ev", Json::Str("crash".into())));
                pairs.push(("worker", num(*worker as f64)));
            }
            WorkerRestart { worker } => {
                pairs.push(("ev", Json::Str("restart".into())));
                pairs.push(("worker", num(*worker as f64)));
            }
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<TraceEvent> {
        let at = req_f64(j, "t")?;
        let ev = req_str(j, "ev")?;
        use TraceEventKind::*;
        let kind = match ev {
            "arrival" => Arrival { inv: req_u64(j, "inv")?, func: req_usize(j, "func")? },
            "decision" => Decision {
                inv: req_u64(j, "inv")?,
                worker: req_usize(j, "worker")?,
                vcpus: req_u32(j, "vcpus")?,
                mem_mb: req_u32(j, "mem_mb")?,
                warm: req_bool(j, "warm")?,
                overhead_s: req_f64(j, "overhead_s")?,
            },
            "queue-enter" => QueueEnter {
                inv: req_u64(j, "inv")?,
                worker: req_usize(j, "worker")?,
                depth: req_usize(j, "depth")?,
            },
            "queue-admit" => QueueAdmit {
                inv: req_u64(j, "inv")?,
                worker: req_usize(j, "worker")?,
                waited_s: req_f64(j, "waited_s")?,
            },
            "cold-start" => ColdStartBegin {
                inv: req_u64(j, "inv")?,
                worker: req_usize(j, "worker")?,
                container: req_u64(j, "container")?,
            },
            "container-ready" => ContainerReady {
                worker: req_usize(j, "worker")?,
                container: req_u64(j, "container")?,
            },
            "bind" => Bind {
                inv: req_u64(j, "inv")?,
                worker: req_usize(j, "worker")?,
                container: req_u64(j, "container")?,
                vcpus: req_u32(j, "vcpus")?,
                mem_mb: req_u32(j, "mem_mb")?,
                warm: req_bool(j, "warm")?,
            },
            "exec-begin" => ExecBegin {
                inv: req_u64(j, "inv")?,
                worker: req_usize(j, "worker")?,
                container: req_u64(j, "container")?,
            },
            "end" => End {
                inv: req_u64(j, "inv")?,
                worker: req_usize(j, "worker")?,
                verdict: verdict_from(req_str(j, "verdict")?)?,
            },
            "launch" => ContainerLaunch {
                worker: req_usize(j, "worker")?,
                container: req_u64(j, "container")?,
                func: req_usize(j, "func")?,
                vcpus: req_u32(j, "vcpus")?,
                mem_mb: req_u32(j, "mem_mb")?,
                background: req_bool(j, "background")?,
            },
            "idle" => ContainerIdle {
                worker: req_usize(j, "worker")?,
                container: req_u64(j, "container")?,
                ttl_s: req_f64(j, "ttl_s")?,
                prewarm: req_bool(j, "prewarm")?,
            },
            "evict" => ContainerEvict {
                worker: req_usize(j, "worker")?,
                container: req_u64(j, "container")?,
                reason: evict_reason_from(req_str(j, "reason")?)?,
            },
            "prewarm" => PrewarmFired {
                worker: req_usize(j, "worker")?,
                func: req_usize(j, "func")?,
                vcpus: req_u32(j, "vcpus")?,
                mem_mb: req_u32(j, "mem_mb")?,
            },
            "prewarm-shed" => PrewarmShed { worker: req_usize(j, "worker")? },
            "crash" => WorkerCrash { worker: req_usize(j, "worker")? },
            "restart" => WorkerRestart { worker: req_usize(j, "worker")? },
            other => bail!("unknown trace event '{other}'"),
        };
        Ok(TraceEvent { at, kind })
    }
}

impl TimelineSample {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("type", Json::Str("sample".into())),
            ("t", Json::Num(self.at)),
            (
                "workers",
                Json::Arr(
                    self.workers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("w", Json::Num(w.worker as f64)),
                                ("alloc_vcpus", Json::Num(w.allocated_vcpus)),
                                ("busy_vcpus", Json::Num(w.busy_vcpus)),
                                ("vcpu_limit", Json::Num(w.vcpu_limit)),
                                ("alloc_mem_mb", Json::Num(w.allocated_mem_mb)),
                                ("mem_limit_mb", Json::Num(w.mem_limit_mb)),
                                ("queue", Json::Num(w.queue_depth as f64)),
                                ("warm", Json::Num(w.warm_pool as f64)),
                                ("down", Json::Bool(w.down)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TimelineSample> {
        let at = req_f64(j, "t")?;
        let workers = j
            .get("workers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("sample missing 'workers'"))?
            .iter()
            .map(|w| {
                Ok(WorkerSample {
                    worker: req_usize(w, "w")?,
                    allocated_vcpus: req_f64(w, "alloc_vcpus")?,
                    busy_vcpus: req_f64(w, "busy_vcpus")?,
                    vcpu_limit: req_f64(w, "vcpu_limit")?,
                    allocated_mem_mb: req_f64(w, "alloc_mem_mb")?,
                    mem_limit_mb: req_f64(w, "mem_limit_mb")?,
                    queue_depth: req_usize(w, "queue")?,
                    warm_pool: req_usize(w, "warm")?,
                    down: req_bool(w, "down")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TimelineSample { at, workers })
    }
}

impl TraceLog {
    /// Line-delimited JSON: one meta line, then every event, then every
    /// timeline sample — each line a standalone JSON object tagged with
    /// `"type"`. Contains only simulated time, so the bytes depend only
    /// on the run's (config, seed) — never on wall clock or `--jobs`.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let meta = Json::obj(vec![
            ("type", Json::Str("meta".into())),
            ("interval_s", Json::Num(self.cfg.sample_interval_s)),
            (
                "run",
                Json::Obj(
                    self.meta.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
                ),
            ),
        ]);
        out.push_str(&meta.to_string());
        out.push('\n');
        for ev in &self.events {
            out.push_str(&ev.to_json().to_string());
            out.push('\n');
        }
        for s in &self.samples {
            out.push_str(&s.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Parse a [`Self::to_jsonl`] document back (the `report` subcommand).
    pub fn from_jsonl(text: &str) -> Result<TraceLog> {
        let mut log = TraceLog::new(TraceConfig::default(), BTreeMap::new());
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let j = json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
            match j.get("type").and_then(Json::as_str) {
                Some("meta") => {
                    log.cfg.sample_interval_s = req_f64(&j, "interval_s")?;
                    if let Some(Json::Obj(run)) = j.get("run") {
                        for (k, v) in run {
                            if let Some(s) = v.as_str() {
                                log.meta.insert(k.clone(), s.to_string());
                            }
                        }
                    }
                }
                Some("event") => log.events.push(
                    TraceEvent::from_json(&j).with_context(|| format!("trace line {}", i + 1))?,
                ),
                Some("sample") => log.samples.push(
                    TimelineSample::from_json(&j)
                        .with_context(|| format!("trace line {}", i + 1))?,
                ),
                other => bail!("trace line {}: unknown type {:?}", i + 1, other),
            }
        }
        Ok(log)
    }

    /// Chrome trace-event JSON (load in Perfetto or `chrome://tracing`):
    /// each worker is a process track (`pid = worker + 1`), each
    /// invocation a thread on its worker carrying its latency-component
    /// spans as `X` complete events, container/worker transitions as
    /// instant events, and the utilization timeline as `C` counter
    /// series. Timestamps are simulated microseconds.
    pub fn to_chrome(&self) -> String {
        let us = |t: SimTime| Json::Num((t * 1e6).round());
        let mut evs: Vec<Json> = Vec::new();
        for w in 0..self.worker_count() {
            evs.push(Json::obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("process_name".into())),
                ("pid", Json::Num((w + 1) as f64)),
                ("tid", Json::Num(0.0)),
                ("args", Json::obj(vec![("name", Json::Str(format!("worker {w}")))])),
            ]));
        }
        // Invocation latency spans (skip zero-length episodes).
        for s in self.spans() {
            for ep in &s.episodes {
                evs.push(Json::obj(vec![
                    ("ph", Json::Str("X".into())),
                    ("name", Json::Str(span_label(ep.kind).into())),
                    ("cat", Json::Str("invocation".into())),
                    ("pid", Json::Num((ep.worker + 1) as f64)),
                    ("tid", Json::Num(s.inv as f64)),
                    ("ts", us(ep.start)),
                    ("dur", us(ep.end - ep.start)),
                    (
                        "args",
                        Json::obj(vec![
                            ("inv", Json::Num(s.inv as f64)),
                            ("func", Json::Num(s.func as f64)),
                            ("verdict", Json::Str(verdict_label(s.verdict).into())),
                        ]),
                    ),
                ]));
            }
        }
        // Container / worker transitions as instant events on tid 0.
        for ev in &self.events {
            use TraceEventKind::*;
            let (name, worker) = match &ev.kind {
                ContainerLaunch { worker, container, background, .. } => (
                    format!("launch c{container}{}", if *background { " (bg)" } else { "" }),
                    *worker,
                ),
                ContainerReady { worker, container } => (format!("ready c{container}"), *worker),
                ContainerIdle { worker, container, .. } => (format!("idle c{container}"), *worker),
                ContainerEvict { worker, container, reason } => {
                    (format!("evict c{container} ({})", evict_reason_label(*reason)), *worker)
                }
                PrewarmFired { worker, .. } => ("prewarm".to_string(), *worker),
                PrewarmShed { worker } => ("prewarm shed".to_string(), *worker),
                WorkerCrash { worker } => ("CRASH".to_string(), *worker),
                WorkerRestart { worker } => ("restart".to_string(), *worker),
                // lint:covers(D008, Arrival, Decision, QueueEnter, QueueAdmit, ColdStartBegin, Bind, ExecBegin, End): per-invocation events reach Chrome as latency spans via spans() above, not as instant events
                _ => continue,
            };
            evs.push(Json::obj(vec![
                ("ph", Json::Str("i".into())),
                ("name", Json::Str(name)),
                ("cat", Json::Str("container".into())),
                ("s", Json::Str("p".into())),
                ("pid", Json::Num((worker + 1) as f64)),
                ("tid", Json::Num(0.0)),
                ("ts", us(ev.at)),
            ]));
        }
        // Utilization counters per worker.
        for s in &self.samples {
            for w in &s.workers {
                evs.push(Json::obj(vec![
                    ("ph", Json::Str("C".into())),
                    ("name", Json::Str("vcpus".into())),
                    ("pid", Json::Num((w.worker + 1) as f64)),
                    ("ts", us(s.at)),
                    (
                        "args",
                        Json::obj(vec![
                            ("busy", Json::Num(w.busy_vcpus)),
                            ("allocated_idle", Json::Num((w.allocated_vcpus - w.busy_vcpus).max(0.0))),
                        ]),
                    ),
                ]));
                evs.push(Json::obj(vec![
                    ("ph", Json::Str("C".into())),
                    ("name", Json::Str("queue+warm".into())),
                    ("pid", Json::Num((w.worker + 1) as f64)),
                    ("ts", us(s.at)),
                    (
                        "args",
                        Json::obj(vec![
                            ("queue", Json::Num(w.queue_depth as f64)),
                            ("warm", Json::Num(w.warm_pool as f64)),
                        ]),
                    ),
                ]));
            }
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(evs)),
            ("displayTimeUnit", Json::Str("ms".into())),
        ])
        .to_string()
    }
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| anyhow!("missing number '{key}'"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    Ok(req_f64(j, key)? as u64)
}

fn req_u32(j: &Json, key: &str) -> Result<u32> {
    Ok(req_f64(j, key)? as u32)
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    Ok(req_f64(j, key)? as usize)
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key).and_then(Json::as_str).ok_or_else(|| anyhow!("missing string '{key}'"))
}

fn req_bool(j: &Json, key: &str) -> Result<bool> {
    match j.get(key) {
        Some(Json::Bool(b)) => Ok(*b),
        _ => bail!("missing bool '{key}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: SimTime, kind: TraceEventKind) -> TraceEvent {
        TraceEvent { at, kind }
    }

    /// A queued + cold-started invocation: arrival 0, decision overhead
    /// to 0.5, queued to 3.0, cold start to 4.2, exec to 10.0.
    fn lifecycle_events() -> Vec<TraceEvent> {
        use TraceEventKind::*;
        vec![
            ev(0.0, Arrival { inv: 7, func: 2 }),
            ev(
                0.0,
                Decision { inv: 7, worker: 1, vcpus: 8, mem_mb: 2048, warm: false, overhead_s: 0.5 },
            ),
            ev(0.5, QueueEnter { inv: 7, worker: 1, depth: 1 }),
            ev(3.0, QueueAdmit { inv: 7, worker: 1, waited_s: 2.5 }),
            ev(
                3.0,
                ContainerLaunch {
                    worker: 1,
                    container: 4,
                    func: 2,
                    vcpus: 8,
                    mem_mb: 2048,
                    background: false,
                },
            ),
            ev(3.0, ColdStartBegin { inv: 7, worker: 1, container: 4 }),
            ev(4.2, ContainerReady { worker: 1, container: 4 }),
            ev(4.2, Bind { inv: 7, worker: 1, container: 4, vcpus: 8, mem_mb: 2048, warm: false }),
            ev(4.2, ExecBegin { inv: 7, worker: 1, container: 4 }),
            ev(10.0, End { inv: 7, worker: 1, verdict: Verdict::Completed }),
        ]
    }

    #[test]
    fn span_assembly_attributes_every_second() {
        let spans = assemble_spans(&lifecycle_events());
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.inv, 7);
        assert_eq!(s.verdict, Verdict::Completed);
        assert!((s.decision_s - 0.5).abs() < 1e-12, "decision {}", s.decision_s);
        assert!((s.queue_s - 2.5).abs() < 1e-12, "queue {}", s.queue_s);
        assert!((s.cold_start_s - 1.2).abs() < 1e-12, "cold {}", s.cold_start_s);
        assert!((s.exec_s - 5.8).abs() < 1e-12, "exec {}", s.exec_s);
        assert!((s.components_sum() - s.e2e_s()).abs() < 1e-9);
    }

    #[test]
    fn span_assembly_closes_open_episodes_at_death() {
        use TraceEventKind::*;
        // Died waiting in queue: the open queue episode closes at End.
        let events = vec![
            ev(0.0, Arrival { inv: 1, func: 0 }),
            ev(0.0, Decision { inv: 1, worker: 0, vcpus: 4, mem_mb: 512, warm: false, overhead_s: 0.0 }),
            ev(0.0, QueueEnter { inv: 1, worker: 0, depth: 1 }),
            ev(30.0, End { inv: 1, worker: 0, verdict: Verdict::TimedOut }),
        ];
        let spans = assemble_spans(&events);
        assert_eq!(spans.len(), 1);
        assert!((spans[0].queue_s - 30.0).abs() < 1e-12);
        assert_eq!(spans[0].exec_s, 0.0);
        assert!((spans[0].components_sum() - spans[0].e2e_s()).abs() < 1e-9);
    }

    #[test]
    fn jsonl_round_trip_is_lossless() {
        let mut log = TraceLog::new(
            TraceConfig { sample_interval_s: 5.0 },
            [("policy".to_string(), "shabari".to_string())].into_iter().collect(),
        );
        for e in lifecycle_events() {
            log.record(e.at, e.kind);
        }
        log.samples.push(TimelineSample {
            at: 5.0,
            workers: vec![WorkerSample {
                worker: 0,
                allocated_vcpus: 8.0,
                busy_vcpus: 8.0,
                vcpu_limit: 90.0,
                allocated_mem_mb: 2048.0,
                mem_limit_mb: 128000.0,
                queue_depth: 2,
                warm_pool: 1,
                down: false,
            }],
        });
        let text = log.to_jsonl();
        let back = TraceLog::from_jsonl(&text).unwrap();
        assert_eq!(back.cfg.sample_interval_s, 5.0);
        assert_eq!(back.meta.get("policy").map(String::as_str), Some("shabari"));
        assert_eq!(back.events, log.events);
        assert_eq!(back.samples, log.samples);
        // and the re-export is byte-identical (stable key order)
        assert_eq!(back.to_jsonl(), text);
    }

    #[test]
    fn chrome_export_is_valid_json_with_worker_tracks() {
        let mut log = TraceLog::new(TraceConfig::default(), BTreeMap::new());
        for e in lifecycle_events() {
            log.record(e.at, e.kind);
        }
        let j = json::parse(&log.to_chrome()).unwrap();
        let evs = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(!evs.is_empty());
        // worker 1 appears in the events, so tracks 0..=1 get names
        let names: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(names.len(), 2);
        // every complete event has pid/tid/ts/dur
        for e in evs.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("X")) {
            for key in ["pid", "tid", "ts", "dur"] {
                assert!(e.get(key).and_then(Json::as_f64).is_some(), "missing {key}");
            }
        }
    }

    #[test]
    fn sampler_bookkeeping_advances_by_interval() {
        let mut log = TraceLog::new(TraceConfig { sample_interval_s: 10.0 }, BTreeMap::new());
        assert_eq!(log.next_sample_at(), 0.0);
        log.push_sample(TimelineSample { at: 0.0, workers: vec![] });
        assert_eq!(log.next_sample_at(), 10.0);
        log.push_sample(TimelineSample { at: 10.0, workers: vec![] });
        assert_eq!(log.next_sample_at(), 20.0);
    }
}
