//! Container lifecycle: cold-starting → idle (warm) → busy → evicted.

use super::SimTime;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    /// Being created (cold start in progress).
    Starting,
    /// Warm and free — a routing target.
    Idle,
    /// Running an invocation.
    Busy,
}

/// A function container on a worker. Sized independently in vCPUs and
/// memory (the paper's decoupled `CPULimit()` extension to OpenWhisk).
#[derive(Debug, Clone)]
pub struct Container {
    pub id: u64,
    /// Index into the function catalog — containers are per-function
    /// (image + runtime state), like OpenWhisk action containers.
    pub func: usize,
    pub vcpus: u32,
    pub mem_mb: u32,
    pub state: ContainerState,
    /// When the container becomes usable (end of cold start).
    pub ready_at: SimTime,
    /// Start of the current idle period (keep-alive accounting).
    pub idle_since: SimTime,
    /// Bumped every time the container goes idle; lets stale eviction
    /// events detect that the container was reused in between.
    pub idle_epoch: u64,
    /// Launched by a hybrid-histogram pre-warm; cleared at first warm
    /// use (the engine counts that use as a `prewarm_hit`).
    pub prewarmed: bool,
    /// TTL deadline the keep-alive policy assigned for the current idle
    /// period (engine bookkeeping for the eviction log; `INFINITY`
    /// until the first idle transition).
    pub evict_deadline: SimTime,
    /// Pre-warm the policy requested for the current idle period: when
    /// the TTL expiry actually evicts this container, the engine
    /// launches a same-size replacement at this time. Overwritten on
    /// every idle transition, so a reuse during the grace window
    /// cancels the pending pre-warm along with the stale eviction.
    pub prewarm_at: Option<SimTime>,
}

impl Container {
    pub fn new(id: u64, func: usize, vcpus: u32, mem_mb: u32, ready_at: SimTime) -> Self {
        Container {
            id,
            func,
            vcpus,
            mem_mb,
            state: ContainerState::Starting,
            ready_at,
            idle_since: ready_at,
            idle_epoch: 0,
            prewarmed: false,
            evict_deadline: f64::INFINITY,
            prewarm_at: None,
        }
    }

    /// Whether this container can serve a request asking for
    /// (`vcpus`, `mem_mb`): same function, at-least-as-large size.
    pub fn fits(&self, func: usize, vcpus: u32, mem_mb: u32) -> bool {
        self.func == func && self.vcpus >= vcpus && self.mem_mb >= mem_mb
    }

    /// Exact-size match.
    pub fn exact(&self, func: usize, vcpus: u32, mem_mb: u32) -> bool {
        self.func == func && self.vcpus == vcpus && self.mem_mb == mem_mb
    }

    pub fn is_warm_idle(&self) -> bool {
        self.state == ContainerState::Idle
    }

    /// Mark busy (serving an invocation).
    pub fn acquire(&mut self) {
        debug_assert_ne!(self.state, ContainerState::Busy, "double acquire");
        self.state = ContainerState::Busy;
    }

    /// Return to the warm pool.
    pub fn release(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Busy);
        self.state = ContainerState::Idle;
        self.idle_since = now;
        self.idle_epoch += 1;
    }

    /// Cold start finished.
    pub fn mark_ready(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Starting);
        self.state = ContainerState::Idle;
        self.idle_since = now;
        self.idle_epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut c = Container::new(1, 0, 8, 2048, 0.5);
        assert_eq!(c.state, ContainerState::Starting);
        c.mark_ready(0.5);
        assert!(c.is_warm_idle());
        c.acquire();
        assert_eq!(c.state, ContainerState::Busy);
        c.release(3.0);
        assert!(c.is_warm_idle());
        assert_eq!(c.idle_since, 3.0);
        assert_eq!(c.idle_epoch, 2);
    }

    #[test]
    fn fits_semantics() {
        let c = Container::new(1, 2, 8, 2048, 0.0);
        assert!(c.fits(2, 8, 2048));
        assert!(c.fits(2, 4, 1024));
        assert!(!c.fits(2, 9, 2048), "smaller container cannot serve");
        assert!(!c.fits(3, 4, 1024), "different function");
        assert!(c.exact(2, 8, 2048));
        assert!(!c.exact(2, 4, 2048));
    }
}
