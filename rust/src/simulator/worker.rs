//! Worker (invoker) model: container pools, allocation accounting, and
//! processor-sharing execution of invocation phases.
//!
//! Execution model: each active invocation is in one phase —
//! `Net` (NIC fair-sharing), `Serial` (1 vCPU), or `Parallel`
//! (`min(alloc, maxpar)` vCPUs). When the sum of vCPU demands exceeds the
//! worker's *physical* cores, every compute phase is slowed by the same
//! factor (Linux CFS-style fair sharing weighted by demand). The per-
//! worker daemon numbers (avg/peak vCPUs used) fall out of the exact work
//! accounting.
//!
//! Determinism contract (DESIGN.md §4): every container here is ordered.
//! `containers`/`active` are `BTreeMap`s (id order), warm-pool lookups go
//! through sorted indexes that tie-break by lowest container id, and the
//! per-phase rate view is cached per worker epoch in invocation-id order
//! — no `HashMap` iteration order leaks into results, and steady-state
//! events reuse buffers instead of allocating.
//!
//! Admission contract (DESIGN.md §Admission): capacity is reserved at
//! container *launch* — a container holds its (vcpus, mem) reservation
//! while `Starting` or `Busy`, and releases it while `Idle` (§5: idle
//! containers consume no scheduler budget) — unless the keep-alive
//! policy runs with reservation-holding idle containers
//! ([`Worker::idle_reserves`], DESIGN.md §KeepAlive: under `pressure`
//! warm containers occupy capacity like OpenWhisk memory slots until
//! evicted, which is what makes demand-driven eviction free anything at
//! all). The reservation view
//! (`allocated_*`, maintained exclusively by the container-lifecycle
//! methods) is what the engine's hard admission check reads; the
//! queued-demand view ([`Worker::queued_vcpus`]/[`Worker::queued_mem_mb`],
//! fed by the engine's per-worker FIFO admission queue) is added on top
//! for scheduler probing so placement decisions see backlog, not just
//! bound load.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::container::Container;
use super::SimTime;

/// Warm-index key: `(func, vcpus, mem_mb, container id)`. Sorted order
/// makes "exact size" a range lookup and "smallest at-least-as-large"
/// an in-order scan, with equal-size ties always won by the lowest id.
pub type WarmKey = (usize, u32, u32, u64);

/// One invocation parked on a worker's FIFO admission queue, with the
/// demand it asked for (the *decision* size; the effective size is
/// re-resolved against the warm pool when the entry is popped).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueuedAdmission {
    pub inv_id: u64,
    pub vcpus: u32,
    pub mem_mb: u32,
}

/// Execution phase of an active invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Fetching input bytes from the external datastore.
    Net,
    /// Serial compute on one vCPU.
    Serial,
    /// Parallel compute on `demand` vCPUs.
    Parallel,
}

/// One queued phase: (phase, work, demand).
/// Work is bytes for Net, CPU-seconds for Serial/Parallel.
#[derive(Debug, Clone, Copy)]
pub struct PhaseSpec {
    pub phase: Phase,
    pub work: f64,
    pub demand: f64,
}

/// An invocation currently executing on this worker.
#[derive(Debug, Clone)]
pub struct ActiveInv {
    pub inv_id: u64,
    pub container_id: u64,
    /// vCPU allocation of the container (cgroup share weight).
    pub alloc_vcpus: f64,
    /// Remaining work in the current phase.
    pub remaining: f64,
    pub current: PhaseSpec,
    /// Later phases, in order.
    pub pending: Vec<PhaseSpec>,
    /// Total CPU-seconds consumed so far (daemon accounting).
    pub cpu_seconds_done: f64,
    pub exec_started: SimTime,
    pub peak_vcpus: f64,
    /// Memory footprint of the invocation (GB).
    pub mem_used_gb: f64,
}

impl ActiveInv {
    /// Move to the next phase; returns false when all phases are done.
    pub fn next_phase(&mut self) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        self.current = self.pending.remove(0);
        self.remaining = self.current.work;
        if matches!(self.current.phase, Phase::Serial | Phase::Parallel) {
            self.peak_vcpus = self.peak_vcpus.max(self.current.demand);
        }
        // zero-work phases are skipped by the caller loop
        true
    }
}

/// A worker node (OpenWhisk invoker).
#[derive(Debug)]
pub struct Worker {
    pub id: usize,
    pub physical_cores: f64,
    /// Scheduler admission limit (`userCpu` hyperparameter).
    pub sched_vcpu_limit: f64,
    pub mem_gb: f64,
    pub net_gbps: f64,
    /// Crashed and not yet restarted (DESIGN.md §Faults). All capacity
    /// predicates answer `false` while down, so schedulers and the
    /// engine's admission path steer around the worker; work already
    /// parked on its FIFO queue waits for the restart (or times out).
    pub down: bool,
    /// Execution speed multiplier (1.0 = nominal; stragglers < 1.0,
    /// DESIGN.md §Faults). Folded into every cached progress rate next
    /// to the interference factor — ×1.0 is bit-exact, so fault-free
    /// runs are unchanged.
    pub speed: f64,
    /// All containers on this worker, in id order. Mutate only through
    /// the container-lifecycle methods (`insert_container`,
    /// `remove_container`, `container_ready`, `acquire_container`,
    /// `release_container`) so the warm index stays consistent.
    pub containers: BTreeMap<u64, Container>,
    /// Active invocations, in invocation-id order (the order every scan,
    /// rate computation, and completion batch uses).
    pub active: BTreeMap<u64, ActiveInv>,
    /// Sorted index of idle warm containers.
    warm: BTreeSet<WarmKey>,
    /// Accounting switch (DESIGN.md §KeepAlive): when true, `Idle`
    /// containers keep holding their `(vcpus, mem)` reservation —
    /// ready/release no longer release and acquire no longer re-charges
    /// — so warmth occupies admission capacity until evicted. Read off
    /// the keep-alive policy `SimConfig::keepalive` builds
    /// (`KeepAlivePolicy::idle_reserves`), the same impl the
    /// engine-owned instance answers from — one source of truth.
    pub idle_reserves: bool,
    /// Reserved resources of `Starting` + `Busy` containers — the hard
    /// admission view. Cold starts and background pre-warms reserve at
    /// *launch* (closing the decision-to-bind race over their 0.1–10 s
    /// startup window); idle containers consume nothing (§5 "Creating
    /// Idle Containers in the Background"). Maintained exclusively by the
    /// container-lifecycle methods; tests may still set it directly on
    /// container-less workers to fake load.
    pub allocated_vcpus: f64,
    pub allocated_mem_mb: f64,
    /// vCPU allocations of *running* invocations only — the cgroup-share
    /// basis of [`Self::interference_factor`] (a reserved-but-starting
    /// container has no runnable threads yet and interferes with no one).
    pub busy_vcpus: f64,
    /// Lifetime peaks of the reservation counters: the release-build
    /// witness that admission was never exceeded (`experiment overload`
    /// asserts `peak_allocated_vcpus <= sched_vcpu_limit`).
    pub peak_allocated_vcpus: f64,
    pub peak_allocated_mem_mb: f64,
    /// FIFO admission queue: invocations the engine could not admit at
    /// bind time, in enqueue order (popped front-first on every capacity
    /// release; head-of-line blocking is deliberate — determinism beats
    /// backfilling here).
    admission_queue: VecDeque<QueuedAdmission>,
    /// Exact aggregate demand parked on the queue (u64 so the sums never
    /// accumulate float drift).
    queued_vcpus_total: u64,
    queued_mem_total: u64,
    /// Last time `advance` ran (work progressed up to here).
    pub last_advance: SimTime,
    /// Bumped on every change to the active set; stale completion events
    /// carry an old epoch and are ignored. Also versions the rate cache.
    pub epoch: u64,
    /// Lifetime counters.
    pub total_cold_starts: u64,
    pub total_invocations: u64,
    /// Cached wall-clock rate of each active invocation's current phase,
    /// parallel to `active`'s id-order iteration. Valid iff
    /// `rates_epoch == epoch`.
    rates: Vec<f64>,
    rates_epoch: u64,
    /// Invocations whose current phase hit zero during `advance`, pending
    /// pickup by the engine (id order within each advance batch).
    done_buf: Vec<u64>,
    /// Water-filling scratch buffers (reused; no steady-state allocs).
    wf_unsat: Vec<(usize, f64, f64)>,
    wf_next: Vec<(usize, f64, f64)>,
}

impl Worker {
    pub fn new(id: usize, cfg: &super::SimConfig) -> Self {
        Self::with_idle_reserves(id, cfg, super::keepalive::build(cfg).idle_reserves())
    }

    /// [`Self::new`] with the keep-alive accounting switch precomputed:
    /// `Cluster::new` builds the policy once and fans the flag out
    /// instead of boxing one throwaway policy per worker.
    pub(crate) fn with_idle_reserves(
        id: usize,
        cfg: &super::SimConfig,
        idle_reserves: bool,
    ) -> Self {
        Worker {
            id,
            physical_cores: cfg.physical_cores,
            sched_vcpu_limit: cfg.sched_vcpu_limit,
            mem_gb: cfg.mem_gb,
            net_gbps: cfg.net_gbps,
            down: false,
            speed: 1.0,
            containers: BTreeMap::new(),
            active: BTreeMap::new(),
            warm: BTreeSet::new(),
            idle_reserves,
            allocated_vcpus: 0.0,
            allocated_mem_mb: 0.0,
            busy_vcpus: 0.0,
            peak_allocated_vcpus: 0.0,
            peak_allocated_mem_mb: 0.0,
            admission_queue: VecDeque::new(),
            queued_vcpus_total: 0,
            queued_mem_total: 0,
            last_advance: 0.0,
            epoch: 0,
            total_cold_starts: 0,
            total_invocations: 0,
            rates: Vec::new(),
            rates_epoch: u64::MAX,
            done_buf: Vec::new(),
            wf_unsat: Vec::new(),
            wf_next: Vec::new(),
        }
    }

    // -- scheduler-facing load view ------------------------------------

    /// Free vCPUs under the admission limit (reservations only).
    pub fn free_sched_vcpus(&self) -> f64 {
        (self.sched_vcpu_limit - self.allocated_vcpus).max(0.0)
    }

    /// Memory admission limit in MB — the denominator shared by the
    /// admission predicates and the timeline sampler's memory gauge
    /// (DESIGN.md §Observability).
    pub fn mem_limit_mb(&self) -> f64 {
        self.mem_gb * 1024.0
    }

    /// Free memory (MB) under the admission limit (reservations only).
    pub fn free_mem_mb(&self) -> f64 {
        (self.mem_limit_mb() - self.allocated_mem_mb).max(0.0)
    }

    /// Hard admission check the *engine* uses when binding or launching a
    /// container: do the in-flight reservations leave room for this size?
    /// Queued demand is deliberately excluded — FIFO fairness is enforced
    /// by the engine popping the queue in order, not by this predicate.
    pub fn can_admit(&self, vcpus: u32, mem_mb: u32) -> bool {
        !self.down
            && self.free_sched_vcpus() >= vcpus as f64
            && self.free_mem_mb() >= mem_mb as f64
    }

    /// Scheduler-facing capacity check: free resources *minus the demand
    /// already parked on the admission queue*. A worker with a backlog
    /// reports no capacity even if a completion just freed some — new
    /// placements would only lengthen its queue (the queue-aware load
    /// view of DESIGN.md §Admission).
    pub fn has_capacity(&self, vcpus: u32, mem_mb: u32) -> bool {
        !self.down
            && self.free_sched_vcpus() - self.queued_vcpus() >= vcpus as f64
            && self.free_mem_mb() - self.queued_mem_mb() >= mem_mb as f64
    }

    /// Scheduler-facing capacity check for binding an *idle warm
    /// container of this size living on this worker*. Under
    /// reservation-holding keep-alive (`pressure`, DESIGN.md §KeepAlive)
    /// the candidate already holds its own reservation, so the bind is
    /// capacity-neutral — without this, a warm container whose own
    /// reservation fills the worker would veto its own reuse and be
    /// pressure-evicted for the resulting cold start. Only queued
    /// backlog rejects the placement then (a new bind parks behind the
    /// FIFO queue regardless). With free idle containers this is
    /// exactly [`Self::has_capacity`].
    pub fn has_capacity_for_warm(&self, vcpus: u32, mem_mb: u32) -> bool {
        if self.down {
            false
        } else if self.idle_reserves {
            self.admission_queue_len() == 0
        } else {
            self.has_capacity(vcpus, mem_mb)
        }
    }

    // -- admission queue (engine-driven FIFO) ---------------------------

    /// Aggregate vCPU demand waiting on the admission queue.
    pub fn queued_vcpus(&self) -> f64 {
        self.queued_vcpus_total as f64
    }

    /// Aggregate memory demand (MB) waiting on the admission queue.
    pub fn queued_mem_mb(&self) -> f64 {
        self.queued_mem_total as f64
    }

    pub fn admission_queue_len(&self) -> usize {
        self.admission_queue.len()
    }

    /// Park an invocation at the back of the admission queue.
    pub fn push_admission(&mut self, q: QueuedAdmission) {
        self.queued_vcpus_total += q.vcpus as u64;
        self.queued_mem_total += q.mem_mb as u64;
        self.admission_queue.push_back(q);
    }

    /// The entry that must be admitted next (FIFO head), if any.
    pub fn front_admission(&self) -> Option<&QueuedAdmission> {
        self.admission_queue.front()
    }

    /// Pop the FIFO head (the engine calls this only after `can_admit`
    /// passed for the head's effective size).
    pub fn pop_admission(&mut self) -> Option<QueuedAdmission> {
        let q = self.admission_queue.pop_front()?;
        self.queued_vcpus_total -= q.vcpus as u64;
        self.queued_mem_total -= q.mem_mb as u64;
        Some(q)
    }

    /// Remove a queued invocation by id (timeout while waiting). Returns
    /// the removed entry; preserves the order of everything else.
    pub fn remove_admission(&mut self, inv_id: u64) -> Option<QueuedAdmission> {
        let pos = self.admission_queue.iter().position(|q| q.inv_id == inv_id)?;
        let q = self.admission_queue.remove(pos)?;
        self.queued_vcpus_total -= q.vcpus as u64;
        self.queued_mem_total -= q.mem_mb as u64;
        Some(q)
    }

    // -- reservation accounting (container-lifecycle internal) ----------

    /// Charge a reservation (container entering `Starting` or `Busy`).
    fn reserve(&mut self, vcpus: u32, mem_mb: u32) {
        self.allocated_vcpus += vcpus as f64;
        self.allocated_mem_mb += mem_mb as f64;
        self.peak_allocated_vcpus = self.peak_allocated_vcpus.max(self.allocated_vcpus);
        self.peak_allocated_mem_mb = self.peak_allocated_mem_mb.max(self.allocated_mem_mb);
    }

    /// Release a reservation (container leaving `Starting`/`Busy`). All
    /// charges are integer-valued, so the sums stay exact and a correct
    /// charge/release pairing can never drive them negative.
    fn unreserve(&mut self, vcpus: u32, mem_mb: u32) {
        self.allocated_vcpus -= vcpus as f64;
        self.allocated_mem_mb -= mem_mb as f64;
        debug_assert!(self.allocated_vcpus >= 0.0 && self.allocated_mem_mb >= 0.0);
    }

    // -- container lifecycle (warm-index maintenance) -------------------

    fn warm_key(c: &Container) -> WarmKey {
        (c.func, c.vcpus, c.mem_mb, c.id)
    }

    /// Adopt a container. `Starting` containers are unindexed and
    /// reserve capacity immediately (reserve-at-launch); `Idle` ones join
    /// the warm index with no reservation (unless [`Self::idle_reserves`]);
    /// `Busy` inserts (test setups) reserve like any running container.
    pub fn insert_container(&mut self, c: Container) {
        if c.is_warm_idle() {
            self.warm.insert(Self::warm_key(&c));
        }
        if !c.is_warm_idle() || self.idle_reserves {
            self.reserve(c.vcpus, c.mem_mb);
        }
        self.containers.insert(c.id, c);
    }

    /// Tear a container down (eviction, OOM, timeout). Releases its
    /// reservation when it was `Starting` or `Busy` — or in any state
    /// under reservation-holding idle semantics.
    pub fn remove_container(&mut self, cid: u64) -> Option<Container> {
        let c = self.containers.remove(&cid)?;
        self.warm.remove(&Self::warm_key(&c));
        if !c.is_warm_idle() || self.idle_reserves {
            self.unreserve(c.vcpus, c.mem_mb);
        }
        Some(c)
    }

    /// Cold start finished: the container joins the warm pool and drops
    /// its launch reservation (a binding invocation re-charges it via
    /// [`Self::acquire_container`] in the same event) — under
    /// reservation-holding idle semantics the launch reservation simply
    /// rolls over into the idle one. Returns its (new idle epoch, warm
    /// key), or None if torn down meanwhile. The key lets [`Cluster`]
    /// update its index without a second probe.
    pub fn container_ready(&mut self, cid: u64, now: SimTime) -> Option<(u64, WarmKey)> {
        let c = self.containers.get_mut(&cid)?;
        c.mark_ready(now);
        let epoch = c.idle_epoch;
        let key = Self::warm_key(c);
        let (vcpus, mem_mb) = (c.vcpus, c.mem_mb);
        self.warm.insert(key);
        if !self.idle_reserves {
            self.unreserve(vcpus, mem_mb);
        }
        Some((epoch, key))
    }

    /// Mark a warm container busy (re-charging its reservation — a
    /// no-op charge when idle containers already hold theirs); returns
    /// its warm key (`(func, vcpus, mem_mb, id)`).
    pub fn acquire_container(&mut self, cid: u64) -> WarmKey {
        let c = self.containers.get_mut(&cid).expect("acquire: container exists");
        let key = Self::warm_key(c);
        let (vcpus, mem_mb) = (c.vcpus, c.mem_mb);
        c.acquire();
        self.warm.remove(&key);
        if !self.idle_reserves {
            self.reserve(vcpus, mem_mb);
        }
        key
    }

    /// Return a busy container to the warm pool, releasing its
    /// reservation (kept when idle containers reserve); returns its
    /// (idle epoch, warm key).
    pub fn release_container(&mut self, cid: u64, now: SimTime) -> (u64, WarmKey) {
        let c = self.containers.get_mut(&cid).expect("release: container exists");
        c.release(now);
        let epoch = c.idle_epoch;
        let key = Self::warm_key(c);
        let (vcpus, mem_mb) = (c.vcpus, c.mem_mb);
        self.warm.insert(key);
        if !self.idle_reserves {
            self.unreserve(vcpus, mem_mb);
        }
        (epoch, key)
    }

    /// Idle warm container of the exact size (lowest id on ties).
    pub fn find_warm_exact(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<&Container> {
        self.warm
            .range((func, vcpus, mem_mb, 0)..=(func, vcpus, mem_mb, u64::MAX))
            .next()
            .map(|&(_, _, _, id)| &self.containers[&id])
    }

    /// Smallest idle warm container at least the requested size: minimal
    /// `(vcpus, mem_mb)` lexicographically, then lowest container id.
    pub fn find_warm_larger(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<&Container> {
        self.warm
            .range((func, vcpus, 0, 0)..)
            .take_while(|&&(f, _, _, _)| f == func)
            .find(|&&(_, _, cm, _)| cm >= mem_mb)
            .map(|&(_, _, _, id)| &self.containers[&id])
    }

    /// Warm-index view (consistency checks).
    pub fn warm_index(&self) -> &BTreeSet<WarmKey> {
        &self.warm
    }

    // -- processor sharing ----------------------------------------------

    /// Total vCPU demand of active compute phases.
    fn cpu_demand(&self) -> f64 {
        self.active
            .values()
            .filter(|a| matches!(a.current.phase, Phase::Serial | Phase::Parallel))
            .map(|a| a.current.demand)
            .sum()
    }

    /// Number of active network phases.
    fn net_active(&self) -> usize {
        self.active
            .values()
            .filter(|a| a.current.phase == Phase::Net)
            .count()
    }

    /// Contention slowdown for compute phases: 1.0 when demand fits the
    /// physical cores, `cores / demand` when oversubscribed (aggregate
    /// view; per-invocation rates come from the cached rate view).
    pub fn cpu_scale(&self) -> f64 {
        let demand = self.cpu_demand();
        if demand <= self.physical_cores {
            1.0
        } else {
            self.physical_cores / demand
        }
    }

    /// Interference slowdown from vCPU over-subscription of *running*
    /// allocations (cgroup shares): when the sum of busy containers' vCPU
    /// limits exceeds the physical cores, the kernel timeslices more
    /// runnable threads than cores (cache pollution, scheduler churn).
    /// This is the §7.2 mechanism by which over-allocating systems
    /// degrade co-located invocations even when *useful* demand still
    /// fits the machine. Reserved-but-`Starting` containers are excluded:
    /// they hold admission budget but run nothing yet.
    pub fn interference_factor(&self) -> f64 {
        let over = (self.busy_vcpus - self.physical_cores) / self.physical_cores;
        1.0 / (1.0 + 0.35 * over.max(0.0))
    }

    /// Refresh the cached per-invocation rate view if the epoch moved.
    ///
    /// The view holds the wall-clock progress rate of every active
    /// invocation's *current* phase, in invocation-id order: NIC fair
    /// share for `Net`, cgroup-share water-filling (capped at demand,
    /// scaled by [`Self::interference_factor`]) for compute. This is the
    /// mechanism behind the paper's "stealing" observation (§7.2):
    /// over-allocated invocations squeeze right-sized ones under
    /// contention even when they cannot use the extra cores themselves.
    fn ensure_rates(&mut self) {
        if self.rates_epoch == self.epoch && self.rates.len() == self.active.len() {
            return;
        }
        self.recompute_rates();
        self.rates_epoch = self.epoch;
    }

    fn recompute_rates(&mut self) {
        // Straggler speed rides next to the interference factor: every
        // compute rate below is scaled by both. `speed == 1.0` multiplies
        // bit-exactly, so fault-free streams are untouched.
        let interference = self.speed * self.interference_factor();
        let net_rate = self.net_rate();
        let cores = self.physical_cores;
        self.rates.clear();
        self.rates.resize(self.active.len(), 0.0);

        // Pass 1: net rates + total compute demand.
        let mut total_demand = 0.0;
        for (i, a) in self.active.values().enumerate() {
            match a.current.phase {
                Phase::Net => self.rates[i] = net_rate,
                Phase::Serial | Phase::Parallel => total_demand += a.current.demand,
            }
        }

        if total_demand <= cores {
            for (i, a) in self.active.values().enumerate() {
                if matches!(a.current.phase, Phase::Serial | Phase::Parallel) {
                    self.rates[i] = a.current.demand * interference;
                }
            }
            return;
        }

        // Water-filling by allocation weight over compute phases, in
        // invocation-id order (deterministic float accumulation).
        self.wf_unsat.clear();
        for (i, a) in self.active.values().enumerate() {
            if matches!(a.current.phase, Phase::Serial | Phase::Parallel) {
                self.wf_unsat.push((i, a.current.demand, a.alloc_vcpus.max(1.0)));
            }
        }
        let mut remaining = cores;
        let mut sat_sum = 0.0;
        loop {
            let total_w: f64 = self.wf_unsat.iter().map(|&(_, _, w)| w).sum();
            if total_w <= 0.0 || remaining <= 1e-12 {
                for &(i, _, _) in &self.wf_unsat {
                    self.rates[i] = 0.0;
                }
                break;
            }
            let mut newly_sat = false;
            self.wf_next.clear();
            for &(i, demand, w) in &self.wf_unsat {
                let share = remaining * w / total_w;
                if share >= demand {
                    self.rates[i] = demand;
                    sat_sum += demand;
                    newly_sat = true;
                } else {
                    self.wf_next.push((i, demand, w));
                }
            }
            // subtract satisfied demands from capacity
            remaining = (cores - sat_sum).max(0.0);
            if !newly_sat {
                // no one newly satisfied: final proportional split
                let total_w: f64 = self.wf_next.iter().map(|&(_, _, w)| w).sum();
                for &(i, demand, w) in &self.wf_next {
                    self.rates[i] = (remaining * w / total_w).min(demand);
                }
                break;
            }
            if self.wf_next.is_empty() {
                break;
            }
            std::mem::swap(&mut self.wf_unsat, &mut self.wf_next);
        }
        for (i, a) in self.active.values().enumerate() {
            if matches!(a.current.phase, Phase::Serial | Phase::Parallel) {
                self.rates[i] *= interference;
            }
        }
    }

    /// Compute-phase rates keyed by invocation id (tests/inspection; the
    /// hot path uses the cached slice directly).
    pub fn cpu_rates(&mut self) -> BTreeMap<u64, f64> {
        self.ensure_rates();
        self.active
            .values()
            .zip(self.rates.iter())
            .filter(|(a, _)| matches!(a.current.phase, Phase::Serial | Phase::Parallel))
            .map(|(a, &r)| (a.inv_id, r))
            .collect()
    }

    /// Bytes/s available to each concurrent network fetch (fair share).
    fn net_rate(&self) -> f64 {
        let n = self.net_active().max(1);
        self.net_gbps * 1e9 / 8.0 / n as f64
    }

    /// Progress all active work up to `now`. Invocations whose current
    /// phase hits zero are queued for the engine (see [`Self::drain_done`]).
    pub fn advance(&mut self, now: SimTime) {
        let dt = now - self.last_advance;
        if dt <= 0.0 {
            self.last_advance = now.max(self.last_advance);
            return;
        }
        self.ensure_rates();
        debug_assert_eq!(self.rates.len(), self.active.len());
        let mut done = std::mem::take(&mut self.done_buf);
        for (a, &rate) in self.active.values_mut().zip(self.rates.iter()) {
            if a.remaining <= 0.0 {
                continue; // already queued for completion
            }
            // The engine advances exactly to phase-completion events, so a
            // phase never crosses zero mid-interval; clamp defensively and
            // account only work actually done.
            let done_work = (rate * dt).min(a.remaining);
            a.remaining -= done_work;
            // Snap float residue to zero so completion checks terminate
            // (a sub-nanosecond work remainder can otherwise produce
            // events whose dt underflows to the same timestamp forever).
            if a.remaining < 1e-9 {
                a.remaining = 0.0;
            }
            if matches!(a.current.phase, Phase::Serial | Phase::Parallel) {
                // Work *is* CPU-seconds for compute phases.
                a.cpu_seconds_done += done_work;
            }
            if a.remaining <= 0.0 {
                done.push(a.inv_id);
            }
        }
        self.done_buf = done;
        self.last_advance = now;
    }

    /// Move the completions queued by [`Self::advance`] into `out`
    /// (append; caller owns ordering/clearing).
    pub fn drain_done(&mut self, out: &mut Vec<u64>) {
        out.append(&mut self.done_buf);
    }

    /// Earliest (dt-from-now, inv_id) at which some current phase
    /// completes, given current rates. None if nothing is active. Ties
    /// break toward the lowest invocation id.
    pub fn next_phase_completion(&mut self) -> Option<(f64, u64)> {
        self.ensure_rates();
        let mut best: Option<(f64, u64)> = None;
        for (a, &rate) in self.active.values().zip(self.rates.iter()) {
            let dt = if a.remaining <= 0.0 {
                0.0
            } else if rate <= 0.0 {
                f64::INFINITY
            } else {
                a.remaining / rate
            };
            match best {
                None => best = Some((dt, a.inv_id)),
                Some((bdt, _)) if dt < bdt => best = Some((dt, a.inv_id)),
                _ => {}
            }
        }
        best
    }

    /// Register a new active invocation (its container must be Busy —
    /// the *container* carries the admission reservation; this only adds
    /// the invocation's cgroup shares to the interference basis).
    pub fn start_invocation(&mut self, inv: ActiveInv, vcpus: u32, mem_mb: u32) {
        let _ = mem_mb; // reservation charged by the container lifecycle
        self.busy_vcpus += vcpus as f64;
        self.total_invocations += 1;
        self.active.insert(inv.inv_id, inv);
        self.epoch += 1;
    }

    /// Remove a finished/killed invocation; returns it for accounting.
    pub fn finish_invocation(&mut self, inv_id: u64, vcpus: u32, mem_mb: u32) -> Option<ActiveInv> {
        let _ = mem_mb;
        let a = self.active.remove(&inv_id)?;
        self.busy_vcpus = (self.busy_vcpus - vcpus as f64).max(0.0);
        self.epoch += 1;
        Some(a)
    }

    /// Verify the reservation counters against container ground truth
    /// and the admission limits (the engine's per-event invariant; also
    /// called by tests). Panics on drift or overcommit.
    pub fn assert_admission_consistent(&self) {
        let mut vcpus = 0u64;
        let mut mem = 0u64;
        for c in self.containers.values() {
            if !c.is_warm_idle() || self.idle_reserves {
                vcpus += c.vcpus as u64;
                mem += c.mem_mb as u64;
            }
        }
        assert_eq!(
            self.allocated_vcpus, vcpus as f64,
            "worker {}: vCPU reservations drifted from container state",
            self.id
        );
        assert_eq!(
            self.allocated_mem_mb, mem as f64,
            "worker {}: memory reservations drifted from container state",
            self.id
        );
        assert!(
            self.allocated_vcpus <= self.sched_vcpu_limit,
            "worker {}: admission invariant violated: {} vCPUs allocated > limit {}",
            self.id,
            self.allocated_vcpus,
            self.sched_vcpu_limit
        );
        assert!(
            self.allocated_mem_mb <= self.mem_gb * 1024.0,
            "worker {}: admission invariant violated: {} MB allocated > {} MB",
            self.id,
            self.allocated_mem_mb,
            self.mem_gb * 1024.0
        );
        let qv: u64 = self.admission_queue.iter().map(|q| q.vcpus as u64).sum();
        let qm: u64 = self.admission_queue.iter().map(|q| q.mem_mb as u64).sum();
        assert_eq!(qv, self.queued_vcpus_total, "worker {}: queued vCPU sum drifted", self.id);
        assert_eq!(qm, self.queued_mem_total, "worker {}: queued mem sum drifted", self.id);
    }
}

/// The cluster: all workers plus a cluster-wide warm-container index
/// (`(func, vcpus, mem_mb, worker, container)` in sorted order), kept in
/// lockstep with the per-worker indexes by routing every container
/// lifecycle change through the methods below.
///
/// `workers` (and `Worker::containers`/`active`) stay `pub` for read
/// access — integration tests and schedulers inspect them freely — but
/// mutating a cluster-owned worker's containers directly, or calling the
/// worker-level lifecycle methods on one, desyncs the cluster index:
/// always go through `Cluster::{insert,remove}_container`,
/// `container_ready`, `acquire_container`, `release_container`
/// (drift is caught by [`Cluster::assert_warm_consistent`] in tests).
#[derive(Debug)]
pub struct Cluster {
    pub workers: Vec<Worker>,
    warm: BTreeSet<(usize, u32, u32, usize, u64)>,
}

impl Cluster {
    pub fn new(cfg: &super::SimConfig) -> Self {
        // One keep-alive policy build for the whole cluster: the
        // `idle_reserves` accounting switch comes from the same impl the
        // engine-owned instance answers from (single source of truth).
        let idle_reserves = super::keepalive::build(cfg).idle_reserves();
        Cluster {
            workers: (0..cfg.workers)
                .map(|i| Worker::with_idle_reserves(i, cfg, idle_reserves))
                .collect(),
            warm: BTreeSet::new(),
        }
    }

    pub fn worker(&self, id: usize) -> &Worker {
        &self.workers[id]
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    // -- container lifecycle --------------------------------------------

    /// Adopt a container onto a worker (cold launch or test setup).
    pub fn insert_container(&mut self, worker: usize, c: Container) {
        if c.is_warm_idle() {
            self.warm.insert((c.func, c.vcpus, c.mem_mb, worker, c.id));
        }
        self.workers[worker].insert_container(c);
    }

    /// Tear a container down everywhere (eviction, OOM, timeout).
    pub fn remove_container(&mut self, worker: usize, cid: u64) -> Option<Container> {
        let c = self.workers[worker].remove_container(cid)?;
        self.warm.remove(&(c.func, c.vcpus, c.mem_mb, worker, cid));
        Some(c)
    }

    /// Cold start finished; returns the container's idle epoch (None if
    /// it was torn down before becoming ready).
    pub fn container_ready(&mut self, worker: usize, cid: u64, now: SimTime) -> Option<u64> {
        let (epoch, (func, vcpus, mem_mb, id)) = self.workers[worker].container_ready(cid, now)?;
        self.warm.insert((func, vcpus, mem_mb, worker, id));
        Some(epoch)
    }

    /// Mark a warm container busy; returns its (vcpus, mem_mb).
    pub fn acquire_container(&mut self, worker: usize, cid: u64) -> (u32, u32) {
        let (func, vcpus, mem_mb, id) = self.workers[worker].acquire_container(cid);
        self.warm.remove(&(func, vcpus, mem_mb, worker, id));
        (vcpus, mem_mb)
    }

    /// Return a busy container to the warm pool; returns its idle epoch.
    pub fn release_container(&mut self, worker: usize, cid: u64, now: SimTime) -> u64 {
        let (epoch, (func, vcpus, mem_mb, id)) = self.workers[worker].release_container(cid, now);
        self.warm.insert((func, vcpus, mem_mb, worker, id));
        epoch
    }

    // -- warm-pool queries ----------------------------------------------

    /// Exact-size idle warm container on a worker passing `admit(worker,
    /// container_vcpus, container_mem)`; lowest `(worker, container)` id
    /// wins ties.
    pub fn find_warm_exact_where(
        &self,
        func: usize,
        vcpus: u32,
        mem_mb: u32,
        admit: impl Fn(&Worker, u32, u32) -> bool,
    ) -> Option<(usize, u64)> {
        self.warm
            .range((func, vcpus, mem_mb, 0, 0)..=(func, vcpus, mem_mb, usize::MAX, u64::MAX))
            .find(|&&(_, _, _, w, _)| admit(&self.workers[w], vcpus, mem_mb))
            .map(|&(_, _, _, w, cid)| (w, cid))
    }

    /// Smallest admissible at-least-as-large idle warm container:
    /// lexicographically minimal `(vcpus, mem_mb, worker, container)`.
    pub fn find_warm_larger_where(
        &self,
        func: usize,
        vcpus: u32,
        mem_mb: u32,
        admit: impl Fn(&Worker, u32, u32) -> bool,
    ) -> Option<(usize, u64)> {
        self.warm
            .range((func, vcpus, 0, 0, 0)..)
            .take_while(|&&(f, _, _, _, _)| f == func)
            .find(|&&(_, cv, cm, w, _)| cm >= mem_mb && admit(&self.workers[w], cv, cm))
            .map(|&(_, _, _, w, cid)| (w, cid))
    }

    /// Find an exact-size idle warm container anywhere (worker, container).
    pub fn find_warm_exact(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<(usize, u64)> {
        self.find_warm_exact_where(func, vcpus, mem_mb, |_, _, _| true)
    }

    /// Find the smallest at-least-as-large idle warm container anywhere.
    pub fn find_warm_larger(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<(usize, u64)> {
        self.find_warm_larger_where(func, vcpus, mem_mb, |_, _, _| true)
    }

    /// Total allocated vCPUs across workers (cluster load).
    pub fn total_allocated_vcpus(&self) -> f64 {
        self.workers.iter().map(|w| w.allocated_vcpus).sum()
    }

    /// Total demand parked on admission queues across workers.
    pub fn total_queued_vcpus(&self) -> f64 {
        self.workers.iter().map(|w| w.queued_vcpus()).sum()
    }

    /// Highest per-worker vCPU reservation ever observed (the overload
    /// experiment's release-build invariant witness).
    pub fn peak_allocated_vcpus(&self) -> f64 {
        self.workers.iter().map(|w| w.peak_allocated_vcpus).fold(0.0, f64::max)
    }

    /// Highest per-worker memory reservation (MB) ever observed.
    pub fn peak_allocated_mem_mb(&self) -> f64 {
        self.workers.iter().map(|w| w.peak_allocated_mem_mb).fold(0.0, f64::max)
    }

    /// Verify reservation accounting + admission limits on every worker
    /// (see [`Worker::assert_admission_consistent`]).
    pub fn assert_admission_consistent(&self) {
        for w in &self.workers {
            w.assert_admission_consistent();
        }
    }

    /// Verify both warm indexes against container ground truth (tests).
    pub fn assert_warm_consistent(&self) {
        let mut expect_cluster: Vec<(usize, u32, u32, usize, u64)> = Vec::new();
        for w in &self.workers {
            let mut expect: Vec<WarmKey> = Vec::new();
            for c in w.containers.values() {
                if c.is_warm_idle() {
                    expect.push((c.func, c.vcpus, c.mem_mb, c.id));
                    expect_cluster.push((c.func, c.vcpus, c.mem_mb, w.id, c.id));
                }
            }
            expect.sort_unstable();
            let got: Vec<WarmKey> = w.warm_index().iter().copied().collect();
            assert_eq!(got, expect, "worker {} warm index drifted", w.id);
        }
        expect_cluster.sort_unstable();
        let got: Vec<_> = self.warm.iter().copied().collect();
        assert_eq!(got, expect_cluster, "cluster warm index drifted");
    }

    /// First-class invariant check (ISSUE 6): reservation accounting,
    /// admission limits, warm-index consistency, and the *peak*
    /// reservation witness, all as plain `assert!`s so they fire in
    /// release builds too — the adversity experiment and the fault test
    /// battery call this per replicate. Peaks are checked against each
    /// worker's **own** limits, so it holds on heterogeneous clusters
    /// where a single cluster-wide limit would be meaningless.
    pub fn check_invariants(&self) {
        self.assert_admission_consistent();
        self.assert_warm_consistent();
        for w in &self.workers {
            assert!(
                w.peak_allocated_vcpus <= w.sched_vcpu_limit + 1e-9,
                "worker {}: peak vCPU reservation {} exceeded its limit {}",
                w.id,
                w.peak_allocated_vcpus,
                w.sched_vcpu_limit
            );
            assert!(
                w.peak_allocated_mem_mb <= w.mem_gb * 1024.0 + 1e-9,
                "worker {}: peak memory reservation {} MB exceeded its limit {} MB",
                w.id,
                w.peak_allocated_mem_mb,
                w.mem_gb * 1024.0
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimConfig;

    fn worker() -> Worker {
        Worker::new(0, &SimConfig::default())
    }

    fn active(inv_id: u64, phase: Phase, work: f64, demand: f64) -> ActiveInv {
        ActiveInv {
            inv_id,
            container_id: inv_id,
            alloc_vcpus: demand.max(1.0),
            remaining: work,
            current: PhaseSpec { phase, work, demand },
            pending: vec![],
            cpu_seconds_done: 0.0,
            exec_started: 0.0,
            peak_vcpus: demand,
            mem_used_gb: 0.5,
        }
    }

    fn warm(id: u64, func: usize, vcpus: u32, mem: u32) -> Container {
        let mut c = Container::new(id, func, vcpus, mem, 0.0);
        c.mark_ready(0.0);
        c
    }

    #[test]
    fn no_contention_full_rate() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Parallel, 80.0, 8.0), 8, 1024);
        assert_eq!(w.cpu_scale(), 1.0);
        let (dt, id) = w.next_phase_completion().unwrap();
        assert_eq!(id, 1);
        assert!((dt - 10.0).abs() < 1e-9, "80 cpu-s at 8 vCPUs = 10 s");
    }

    #[test]
    fn contention_slows_everyone() {
        let mut w = worker();
        // two invocations, each demanding 64 vCPUs on a 96-core box
        w.start_invocation(active(1, Phase::Parallel, 64.0, 64.0), 64, 1024);
        w.start_invocation(active(2, Phase::Parallel, 64.0, 64.0), 64, 1024);
        let scale = w.cpu_scale();
        assert!((scale - 96.0 / 128.0).abs() < 1e-12);
        let interference = w.interference_factor();
        let (dt, _) = w.next_phase_completion().unwrap();
        // equal weights: each gets 48 effective vCPUs, then the
        // allocation-oversubscription interference factor applies
        // (128 alloc on 96 cores -> 1/(1 + 0.35/3))
        assert!(interference < 1.0);
        let expect = 64.0 / (48.0 * interference);
        assert!((dt - expect).abs() < 1e-9, "dt {dt} expect {expect}");
    }

    #[test]
    fn advance_consumes_work_and_accounts_cpu() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Serial, 5.0, 1.0), 4, 512);
        w.advance(2.0);
        let a = &w.active[&1];
        assert!((a.remaining - 3.0).abs() < 1e-9);
        assert!((a.cpu_seconds_done - 2.0).abs() < 1e-9);
    }

    #[test]
    fn advance_queues_completions_in_id_order() {
        let mut w = worker();
        // insert out of id order; both finish within the window
        w.start_invocation(active(9, Phase::Serial, 1.0, 1.0), 1, 128);
        w.start_invocation(active(3, Phase::Serial, 1.0, 1.0), 1, 128);
        w.advance(2.0);
        let mut done = Vec::new();
        w.drain_done(&mut done);
        assert_eq!(done, vec![3, 9], "completions surface in invocation-id order");
        // drained: a second drain is empty
        let mut again = Vec::new();
        w.drain_done(&mut again);
        assert!(again.is_empty());
    }

    #[test]
    fn rate_cache_tracks_epoch() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Serial, 10.0, 1.0), 1, 128);
        let r1 = w.cpu_rates();
        assert!((r1[&1] - 1.0).abs() < 1e-12);
        // adding load bumps the epoch and invalidates the cache
        w.start_invocation(active(2, Phase::Parallel, 1000.0, 200.0), 48, 512);
        let r2 = w.cpu_rates();
        assert!(r2[&1] < 1.0, "contention must slow the serial phase");
        assert_eq!(r2.len(), 2);
    }

    #[test]
    fn net_fair_share() {
        let mut w = worker();
        // 10 Gb/s = 1.25 GB/s; two fetches share it
        w.start_invocation(active(1, Phase::Net, 1.25e9, 1.0), 4, 512);
        w.start_invocation(active(2, Phase::Net, 1.25e9, 1.0), 4, 512);
        let (dt, _) = w.next_phase_completion().unwrap();
        assert!((dt - 2.0).abs() < 1e-6, "two 1.25GB fetches over shared NIC: {dt}");
    }

    #[test]
    fn net_phase_unaffected_by_cpu_storm() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Net, 1.25e9, 1.0), 4, 512);
        w.start_invocation(active(2, Phase::Parallel, 1000.0, 200.0), 48, 512);
        let cpu_scale = w.cpu_scale();
        assert!(cpu_scale < 1.0);
        // net fetch still completes in ~1 s
        w.advance(1.0);
        assert!(w.active[&1].remaining < 1.0);
    }

    #[test]
    fn reservation_follows_container_lifecycle() {
        let mut w = worker();
        // launch (Starting) reserves immediately — cold starts hold their
        // capacity through the whole startup window
        w.insert_container(Container::new(1, 0, 8, 2048, 1.0));
        assert_eq!(w.allocated_vcpus, 8.0);
        assert_eq!(w.allocated_mem_mb, 2048.0);
        assert!(w.can_admit(82, 1024));
        assert!(!w.can_admit(83, 1024));
        // ready -> idle releases (idle containers consume nothing)
        w.container_ready(1, 1.0).unwrap();
        assert_eq!(w.allocated_vcpus, 0.0);
        assert_eq!(w.allocated_mem_mb, 0.0);
        // busy re-charges; release frees again
        w.acquire_container(1);
        assert_eq!(w.allocated_vcpus, 8.0);
        w.release_container(1, 2.0);
        assert_eq!(w.allocated_vcpus, 0.0);
        // teardown of a busy container releases its reservation too
        w.acquire_container(1);
        w.remove_container(1).unwrap();
        assert_eq!(w.allocated_vcpus, 0.0);
        assert_eq!(w.allocated_mem_mb, 0.0);
        assert_eq!(w.peak_allocated_vcpus, 8.0, "peak witnesses the high-water mark");
        w.assert_admission_consistent();
    }

    #[test]
    fn idle_containers_hold_reservations_under_pressure_mode() {
        use crate::simulator::keepalive::KeepAliveMode;
        let cfg = SimConfig { keepalive: KeepAliveMode::Pressure, ..SimConfig::default() };
        let mut w = Worker::new(0, &cfg);
        assert!(w.idle_reserves);
        // launch reserves as always
        w.insert_container(Container::new(1, 0, 8, 2048, 1.0));
        assert_eq!(w.allocated_vcpus, 8.0);
        // ready -> idle KEEPS the reservation (warmth occupies capacity)
        w.container_ready(1, 1.0).unwrap();
        assert_eq!(w.allocated_vcpus, 8.0);
        assert_eq!(w.allocated_mem_mb, 2048.0);
        w.assert_admission_consistent();
        // acquire must not double-charge; release keeps holding
        w.acquire_container(1);
        assert_eq!(w.allocated_vcpus, 8.0);
        w.release_container(1, 2.0);
        assert_eq!(w.allocated_vcpus, 8.0);
        w.assert_admission_consistent();
        // only eviction/teardown frees the capacity
        w.remove_container(1).unwrap();
        assert_eq!(w.allocated_vcpus, 0.0);
        assert_eq!(w.allocated_mem_mb, 0.0);
        assert_eq!(w.peak_allocated_vcpus, 8.0);
        w.assert_admission_consistent();
        // inserting an already-idle container (test setups) reserves too
        let mut idle = Container::new(2, 0, 4, 512, 0.0);
        idle.mark_ready(0.0);
        w.insert_container(idle);
        assert_eq!(w.allocated_vcpus, 4.0);
        w.assert_admission_consistent();
    }

    #[test]
    fn busy_vcpus_track_running_invocations() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Serial, 1.0, 1.0), 8, 2048);
        assert_eq!(w.busy_vcpus, 8.0);
        assert_eq!(w.allocated_vcpus, 0.0, "invocations don't reserve; containers do");
        w.finish_invocation(1, 8, 2048).unwrap();
        assert_eq!(w.busy_vcpus, 0.0);
    }

    #[test]
    fn admission_queue_fifo_and_queue_aware_capacity() {
        let mut w = worker();
        w.push_admission(QueuedAdmission { inv_id: 5, vcpus: 8, mem_mb: 1024 });
        w.push_admission(QueuedAdmission { inv_id: 2, vcpus: 4, mem_mb: 512 });
        w.push_admission(QueuedAdmission { inv_id: 9, vcpus: 2, mem_mb: 256 });
        assert_eq!(w.admission_queue_len(), 3);
        assert_eq!(w.queued_vcpus(), 14.0);
        assert_eq!(w.queued_mem_mb(), 1792.0);
        // the hard engine check ignores the queue; the scheduler view
        // subtracts parked demand
        assert!(w.can_admit(80, 4096));
        assert!(!w.has_capacity(80, 4096), "90 limit - 14 queued leaves 76");
        assert!(w.has_capacity(76, 4096));
        // removal by id preserves FIFO order of the rest
        assert_eq!(w.remove_admission(2).unwrap().vcpus, 4);
        assert!(w.remove_admission(2).is_none());
        assert_eq!(w.front_admission().unwrap().inv_id, 5);
        assert_eq!(w.pop_admission().unwrap().inv_id, 5);
        assert_eq!(w.pop_admission().unwrap().inv_id, 9);
        assert!(w.pop_admission().is_none());
        assert_eq!(w.queued_vcpus(), 0.0);
        assert_eq!(w.queued_mem_mb(), 0.0);
        w.assert_admission_consistent();
    }

    #[test]
    fn warm_lookup_prefers_smallest_fitting() {
        let mut w = worker();
        for (id, v) in [(1u64, 8u32), (2, 16), (3, 12)] {
            w.insert_container(warm(id, 0, v, 2048));
        }
        let c = w.find_warm_larger(0, 9, 1024).unwrap();
        assert_eq!(c.vcpus, 12, "closest-larger should win");
        assert!(w.find_warm_exact(0, 9, 1024).is_none());
        assert!(w.find_warm_exact(0, 8, 2048).is_some());
    }

    #[test]
    fn equal_size_warm_ties_break_to_lowest_id() {
        let mut w = worker();
        // insert several identically-sized warm containers, high ids first
        for id in [44u64, 17, 92, 23] {
            w.insert_container(warm(id, 0, 8, 2048));
        }
        assert_eq!(w.find_warm_exact(0, 8, 2048).unwrap().id, 17);
        assert_eq!(w.find_warm_larger(0, 4, 1024).unwrap().id, 17);
        // removing the winner promotes the next-lowest id
        w.remove_container(17).unwrap();
        assert_eq!(w.find_warm_exact(0, 8, 2048).unwrap().id, 23);
    }

    #[test]
    fn warm_index_follows_lifecycle() {
        let mut w = worker();
        let c = Container::new(5, 2, 8, 1024, 1.0); // Starting
        w.insert_container(c);
        assert!(w.find_warm_exact(2, 8, 1024).is_none(), "starting is not warm");
        w.container_ready(5, 1.0).unwrap();
        assert!(w.find_warm_exact(2, 8, 1024).is_some());
        let (func, vc, mem, id) = w.acquire_container(5);
        assert_eq!((func, vc, mem, id), (2, 8, 1024, 5));
        assert!(w.find_warm_exact(2, 8, 1024).is_none(), "busy left the pool");
        w.release_container(5, 3.0);
        assert!(w.find_warm_exact(2, 8, 1024).is_some(), "released rejoins");
        w.remove_container(5).unwrap();
        assert!(w.find_warm_exact(2, 8, 1024).is_none());
        assert!(w.warm_index().is_empty());
    }

    #[test]
    fn busy_containers_not_warm() {
        let mut w = worker();
        let mut c = warm(1, 0, 8, 1024);
        c.acquire();
        w.insert_container(c);
        assert!(w.find_warm_larger(0, 4, 512).is_none());
    }

    #[test]
    fn cluster_warm_search() {
        let cfg = SimConfig::small();
        let mut cl = Cluster::new(&cfg);
        cl.insert_container(2, warm(7, 3, 10, 4096));
        assert_eq!(cl.find_warm_exact(3, 10, 4096), Some((2, 7)));
        assert_eq!(cl.find_warm_larger(3, 6, 2048), Some((2, 7)));
        assert_eq!(cl.find_warm_exact(3, 11, 4096), None);
        cl.assert_warm_consistent();
    }

    #[test]
    fn cluster_ties_break_to_lowest_worker_then_container() {
        let cfg = SimConfig::small();
        let mut cl = Cluster::new(&cfg);
        // equal-size candidates scattered across workers, high ids first
        cl.insert_container(3, warm(31, 0, 8, 1024));
        cl.insert_container(1, warm(40, 0, 8, 1024));
        cl.insert_container(1, warm(12, 0, 8, 1024));
        assert_eq!(cl.find_warm_exact(0, 8, 1024), Some((1, 12)));
        assert_eq!(cl.find_warm_larger(0, 2, 256), Some((1, 12)));
        // a predicate can veto workers; the next (worker, id) wins
        let skip_w1 = |w: &Worker, _: u32, _: u32| w.id != 1;
        assert_eq!(cl.find_warm_exact_where(0, 8, 1024, skip_w1), Some((3, 31)));
        cl.assert_warm_consistent();
    }

    #[test]
    fn cluster_larger_prefers_smaller_size_over_lower_worker() {
        let cfg = SimConfig::small();
        let mut cl = Cluster::new(&cfg);
        cl.insert_container(0, warm(1, 0, 16, 4096));
        cl.insert_container(3, warm(2, 0, 6, 1024));
        assert_eq!(
            cl.find_warm_larger(0, 4, 512),
            Some((3, 2)),
            "smallest fitting size wins regardless of worker order"
        );
    }
}
