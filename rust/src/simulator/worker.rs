//! Worker (invoker) model: container pools, allocation accounting, and
//! processor-sharing execution of invocation phases.
//!
//! Execution model: each active invocation is in one phase —
//! `Net` (NIC fair-sharing), `Serial` (1 vCPU), or `Parallel`
//! (`min(alloc, maxpar)` vCPUs). When the sum of vCPU demands exceeds the
//! worker's *physical* cores, every compute phase is slowed by the same
//! factor (Linux CFS-style fair sharing weighted by demand). The per-
//! worker daemon numbers (avg/peak vCPUs used) fall out of the exact work
//! accounting.

use std::collections::HashMap;

use super::container::Container;
use super::SimTime;

/// Execution phase of an active invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Fetching input bytes from the external datastore.
    Net,
    /// Serial compute on one vCPU.
    Serial,
    /// Parallel compute on `demand` vCPUs.
    Parallel,
}

/// One queued phase: (phase, work, demand).
/// Work is bytes for Net, CPU-seconds for Serial/Parallel.
#[derive(Debug, Clone, Copy)]
pub struct PhaseSpec {
    pub phase: Phase,
    pub work: f64,
    pub demand: f64,
}

/// An invocation currently executing on this worker.
#[derive(Debug, Clone)]
pub struct ActiveInv {
    pub inv_id: u64,
    pub container_id: u64,
    /// vCPU allocation of the container (cgroup share weight).
    pub alloc_vcpus: f64,
    /// Remaining work in the current phase.
    pub remaining: f64,
    pub current: PhaseSpec,
    /// Later phases, in order.
    pub pending: Vec<PhaseSpec>,
    /// Total CPU-seconds consumed so far (daemon accounting).
    pub cpu_seconds_done: f64,
    pub exec_started: SimTime,
    pub peak_vcpus: f64,
    /// Memory footprint of the invocation (GB).
    pub mem_used_gb: f64,
}

impl ActiveInv {
    /// Move to the next phase; returns false when all phases are done.
    pub fn next_phase(&mut self) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        self.current = self.pending.remove(0);
        self.remaining = self.current.work;
        if matches!(self.current.phase, Phase::Serial | Phase::Parallel) {
            self.peak_vcpus = self.peak_vcpus.max(self.current.demand);
        }
        // zero-work phases are skipped by the caller loop
        true
    }
}

/// A worker node (OpenWhisk invoker).
#[derive(Debug)]
pub struct Worker {
    pub id: usize,
    pub physical_cores: f64,
    /// Scheduler admission limit (`userCpu` hyperparameter).
    pub sched_vcpu_limit: f64,
    pub mem_gb: f64,
    pub net_gbps: f64,
    pub containers: HashMap<u64, Container>,
    pub active: HashMap<u64, ActiveInv>,
    /// Allocated resources of *busy* containers (idle containers consume
    /// nothing — §5 "Creating Idle Containers in the Background").
    pub allocated_vcpus: f64,
    pub allocated_mem_mb: f64,
    /// Last time `advance` ran (work progressed up to here).
    pub last_advance: SimTime,
    /// Bumped on every change to the active set; stale completion events
    /// carry an old epoch and are ignored.
    pub epoch: u64,
    /// Lifetime counters.
    pub total_cold_starts: u64,
    pub total_invocations: u64,
}

impl Worker {
    pub fn new(id: usize, cfg: &super::SimConfig) -> Self {
        Worker {
            id,
            physical_cores: cfg.physical_cores,
            sched_vcpu_limit: cfg.sched_vcpu_limit,
            mem_gb: cfg.mem_gb,
            net_gbps: cfg.net_gbps,
            containers: HashMap::new(),
            active: HashMap::new(),
            allocated_vcpus: 0.0,
            allocated_mem_mb: 0.0,
            last_advance: 0.0,
            epoch: 0,
            total_cold_starts: 0,
            total_invocations: 0,
        }
    }

    // -- scheduler-facing load view ------------------------------------

    /// Free vCPUs under the admission limit.
    pub fn free_sched_vcpus(&self) -> f64 {
        (self.sched_vcpu_limit - self.allocated_vcpus).max(0.0)
    }

    /// Free memory (MB) under the admission limit.
    pub fn free_mem_mb(&self) -> f64 {
        (self.mem_gb * 1024.0 - self.allocated_mem_mb).max(0.0)
    }

    /// Whether an invocation of this size can be admitted.
    pub fn has_capacity(&self, vcpus: u32, mem_mb: u32) -> bool {
        self.free_sched_vcpus() >= vcpus as f64 && self.free_mem_mb() >= mem_mb as f64
    }

    /// Idle warm containers for `func`, any size.
    pub fn warm_containers(&self, func: usize) -> impl Iterator<Item = &Container> {
        self.containers
            .values()
            .filter(move |c| c.func == func && c.is_warm_idle())
    }

    /// Idle warm container of the exact size.
    pub fn find_warm_exact(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<&Container> {
        self.warm_containers(func)
            .find(|c| c.exact(func, vcpus, mem_mb))
    }

    /// Smallest idle warm container that is at least the requested size.
    pub fn find_warm_larger(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<&Container> {
        self.warm_containers(func)
            .filter(|c| c.fits(func, vcpus, mem_mb))
            .min_by_key(|c| (c.vcpus, c.mem_mb))
    }

    // -- processor sharing ----------------------------------------------

    /// Total vCPU demand of active compute phases.
    fn cpu_demand(&self) -> f64 {
        self.active
            .values()
            .filter(|a| matches!(a.current.phase, Phase::Serial | Phase::Parallel))
            .map(|a| a.current.demand)
            .sum()
    }

    /// Number of active network phases.
    fn net_active(&self) -> usize {
        self.active
            .values()
            .filter(|a| a.current.phase == Phase::Net)
            .count()
    }

    /// Contention slowdown for compute phases: 1.0 when demand fits the
    /// physical cores, `cores / demand` when oversubscribed (aggregate
    /// view; per-invocation rates come from [`Self::cpu_rates`]).
    pub fn cpu_scale(&self) -> f64 {
        let demand = self.cpu_demand();
        if demand <= self.physical_cores {
            1.0
        } else {
            self.physical_cores / demand
        }
    }

    /// Per-invocation CPU rates (cpu-seconds per wall-second) under
    /// cgroup-share semantics: when the worker's compute demand exceeds
    /// its physical cores, capacity is distributed in proportion to each
    /// invocation's *allocation* (its cpu share weight), capped at what
    /// the phase can use (its demand), work-conservingly (water-filling).
    ///
    /// This is the mechanism behind the paper's "stealing" observation
    /// (§7.2): over-allocated invocations squeeze right-sized ones under
    /// contention even when they cannot use the extra cores themselves.
    /// Interference slowdown from vCPU over-subscription of *allocations*
    /// (cgroup shares): when the sum of busy containers' vCPU limits
    /// exceeds the physical cores, the kernel timeslices more runnable
    /// threads than cores (cache pollution, scheduler churn). This is the
    /// §7.2 mechanism by which over-allocating systems degrade co-located
    /// invocations even when *useful* demand still fits the machine.
    pub fn interference_factor(&self) -> f64 {
        let over = (self.allocated_vcpus - self.physical_cores) / self.physical_cores;
        1.0 / (1.0 + 0.35 * over.max(0.0))
    }

    pub fn cpu_rates(&self) -> HashMap<u64, f64> {
        let mut rates = HashMap::new();
        let interference = self.interference_factor();
        let compute: Vec<(&u64, &ActiveInv)> = self
            .active
            .iter()
            .filter(|(_, a)| matches!(a.current.phase, Phase::Serial | Phase::Parallel))
            .collect();
        let total_demand: f64 = compute.iter().map(|(_, a)| a.current.demand).sum();
        if total_demand <= self.physical_cores {
            for (id, a) in compute {
                rates.insert(*id, a.current.demand * interference);
            }
            return rates;
        }
        // water-filling by allocation weight
        let mut remaining = self.physical_cores;
        let mut unsat: Vec<(u64, f64, f64)> = compute
            .iter()
            .map(|(id, a)| (**id, a.current.demand, a.alloc_vcpus.max(1.0)))
            .collect();
        loop {
            let total_w: f64 = unsat.iter().map(|(_, _, w)| *w).sum();
            if total_w <= 0.0 || remaining <= 1e-12 {
                for (id, _, _) in &unsat {
                    rates.insert(*id, 0.0);
                }
                break;
            }
            let mut newly_sat = false;
            let mut still = Vec::with_capacity(unsat.len());
            for (id, demand, w) in unsat.drain(..) {
                let share = remaining * w / total_w;
                if share >= demand {
                    rates.insert(id, demand);
                    newly_sat = true;
                } else {
                    still.push((id, demand, w));
                }
            }
            // subtract satisfied demands from capacity
            let sat_sum: f64 = rates
                .iter()
                .filter(|(id, _)| !still.iter().any(|(sid, _, _)| sid == *id))
                .map(|(_, r)| *r)
                .sum();
            remaining = (self.physical_cores - sat_sum).max(0.0);
            if !newly_sat {
                // no one newly satisfied: final proportional split
                let total_w: f64 = still.iter().map(|(_, _, w)| *w).sum();
                for (id, demand, w) in still {
                    rates.insert(id, (remaining * w / total_w).min(demand));
                }
                break;
            }
            if still.is_empty() {
                break;
            }
            unsat = still;
        }
        for r in rates.values_mut() {
            *r *= interference;
        }
        rates
    }

    /// Bytes/s available to each concurrent network fetch (fair share).
    fn net_rate(&self) -> f64 {
        let n = self.net_active().max(1);
        self.net_gbps * 1e9 / 8.0 / n as f64
    }

    /// Progress all active work up to `now`.
    pub fn advance(&mut self, now: SimTime) {
        let dt = now - self.last_advance;
        if dt <= 0.0 {
            self.last_advance = now.max(self.last_advance);
            return;
        }
        let cpu_rates = self.cpu_rates();
        let net_rate = self.net_rate();
        for a in self.active.values_mut() {
            let rate = match a.current.phase {
                Phase::Net => net_rate,
                Phase::Serial | Phase::Parallel => cpu_rates[&a.inv_id],
            };
            // The engine advances exactly to phase-completion events, so a
            // phase never crosses zero mid-interval; clamp defensively and
            // account only work actually done.
            let done = (rate * dt).min(a.remaining);
            a.remaining -= done;
            // Snap float residue to zero so completion checks terminate
            // (a sub-nanosecond work remainder can otherwise produce
            // events whose dt underflows to the same timestamp forever).
            if a.remaining < 1e-9 {
                a.remaining = 0.0;
            }
            if matches!(a.current.phase, Phase::Serial | Phase::Parallel) {
                // Work *is* CPU-seconds for compute phases.
                a.cpu_seconds_done += done;
            }
        }
        self.last_advance = now;
    }

    /// Earliest (dt-from-now, inv_id) at which some current phase
    /// completes, given current rates. None if nothing is active.
    pub fn next_phase_completion(&self) -> Option<(f64, u64)> {
        let cpu_rates = self.cpu_rates();
        let net_rate = self.net_rate();
        let mut best: Option<(f64, u64)> = None;
        for a in self.active.values() {
            let rate = match a.current.phase {
                Phase::Net => net_rate,
                Phase::Serial | Phase::Parallel => cpu_rates[&a.inv_id],
            };
            let dt = if rate <= 0.0 {
                f64::INFINITY
            } else {
                a.remaining / rate
            };
            match best {
                None => best = Some((dt, a.inv_id)),
                Some((bdt, _)) if dt < bdt => best = Some((dt, a.inv_id)),
                _ => {}
            }
        }
        best
    }

    /// Register a new active invocation (its container must be Busy).
    pub fn start_invocation(&mut self, inv: ActiveInv, vcpus: u32, mem_mb: u32) {
        self.allocated_vcpus += vcpus as f64;
        self.allocated_mem_mb += mem_mb as f64;
        self.total_invocations += 1;
        self.active.insert(inv.inv_id, inv);
        self.epoch += 1;
    }

    /// Remove a finished/killed invocation; returns it for accounting.
    pub fn finish_invocation(&mut self, inv_id: u64, vcpus: u32, mem_mb: u32) -> Option<ActiveInv> {
        let a = self.active.remove(&inv_id)?;
        self.allocated_vcpus = (self.allocated_vcpus - vcpus as f64).max(0.0);
        self.allocated_mem_mb = (self.allocated_mem_mb - mem_mb as f64).max(0.0);
        self.epoch += 1;
        Some(a)
    }
}

/// The cluster: all workers plus global container-id assignment.
#[derive(Debug)]
pub struct Cluster {
    pub workers: Vec<Worker>,
}

impl Cluster {
    pub fn new(cfg: &super::SimConfig) -> Self {
        Cluster {
            workers: (0..cfg.workers).map(|i| Worker::new(i, cfg)).collect(),
        }
    }

    pub fn worker(&self, id: usize) -> &Worker {
        &self.workers[id]
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Find an exact-size idle warm container anywhere (worker, container).
    pub fn find_warm_exact(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<(usize, u64)> {
        for w in &self.workers {
            if let Some(c) = w.find_warm_exact(func, vcpus, mem_mb) {
                return Some((w.id, c.id));
            }
        }
        None
    }

    /// Find the smallest at-least-as-large idle warm container anywhere.
    pub fn find_warm_larger(&self, func: usize, vcpus: u32, mem_mb: u32) -> Option<(usize, u64)> {
        let mut best: Option<(u32, u32, usize, u64)> = None;
        for w in &self.workers {
            if let Some(c) = w.find_warm_larger(func, vcpus, mem_mb) {
                let key = (c.vcpus, c.mem_mb, w.id, c.id);
                if best.map_or(true, |b| (key.0, key.1) < (b.0, b.1)) {
                    best = Some(key);
                }
            }
        }
        best.map(|(_, _, w, c)| (w, c))
    }

    /// Total allocated vCPUs across workers (cluster load).
    pub fn total_allocated_vcpus(&self) -> f64 {
        self.workers.iter().map(|w| w.allocated_vcpus).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimConfig;

    fn worker() -> Worker {
        Worker::new(0, &SimConfig::default())
    }

    fn active(inv_id: u64, phase: Phase, work: f64, demand: f64) -> ActiveInv {
        ActiveInv {
            inv_id,
            container_id: inv_id,
            alloc_vcpus: demand.max(1.0),
            remaining: work,
            current: PhaseSpec { phase, work, demand },
            pending: vec![],
            cpu_seconds_done: 0.0,
            exec_started: 0.0,
            peak_vcpus: demand,
            mem_used_gb: 0.5,
        }
    }

    #[test]
    fn no_contention_full_rate() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Parallel, 80.0, 8.0), 8, 1024);
        assert_eq!(w.cpu_scale(), 1.0);
        let (dt, id) = w.next_phase_completion().unwrap();
        assert_eq!(id, 1);
        assert!((dt - 10.0).abs() < 1e-9, "80 cpu-s at 8 vCPUs = 10 s");
    }

    #[test]
    fn contention_slows_everyone() {
        let mut w = worker();
        // two invocations, each demanding 64 vCPUs on a 96-core box
        w.start_invocation(active(1, Phase::Parallel, 64.0, 64.0), 64, 1024);
        w.start_invocation(active(2, Phase::Parallel, 64.0, 64.0), 64, 1024);
        let scale = w.cpu_scale();
        assert!((scale - 96.0 / 128.0).abs() < 1e-12);
        let (dt, _) = w.next_phase_completion().unwrap();
        // equal weights: each gets 48 effective vCPUs, then the
        // allocation-oversubscription interference factor applies
        // (128 alloc on 96 cores -> 1/(1 + 0.35/3))
        let interference = w.interference_factor();
        assert!(interference < 1.0);
        let expect = 64.0 / (48.0 * interference);
        assert!((dt - expect).abs() < 1e-9, "dt {dt} expect {expect}");
    }

    #[test]
    fn advance_consumes_work_and_accounts_cpu() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Serial, 5.0, 1.0), 4, 512);
        w.advance(2.0);
        let a = &w.active[&1];
        assert!((a.remaining - 3.0).abs() < 1e-9);
        assert!((a.cpu_seconds_done - 2.0).abs() < 1e-9);
    }

    #[test]
    fn net_fair_share() {
        let mut w = worker();
        // 10 Gb/s = 1.25 GB/s; two fetches share it
        w.start_invocation(active(1, Phase::Net, 1.25e9, 1.0), 4, 512);
        w.start_invocation(active(2, Phase::Net, 1.25e9, 1.0), 4, 512);
        let (dt, _) = w.next_phase_completion().unwrap();
        assert!((dt - 2.0).abs() < 1e-6, "two 1.25GB fetches over shared NIC: {dt}");
    }

    #[test]
    fn net_phase_unaffected_by_cpu_storm() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Net, 1.25e9, 1.0), 4, 512);
        w.start_invocation(active(2, Phase::Parallel, 1000.0, 200.0), 48, 512);
        let cpu_scale = w.cpu_scale();
        assert!(cpu_scale < 1.0);
        // net fetch still completes in ~1 s
        w.advance(1.0);
        assert!(w.active[&1].remaining < 1.0);
    }

    #[test]
    fn allocation_accounting() {
        let mut w = worker();
        w.start_invocation(active(1, Phase::Serial, 1.0, 1.0), 8, 2048);
        assert_eq!(w.allocated_vcpus, 8.0);
        assert_eq!(w.allocated_mem_mb, 2048.0);
        assert!(w.has_capacity(82, 1024));
        assert!(!w.has_capacity(83, 1024));
        w.finish_invocation(1, 8, 2048).unwrap();
        assert_eq!(w.allocated_vcpus, 0.0);
        assert_eq!(w.allocated_mem_mb, 0.0);
    }

    #[test]
    fn warm_lookup_prefers_smallest_fitting() {
        let mut w = worker();
        for (id, v) in [(1u64, 8u32), (2, 16), (3, 12)] {
            let mut c = Container::new(id, 0, v, 2048, 0.0);
            c.mark_ready(0.0);
            w.containers.insert(id, c);
        }
        let c = w.find_warm_larger(0, 9, 1024).unwrap();
        assert_eq!(c.vcpus, 12, "closest-larger should win");
        assert!(w.find_warm_exact(0, 9, 1024).is_none());
        assert!(w.find_warm_exact(0, 8, 2048).is_some());
    }

    #[test]
    fn busy_containers_not_warm() {
        let mut w = worker();
        let mut c = Container::new(1, 0, 8, 1024, 0.0);
        c.mark_ready(0.0);
        c.acquire();
        w.containers.insert(1, c);
        assert!(w.find_warm_larger(0, 4, 512).is_none());
    }

    #[test]
    fn cluster_warm_search() {
        let cfg = SimConfig::small();
        let mut cl = Cluster::new(&cfg);
        let mut c = Container::new(7, 3, 10, 4096, 0.0);
        c.mark_ready(0.0);
        cl.workers[2].containers.insert(7, c);
        assert_eq!(cl.find_warm_exact(3, 10, 4096), Some((2, 7)));
        assert_eq!(cl.find_warm_larger(3, 6, 2048), Some((2, 7)));
        assert_eq!(cl.find_warm_exact(3, 11, 4096), None);
    }
}
