//! Serverless-in-the-Wild–style hybrid histogram keep-alive (Shahrad et
//! al., ATC'20, via PAPERS.md): track per-function inter-arrival times in
//! coarse bins and size the keep-alive window from the distribution
//! instead of one global TTL.
//!
//! Per idle transition:
//!
//! * **cold history** (fewer than [`MIN_OBSERVATIONS`] gaps): fall back
//!   to the configured fixed TTL — indistinguishable from `fixed` until
//!   the function has a usable distribution;
//! * **bursty / short-gap** (head percentile under
//!   [`PREWARM_CUTOFF_S`]): keep the container for the *tail* percentile
//!   of observed gaps (plus one bin of slack), clamped to never exceed
//!   the fixed default — the common case where most reuse happens within
//!   seconds and a 600 s TTL is pure memory waste;
//! * **predictably long-gap** (head percentile at or past the cutoff):
//!   give the container up after a short [`GRACE_TTL_S`] and request a
//!   **pre-warm** — a fresh same-size launch timed [`PREWARM_LEAD_S`]
//!   before the earliest expected next arrival (the head percentile's
//!   *lower* bin edge), so the next invocation lands warm without the
//!   container idling through the whole gap.
//!
//! Divergence from the paper's policy is documented in DESIGN.md
//! §KeepAlive: we observe inter-*arrival* gaps (not end-of-execution to
//! next-start idle times) and pre-warm a fresh container rather than
//! unloading/reloading the same one — both simplifications keep the
//! policy deterministic and epoch-consistent with the indexed warm pool.

use super::{IdleDecision, KeepAlivePolicy};
use crate::simulator::SimTime;

/// Histogram bin width, seconds.
pub const BIN_S: f64 = 10.0;
/// Number of bins; the last bin absorbs every gap ≥ `(NBINS-1) * BIN_S`.
pub const NBINS: usize = 120;
/// Gaps observed before the histogram overrides the fixed fallback TTL.
pub const MIN_OBSERVATIONS: u64 = 8;
/// Head percentile: the earliest likely next arrival.
const HEAD_PCT: f64 = 0.05;
/// Tail percentile: the keep-alive horizon for bursty functions.
const TAIL_PCT: f64 = 0.99;
/// Head-percentile threshold past which idling is wasteful and the
/// policy switches to evict-then-pre-warm.
pub const PREWARM_CUTOFF_S: f64 = 60.0;
/// TTL granted in pre-warm mode (absorbs immediate back-to-back reuse,
/// and keeps a freshly pre-warmed container alive from its ready time
/// through the predicted arrival — it must exceed [`PREWARM_LEAD_S`],
/// or the grace eviction would reclaim the pre-warm before the request
/// it was launched for).
pub const GRACE_TTL_S: f64 = 30.0;
/// How far before the expected arrival the pre-warm launches. Must
/// exceed the engine's cold-start clamp ceiling (10 s) so a pre-warmed
/// container is always ready by the predicted arrival.
pub const PREWARM_LEAD_S: f64 = 15.0;

/// One function's inter-arrival histogram.
#[derive(Debug, Default, Clone)]
struct FuncHist {
    /// Lazily allocated to `NBINS` on first observation.
    counts: Vec<u32>,
    total: u64,
    last_arrival: Option<SimTime>,
}

impl FuncHist {
    fn observe(&mut self, gap_s: f64) {
        if self.counts.is_empty() {
            self.counts = vec![0; NBINS];
        }
        let bin = ((gap_s / BIN_S) as usize).min(NBINS - 1);
        self.counts[bin] = self.counts[bin].saturating_add(1);
        self.total += 1;
    }

    /// Upper edge (seconds) of the smallest bin at which the cumulative
    /// count reaches `pct` of the total.
    fn percentile_edge(&self, pct: f64) -> f64 {
        let need = (pct * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c as u64;
            if cum >= need {
                return (i + 1) as f64 * BIN_S;
            }
        }
        NBINS as f64 * BIN_S
    }
}

/// The hybrid histogram policy. No RNG, no floating accumulation across
/// functions: state is per-function bin counts, so identical runs build
/// identical histograms.
#[derive(Debug)]
pub struct HistogramKeepAlive {
    /// TTL while a function's history is cold (`SimConfig::keep_alive_s`).
    default_ttl_s: f64,
    funcs: Vec<FuncHist>,
}

impl HistogramKeepAlive {
    pub fn new(default_ttl_s: f64) -> Self {
        HistogramKeepAlive { default_ttl_s, funcs: Vec::new() }
    }

    fn hist(&mut self, func: usize) -> &mut FuncHist {
        if func >= self.funcs.len() {
            self.funcs.resize_with(func + 1, FuncHist::default);
        }
        &mut self.funcs[func]
    }
}

impl KeepAlivePolicy for HistogramKeepAlive {
    fn name(&self) -> &'static str {
        "histogram"
    }

    fn observe_arrival(&mut self, now: SimTime, func: usize) {
        let h = self.hist(func);
        if let Some(last) = h.last_arrival {
            h.observe((now - last).max(0.0));
        }
        h.last_arrival = Some(now);
    }

    fn on_idle(&mut self, now: SimTime, func: usize) -> IdleDecision {
        let default_ttl = self.default_ttl_s;
        let h = self.hist(func);
        if h.total < MIN_OBSERVATIONS {
            return IdleDecision { ttl_s: default_ttl, prewarm_at: None };
        }
        let head = h.percentile_edge(HEAD_PCT);
        if head >= PREWARM_CUTOFF_S {
            // Predictably long gaps: idling through them is the waste the
            // paper's 64-94% numbers come from. The next arrival is
            // predicted from the *last arrival* (inter-arrival gaps are
            // what the histogram observed), not from this idle
            // transition — for functions whose execution eats a chunk of
            // the gap, anchoring at completion would pre-warm after the
            // request already landed cold.
            let anchor = h.last_arrival.unwrap_or(now);
            let prewarm = anchor + (head - BIN_S) - PREWARM_LEAD_S;
            if prewarm > now + GRACE_TTL_S {
                // evict after the grace window, replace just in time
                IdleDecision { ttl_s: GRACE_TTL_S, prewarm_at: Some(prewarm) }
            } else {
                // execution consumed most of the gap: the expected
                // arrival is too close for evict-then-pre-warm to save
                // anything — hold the container through it instead. Not
                // capped by the fallback TTL (a small `histogram:<secs>`
                // override must not evict right before the arrival this
                // branch exists to cover); the hold is intrinsically
                // bounded: this branch only runs when the remaining gap
                // is at most grace + lead + one bin (~55 s).
                IdleDecision {
                    ttl_s: (anchor + head - now).max(GRACE_TTL_S),
                    prewarm_at: None,
                }
            }
        } else {
            // Bursty reuse: keep through the tail percentile (one bin of
            // slack), never longer than the fixed default.
            let tail = h.percentile_edge(TAIL_PCT) + BIN_S;
            IdleDecision {
                ttl_s: tail.clamp(BIN_S, default_ttl.max(BIN_S)),
                prewarm_at: None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_history_falls_back_to_fixed_ttl() {
        let mut p = HistogramKeepAlive::new(600.0);
        // fewer than MIN_OBSERVATIONS gaps: behave exactly like `fixed`
        for i in 0..MIN_OBSERVATIONS {
            assert_eq!(
                p.on_idle(i as f64, 0),
                IdleDecision { ttl_s: 600.0, prewarm_at: None }
            );
            p.observe_arrival(i as f64 * 20.0, 0);
        }
        // MIN_OBSERVATIONS arrivals = MIN_OBSERVATIONS - 1 gaps: still cold
        assert_eq!(p.on_idle(200.0, 0).ttl_s, 600.0);
    }

    #[test]
    fn bursty_gaps_shrink_the_ttl_to_the_tail_percentile() {
        let mut p = HistogramKeepAlive::new(600.0);
        // 20 arrivals 10 s apart: every gap lands in bin 1 (edge 20 s)
        for i in 0..20 {
            p.observe_arrival(i as f64 * 10.0, 0);
        }
        let d = p.on_idle(200.0, 0);
        assert_eq!(d.prewarm_at, None);
        assert!((d.ttl_s - 30.0).abs() < 1e-9, "p99 edge 20 + one bin slack: {}", d.ttl_s);
        assert!(d.ttl_s < 600.0, "bursty functions must not idle for the fixed default");
    }

    #[test]
    fn tail_ttl_never_exceeds_the_fixed_default() {
        let mut p = HistogramKeepAlive::new(40.0);
        for i in 0..20 {
            // gaps of 40 s: head edge 50 stays under the pre-warm cutoff
            p.observe_arrival(i as f64 * 40.0, 0);
        }
        let d = p.on_idle(800.0, 0);
        assert_eq!(d.prewarm_at, None);
        assert!(d.ttl_s <= 40.0, "clamped to the default: {}", d.ttl_s);
    }

    #[test]
    fn long_predictable_gaps_switch_to_evict_then_prewarm() {
        let mut p = HistogramKeepAlive::new(600.0);
        // gaps of 120 s: head percentile edge = 130, well past the cutoff
        for i in 0..12 {
            p.observe_arrival(i as f64 * 120.0, 0); // last arrival: 1320
        }
        let d = p.on_idle(1320.0, 0);
        assert_eq!(d.ttl_s, GRACE_TTL_S, "give the container up after the grace window");
        let at = d.prewarm_at.expect("long gaps must request a pre-warm");
        // anchored at the last arrival: lower bin edge (120) minus lead
        assert!((at - (1320.0 + 120.0 - PREWARM_LEAD_S)).abs() < 1e-9, "prewarm at {at}");
        assert!(at > 1320.0, "pre-warm is in the future");
    }

    #[test]
    fn prewarm_is_anchored_at_the_last_arrival_not_the_idle_transition() {
        let mut p = HistogramKeepAlive::new(600.0);
        for i in 0..12 {
            p.observe_arrival(i as f64 * 120.0, 0); // last arrival: 1320
        }
        // 60 s of execution: the container idles at 1380, but the next
        // arrival is still predicted at ~1440 — the pre-warm must target
        // 1320 + 120 - lead, not 1380 + 120 - lead
        let d = p.on_idle(1380.0, 0);
        let at = d.prewarm_at.expect("still worth pre-warming");
        assert!((at - (1320.0 + 120.0 - PREWARM_LEAD_S)).abs() < 1e-9, "prewarm at {at}");
        // 110 s of execution: the expected arrival (~1440) lands inside
        // the grace window — evict-then-pre-warm saves nothing, so the
        // policy holds the container through the predicted arrival
        let d = p.on_idle(1430.0, 0);
        assert_eq!(d.prewarm_at, None, "too close to evict-and-replace");
        assert!(
            d.ttl_s >= GRACE_TTL_S && 1430.0 + d.ttl_s >= 1440.0,
            "must hold through the expected arrival: ttl {}",
            d.ttl_s
        );
    }

    #[test]
    fn hold_through_ttl_is_not_capped_by_a_small_fallback_override() {
        // histogram:40 — the fallback TTL caps the *bursty* branch, but
        // must not cut the hold-through branch short of the predicted
        // arrival it exists to cover
        let mut p = HistogramKeepAlive::new(40.0);
        for i in 0..12 {
            p.observe_arrival(i as f64 * 120.0, 0); // last arrival: 1320
        }
        // execution ate 80 s of the gap: expected arrival by 1450
        let d = p.on_idle(1400.0, 0);
        assert_eq!(d.prewarm_at, None);
        assert!(
            1400.0 + d.ttl_s >= 1450.0,
            "must hold through the predicted arrival: ttl {}",
            d.ttl_s
        );
    }

    #[test]
    fn prewarm_timing_constants_are_mutually_consistent() {
        // engine::launch_container clamps cold-start latency to <= 10 s;
        // the lead must exceed that or pre-warms can land late by design
        assert!(PREWARM_LEAD_S > 10.0);
        // and the grace TTL must outlast the lead, or a pre-warmed
        // container would be grace-evicted before its predicted arrival
        assert!(GRACE_TTL_S > PREWARM_LEAD_S);
    }

    #[test]
    fn histograms_are_per_function() {
        let mut p = HistogramKeepAlive::new(600.0);
        for i in 0..20 {
            p.observe_arrival(i as f64 * 10.0, 0); // func 0: bursty
        }
        assert!(p.on_idle(200.0, 0).ttl_s < 600.0);
        // func 7 has no history: fixed fallback
        assert_eq!(p.on_idle(200.0, 7).ttl_s, 600.0);
    }

    #[test]
    fn percentile_edges_are_monotone_and_overflow_safe() {
        let mut h = FuncHist::default();
        h.observe(5.0);
        h.observe(15.0);
        h.observe(1e9); // overflow bin
        assert_eq!(h.percentile_edge(0.05), 10.0);
        assert_eq!(h.percentile_edge(0.5), 20.0);
        assert_eq!(h.percentile_edge(0.99), NBINS as f64 * BIN_S);
        assert!(h.percentile_edge(0.05) <= h.percentile_edge(0.99));
    }
}
