//! Demand-driven keep-alive: fixed TTL plus LRU eviction under
//! admission pressure.
//!
//! Two semantic switches distinguish this from `fixed`:
//!
//! * **idle containers hold their reservation** ([`KeepAlivePolicy::
//!   idle_reserves`]): like OpenWhisk's memory slots, a warm container
//!   occupies capacity until it is evicted, so hoarded warmth is
//!   visible to admission instead of free;
//! * **queued demand evicts** ([`KeepAlivePolicy::demand_driven`]):
//!   when an admission bind parks on a worker's FIFO queue and evicting
//!   idle containers would free enough vCPU/memory, the engine evicts
//!   the least-recently-used idle containers — lowest
//!   `(idle_since, container id)` first, `Starting`/`Busy` containers
//!   are never touched — until the queued head admits immediately
//!   (`Engine::pressure_evict_for`).
//!
//! The TTL itself stays fixed (`SimConfig::keep_alive_s`, or the
//! `pressure:<secs>` override): pressure changes *who wins* when warmth
//! and demand collide, not the idle horizon.

use super::{IdleDecision, KeepAlivePolicy};
use crate::simulator::SimTime;

#[derive(Debug)]
pub struct PressureKeepAlive {
    ttl_s: f64,
}

impl PressureKeepAlive {
    pub fn new(ttl_s: f64) -> Self {
        PressureKeepAlive { ttl_s }
    }
}

impl KeepAlivePolicy for PressureKeepAlive {
    fn name(&self) -> &'static str {
        "pressure"
    }

    fn on_idle(&mut self, _now: SimTime, _func: usize) -> IdleDecision {
        IdleDecision { ttl_s: self.ttl_s, prewarm_at: None }
    }

    fn idle_reserves(&self) -> bool {
        true
    }

    fn demand_driven(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttl_is_fixed_and_pressure_flags_are_set() {
        let mut p = PressureKeepAlive::new(300.0);
        assert_eq!(p.on_idle(7.0, 2), IdleDecision { ttl_s: 300.0, prewarm_at: None });
        assert_eq!(p.on_idle(900.0, 5).ttl_s, 300.0, "TTL does not drift over time");
        assert!(p.idle_reserves(), "idle warmth must occupy capacity to be evictable");
        assert!(p.demand_driven());
    }
}
