//! Pluggable keep-alive & demand-driven eviction policies (ISSUE 5 /
//! DESIGN.md §KeepAlive).
//!
//! "How long to keep a warm container" was a single fixed TTL
//! (`SimConfig::keep_alive_s`) baked into the engine; this module makes
//! it an independently testable axis, orthogonal to "where to run
//! invocations" (the scheduling [`Policy`](crate::simulator::Policy)).
//! A [`KeepAlivePolicy`] decides, per idle transition, when the
//! container should be evicted ([`IdleDecision`]) and whether queued
//! admission demand may reclaim idle containers early (`pressure`).
//!
//! Three registered policies (`--keepalive` on every subcommand):
//!
//! * `fixed[:secs]` — the legacy behavior: one TTL for everything. With
//!   the default 600 s this reproduces the pre-subsystem record streams
//!   byte-for-byte (same events, same order, no extra RNG draws).
//! * `histogram[:secs]` — Serverless-in-the-Wild–style per-function
//!   inter-arrival histograms: short keep-alive for bursty functions
//!   (the tail percentile), evict-then-pre-warm for predictable
//!   long-gap functions. `:secs` overrides the fallback TTL used while
//!   a function's history is still cold.
//! * `pressure[:secs]` — fixed TTL, but idle containers *hold their
//!   reservation* (OpenWhisk memory-slot semantics) and yield to queued
//!   demand: when an admission bind parks and evicting idle containers
//!   (least-recently-used first) would free enough vCPU/memory, the
//!   engine evicts exactly enough of them so the queued head admits
//!   immediately.
//!
//! The policy object is stateful (histograms accumulate over a run) and
//! engine-owned: [`build`] constructs one per simulation, so state is
//! rebuilt deterministically from the run itself and sweep cells stay
//! independent.

use anyhow::{bail, ensure, Result};

use super::{SimConfig, SimTime};

pub mod histogram;
pub mod pressure;

pub use histogram::HistogramKeepAlive;
pub use pressure::PressureKeepAlive;

/// Which keep-alive policy the engine instantiates. Rides in
/// [`SimConfig`] (which must stay `Clone`); the stateful policy object
/// itself is built per run by [`build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KeepAliveMode {
    /// Legacy fixed TTL (`SimConfig::keep_alive_s`).
    #[default]
    Fixed,
    /// Per-function idle-time histograms + pre-warm window.
    Histogram,
    /// Fixed TTL + reservation-holding idle + demand-driven eviction.
    Pressure,
}

impl KeepAliveMode {
    /// Registry name of the mode (trace metadata, display).
    pub fn label(self) -> &'static str {
        match self {
            KeepAliveMode::Fixed => "fixed",
            KeepAliveMode::Histogram => "histogram",
            KeepAliveMode::Pressure => "pressure",
        }
    }
}

/// Parsed `--keepalive` value: a mode plus an optional TTL override.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct KeepAliveSpec {
    pub mode: KeepAliveMode,
    /// Overrides `SimConfig::keep_alive_s` when set (`fixed:<secs>`,
    /// `pressure:<secs>`, `histogram:<secs>` for the fallback TTL).
    pub ttl_s: Option<f64>,
}

impl KeepAliveSpec {
    /// Imprint this spec on a config (the `experiments::common::sim_config`
    /// hook).
    pub fn apply(&self, cfg: &mut SimConfig) {
        cfg.keepalive = self.mode;
        if let Some(t) = self.ttl_s {
            cfg.keep_alive_s = t;
        }
    }

    /// Canonical display name (`fixed:600`-style when a TTL is set).
    pub fn label(&self) -> String {
        let base = self.mode.label();
        match self.ttl_s {
            Some(t) => format!("{base}:{t}"),
            None => base.to_string(),
        }
    }
}

/// Registered policy names (`shabari list`, CLI errors).
pub const KEEPALIVES: &[&str] = &["fixed", "histogram", "pressure"];

/// Parse a `--keepalive` value: `fixed`, `fixed:<secs>`, `histogram`,
/// `histogram:<secs>`, `pressure`, `pressure:<secs>`.
pub fn parse(name: &str) -> Result<KeepAliveSpec> {
    let (base, ttl_s) = match name.split_once(':') {
        Some((b, t)) => {
            let secs: f64 = t.parse().map_err(|_| {
                anyhow::anyhow!("--keepalive {b}:<secs> expects a number, got '{t}'")
            })?;
            ensure!(
                secs.is_finite() && secs > 0.0,
                "--keepalive {b}:<secs> expects a positive TTL, got {secs}"
            );
            (b, Some(secs))
        }
        None => (name, None),
    };
    let mode = match base {
        "fixed" => KeepAliveMode::Fixed,
        "histogram" => KeepAliveMode::Histogram,
        "pressure" => KeepAliveMode::Pressure,
        other => bail!(
            "unknown keep-alive policy '{other}' \
             (known: {KEEPALIVES:?}, each optionally ':<secs>')"
        ),
    };
    Ok(KeepAliveSpec { mode, ttl_s })
}

/// What to do with a container that just went idle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IdleDecision {
    /// Evict after this many idle seconds (the TTL; the engine stamps
    /// `now + ttl_s` on the container as its eviction deadline).
    pub ttl_s: f64,
    /// Optionally launch a fresh same-size container on the same worker
    /// at this absolute time (the hybrid-histogram pre-warm covering
    /// the warmth a short TTL gives up). The engine stamps this on the
    /// container and fires it only if the TTL expiry *actually evicts*
    /// it — a reuse during the TTL window cancels the pre-warm along
    /// with the stale eviction.
    pub prewarm_at: Option<SimTime>,
}

/// A keep-alive policy: per idle transition, an eviction deadline (and
/// optional pre-warm); globally, whether idle containers hold
/// reservations and whether queued demand may evict them. Fed
/// observations through the hooks so per-function state (histograms) is
/// rebuilt deterministically from each run.
pub trait KeepAlivePolicy {
    fn name(&self) -> &'static str;

    /// A container of `func` went idle at `now`: decide its TTL and any
    /// pre-warm. Called once per idle transition (background-ready and
    /// release-after-completion both funnel through the engine's
    /// `schedule_idle_evict`).
    fn on_idle(&mut self, now: SimTime, func: usize) -> IdleDecision;

    /// Observe a request arrival (feeds per-function inter-arrival
    /// histograms). Called for every arrival, before routing.
    fn observe_arrival(&mut self, _now: SimTime, _func: usize) {}

    /// Idle containers keep holding their `(vcpus, mem)` reservation
    /// (OpenWhisk-like memory-slot semantics). The single source of
    /// truth: `Worker::new` reads this off `build(cfg)` for its
    /// accounting switch, and the engine's admission predicate consults
    /// its own instance — both see the same impl.
    fn idle_reserves(&self) -> bool {
        false
    }

    /// Queued admissions may evict idle containers (LRU) to free
    /// capacity. Only meaningful together with `idle_reserves` (idle
    /// containers that reserve nothing free nothing).
    fn demand_driven(&self) -> bool {
        false
    }
}

/// Legacy behavior: one fixed TTL for every container, no pre-warm, no
/// demand-driven eviction. Byte-identical streams to the pre-subsystem
/// engine when the TTL matches `SimConfig::keep_alive_s`.
#[derive(Debug)]
pub struct FixedKeepAlive {
    ttl_s: f64,
}

impl FixedKeepAlive {
    pub fn new(ttl_s: f64) -> Self {
        FixedKeepAlive { ttl_s }
    }
}

impl KeepAlivePolicy for FixedKeepAlive {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn on_idle(&mut self, _now: SimTime, _func: usize) -> IdleDecision {
        IdleDecision { ttl_s: self.ttl_s, prewarm_at: None }
    }
}

/// Build the policy a config asks for (one instance per run).
pub fn build(cfg: &SimConfig) -> Box<dyn KeepAlivePolicy> {
    match cfg.keepalive {
        KeepAliveMode::Fixed => Box::new(FixedKeepAlive::new(cfg.keep_alive_s)),
        KeepAliveMode::Histogram => Box::new(HistogramKeepAlive::new(cfg.keep_alive_s)),
        KeepAliveMode::Pressure => Box::new(PressureKeepAlive::new(cfg.keep_alive_s)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_registered_names() {
        for name in KEEPALIVES {
            let spec = parse(name).unwrap();
            assert_eq!(spec.ttl_s, None);
            assert_eq!(spec.label(), *name);
        }
    }

    #[test]
    fn parse_ttl_suffix_and_label_round_trip() {
        let spec = parse("fixed:600").unwrap();
        assert_eq!(spec.mode, KeepAliveMode::Fixed);
        assert_eq!(spec.ttl_s, Some(600.0));
        assert_eq!(spec.label(), "fixed:600");
        assert_eq!(parse("pressure:90").unwrap().mode, KeepAliveMode::Pressure);
        assert_eq!(parse("histogram:120").unwrap().ttl_s, Some(120.0));
    }

    #[test]
    fn parse_rejects_unknown_and_malformed() {
        assert!(parse("nope").is_err());
        assert!(parse("fixed:abc").is_err());
        assert!(parse("fixed:-5").is_err());
        assert!(parse("fixed:0").is_err());
        let msg = format!("{:#}", parse("nope").unwrap_err());
        assert!(msg.contains("fixed"), "error must list known names: {msg}");
    }

    #[test]
    fn spec_applies_mode_and_ttl_to_config() {
        let mut cfg = SimConfig::default();
        parse("pressure:90").unwrap().apply(&mut cfg);
        assert_eq!(cfg.keepalive, KeepAliveMode::Pressure);
        assert_eq!(cfg.keep_alive_s, 90.0);
        // no TTL suffix leaves the config's TTL untouched
        let mut cfg = SimConfig::default();
        parse("histogram").unwrap().apply(&mut cfg);
        assert_eq!(cfg.keepalive, KeepAliveMode::Histogram);
        assert_eq!(cfg.keep_alive_s, 600.0);
    }

    #[test]
    fn default_spec_is_the_legacy_fixed_ttl() {
        let mut cfg = SimConfig::default();
        let before = cfg.clone();
        KeepAliveSpec::default().apply(&mut cfg);
        assert_eq!(cfg.keepalive, KeepAliveMode::Fixed);
        assert_eq!(cfg.keep_alive_s, before.keep_alive_s);
    }

    #[test]
    fn built_policies_have_coherent_semantic_flags() {
        for mode in [KeepAliveMode::Fixed, KeepAliveMode::Histogram, KeepAliveMode::Pressure] {
            let cfg = SimConfig { keepalive: mode, ..SimConfig::default() };
            let p = build(&cfg);
            // only pressure runs with reservation-holding idle containers
            assert_eq!(p.idle_reserves(), mode == KeepAliveMode::Pressure, "{}", p.name());
            // demand-driven eviction without reservation-holding idle
            // containers would evict warmth that frees nothing
            assert!(!p.demand_driven() || p.idle_reserves(), "{}", p.name());
        }
    }

    #[test]
    fn fixed_policy_returns_the_configured_ttl() {
        let mut p = FixedKeepAlive::new(600.0);
        let d = p.on_idle(12.5, 3);
        assert_eq!(d, IdleDecision { ttl_s: 600.0, prewarm_at: None });
        assert!(!p.idle_reserves() && !p.demand_driven());
    }
}
