//! Fifer-style proactive cluster autoscaling (ISSUE 10): an orthogonal
//! capacity axis next to scheduling (`Policy`), retention
//! (`KeepAlivePolicy`), and cluster dynamics (`FaultsSpec`). Fifer
//! (Gunasekaran et al., PAPERS.md) shows that surviving hour-long
//! replays of real traces at realistic rates needs a cluster that grows
//! and shrinks with load, not a fixed worker count; this module adds an
//! **extension pool** of workers above the configured base that the
//! engine provisions and drains on queue-depth/utilization signals.
//!
//! Determinism contract (DESIGN.md §Scaler):
//!
//! * the scaler evaluates on a fixed cadence ([`SCALER_TICK_S`]) as
//!   ordinary timestamped heap events — same-timestamp ties resolve by
//!   push order (the PR 3 sequence-number contract), and every scaling
//!   action names its worker id;
//! * provisioning delays come from one `seed ^ SALT_SCALER` stream,
//!   disjoint from the engine/trace/policy/fault streams, so enabling
//!   the scaler never perturbs a pre-existing draw;
//! * `scaler:none` (the default) builds no state: zero extra events,
//!   zero extra draws, byte-identical streams to a build without this
//!   module (pinned in `rust/tests/test_determinism.rs`).
//!
//! Divergence from Fifer: Fifer scales *per-function container pools*
//! behind a load balancer with an LSTM load predictor; here the unit is
//! the whole worker (the simulator's capacity grain), the signal is the
//! current queue/utilization reading (reactive, no predictor), and the
//! base pool is never drained — so `--scaler fifer` captures Fifer's
//! headroom-driven proactive growth, not its ML forecasting.
//!
//! Parsed from `--scaler none|fifer[:headroom]` exactly like `--faults`
//! (registry in [`SCALERS`], parser in [`parse`]).

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;

use super::SimConfig;

/// Seconds between scaler evaluations of the cluster signals.
pub const SCALER_TICK_S: f64 = 5.0;

/// Default utilization target: scale up when allocated vCPUs exceed this
/// fraction of the serving pool's scheduler limit (Fifer's headroom
/// knob). Override with `fifer:<headroom>`.
pub const DEFAULT_HEADROOM: f64 = 0.7;

/// Scale-down hysteresis: drain only when utilization falls below
/// `headroom * DOWN_FRACTION` (and nothing is queued or provisioning),
/// so the pool does not thrash around the threshold.
pub const DOWN_FRACTION: f64 = 0.5;

/// Extension-pool cap: the cluster never grows past this multiple of
/// the configured base worker count.
pub const MAX_SCALE_FACTOR: usize = 4;

/// Mean worker provisioning (boot) delay in seconds — the cost Fifer's
/// proactive growth exists to hide (VM/worker bring-up is seconds-to-
/// minutes in the serverless fleets the paper measures).
pub const BOOT_MEAN_S: f64 = 8.0;

/// Lognormal sigma of the provisioning delay.
pub const BOOT_SIGMA: f64 = 0.35;

/// Salt for the scaler's provisioning-delay stream, decorrelated from
/// the engine/workload/fault streams off the same seed (lint D006
/// registry; pairwise-distinct from every other salt).
pub const SALT_SCALER: u64 = 0x5CA1_E550;

/// Which scaling profile a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScalerMode {
    /// No scaling — the fixed-size pre-ISSUE-10 cluster.
    #[default]
    None,
    /// Fifer-style reactive headroom scaling of an extension pool.
    Fifer,
}

/// Parsed `--scaler` selection: mode plus its optional headroom target.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ScalerSpec {
    pub mode: ScalerMode,
    /// Utilization threshold for scale-up (`DEFAULT_HEADROOM` if unset).
    pub headroom: Option<f64>,
}

/// One scaling action in the run's timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// An extension worker began provisioning (down until `Ready`).
    Provision,
    /// The provisioned worker finished booting and joined the pool.
    Ready,
    /// An idle extension worker was drained out of the pool.
    Drain,
}

impl ScaleAction {
    /// Stable lowercase label for reports/JSON.
    pub fn label(&self) -> &'static str {
        match self {
            ScaleAction::Provision => "provision",
            ScaleAction::Ready => "ready",
            ScaleAction::Drain => "drain",
        }
    }
}

/// One entry of the scaling timeline (`SimResult::scaling`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    pub at: f64,
    pub worker: usize,
    pub action: ScaleAction,
    /// Serving (up) workers after this action took effect.
    pub up_workers: usize,
}

/// What the scaler wants to do at one tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    Up,
    Down,
}

/// Live scaler state for one run, built by [`ScalerSpec::build`]
/// (`None` under `scaler:none`: zero events, zero draws).
#[derive(Debug)]
pub struct ClusterScaler {
    rng: Rng,
    pub headroom: f64,
    /// Workers `0..base_workers` are the configured pool — never drained.
    pub base_workers: usize,
    /// Hard cap on the total pool (base × [`MAX_SCALE_FACTOR`]).
    pub max_workers: usize,
    /// Last instant the tick cadence covers (last arrival + timeout).
    pub horizon_s: f64,
    /// Extension workers currently provisioning (down until their
    /// `ScalerReady` fires).
    pub provisioning: std::collections::BTreeSet<usize>,
    /// The scaling timeline, in event order.
    pub scaling: Vec<ScaleEvent>,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Most workers ever serving at once.
    pub peak_up_workers: usize,
}

impl ClusterScaler {
    /// Fifer-style signals over the *serving* pool: grow when demand is
    /// parked on admission queues or utilization runs past the headroom
    /// target (and the cap allows); shrink — with hysteresis, and never
    /// while a boot is in flight — when the queue is empty and
    /// utilization sits below `headroom * DOWN_FRACTION`.
    pub fn evaluate(&self, queued: usize, utilization: f64, up_workers: usize) -> ScaleDecision {
        let pool = up_workers + self.provisioning.len();
        if (queued > 0 || utilization > self.headroom) && pool < self.max_workers {
            return ScaleDecision::Up;
        }
        if queued == 0
            && utilization < self.headroom * DOWN_FRACTION
            && self.provisioning.is_empty()
            && up_workers > self.base_workers
        {
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }

    /// Draw one provisioning delay from the scaler's own stream.
    pub fn boot_delay(&mut self) -> f64 {
        self.rng.lognormal(BOOT_MEAN_S.ln(), BOOT_SIGMA).clamp(1.0, 60.0)
    }
}

impl ScalerSpec {
    /// Write this spec into a sim config (mirrors `FaultsSpec::apply`).
    pub fn apply(&self, cfg: &mut SimConfig) {
        cfg.scaler = *self;
    }

    /// Canonical registry-style label, e.g. `fifer:0.5`.
    pub fn label(&self) -> String {
        let name = match self.mode {
            ScalerMode::None => "none",
            ScalerMode::Fifer => "fifer",
        };
        match self.headroom {
            Some(h) => format!("{name}:{h}"),
            None => name.to_string(),
        }
    }

    /// Build the live state for one run. `scaler:none` returns `None` —
    /// the engine then pushes no ticks and draws nothing, keeping its
    /// streams byte-identical to a build without the scaler.
    pub fn build(&self, base_workers: usize, horizon_s: f64, seed: u64) -> Option<ClusterScaler> {
        match self.mode {
            ScalerMode::None => None,
            ScalerMode::Fifer => Some(ClusterScaler {
                rng: Rng::new(seed ^ SALT_SCALER),
                headroom: self.headroom.unwrap_or(DEFAULT_HEADROOM),
                base_workers,
                max_workers: base_workers.max(1) * MAX_SCALE_FACTOR,
                horizon_s,
                provisioning: std::collections::BTreeSet::new(),
                scaling: Vec::new(),
                scale_ups: 0,
                scale_downs: 0,
                peak_up_workers: base_workers,
            }),
        }
    }
}

/// All registered scaler names (shown by `list`; the parametric form
/// `fifer:<headroom>` is accepted too).
pub const SCALERS: &[&str] = &["none", "fifer"];

/// Parse a `--scaler` value (mirrors `faults::parse`).
pub fn parse(name: &str) -> Result<ScalerSpec> {
    let (mode, param) = match name.split_once(':') {
        Some((m, p)) => (m, Some(p)),
        None => (name, None),
    };
    let headroom = match param {
        None => None,
        Some(p) => {
            let h: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("--scaler {mode}: bad headroom '{p}'"))?;
            Some(h)
        }
    };
    let spec = match mode {
        "none" => {
            ensure!(headroom.is_none(), "scaler 'none' takes no parameter");
            ScalerSpec { mode: ScalerMode::None, headroom: None }
        }
        "fifer" => {
            if let Some(h) = headroom {
                ensure!(
                    h.is_finite() && h > 0.0 && h <= 1.0,
                    "--scaler fifer: headroom must be in (0, 1], got {h}"
                );
            }
            ScalerSpec { mode: ScalerMode::Fifer, headroom }
        }
        other => bail!("unknown scaler '{other}' (known: {SCALERS:?}, or 'fifer:<headroom>')"),
    };
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_registered_names() {
        for name in SCALERS {
            let spec = parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.label(), *name);
        }
    }

    #[test]
    fn parse_headroom_suffix_and_label_round_trip() {
        let s = parse("fifer:0.5").unwrap();
        assert_eq!(s.mode, ScalerMode::Fifer);
        assert_eq!(s.headroom, Some(0.5));
        assert_eq!(s.label(), "fifer:0.5");
    }

    #[test]
    fn parse_rejects_unknown_and_malformed() {
        assert!(parse("autoscale").is_err());
        assert!(parse("fifer:abc").is_err());
        assert!(parse("fifer:0").is_err());
        assert!(parse("fifer:-0.5").is_err());
        assert!(parse("fifer:1.5").is_err());
        assert!(parse("none:0.5").is_err());
    }

    #[test]
    fn spec_applies_to_config() {
        let mut cfg = SimConfig::default();
        assert_eq!(cfg.scaler.mode, ScalerMode::None);
        parse("fifer:0.6").unwrap().apply(&mut cfg);
        assert_eq!(cfg.scaler.mode, ScalerMode::Fifer);
        assert_eq!(cfg.scaler.headroom, Some(0.6));
    }

    #[test]
    fn none_builds_no_state() {
        assert!(ScalerSpec::default().build(8, 600.0, 42).is_none());
    }

    #[test]
    fn fifer_state_defaults_and_caps() {
        let s = parse("fifer").unwrap().build(4, 600.0, 42).unwrap();
        assert_eq!(s.headroom, DEFAULT_HEADROOM);
        assert_eq!(s.base_workers, 4);
        assert_eq!(s.max_workers, 16);
        assert_eq!(s.peak_up_workers, 4);
        assert!(s.scaling.is_empty());
    }

    #[test]
    fn evaluate_signals() {
        let mut s = parse("fifer:0.5").unwrap().build(4, 600.0, 1).unwrap();
        // queued demand -> up, regardless of utilization
        assert_eq!(s.evaluate(3, 0.1, 4), ScaleDecision::Up);
        // hot pool -> up
        assert_eq!(s.evaluate(0, 0.8, 4), ScaleDecision::Up);
        // between the thresholds -> hold
        assert_eq!(s.evaluate(0, 0.4, 5), ScaleDecision::Hold);
        // cold pool with extension workers -> down
        assert_eq!(s.evaluate(0, 0.1, 5), ScaleDecision::Down);
        // cold pool at base size -> hold (the base is never drained)
        assert_eq!(s.evaluate(0, 0.1, 4), ScaleDecision::Hold);
        // at the cap -> hold even under pressure
        assert_eq!(s.evaluate(9, 0.9, 16), ScaleDecision::Hold);
        // a boot in flight suppresses scale-down
        s.provisioning.insert(5);
        assert_eq!(s.evaluate(0, 0.1, 5), ScaleDecision::Hold);
        // and counts toward the cap
        for w in 6..16 {
            s.provisioning.insert(w);
        }
        assert_eq!(s.evaluate(9, 0.9, 5), ScaleDecision::Hold);
    }

    #[test]
    fn boot_delays_are_deterministic_and_bounded() {
        let mut a = parse("fifer").unwrap().build(4, 600.0, 7).unwrap();
        let mut b = parse("fifer").unwrap().build(4, 600.0, 7).unwrap();
        for _ in 0..32 {
            let d = a.boot_delay();
            assert_eq!(d, b.boot_delay());
            assert!((1.0..=60.0).contains(&d), "delay {d}");
        }
        // a different seed samples a different stream
        let mut c = parse("fifer").unwrap().build(4, 600.0, 8).unwrap();
        assert_ne!(a.boot_delay(), c.boot_delay());
    }
}
