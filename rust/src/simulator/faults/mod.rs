//! Deterministic fault injection (ISSUE 6): an orthogonal cluster-dynamics
//! axis next to scheduling (`Policy`) and retention (`KeepAlivePolicy`).
//! All five workload scenarios vary *arrivals* only; this module makes the
//! cluster itself adversarial — worker crash/restart cycles, straggler
//! (slowed) workers, and heterogeneous capacity classes — while preserving
//! every determinism contract:
//!
//! * the whole fault schedule is derived up front from
//!   `seed ^ <per-axis salt>` RNG streams ([`FaultsSpec::plan`]), disjoint
//!   from the engine/trace/policy streams, so enabling faults never
//!   perturbs a single pre-existing draw;
//! * crash/restart events enter the ordinary discrete-event heap as
//!   timestamped events (sorted by `(at, worker)` before pushing, so the
//!   sequence-number tie-break is the worker id — the PR 3 contract);
//! * `faults:none` (the default) builds an empty plan: zero extra events,
//!   zero extra draws, byte-identical streams to a build without this
//!   module (pinned in `rust/tests/test_determinism.rs`).
//!
//! Parsed from `--faults <name>` exactly like `--keepalive` (DESIGN.md
//! §Faults; registry in [`FAULTS`], parser in [`parse`]).

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;

use super::SimConfig;

/// Mean time between crashes per worker (seconds of simulated time).
/// Deliberately short relative to the 600 s experiment window so every
/// adversity replicate actually exercises the crash path.
pub const CRASH_MTBF_S: f64 = 120.0;

/// Downtime between a crash and the worker's restart (override with
/// `crash:<secs>` / `chaos:<secs>`).
pub const DEFAULT_DOWNTIME_S: f64 = 60.0;

/// Speed multiplier stragglers run at (override with `stragglers:<factor>`).
pub const DEFAULT_STRAGGLER_FACTOR: f64 = 0.5;

/// Fraction of workers turned into stragglers (ceil, so a 1-worker
/// cluster still gets one).
pub const STRAGGLER_FRACTION: f64 = 0.25;

/// Capacity classes cycled across workers under `hetero`: full-size,
/// half, quarter (scales `physical_cores`, `sched_vcpu_limit`, `mem_gb`).
/// Worker 0 always keeps the full testbed shape.
pub const HETERO_SCALE: &[f64] = &[1.0, 0.5, 0.25];

const SALT_CRASH: u64 = 0xC4A5_4ED1;
const SALT_STRAGGLER: u64 = 0x57A6_61E4;

/// Which fault profile a run injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultsMode {
    /// No faults — the pre-ISSUE-6 immortal, uniform cluster.
    #[default]
    None,
    /// Seed-derived worker crash/restart cycles.
    Crash,
    /// A fixed fraction of workers run slowed by a speed factor.
    Stragglers,
    /// Mixed worker capacity classes (uniform limits scaled per worker).
    Hetero,
    /// All three at once.
    Chaos,
}

/// Parsed `--faults` selection: mode plus its optional numeric parameter
/// (crash/chaos: downtime seconds; stragglers: speed factor).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultsSpec {
    pub mode: FaultsMode,
    pub param: Option<f64>,
}

/// One crash/restart cycle for one worker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashEvent {
    pub at: f64,
    pub restart_at: f64,
    pub worker: usize,
}

/// The fully materialized fault schedule for one run: computed once at
/// engine construction, then replayed as ordinary events.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Crash cycles sorted by `(at, worker)` — push order is the
    /// same-timestamp tie-break.
    pub crashes: Vec<CrashEvent>,
    /// Per-worker execution speed multiplier (1.0 = nominal).
    pub speed: Vec<f64>,
    /// Per-worker capacity scale on cores/vCPU-limit/memory (1.0 = uniform).
    pub capacity_scale: Vec<f64>,
}

impl FaultPlan {
    fn uniform(workers: usize) -> Self {
        FaultPlan {
            crashes: Vec::new(),
            speed: vec![1.0; workers],
            capacity_scale: vec![1.0; workers],
        }
    }

    /// The slowest configured worker speed (1.0 when no stragglers) —
    /// surfaced as `RunMetrics::straggler_slowdown`.
    pub fn slowest_speed(&self) -> f64 {
        self.speed.iter().copied().fold(1.0, f64::min)
    }

    /// One-line plan summary for trace metadata (DESIGN.md
    /// §Observability), e.g. `7 crash cycles, slowest speed 0.5,
    /// smallest capacity 0.25`.
    pub fn describe(&self) -> String {
        let stragglers = self.speed.iter().filter(|s| **s < 1.0).count();
        let smallest = self.capacity_scale.iter().copied().fold(1.0, f64::min);
        format!(
            "{} crash cycles, {} stragglers, slowest speed {}, smallest capacity {}",
            self.crashes.len(),
            stragglers,
            self.slowest_speed(),
            smallest
        )
    }
}

impl FaultsSpec {
    /// Write this spec into a sim config (mirrors `KeepAliveSpec::apply`).
    pub fn apply(&self, cfg: &mut SimConfig) {
        cfg.faults = *self;
    }

    /// Canonical registry-style label, e.g. `crash:30`.
    pub fn label(&self) -> String {
        let name = match self.mode {
            FaultsMode::None => "none",
            FaultsMode::Crash => "crash",
            FaultsMode::Stragglers => "stragglers",
            FaultsMode::Hetero => "hetero",
            FaultsMode::Chaos => "chaos",
        };
        match self.param {
            Some(p) => format!("{name}:{p}"),
            None => name.to_string(),
        }
    }

    /// Materialize the schedule for `workers` workers over `[0, horizon_s]`.
    ///
    /// Per-worker crash streams are independent forks of one
    /// `seed ^ SALT_CRASH` RNG taken in ascending worker id, so the plan is
    /// identical on any thread and a *prefix* of the plan for any larger
    /// horizon — tests may call `plan` with a big horizon to learn exact
    /// crash times and build workloads around them. The first crash lands
    /// in `[0.25, 0.75] × MTBF`, guaranteeing at least one crash per
    /// worker whenever the horizon covers the window.
    pub fn plan(&self, workers: usize, horizon_s: f64, seed: u64) -> FaultPlan {
        let mut plan = FaultPlan::uniform(workers);
        let crash = matches!(self.mode, FaultsMode::Crash | FaultsMode::Chaos);
        let straggle = matches!(self.mode, FaultsMode::Stragglers | FaultsMode::Chaos);
        let hetero = matches!(self.mode, FaultsMode::Hetero | FaultsMode::Chaos);

        if crash {
            let downtime = self.param.unwrap_or(DEFAULT_DOWNTIME_S);
            let mut rng = Rng::new(seed ^ SALT_CRASH);
            for w in 0..workers {
                let mut wr = rng.fork(w as u64);
                let mut t = CRASH_MTBF_S * wr.range_f64(0.25, 0.75);
                while t < horizon_s {
                    plan.crashes.push(CrashEvent { at: t, restart_at: t + downtime, worker: w });
                    t += downtime + CRASH_MTBF_S * wr.range_f64(0.5, 1.5);
                }
            }
            plan.crashes
                .sort_by(|a, b| a.at.total_cmp(&b.at).then_with(|| a.worker.cmp(&b.worker)));
        }
        if straggle {
            let factor = match self.mode {
                // chaos's param is the crash downtime; stragglers keep the default
                FaultsMode::Stragglers => self.param.unwrap_or(DEFAULT_STRAGGLER_FACTOR),
                _ => DEFAULT_STRAGGLER_FACTOR,
            };
            let mut rng = Rng::new(seed ^ SALT_STRAGGLER);
            let k = ((workers as f64) * STRAGGLER_FRACTION).ceil().max(1.0) as usize;
            let mut ids: Vec<usize> = (0..workers).collect();
            rng.shuffle(&mut ids);
            for &w in ids.iter().take(k.min(workers)) {
                plan.speed[w] = factor;
            }
        }
        if hetero {
            for w in 0..workers {
                plan.capacity_scale[w] = HETERO_SCALE[w % HETERO_SCALE.len()];
            }
        }
        plan
    }
}

/// All registered fault-profile names (shown by `list`; parametric forms
/// `crash:<downtime_s>`, `stragglers:<factor>`, `chaos:<downtime_s>` are
/// accepted too).
pub const FAULTS: &[&str] = &["none", "crash", "stragglers", "hetero", "chaos"];

/// Parse a `--faults` value (mirrors `keepalive::parse`).
pub fn parse(name: &str) -> Result<FaultsSpec> {
    let (mode, param) = match name.split_once(':') {
        Some((m, p)) => (m, Some(p)),
        None => (name, None),
    };
    let param = match param {
        None => None,
        Some(p) => {
            let v: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("--faults {mode}: bad parameter '{p}'"))?;
            Some(v)
        }
    };
    let spec = match mode {
        "none" => {
            ensure!(param.is_none(), "faults profile 'none' takes no parameter");
            FaultsSpec { mode: FaultsMode::None, param: None }
        }
        "crash" | "chaos" => {
            if let Some(d) = param {
                ensure!(
                    d.is_finite() && d > 0.0,
                    "--faults {mode}: downtime must be positive seconds, got {d}"
                );
            }
            let m = if mode == "crash" { FaultsMode::Crash } else { FaultsMode::Chaos };
            FaultsSpec { mode: m, param }
        }
        "stragglers" => {
            if let Some(f) = param {
                ensure!(
                    f.is_finite() && f > 0.0,
                    "--faults stragglers: speed factor must be > 0, got {f}"
                );
            }
            FaultsSpec { mode: FaultsMode::Stragglers, param }
        }
        "hetero" => {
            ensure!(param.is_none(), "faults profile 'hetero' takes no parameter");
            FaultsSpec { mode: FaultsMode::Hetero, param: None }
        }
        other => bail!(
            "unknown faults profile '{other}' (known: {FAULTS:?}, or 'crash:<downtime_s>', \
             'stragglers:<factor>', 'chaos:<downtime_s>')"
        ),
    };
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_registered_names() {
        for name in FAULTS {
            let spec = parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.label(), *name);
        }
    }

    #[test]
    fn parse_param_suffix_and_label_round_trip() {
        let s = parse("crash:30").unwrap();
        assert_eq!(s.mode, FaultsMode::Crash);
        assert_eq!(s.param, Some(30.0));
        assert_eq!(s.label(), "crash:30");
        let s = parse("stragglers:0.25").unwrap();
        assert_eq!(s.mode, FaultsMode::Stragglers);
        assert_eq!(s.param, Some(0.25));
        let s = parse("chaos:15").unwrap();
        assert_eq!(s.mode, FaultsMode::Chaos);
        assert_eq!(s.param, Some(15.0));
    }

    #[test]
    fn parse_rejects_unknown_and_malformed() {
        assert!(parse("meteor").is_err());
        assert!(parse("crash:abc").is_err());
        assert!(parse("crash:-5").is_err());
        assert!(parse("crash:0").is_err());
        assert!(parse("stragglers:0").is_err());
        assert!(parse("hetero:2").is_err());
        assert!(parse("none:1").is_err());
    }

    #[test]
    fn spec_applies_mode_and_param_to_config() {
        let mut cfg = SimConfig::default();
        assert_eq!(cfg.faults.mode, FaultsMode::None);
        parse("crash:45").unwrap().apply(&mut cfg);
        assert_eq!(cfg.faults.mode, FaultsMode::Crash);
        assert_eq!(cfg.faults.param, Some(45.0));
    }

    #[test]
    fn default_spec_is_none_and_plans_empty() {
        let spec = FaultsSpec::default();
        assert_eq!(spec.mode, FaultsMode::None);
        let plan = spec.plan(8, 600.0, 42);
        assert!(plan.crashes.is_empty());
        assert!(plan.speed.iter().all(|s| *s == 1.0));
        assert!(plan.capacity_scale.iter().all(|s| *s == 1.0));
        assert_eq!(plan.slowest_speed(), 1.0);
    }

    #[test]
    fn crash_plan_is_deterministic_and_horizon_prefix_stable() {
        let spec = parse("crash:20").unwrap();
        let a = spec.plan(4, 600.0, 7);
        let b = spec.plan(4, 600.0, 7);
        assert_eq!(a.crashes, b.crashes);
        assert!(!a.crashes.is_empty());
        // a longer horizon extends the schedule without rewriting it
        let long = spec.plan(4, 1200.0, 7);
        assert_eq!(&long.crashes_for(0)[..a.crashes_for(0).len()], &a.crashes_for(0)[..]);
        // distinct seeds sample distinct schedules
        let c = spec.plan(4, 600.0, 8);
        assert_ne!(a.crashes, c.crashes);
    }

    #[test]
    fn crash_cycles_are_well_formed() {
        let spec = parse("crash:30").unwrap();
        let plan = spec.plan(4, 2000.0, 11);
        // sorted by (at, worker)
        for pair in plan.crashes.windows(2) {
            assert!(
                (pair[0].at, pair[0].worker) < (pair[1].at, pair[1].worker),
                "plan must be sorted"
            );
        }
        for w in 0..4 {
            let cycles = plan.crashes_for(w);
            assert!(!cycles.is_empty(), "horizon covers the first-crash window");
            // first crash inside [0.25, 0.75] x MTBF
            assert!(cycles[0].at >= 0.25 * CRASH_MTBF_S && cycles[0].at <= 0.75 * CRASH_MTBF_S);
            for c in &cycles {
                assert!((c.restart_at - (c.at + 30.0)).abs() < 1e-9, "restart = crash + downtime");
            }
            // a worker never crashes while already down
            for pair in cycles.windows(2) {
                assert!(pair[1].at > pair[0].restart_at);
            }
        }
    }

    #[test]
    fn stragglers_pick_a_deterministic_ceil_fraction() {
        let spec = parse("stragglers:0.5").unwrap();
        let plan = spec.plan(8, 600.0, 3);
        let slowed = plan.speed.iter().filter(|s| **s == 0.5).count();
        assert_eq!(slowed, 2, "ceil(8 * 0.25)");
        assert!(plan.crashes.is_empty());
        assert_eq!(plan.slowest_speed(), 0.5);
        assert_eq!(plan.speed, spec.plan(8, 600.0, 3).speed, "selection deterministic");
        // even a 1-worker cluster gets its straggler
        assert_eq!(spec.plan(1, 600.0, 3).speed, vec![0.5]);
    }

    #[test]
    fn hetero_cycles_capacity_classes_keeping_worker0_full() {
        let plan = parse("hetero").unwrap().plan(5, 600.0, 1);
        assert_eq!(plan.capacity_scale, vec![1.0, 0.5, 0.25, 1.0, 0.5]);
        assert!(plan.crashes.is_empty());
        assert!(plan.speed.iter().all(|s| *s == 1.0));
    }

    #[test]
    fn chaos_combines_all_three_axes() {
        let plan = parse("chaos:10").unwrap().plan(4, 600.0, 9);
        assert!(!plan.crashes.is_empty());
        assert!((plan.crashes[0].restart_at - plan.crashes[0].at - 10.0).abs() < 1e-9);
        assert!(plan.speed.iter().any(|s| *s == DEFAULT_STRAGGLER_FACTOR));
        assert_eq!(plan.capacity_scale[1], 0.5);
    }

    impl FaultPlan {
        /// Test helper: this worker's cycles in time order.
        fn crashes_for(&self, worker: usize) -> Vec<CrashEvent> {
            self.crashes.iter().copied().filter(|c| c.worker == worker).collect()
        }
    }
}
