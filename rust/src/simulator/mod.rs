//! Discrete-event cluster simulator — the substrate standing in for the
//! paper's 17-node OpenWhisk testbed (DESIGN.md §2, §5).
//!
//! Mechanics modeled:
//! * workers with physical cores, scheduler admission limits (`userCpu`),
//!   memory capacity, and a shared NIC;
//! * container lifecycle: cold start (lognormal latency), warm pools,
//!   pluggable keep-alive/eviction policies (fixed TTL, per-function
//!   histograms with pre-warm, demand-driven pressure eviction —
//!   [`keepalive`], DESIGN.md §KeepAlive), proactive background
//!   launches;
//! * execution in phases — network fetch (bandwidth-shared), serial
//!   compute (1 vCPU), parallel compute (`min(alloc, maxpar)` vCPUs) —
//!   under processor sharing when a worker's demand exceeds its cores;
//! * OOM kills when an invocation's footprint *exceeds* its container's
//!   memory (exact fits survive), walltime timeouts counted from request
//!   arrival (OpenWhisk semantics — decision overhead, admission
//!   queueing, and cold starts eat into the budget, and a request can
//!   die while still queued; timed-out containers are torn down, not
//!   kept warm), per-invocation utilization sampling (the paper's
//!   per-worker daemon);
//! * *enforced* admission: containers reserve vCPU/memory at launch and
//!   while busy, binds that don't fit park on a per-worker FIFO queue,
//!   and `allocated ≤ limit` holds at every event (DESIGN.md §Admission);
//! * deterministic fault injection ([`faults`], DESIGN.md §Faults):
//!   seed-derived worker crash/restart cycles, straggler speed factors,
//!   and heterogeneous capacity classes, all as ordinary timestamped
//!   events — `faults:none` (the default) is byte-identical to a
//!   fault-free build.
//!
//! The *policy* (Shabari or a baseline) plugs in through [`Policy`]: it
//! sees each request plus a read-only cluster view and returns a routing
//! [`Decision`]; the engine executes the mechanics.
//!
//! Everything in here is bit-deterministic for a fixed seed (DESIGN.md
//! §4): container pools and active sets are ordered maps, warm-pool
//! lookups go through sorted indexes (ties → lowest container id), and
//! completion/feedback batches are processed in invocation-id order — no
//! hash-iteration order reaches results, learner updates, or records.

pub mod container;
pub mod engine;
pub mod faults;
pub mod keepalive;
pub mod scaler;
pub mod trace;
pub mod worker;

use crate::featurizer::InputSpec;

/// Simulated seconds since experiment start.
pub type SimTime = f64;

/// One incoming invocation request (produced by `workload::trace`).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Index into `functions::catalog::CATALOG`.
    pub func: usize,
    pub input: InputSpec,
    pub arrival: SimTime,
    /// Target execution time (the Shabari interface's SLO). Baselines that
    /// ignore SLOs still have it recorded for violation accounting.
    pub slo_s: f64,
}

/// How the policy wants the invocation to get a container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerChoice {
    /// Run in an existing idle warm container (id on the chosen worker).
    Warm(u64),
    /// Create a new container of the decision's size (pays cold start).
    Cold,
}

/// A proactive background container launch (§5: off the critical path).
#[derive(Debug, Clone, Copy)]
pub struct BackgroundLaunch {
    pub worker: usize,
    pub vcpus: u32,
    pub mem_mb: u32,
}

/// The policy's routing decision for one request.
#[derive(Debug, Clone)]
pub struct Decision {
    pub worker: usize,
    /// vCPU hard limit for the invocation (the paper's `CPULimit()`).
    pub vcpus: u32,
    /// Memory limit in MB (128 MB granularity upstream).
    pub mem_mb: u32,
    pub container: ContainerChoice,
    pub background: Option<BackgroundLaunch>,
    /// Critical-path decision latency (featurize + predict + schedule).
    pub overhead_s: f64,
}

/// Terminal state of an invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Completed,
    /// Killed by the host OOM killer: footprint exceeded container memory.
    OomKilled,
    /// Exceeded the platform's max execution walltime; no response sent.
    TimedOut,
    /// Lost to a worker crash (DESIGN.md §Faults): the container died
    /// mid-execution, or the invocation had nowhere left to requeue.
    Failed,
}

/// Everything recorded about a finished invocation — the input to both
/// the metrics layer and the online learner's feedback loop.
#[derive(Debug, Clone)]
pub struct InvocationRecord {
    pub id: u64,
    pub func: usize,
    pub input: InputSpec,
    pub worker: usize,
    /// Container size the invocation actually ran in.
    pub vcpus: u32,
    pub mem_mb: u32,
    /// Size the policy *asked* for (differs when routed to a larger warm
    /// container).
    pub requested_vcpus: u32,
    pub requested_mem_mb: u32,
    pub arrival: SimTime,
    /// Cold-start latency paid on the critical path (0 for warm hits).
    pub cold_start_s: f64,
    pub had_cold_start: bool,
    /// Decision latency paid on the critical path.
    pub overhead_s: f64,
    /// Time parked on the bound worker's FIFO admission queue (0 when
    /// the worker admitted the invocation immediately).
    pub queue_s: f64,
    /// Execution time (start-of-exec to finish) — what the SLO governs.
    pub exec_s: f64,
    /// End-to-end latency including overheads + cold start.
    pub e2e_s: f64,
    pub end: SimTime,
    pub slo_s: f64,
    pub verdict: Verdict,
    /// Daemon-sampled usage.
    pub avg_vcpus_used: f64,
    pub peak_vcpus_used: f64,
    pub mem_used_gb: f64,
}

impl InvocationRecord {
    /// SLO violation per the paper: execution time above target, or a
    /// failed invocation (OOM/timeout).
    pub fn slo_violated(&self) -> bool {
        self.verdict != Verdict::Completed || self.exec_s > self.slo_s
    }

    /// Allocated-but-idle vCPUs (Fig 8b's "wasted vCPUs per invocation"):
    /// cores the invocation never touched even at its parallel peak —
    /// the cgroup-style "idle allocated" number the paper reports.
    pub fn wasted_vcpus(&self) -> f64 {
        (self.vcpus as f64 - self.peak_vcpus_used).max(0.0)
    }

    /// Allocated-but-idle memory in GB (Fig 8c).
    pub fn wasted_mem_gb(&self) -> f64 {
        (self.mem_mb as f64 / 1024.0 - self.mem_used_gb).max(0.0)
    }

    /// vCPU utilization fraction (Fig 8d).
    pub fn vcpu_utilization(&self) -> f64 {
        if self.vcpus == 0 {
            0.0
        } else {
            (self.avg_vcpus_used / self.vcpus as f64).min(1.0)
        }
    }

    /// Memory utilization fraction (Fig 8e).
    pub fn mem_utilization(&self) -> f64 {
        let alloc = self.mem_mb as f64 / 1024.0;
        if alloc <= 0.0 {
            0.0
        } else {
            (self.mem_used_gb / alloc).min(1.0)
        }
    }
}

/// Cluster/testbed parameters (§7.1 defaults).
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub workers: usize,
    /// Physical cores per worker (contention threshold).
    pub physical_cores: f64,
    /// Scheduler admission limit per worker (`userCpu`, Fig 11).
    pub sched_vcpu_limit: f64,
    /// Memory per worker, GB.
    pub mem_gb: f64,
    /// NIC bandwidth, Gb/s.
    pub net_gbps: f64,
    /// Mean cold-start latency, seconds (lognormal).
    pub cold_start_mean_s: f64,
    pub cold_start_sigma: f64,
    /// Idle container keep-alive before eviction, seconds (the fixed
    /// TTL; also the histogram policy's cold-history fallback).
    pub keep_alive_s: f64,
    /// Which keep-alive/eviction policy the engine runs (DESIGN.md
    /// §KeepAlive). `Fixed` reproduces the legacy single-TTL behavior.
    pub keepalive: keepalive::KeepAliveMode,
    /// Which fault profile the run injects (DESIGN.md §Faults). The
    /// default `none` adds zero events and zero RNG draws — byte-identical
    /// to the pre-fault engine.
    pub faults: faults::FaultsSpec,
    /// Which cluster-scaling profile the run uses (DESIGN.md §Scaler).
    /// The default `none` adds zero events and zero RNG draws —
    /// byte-identical to the fixed-size cluster.
    pub scaler: scaler::ScalerSpec,
    /// Platform max invocation walltime.
    pub timeout_s: f64,
    /// RNG seed for execution noise / cold-start draws.
    pub seed: u64,
    /// Lifecycle tracing (DESIGN.md §Observability). `None` (the
    /// default) records nothing and is byte-identical to an untraced
    /// build: tracing adds zero events and zero RNG draws either way.
    pub trace: Option<trace::TraceConfig>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            workers: 16,
            physical_cores: 96.0,
            sched_vcpu_limit: 90.0,
            mem_gb: 125.0,
            net_gbps: 10.0,
            cold_start_mean_s: 0.55,
            cold_start_sigma: 0.35,
            keep_alive_s: 600.0,
            keepalive: keepalive::KeepAliveMode::Fixed,
            faults: faults::FaultsSpec::default(),
            scaler: scaler::ScalerSpec::default(),
            timeout_s: 300.0,
            seed: 0xC0FFEE,
            trace: None,
        }
    }
}

impl SimConfig {
    /// A small cluster for unit/integration tests.
    pub fn small() -> Self {
        SimConfig { workers: 4, ..Default::default() }
    }
}

/// A policy: the coordinator (Shabari) or a baseline system.
pub trait Policy {
    fn name(&self) -> String;

    /// Route one request. The engine trusts the worker/container choice
    /// but enforces mechanics (cold start if the warm id is gone, etc.).
    fn on_request(
        &mut self,
        now: SimTime,
        req: &Request,
        cluster: &worker::Cluster,
    ) -> Decision;

    /// Feedback after an invocation finishes (drives online learning).
    fn on_complete(
        &mut self,
        _now: SimTime,
        _rec: &InvocationRecord,
        _cluster: &worker::Cluster,
    ) {
    }

    /// A worker crashed (DESIGN.md §Faults): its warm pool, reservations,
    /// and any per-worker learning state are gone. Policies tracking
    /// observations per worker roll them back here.
    fn on_worker_crash(&mut self, _now: SimTime, _worker: usize, _cluster: &worker::Cluster) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};

    fn rec() -> InvocationRecord {
        InvocationRecord {
            id: 1,
            func: 0,
            input: InputSpec::new(InputKind::Payload),
            worker: 0,
            vcpus: 8,
            mem_mb: 2048,
            requested_vcpus: 8,
            requested_mem_mb: 2048,
            arrival: 0.0,
            cold_start_s: 0.0,
            had_cold_start: false,
            overhead_s: 0.0,
            queue_s: 0.0,
            exec_s: 2.0,
            e2e_s: 2.0,
            end: 2.0,
            slo_s: 3.0,
            verdict: Verdict::Completed,
            avg_vcpus_used: 5.0,
            peak_vcpus_used: 8.0,
            mem_used_gb: 1.0,
        }
    }

    #[test]
    fn violation_logic() {
        let mut r = rec();
        assert!(!r.slo_violated());
        r.exec_s = 4.0;
        assert!(r.slo_violated());
        r.exec_s = 1.0;
        r.verdict = Verdict::OomKilled;
        assert!(r.slo_violated());
    }

    #[test]
    fn waste_and_utilization() {
        let mut r = rec();
        r.peak_vcpus_used = 5.0; // 3 cores never touched
        assert!((r.wasted_vcpus() - 3.0).abs() < 1e-12);
        assert!((r.wasted_mem_gb() - 1.0).abs() < 1e-12);
        assert!((r.vcpu_utilization() - 5.0 / 8.0).abs() < 1e-12);
        assert!((r.mem_utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn default_config_matches_testbed() {
        let c = SimConfig::default();
        assert_eq!(c.workers, 16);
        assert_eq!(c.sched_vcpu_limit, 90.0);
        assert_eq!(c.mem_gb, 125.0);
        // the default keep-alive is the legacy fixed 600 s TTL
        assert_eq!(c.keepalive, keepalive::KeepAliveMode::Fixed);
        assert_eq!(c.keep_alive_s, 600.0);
    }
}

impl Policy for Box<dyn Policy> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn on_request(
        &mut self,
        now: SimTime,
        req: &Request,
        cluster: &worker::Cluster,
    ) -> Decision {
        (**self).on_request(now, req, cluster)
    }

    fn on_complete(
        &mut self,
        now: SimTime,
        rec: &InvocationRecord,
        cluster: &worker::Cluster,
    ) {
        (**self).on_complete(now, rec, cluster)
    }

    fn on_worker_crash(&mut self, now: SimTime, worker: usize, cluster: &worker::Cluster) {
        (**self).on_worker_crash(now, worker, cluster)
    }
}
