//! The discrete-event engine: arrivals → policy decision → *enforced*
//! admission (reserve-at-launch; FIFO queue when the bound worker is
//! full) → container acquisition (cold start if needed) → phased
//! execution under processor sharing → completion, feedback, keep-alive
//! eviction.
//!
//! Admission is a hard engine invariant, not a scheduler courtesy
//! (DESIGN.md §Admission): a container launch or warm bind only happens
//! when the worker's reservations leave room under `sched_vcpu_limit`
//! and memory; otherwise the invocation parks on the worker's FIFO
//! admission queue and is popped in enqueue order on every capacity
//! release (completion, eviction, teardown, background-ready). A request
//! can die *in queue*: its walltime clock is scheduled at arrival, so
//! timeout produces a `TimedOut` record whether or not it ever bound.
//!
//! With `SimConfig::trace` set, every lifecycle transition above is also
//! recorded into a side-band [`TraceLog`] (DESIGN.md §Observability) and
//! a fixed-interval utilization timeline rides the run loop — zero extra
//! RNG draws, zero extra heap events, byte-identical records either way.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use crate::functions::catalog::CATALOG;
use crate::functions::Demand;
use crate::util::rng::Rng;

use super::container::Container;
use super::faults::FaultPlan;
use super::keepalive::{self, KeepAlivePolicy};
use super::scaler::{ClusterScaler, ScaleAction, ScaleDecision, ScaleEvent, SCALER_TICK_S};
use super::trace::{TimelineSample, TraceEventKind, TraceLog};
use super::worker::{ActiveInv, Cluster, Phase, PhaseSpec, QueuedAdmission, Worker};
use super::{
    ContainerChoice, Decision, InvocationRecord, Policy, Request, SimConfig, SimTime, Verdict,
};

/// Event kinds, ordered by time (min-heap via `Reverse`-style ordering).
#[derive(Debug, Clone)]
enum EventKind {
    /// A request arrives (index into the sorted request vec).
    Arrival(usize),
    /// The decision overhead elapsed; try to start execution.
    BeginExec(u64),
    /// A cold-started container becomes ready on a worker.
    ContainerReady { worker: usize, container: u64 },
    /// Some phase on the worker may have completed (validated by epoch).
    PhaseDone { worker: usize, epoch: u64 },
    /// Kill an invocation: OOM at the projected crossing time.
    OomKill { inv: u64 },
    /// Platform walltime limit.
    Timeout { inv: u64 },
    /// Keep-alive expiry for an idle container.
    Evict { worker: usize, container: u64, idle_epoch: u64 },
    /// Hybrid-histogram pre-warm: launch a background container of this
    /// size, timed against the function's expected next arrival.
    PreWarm { worker: usize, func: usize, vcpus: u32, mem_mb: u32 },
    /// Fault injection (DESIGN.md §Faults): the worker dies — containers,
    /// reservations, and in-flight work on it are lost.
    WorkerCrash { worker: usize },
    /// The crashed worker comes back empty after its downtime.
    WorkerRestart { worker: usize },
    /// Cluster-scaler cadence (DESIGN.md §Scaler): read queue/utilization
    /// signals and maybe provision or drain an extension worker. Never
    /// pushed under `scaler:none`.
    ScalerTick,
    /// A provisioned extension worker finished booting and joins the
    /// serving pool.
    ScalerReady { worker: usize },
}

#[derive(Debug, Clone)]
struct Event {
    at: SimTime,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at.total_cmp(&other.at).is_eq() && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    // lint:allow(D004): trait-mandated signature; delegates to the total `Ord::cmp` below
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first.
        // total_cmp (not partial_cmp-or-Equal): a NaN timestamp must take a
        // deterministic position instead of comparing Equal to everything,
        // which would silently corrupt heap ordering. Under IEEE total
        // order the position depends on the NaN's sign bit (positive NaN
        // after +inf, negative NaN before -inf) — either way ordering
        // stays transitive and the `time went backwards` debug assertion
        // can actually catch the poisoned event (NaN >= now is false).
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Bookkeeping for an admitted invocation before/while it runs.
#[derive(Debug, Clone)]
struct Pending {
    req: Request,
    decision: Decision,
    /// Container the invocation will run in (set once bound).
    container: Option<u64>,
    /// Effective container size (may exceed the requested size).
    vcpus: u32,
    mem_mb: u32,
    had_cold_start: bool,
    cold_start_s: f64,
    /// Ground-truth demand (with noise) drawn at arrival.
    demand: Demand,
    exec_started: Option<SimTime>,
    /// Set while parked on the bound worker's admission queue.
    queued_since: Option<SimTime>,
    /// Total time spent waiting for admission.
    queue_s: f64,
}

/// One container creation (Table 3 derives unique sizes from this log).
#[derive(Debug, Clone, Copy)]
pub struct LaunchRecord {
    pub at: SimTime,
    pub worker: usize,
    pub func: usize,
    pub vcpus: u32,
    pub mem_mb: u32,
    /// true for proactive background launches (off critical path).
    pub background: bool,
}

/// Why the keep-alive subsystem tore a container down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictReason {
    /// Its idle TTL (assigned by the keep-alive policy) expired.
    Expired,
    /// Demand-driven: evicted before its deadline to admit queued work
    /// (`--keepalive pressure`).
    Pressure,
}

/// One keep-alive/pressure eviction. The warm-pool test battery audits
/// deadlines and idle periods from this log: `Expired` evictions fire
/// exactly at their policy deadline, `Pressure` evictions at or before
/// it, and every eviction targets a container that was idle since
/// `idle_since` (never `Starting`/`Busy` — a violated invocation would
/// also surface as a lost record).
#[derive(Debug, Clone, Copy)]
pub struct EvictionRecord {
    pub at: SimTime,
    pub worker: usize,
    pub container: u64,
    pub func: usize,
    pub reason: EvictReason,
    /// TTL deadline the policy assigned for this idle period.
    pub deadline: SimTime,
    /// When the evicted container's final idle period began.
    pub idle_since: SimTime,
}

/// Result of a full simulation run.
#[derive(Debug)]
pub struct SimResult {
    pub records: Vec<InvocationRecord>,
    pub cluster: Cluster,
    /// Containers created over the run (cold starts + background).
    pub containers_created: u64,
    pub background_launches: u64,
    /// Background launches dropped because the target worker could not
    /// admit them (shed, never queued — pre-warming must not jump ahead
    /// of demand already waiting). Late hybrid-histogram pre-warms shed
    /// by the same rule count here too.
    pub background_shed: u64,
    /// Every container creation, in order.
    pub launches: Vec<LaunchRecord>,
    /// Every keep-alive/pressure eviction, in order (DESIGN.md §KeepAlive).
    pub evictions: Vec<EvictionRecord>,
    /// Demand-driven evictions (subset of `evictions`).
    pub pressure_evictions: u64,
    /// Hybrid-histogram pre-warm launches that passed admission.
    pub prewarm_launches: u64,
    /// Warm binds served by a pre-warmed container (first use each).
    pub prewarm_hits: u64,
    /// Total container-seconds spent idle in the warm pool — the run's
    /// memory-waste proxy (what keep-alive policies trade against cold
    /// starts). Includes idle time trailing the last use until eviction.
    pub idle_container_s: f64,
    /// `ContainerReady` events whose container no longer existed. The only
    /// teardown path that removes a `Starting` container is a worker crash,
    /// which voids the ready event through the `crashed_starting` set
    /// instead of counting here — so this stays a tripwire: always 0
    /// (debug builds assert on it).
    pub ready_miss: u64,
    /// Fault injection (DESIGN.md §Faults): worker crash events that fired.
    pub worker_crashes: u64,
    /// Invocations that lost their bound worker to a crash and re-entered
    /// the admission path on another worker (the rest died `Failed`).
    pub requeued_on_crash: u64,
    /// Slowest configured worker speed factor (1.0 without stragglers).
    pub straggler_slowdown: f64,
    /// Cluster-scaling timeline (DESIGN.md §Scaler), in event order —
    /// empty under `scaler:none`.
    pub scaling: Vec<ScaleEvent>,
    /// Extension-worker provisions started (subset reach `Ready`).
    pub scale_ups: u64,
    /// Idle extension workers drained back out of the pool.
    pub scale_downs: u64,
    /// Most workers ever serving at once (the configured base count
    /// under `scaler:none`).
    pub peak_up_workers: usize,
    /// Heap events processed over the run — with wall-clock time at the
    /// caller this gives the engine's self-throughput (`sim_events_per_s`).
    pub events_processed: u64,
    /// The lifecycle trace (DESIGN.md §Observability), present iff
    /// `SimConfig::trace` was set. The engine never writes files — the
    /// caller serializes via `TraceLog::{to_jsonl, to_chrome}`.
    pub trace: Option<TraceLog>,
}

impl SimResult {
    /// Number of distinct (vcpus, mem) container sizes created for `func`
    /// (paper Table 3).
    pub fn unique_container_sizes(&self, func: usize) -> usize {
        let set: std::collections::BTreeSet<(u32, u32)> = self
            .launches
            .iter()
            .filter(|l| l.func == func)
            .map(|l| (l.vcpus, l.mem_mb))
            .collect();
        set.len()
    }
}

impl SimResult {
    /// Records of completed+failed invocations sorted by arrival.
    pub fn sorted_records(&self) -> Vec<&InvocationRecord> {
        let mut v: Vec<&InvocationRecord> = self.records.iter().collect();
        v.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        v
    }
}

/// The engine. Owns cluster state and the keep-alive policy; borrows
/// the scheduling policy.
pub struct Engine<'p, P: Policy> {
    cfg: SimConfig,
    policy: &'p mut P,
    /// Keep-alive/eviction policy (DESIGN.md §KeepAlive), built per run
    /// from `SimConfig::keepalive` so its state (histograms) is rebuilt
    /// deterministically from the run itself.
    ka: Box<dyn KeepAlivePolicy>,
    cluster: Cluster,
    rng: Rng,
    events: BinaryHeap<Event>,
    seq: u64,
    now: SimTime,
    requests: Vec<Request>,
    pending: BTreeMap<u64, Pending>,
    /// container id -> invocation waiting for its cold start.
    waiting_on_container: BTreeMap<u64, u64>,
    records: Vec<InvocationRecord>,
    next_container_id: u64,
    containers_created: u64,
    background_launches: u64,
    background_shed: u64,
    launches: Vec<LaunchRecord>,
    evictions: Vec<EvictionRecord>,
    pressure_evictions: u64,
    prewarm_launches: u64,
    prewarm_hits: u64,
    idle_container_s: f64,
    ready_miss: u64,
    /// Materialized fault schedule (empty under `faults:none`).
    faults: FaultPlan,
    /// Live cluster-scaler state (DESIGN.md §Scaler); `None` under
    /// `scaler:none` — zero ticks pushed, zero draws, byte-identical
    /// streams to a scaler-free build.
    scaler: Option<ClusterScaler>,
    /// `Starting` containers torn down by a crash: their in-flight
    /// `ContainerReady` events are void, not `ready_miss` tripwires.
    crashed_starting: BTreeSet<u64>,
    worker_crashes: u64,
    requeued_on_crash: u64,
    /// Reused completion buffers (no steady-state allocation).
    done_scratch: Vec<u64>,
    finished_scratch: Vec<u64>,
    events_processed: u64,
    /// Lifecycle trace sink (DESIGN.md §Observability). `None` is the
    /// zero-cost off state: every recording site is an `is_some()` check,
    /// and the sink draws no RNG and pushes no heap events either way, so
    /// record streams are byte-identical with tracing on or off.
    trace: Option<TraceLog>,
}

/// Manual `Debug`: the engine borrows the policy generically and owns a
/// `Box<dyn KeepAlivePolicy>`; print the simulation cursor and queue
/// shape, which is what a stuck-run report needs.
impl<P: Policy> std::fmt::Debug for Engine<'_, P> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("now", &self.now)
            .field("seq", &self.seq)
            .field("events", &self.events.len())
            .field("pending", &self.pending.len())
            .field("events_processed", &self.events_processed)
            .finish_non_exhaustive()
    }
}

/// Salt for the engine's own RNG stream (exec-time noise, OOM coin
/// flips), decorrelated from workload/policy streams off the same seed.
const SALT_ENGINE: u64 = 0x5115_BA71;

impl<'p, P: Policy> Engine<'p, P> {
    pub fn new(cfg: SimConfig, policy: &'p mut P, mut requests: Vec<Request>) -> Self {
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let rng = Rng::new(cfg.seed ^ SALT_ENGINE);
        let mut cluster = Cluster::new(&cfg);
        // Materialize the fault schedule up front from its own salted RNG
        // streams (DESIGN.md §Faults) — `faults:none` builds an empty plan
        // with zero extra draws or events. The horizon covers the last
        // arrival plus the walltime limit, i.e. every instant an
        // invocation can still be in flight.
        let horizon = requests.last().map(|r| r.arrival).unwrap_or(0.0) + cfg.timeout_s;
        let faults = cfg.faults.plan(cfg.workers, horizon, cfg.seed);
        // Scaler state off its own salted stream (DESIGN.md §Scaler) —
        // `scaler:none` builds nothing: zero draws, zero events.
        let scaler = cfg.scaler.build(cfg.workers, horizon, cfg.seed);
        for (w, worker) in cluster.workers.iter_mut().enumerate() {
            worker.speed = faults.speed[w];
            let scale = faults.capacity_scale[w];
            // lint:allow(D004): 1.0 is an exact sentinel assigned above, not a computed value
            if scale != 1.0 {
                // Heterogeneous classes scale the whole worker shape;
                // floors keep even the smallest class schedulable.
                worker.physical_cores *= scale;
                worker.sched_vcpu_limit = (worker.sched_vcpu_limit * scale).max(1.0);
                worker.mem_gb = (worker.mem_gb * scale).max(1.0);
            }
        }
        // Workers read their `idle_reserves` accounting switch off the
        // same `keepalive::build` impl this instance answers from.
        let ka = keepalive::build(&cfg);
        let trace = cfg.trace.clone().map(|tc| {
            let mut meta = BTreeMap::new();
            meta.insert("policy".to_string(), policy.name());
            meta.insert("keepalive".to_string(), cfg.keepalive.label().to_string());
            meta.insert("keep_alive_s".to_string(), format!("{}", cfg.keep_alive_s));
            meta.insert("faults".to_string(), cfg.faults.label());
            meta.insert("fault_plan".to_string(), faults.describe());
            meta.insert("scaler".to_string(), cfg.scaler.label());
            meta.insert("workers".to_string(), cfg.workers.to_string());
            meta.insert("seed".to_string(), cfg.seed.to_string());
            meta.insert("requests".to_string(), requests.len().to_string());
            TraceLog::new(tc, meta)
        });
        Engine {
            cfg,
            policy,
            ka,
            cluster,
            rng,
            events: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            requests,
            pending: BTreeMap::new(),
            waiting_on_container: BTreeMap::new(),
            records: Vec::new(),
            next_container_id: 1,
            containers_created: 0,
            background_launches: 0,
            background_shed: 0,
            launches: Vec::new(),
            evictions: Vec::new(),
            pressure_evictions: 0,
            prewarm_launches: 0,
            prewarm_hits: 0,
            idle_container_s: 0.0,
            ready_miss: 0,
            faults,
            scaler,
            crashed_starting: BTreeSet::new(),
            worker_crashes: 0,
            requeued_on_crash: 0,
            done_scratch: Vec::new(),
            finished_scratch: Vec::new(),
            events_processed: 0,
            trace,
        }
    }

    /// Record one lifecycle event at the current simulated time. No-op
    /// with tracing off; purely side-band either way (never touches
    /// engine state, the RNG, or the event heap).
    fn trace_event(&mut self, kind: TraceEventKind) {
        if let Some(t) = self.trace.as_mut() {
            t.record(self.now, kind);
        }
    }

    /// Emit every due utilization snapshot up to `upto` (the next event's
    /// timestamp). The sampler rides the run loop instead of scheduling
    /// heap events, so event sequence numbers — and therefore every
    /// record stream — are identical with tracing on or off. Cluster
    /// state is piecewise-constant between events, so sampling at a
    /// boundary that falls inside an event gap reads the exact value
    /// that held across the whole gap.
    fn sample_timeline_to(&mut self, upto: SimTime) {
        let Some(t) = self.trace.as_mut() else {
            return;
        };
        while t.next_sample_at() <= upto {
            let at = t.next_sample_at();
            t.push_sample(TimelineSample::capture(at, &self.cluster));
        }
    }

    fn push(&mut self, at: SimTime, kind: EventKind) {
        self.seq += 1;
        self.events.push(Event { at, seq: self.seq, kind });
    }

    /// Run to completion and return all records.
    pub fn run(mut self) -> SimResult {
        // Fault schedule first: the plan is sorted by `(at, worker)`, so
        // the sequence-number tie-break makes same-timestamp crashes fire
        // in worker-id order (the PR 3 contract), and a crash at an
        // arrival's exact timestamp is visible to that arrival's decision.
        // Under `faults:none` the plan is empty and event seq numbers are
        // byte-identical to a run without this block.
        let crashes = std::mem::take(&mut self.faults.crashes);
        for c in &crashes {
            self.push(c.at, EventKind::WorkerCrash { worker: c.worker });
            self.push(c.restart_at, EventKind::WorkerRestart { worker: c.worker });
        }
        self.faults.crashes = crashes;
        for i in 0..self.requests.len() {
            let at = self.requests[i].arrival;
            self.push(at, EventKind::Arrival(i));
        }
        // Scaler cadence last (DESIGN.md §Scaler): the tick chain carries
        // itself forward from inside `on_scaler_tick`. Under `scaler:none`
        // nothing is pushed here or later, so event sequence numbers stay
        // byte-identical to a scaler-free build.
        if self.scaler.is_some() && !self.requests.is_empty() {
            self.push(SCALER_TICK_S, EventKind::ScalerTick);
        }
        while let Some(ev) = self.events.pop() {
            debug_assert!(ev.at >= self.now - 1e-9, "time went backwards");
            self.events_processed += 1;
            if self.trace.is_some() {
                self.sample_timeline_to(ev.at);
            }
            self.now = ev.at.max(self.now);
            match ev.kind {
                EventKind::Arrival(i) => self.on_arrival(i),
                EventKind::BeginExec(inv) => self.on_begin_exec(inv),
                EventKind::ContainerReady { worker, container } => {
                    self.on_container_ready(worker, container)
                }
                EventKind::PhaseDone { worker, epoch } => self.on_phase_done(worker, epoch),
                EventKind::OomKill { inv } => self.kill(inv, Verdict::OomKilled),
                EventKind::Timeout { inv } => self.kill(inv, Verdict::TimedOut),
                EventKind::Evict { worker, container, idle_epoch } => {
                    self.on_evict(worker, container, idle_epoch)
                }
                EventKind::PreWarm { worker, func, vcpus, mem_mb } => {
                    self.on_prewarm(worker, func, vcpus, mem_mb)
                }
                EventKind::WorkerCrash { worker } => self.on_worker_crash(worker),
                EventKind::WorkerRestart { worker } => self.on_worker_restart(worker),
                EventKind::ScalerTick => self.on_scaler_tick(),
                EventKind::ScalerReady { worker } => self.on_scaler_ready(worker),
            }
            // Admission is an invariant at *every* event, not just at the
            // end of the run. Cheap (two float compares per worker); the
            // full container-state cross-check lives in
            // `Cluster::assert_admission_consistent` for tests, and the
            // per-worker peaks witness the same bound in release builds.
            #[cfg(debug_assertions)]
            self.debug_assert_admission_bounds();
        }
        // Safety net for idle accounting: every idle container schedules
        // an Evict that fires before the heap drains, so the pool should
        // be empty here; anything left still gets its idle time counted.
        let now = self.now;
        let trailing: f64 = self
            .cluster
            .workers
            .iter()
            .flat_map(|w| w.containers.values())
            .filter(|c| c.is_warm_idle())
            .map(|c| (now - c.idle_since).max(0.0))
            .sum();
        self.idle_container_s += trailing;
        // Close the utilization timeline: any boundaries left before the
        // final event, then one end-of-run snapshot (skipped when the
        // last boundary already sampled this exact instant).
        if self.trace.is_some() {
            self.sample_timeline_to(now);
            if let Some(t) = self.trace.as_mut() {
                t.close(now, &self.cluster);
            }
        }
        let (scaling, scale_ups, scale_downs, peak_up_workers) = match self.scaler {
            Some(s) => (s.scaling, s.scale_ups, s.scale_downs, s.peak_up_workers),
            None => (Vec::new(), 0, 0, self.cfg.workers),
        };
        SimResult {
            records: self.records,
            cluster: self.cluster,
            containers_created: self.containers_created,
            background_launches: self.background_launches,
            background_shed: self.background_shed,
            launches: self.launches,
            evictions: self.evictions,
            pressure_evictions: self.pressure_evictions,
            prewarm_launches: self.prewarm_launches,
            prewarm_hits: self.prewarm_hits,
            idle_container_s: self.idle_container_s,
            ready_miss: self.ready_miss,
            worker_crashes: self.worker_crashes,
            requeued_on_crash: self.requeued_on_crash,
            straggler_slowdown: self.faults.slowest_speed(),
            scaling,
            scale_ups,
            scale_downs,
            peak_up_workers,
            events_processed: self.events_processed,
            trace: self.trace,
        }
    }

    /// Per-event admission bound check (debug builds): no worker's
    /// reservations may exceed its scheduler limits.
    #[cfg(debug_assertions)]
    fn debug_assert_admission_bounds(&self) {
        for w in &self.cluster.workers {
            debug_assert!(
                w.allocated_vcpus <= w.sched_vcpu_limit,
                "worker {}: {} vCPUs allocated > limit {} at t={}",
                w.id,
                w.allocated_vcpus,
                w.sched_vcpu_limit,
                self.now
            );
            debug_assert!(
                w.allocated_mem_mb <= w.mem_gb * 1024.0,
                "worker {}: {} MB allocated > limit {} at t={}",
                w.id,
                w.allocated_mem_mb,
                w.mem_gb * 1024.0,
                self.now
            );
        }
    }

    // -- cluster scaling (DESIGN.md §Scaler) ----------------------------

    /// One scaler cadence tick: read queue depth and vCPU utilization
    /// over the *serving* pool (down workers — crashed, provisioning, or
    /// drained — serve nothing and must not dilute the signals), act on
    /// the decision, and reschedule the next tick while the horizon
    /// still has work in flight.
    fn on_scaler_tick(&mut self) {
        let Some(s) = self.scaler.as_mut() else {
            return;
        };
        let mut queued = 0usize;
        let mut allocated = 0.0;
        let mut limit = 0.0;
        let mut up = 0usize;
        for w in &self.cluster.workers {
            if w.down {
                continue;
            }
            up += 1;
            queued += w.admission_queue_len();
            allocated += w.allocated_vcpus;
            limit += w.sched_vcpu_limit;
        }
        // A fully-down cluster reads as saturated: provisioning fresh
        // capacity is exactly the right reaction to zero serving limit.
        let utilization = if limit > 0.0 { allocated / limit } else { 1.0 };
        s.peak_up_workers = s.peak_up_workers.max(up);
        let decision = s.evaluate(queued, utilization, up);
        let horizon = s.horizon_s;
        match decision {
            ScaleDecision::Up => self.scale_up(up),
            ScaleDecision::Down => self.scale_down(up),
            ScaleDecision::Hold => {}
        }
        if self.now + SCALER_TICK_S <= horizon {
            self.push(self.now + SCALER_TICK_S, EventKind::ScalerTick);
        }
    }

    /// Provision one extension worker: reuse the lowest-id drained
    /// extension slot if one exists (stable worker ids keep the PR 3
    /// worker-id tie-breaks meaningful across scale cycles), otherwise
    /// append a fresh worker in the `down` state. It starts serving when
    /// its `ScalerReady` fires after a boot delay drawn from the
    /// scaler's own RNG stream.
    fn scale_up(&mut self, up_now: usize) {
        let Some(s) = self.scaler.as_ref() else {
            return;
        };
        let base = s.base_workers;
        let reuse = self
            .cluster
            .workers
            .iter()
            .skip(base)
            .find(|w| w.down && !s.provisioning.contains(&w.id))
            .map(|w| w.id);
        let idle_reserves = self.ka.idle_reserves();
        let worker = match reuse {
            Some(id) => id,
            None => {
                let id = self.cluster.workers.len();
                // Extension workers join at the *nominal* shape: the
                // fault plan's straggler/hetero factors cover only the
                // base ids it was materialized for.
                let mut w = Worker::with_idle_reserves(id, &self.cfg, idle_reserves);
                w.down = true;
                self.cluster.workers.push(w);
                id
            }
        };
        let now = self.now;
        let Some(s) = self.scaler.as_mut() else {
            return;
        };
        s.provisioning.insert(worker);
        s.scale_ups += 1;
        s.scaling.push(ScaleEvent {
            at: now,
            worker,
            action: ScaleAction::Provision,
            up_workers: up_now,
        });
        let delay = s.boot_delay();
        self.push(now + delay, EventKind::ScalerReady { worker });
    }

    /// A provisioned extension worker finished booting: it comes up
    /// empty and serves from the next decision on. Work a policy routed
    /// at it while it was still down parked on its FIFO queue and
    /// drains now (same contract as a worker restart).
    fn on_scaler_ready(&mut self, worker: usize) {
        let now = self.now;
        let Some(s) = self.scaler.as_mut() else {
            return;
        };
        if !s.provisioning.remove(&worker) {
            return; // defensive: never scheduled twice today
        }
        {
            let w = &mut self.cluster.workers[worker];
            debug_assert!(w.down, "scaler-ready worker was already up");
            w.down = false;
            // No active work existed while down; this just moves the
            // processor-sharing clock past the provisioning window.
            w.advance(now);
        }
        let up = self.cluster.workers.iter().filter(|w| !w.down).count();
        s.peak_up_workers = s.peak_up_workers.max(up);
        s.scaling.push(ScaleEvent { at: now, worker, action: ScaleAction::Ready, up_workers: up });
        self.drain_admission(worker);
    }

    /// Drain one idle extension worker — highest id first (LIFO keeps
    /// the pool compact and the choice deterministic), and only one
    /// candidate with no active work, no queued admissions, and nothing
    /// but warm-idle containers. Its warm pool is evicted in container-id
    /// order (pressure-style: before the TTL deadline, to free capacity
    /// — here the whole worker), then the worker leaves the serving pool
    /// the same way a crashed worker does: every capacity predicate
    /// answers false until the scaler re-provisions the slot.
    fn scale_down(&mut self, up_now: usize) {
        let Some(s) = self.scaler.as_ref() else {
            return;
        };
        let target = self
            .cluster
            .workers
            .iter()
            .skip(s.base_workers)
            .rev()
            .find(|w| {
                !w.down
                    && w.active.is_empty()
                    && w.admission_queue_len() == 0
                    && w.containers.values().all(|c| c.is_warm_idle())
            })
            .map(|w| w.id);
        let Some(worker) = target else {
            return;
        };
        let cids: Vec<u64> = self.cluster.workers[worker].containers.keys().copied().collect();
        for cid in cids {
            self.evict_container(worker, cid, EvictReason::Pressure);
        }
        let now = self.now;
        self.cluster.workers[worker].down = true;
        if let Some(s) = self.scaler.as_mut() {
            s.scale_downs += 1;
            s.scaling.push(ScaleEvent {
                at: now,
                worker,
                action: ScaleAction::Drain,
                up_workers: up_now.saturating_sub(1),
            });
        }
    }

    // ------------------------------------------------------------------

    fn on_arrival(&mut self, idx: usize) {
        // Feed the keep-alive policy's per-function inter-arrival
        // histograms (no-op for fixed/pressure).
        let func_idx = self.requests[idx].func;
        self.ka.observe_arrival(self.now, func_idx);
        let req = self.requests[idx].clone();
        let decision = self.policy.on_request(self.now, &req, &self.cluster);
        debug_assert!(decision.worker < self.cluster.len(), "bad worker id");

        // Draw the ground-truth demand once per invocation.
        let func = &CATALOG[req.func];
        let mut inv_rng = self.rng.fork(req.id);
        let demand = func.noisy_demand(&req.input, &mut inv_rng);

        let inv_id = req.id;
        let arrival = req.arrival;
        if self.trace.is_some() {
            self.trace_event(TraceEventKind::Arrival { inv: inv_id, func: req.func });
            self.trace_event(TraceEventKind::Decision {
                inv: inv_id,
                worker: decision.worker,
                vcpus: decision.vcpus,
                mem_mb: decision.mem_mb,
                warm: matches!(decision.container, ContainerChoice::Warm(_)),
                overhead_s: decision.overhead_s,
            });
        }
        let pend = Pending {
            vcpus: decision.vcpus,
            mem_mb: decision.mem_mb,
            req,
            decision,
            container: None,
            had_cold_start: false,
            cold_start_s: 0.0,
            demand,
            exec_started: None,
            queued_since: None,
            queue_s: 0.0,
        };
        let overhead = pend.decision.overhead_s.max(0.0);
        self.pending.insert(inv_id, pend);
        // The platform walltime clock starts at *arrival* (OpenWhisk
        // semantics) — scheduled here, not at bind, so a request that
        // never escapes the admission queue (or the decision overhead
        // window) still dies with a TimedOut record.
        self.push(arrival + self.cfg.timeout_s, EventKind::Timeout { inv: inv_id });
        // Decision overhead elapses before the container is bound.
        self.push(self.now + overhead, EventKind::BeginExec(inv_id));
    }

    fn on_begin_exec(&mut self, inv_id: u64) {
        // The invocation may have timed out during the decision overhead
        // window; its record is already written then.
        if !self.pending.contains_key(&inv_id) {
            return;
        }
        self.try_admit(inv_id);
        // Fire the proactive background launch the decision requested.
        // It happens *here*, after the foreground admission — the
        // decision that asked for it takes `overhead_s`, so pre-warming
        // can never precede its own decision — and it must pass
        // queue-aware admission: a pre-warm is shed (not queued) rather
        // than jump ahead of demand already waiting.
        if let Some(bg) = self.pending.get(&inv_id).and_then(|p| p.decision.background) {
            let func = self.pending[&inv_id].req.func;
            if self.cluster.workers[bg.worker].has_capacity(bg.vcpus, bg.mem_mb) {
                self.launch_container(bg.worker, func, bg.vcpus, bg.mem_mb, None);
                self.background_launches += 1;
            } else {
                self.background_shed += 1;
                self.trace_event(TraceEventKind::PrewarmShed { worker: bg.worker });
            }
        }
    }

    /// Resolve what admitting this invocation on its bound worker would
    /// actually charge: the chosen warm container's size when the warm
    /// hit is still valid, the decision's size for a cold launch.
    fn resolve_route(&self, inv_id: u64) -> (usize, Option<u64>, u32, u32) {
        let p = &self.pending[&inv_id];
        let worker_id = p.decision.worker;
        if let ContainerChoice::Warm(cid) = p.decision.container {
            if let Some(c) = self.cluster.workers[worker_id].containers.get(&cid) {
                if c.is_warm_idle() && c.func == p.req.func {
                    return (worker_id, Some(cid), c.vcpus, c.mem_mb);
                }
            }
            // Stale warm hit (raced with another invocation or an
            // eviction): fall back to a cold container of the decided
            // size — through the same admission path, never around it.
        }
        (worker_id, None, p.decision.vcpus, p.decision.mem_mb)
    }

    /// Admission predicate for a resolved route. A still-valid warm bind
    /// under reservation-holding keep-alive is capacity-neutral — the
    /// idle container already holds its own reservation, which simply
    /// rolls over to busy — so it is always admissible; everything else
    /// must fit under the worker's free reservations.
    fn can_admit_route(
        &self,
        worker_id: usize,
        warm: Option<u64>,
        vcpus: u32,
        mem_mb: u32,
    ) -> bool {
        if self.cluster.workers[worker_id].down {
            // A down worker admits nothing — not even capacity-neutral
            // warm binds (its warm pool died with it anyway).
            return false;
        }
        if warm.is_some() && self.ka.idle_reserves() {
            return true;
        }
        self.cluster.workers[worker_id].can_admit(vcpus, mem_mb)
    }

    /// Enforced admission at bind time: start the invocation if the
    /// worker can reserve its effective size *and* nothing is already
    /// waiting (FIFO — newcomers go behind the queue); park it otherwise.
    fn try_admit(&mut self, inv_id: u64) {
        let (worker_id, warm, ask_vcpus, ask_mem) = self.resolve_route(inv_id);
        let queue_empty = self.cluster.workers[worker_id].admission_queue_len() == 0;
        if queue_empty && self.can_admit_route(worker_id, warm, ask_vcpus, ask_mem) {
            self.admit(inv_id, worker_id, warm);
        } else {
            let p = self.pending.get_mut(&inv_id).expect("pending invocation");
            p.queued_since = Some(self.now);
            self.cluster.workers[worker_id].push_admission(QueuedAdmission {
                inv_id,
                vcpus: p.decision.vcpus,
                mem_mb: p.decision.mem_mb,
            });
            if self.trace.is_some() {
                let depth = self.cluster.workers[worker_id].admission_queue_len();
                self.trace_event(TraceEventKind::QueueEnter {
                    inv: inv_id,
                    worker: worker_id,
                    depth,
                });
            }
            // Under demand-driven keep-alive, parking is itself pressure:
            // idle containers may yield to the queue head right now.
            if self.ka.demand_driven() {
                self.drain_admission(worker_id);
            }
        }
    }

    /// Start an admitted invocation on its resolved route.
    fn admit(&mut self, inv_id: u64, worker_id: usize, warm: Option<u64>) {
        match warm {
            Some(cid) => self.bind_and_start(inv_id, worker_id, cid),
            None => {
                let (func, vcpus, mem_mb) = {
                    let p = &self.pending[&inv_id];
                    (p.req.func, p.decision.vcpus, p.decision.mem_mb)
                };
                self.cold_start(inv_id, worker_id, func, vcpus, mem_mb);
            }
        }
    }

    /// Pop the worker's admission queue in enqueue order for as long as
    /// the head fits — called on every capacity release (completion,
    /// teardown, eviction, background-ready). Strict FIFO: a head that
    /// does not fit blocks everything behind it (deterministic; no
    /// backfilling).
    fn drain_admission(&mut self, worker_id: usize) {
        if self.cluster.workers[worker_id].down {
            // Down workers admit nothing; their queue waits for the
            // restart (or the queued requests' own walltime limits).
            return;
        }
        loop {
            let Some(front) = self.cluster.workers[worker_id].front_admission() else {
                break;
            };
            let inv_id = front.inv_id;
            let (_, warm, ask_vcpus, ask_mem) = self.resolve_route(inv_id);
            if !self.can_admit_route(worker_id, warm, ask_vcpus, ask_mem) {
                // Demand-driven keep-alive: idle containers yield (LRU
                // first) to the queued head before we give up on it.
                if !(self.ka.demand_driven()
                    && self.pressure_evict_for(worker_id, ask_vcpus, ask_mem))
                {
                    break;
                }
            }
            let popped = self.cluster.workers[worker_id].pop_admission();
            debug_assert_eq!(popped.map(|q| q.inv_id), Some(inv_id));
            let p = self.pending.get_mut(&inv_id).expect("queued invocation pending");
            let since = p.queued_since.take().expect("queued invocation has queued_since");
            let waited_s = self.now - since;
            p.queue_s += waited_s;
            self.trace_event(TraceEventKind::QueueAdmit {
                inv: inv_id,
                worker: worker_id,
                waited_s,
            });
            self.admit(inv_id, worker_id, warm);
        }
    }

    /// Demand-driven eviction (DESIGN.md §KeepAlive): evict idle
    /// containers — least-recently-used first, i.e. lowest
    /// `(idle_since, container id)` — until the worker can admit
    /// `(vcpus, mem_mb)`. Feasibility is checked first: if even evicting
    /// *every* idle container would not fit the ask, no warmth is
    /// sacrificed. `Starting`/`Busy` containers are never candidates.
    /// Returns whether the ask now fits.
    fn pressure_evict_for(&mut self, worker_id: usize, vcpus: u32, mem_mb: u32) -> bool {
        debug_assert!(
            self.ka.idle_reserves(),
            "demand-driven eviction without reservation-holding idle frees nothing"
        );
        let w = &self.cluster.workers[worker_id];
        let (idle_vcpus, idle_mem) = w
            .containers
            .values()
            .filter(|c| c.is_warm_idle())
            .fold((0.0, 0.0), |(v, m), c| (v + c.vcpus as f64, m + c.mem_mb as f64));
        if w.free_sched_vcpus() + idle_vcpus < vcpus as f64
            || w.free_mem_mb() + idle_mem < mem_mb as f64
        {
            return false;
        }
        while !self.cluster.workers[worker_id].can_admit(vcpus, mem_mb) {
            let victim = self.cluster.workers[worker_id]
                .containers
                .values()
                .filter(|c| c.is_warm_idle())
                .min_by(|a, b| a.idle_since.total_cmp(&b.idle_since).then(a.id.cmp(&b.id)))
                .map(|c| c.id);
            let Some(cid) = victim else {
                return false;
            };
            self.evict_container(worker_id, cid, EvictReason::Pressure);
        }
        true
    }

    fn cold_start(&mut self, inv_id: u64, worker: usize, func: usize, vcpus: u32, mem_mb: u32) {
        let cid = self.launch_container(worker, func, vcpus, mem_mb, Some(inv_id));
        let p = self.pending.get_mut(&inv_id).expect("pending");
        p.had_cold_start = true;
        let ready = self.cluster.workers[worker].containers[&cid].ready_at;
        // `+=`, not `=`: an invocation whose first cold start died with a
        // crashed worker pays for both launches (0.0 + x is bit-exact, so
        // the single-launch path is unchanged).
        p.cold_start_s += (ready - self.now).max(0.0);
        self.cluster.workers[worker].total_cold_starts += 1;
        self.trace_event(TraceEventKind::ColdStartBegin { inv: inv_id, worker, container: cid });
    }

    /// Create a container (cold). If `for_inv` is set, the invocation is
    /// parked on it; otherwise it is a background launch that goes idle.
    fn launch_container(
        &mut self,
        worker: usize,
        func: usize,
        vcpus: u32,
        mem_mb: u32,
        for_inv: Option<u64>,
    ) -> u64 {
        let cid = self.next_container_id;
        self.next_container_id += 1;
        self.containers_created += 1;
        self.launches.push(LaunchRecord {
            at: self.now,
            worker,
            func,
            vcpus,
            mem_mb,
            background: for_inv.is_none(),
        });
        self.trace_event(TraceEventKind::ContainerLaunch {
            worker,
            container: cid,
            func,
            vcpus,
            mem_mb,
            background: for_inv.is_none(),
        });
        let latency = self
            .rng
            .lognormal(self.cfg.cold_start_mean_s.ln(), self.cfg.cold_start_sigma)
            .clamp(0.1, 10.0);
        let ready = self.now + latency;
        let c = Container::new(cid, func, vcpus, mem_mb, ready);
        self.cluster.insert_container(worker, c);
        if let Some(inv) = for_inv {
            self.waiting_on_container.insert(cid, inv);
        }
        self.push(ready, EventKind::ContainerReady { worker, container: cid });
        cid
    }

    fn on_container_ready(&mut self, worker: usize, container: u64) {
        if self.crashed_starting.remove(&container) {
            // The cold start raced a worker crash: the `Starting`
            // container was already torn down (and its waiter rerouted or
            // failed) by `on_worker_crash` — the ready event is void, not
            // a `ready_miss` tripwire.
            return;
        }
        let Some(idle_epoch) = self.cluster.container_ready(worker, container, self.now) else {
            // A ready event for a container that no longer exists. No
            // teardown path removes a `Starting` container (keep-alive
            // and pressure evictions only ever target `Idle`), so this
            // is a tripwire: counted in release builds, fatal in debug.
            self.ready_miss += 1;
            debug_assert!(false, "container {container} evicted before ready");
            return;
        };
        self.trace_event(TraceEventKind::ContainerReady { worker, container });
        if let Some(inv) = self.waiting_on_container.remove(&container) {
            if !self.pending.contains_key(&inv) {
                // The waiting invocation timed out mid-cold-start (its
                // record is already written): tear the orphan down like
                // any timed-out container and free its reservation.
                self.cluster.remove_container(worker, container);
                self.drain_admission(worker);
                return;
            }
            // The launch reservation rolls over into the busy reservation
            // inside `bind_and_start` — capacity-neutral, nothing to drain.
            self.bind_and_start(inv, worker, container);
        } else {
            // Background container goes idle: its launch reservation is
            // released (unless idle holds reservations), which may admit
            // queued work. `may_prewarm = false`: only containers that
            // actually served work request pre-warms, or an unused
            // pre-warm's own idle transition would chain replacements
            // forever.
            self.schedule_idle_evict(worker, container, idle_epoch, false);
            self.drain_admission(worker);
        }
    }

    /// Bind the invocation to a ready container and start its phases.
    fn bind_and_start(&mut self, inv_id: u64, worker_id: usize, cid: u64) {
        // For the trace: a bind is warm iff this invocation never paid a
        // cold start (its own just-ready container also parks `Idle` for
        // an instant, so the container's state can't distinguish them).
        let was_warm = !self.pending[&inv_id].had_cold_start;
        // Warm-pool accounting: a warm bind consumes the container's
        // idle period (idle container-seconds are the memory-waste
        // proxy), and the first use of a pre-warmed container is a
        // prewarm hit. A just-ready cold start has `idle_since == now`,
        // so it contributes zero.
        {
            let c = self.cluster.workers[worker_id]
                .containers
                .get_mut(&cid)
                .expect("bind: container exists");
            if c.is_warm_idle() {
                self.idle_container_s += (self.now - c.idle_since).max(0.0);
            }
            if c.prewarmed {
                c.prewarmed = false;
                self.prewarm_hits += 1;
            }
        }
        // Container size wins (may be larger than requested).
        let (c_vcpus, c_mem) = self.cluster.acquire_container(worker_id, cid);
        let p = self.pending.get_mut(&inv_id).expect("pending invocation");
        p.container = Some(cid);
        p.vcpus = c_vcpus;
        p.mem_mb = c_mem;
        p.exec_started = Some(self.now);

        // Build the phase list from the ground-truth demand.
        let d = p.demand.clone();
        if self.trace.is_some() {
            self.trace_event(TraceEventKind::Bind {
                inv: inv_id,
                worker: worker_id,
                container: cid,
                vcpus: c_vcpus,
                mem_mb: c_mem,
                warm: was_warm,
            });
            self.trace_event(TraceEventKind::ExecBegin {
                inv: inv_id,
                worker: worker_id,
                container: cid,
            });
        }
        let mut phases: Vec<PhaseSpec> = Vec::new();
        if d.net_bytes > 0.0 {
            phases.push(PhaseSpec { phase: Phase::Net, work: d.net_bytes, demand: 1.0 });
        }
        if d.serial_s > 0.0 {
            phases.push(PhaseSpec { phase: Phase::Serial, work: d.serial_s, demand: 1.0 });
        }
        if d.parallel_cpu_s > 0.0 {
            let par = d.effective_parallelism(c_vcpus as f64);
            phases.push(PhaseSpec { phase: Phase::Parallel, work: d.parallel_cpu_s, demand: par });
        }
        if phases.is_empty() {
            phases.push(PhaseSpec { phase: Phase::Serial, work: 1e-6, demand: 1.0 });
        }
        let first = phases.remove(0);
        let peak = phases
            .iter()
            .chain(std::iter::once(&first))
            .filter(|p| matches!(p.phase, Phase::Serial | Phase::Parallel))
            .map(|p| p.demand)
            .fold(0.0f64, f64::max);
        let active = ActiveInv {
            inv_id,
            container_id: cid,
            alloc_vcpus: c_vcpus as f64,
            remaining: first.work,
            current: first,
            pending: phases,
            cpu_seconds_done: 0.0,
            exec_started: self.now,
            peak_vcpus: peak.max(if d.total_cpu_s() > 0.0 { 1.0 } else { 0.0 }),
            mem_used_gb: d.mem_gb,
        };

        // Advance the worker to `now` before mutating its active set.
        self.cluster.workers[worker_id].advance(self.now);
        self.cluster.workers[worker_id].start_invocation(active, c_vcpus, c_mem);
        self.reschedule_worker(worker_id);

        // OOM: footprint beyond the container's memory kills the
        // invocation partway through (when usage crosses the limit).
        let alloc_gb = c_mem as f64 / 1024.0;
        let ideal = d.ideal_exec_s(c_vcpus as f64, self.cfg.net_gbps);
        if let Some(crossing) = oom_crossing_s(d.mem_gb, alloc_gb, ideal) {
            self.push(self.now + crossing, EventKind::OomKill { inv: inv_id });
        }
        // The platform walltime limit was scheduled at *arrival*
        // (`on_arrival`): decision overhead, admission queueing, and
        // cold-start latency all eat into the budget.
    }

    /// Re-derive the earliest phase completion for a worker and schedule
    /// a PhaseDone event tagged with the current epoch.
    fn reschedule_worker(&mut self, worker_id: usize) {
        let next = {
            let w = &mut self.cluster.workers[worker_id];
            w.next_phase_completion().map(|(dt, _)| (dt, w.epoch))
        };
        if let Some((dt, epoch)) = next {
            if dt.is_finite() {
                // Lower-bound dt so the event strictly advances time even
                // when float residue makes the nominal dt underflow.
                let at = self.now + dt.max(1e-9);
                self.push(at, EventKind::PhaseDone { worker: worker_id, epoch });
            }
        }
    }

    fn on_phase_done(&mut self, worker_id: usize, epoch: u64) {
        if self.cluster.workers[worker_id].epoch != epoch {
            return; // stale
        }
        self.cluster.workers[worker_id].advance(self.now);
        // Completions were collected by `advance` while it progressed the
        // work — no second scan over the active set. Sort so phase
        // transitions, completion records, and `policy.on_complete`
        // feedback (which drives learner SGD state) always happen in
        // invocation-id order regardless of how batches accumulated.
        let mut done_ids = std::mem::take(&mut self.done_scratch);
        let mut finished = std::mem::take(&mut self.finished_scratch);
        self.cluster.workers[worker_id].drain_done(&mut done_ids);
        done_ids.sort_unstable();
        let mut changed = false;
        {
            let w = &mut self.cluster.workers[worker_id];
            for &id in &done_ids {
                // An id may have been OOM-killed or timed out between its
                // phase hitting zero and this event; skip it then.
                let Some(a) = w.active.get_mut(&id) else {
                    continue;
                };
                if a.remaining > 0.0 {
                    continue;
                }
                changed = true;
                loop {
                    if !a.next_phase() {
                        finished.push(id);
                        break;
                    }
                    if a.remaining > 1e-12 {
                        break;
                    }
                    // zero-work phase: skip through
                }
            }
            if changed {
                w.epoch += 1;
            }
        }
        for &id in &finished {
            self.complete(id, Verdict::Completed);
        }
        done_ids.clear();
        finished.clear();
        self.done_scratch = done_ids;
        self.finished_scratch = finished;
        self.reschedule_worker(worker_id);
    }

    fn kill(&mut self, inv_id: u64, verdict: Verdict) {
        // Timeout/OOM events may fire after completion; ignore then.
        let Some(p) = self.pending.get(&inv_id) else {
            return;
        };
        if p.exec_started.is_some() {
            self.complete(inv_id, verdict);
            return;
        }
        // Not bound yet: only the walltime clock (scheduled at arrival)
        // reaches unbound invocations — OOM is scheduled at bind.
        debug_assert_eq!(verdict, Verdict::TimedOut, "only timeouts kill unbound work");
        self.fail_unbound(inv_id, verdict);
    }

    /// A request died before ever binding a container: waiting in the
    /// admission queue, in the decision-overhead window, or on a cold
    /// start still in flight. Removes it from its worker's queue (which
    /// can unblock the head-of-line for everyone behind it) and records
    /// the failure — previously this path panicked on
    /// `p.container.expect("bound container")`.
    fn fail_unbound(&mut self, inv_id: u64, verdict: Verdict) {
        let Some(mut p) = self.pending.remove(&inv_id) else {
            return;
        };
        let worker_id = p.decision.worker;
        let was_queued = self.cluster.workers[worker_id].remove_admission(inv_id).is_some();
        if let Some(since) = p.queued_since.take() {
            p.queue_s += self.now - since;
        }
        let rec = InvocationRecord {
            id: inv_id,
            func: p.req.func,
            input: p.req.input.clone(),
            worker: worker_id,
            vcpus: p.vcpus,
            mem_mb: p.mem_mb,
            requested_vcpus: p.decision.vcpus,
            requested_mem_mb: p.decision.mem_mb,
            arrival: p.req.arrival,
            cold_start_s: p.cold_start_s,
            had_cold_start: p.had_cold_start,
            overhead_s: p.decision.overhead_s,
            queue_s: p.queue_s,
            exec_s: 0.0,
            e2e_s: (self.now - p.req.arrival).max(0.0),
            end: self.now,
            slo_s: p.req.slo_s,
            verdict,
            avg_vcpus_used: 0.0,
            peak_vcpus_used: 0.0,
            mem_used_gb: 0.0,
        };
        self.trace_event(TraceEventKind::End { inv: inv_id, worker: worker_id, verdict });
        self.policy.on_complete(self.now, &rec, &self.cluster);
        self.records.push(rec);
        if was_queued {
            // Removing a queue entry can expose an admissible new head.
            self.drain_admission(worker_id);
        }
    }

    /// Tear down a finished invocation, record it, release the container,
    /// and feed the policy.
    fn complete(&mut self, inv_id: u64, verdict: Verdict) {
        let Some(p) = self.pending.remove(&inv_id) else {
            return;
        };
        let worker_id = p.decision.worker;
        let cid = p.container.expect("bound container");
        self.cluster.workers[worker_id].advance(self.now);
        let active = self.cluster.workers[worker_id]
            .finish_invocation(inv_id, p.vcpus, p.mem_mb)
            .expect("active invocation");
        self.reschedule_worker(worker_id);

        // Release or destroy the container. Failed invocations do not
        // donate warm containers: OOM kills are torn down by the platform,
        // and a function that just burned the full walltime limit gets its
        // container reclaimed rather than parked warm. Either way the
        // container's reservation is released — pop the admission queue.
        match verdict {
            Verdict::Completed => {
                let idle_epoch = self.cluster.release_container(worker_id, cid, self.now);
                // This container served work, so it may request a
                // pre-warmed replacement when its TTL is short.
                self.schedule_idle_evict(worker_id, cid, idle_epoch, true);
            }
            Verdict::OomKilled | Verdict::TimedOut | Verdict::Failed => {
                self.cluster.remove_container(worker_id, cid);
            }
        }
        self.drain_admission(worker_id);

        let exec_started = active.exec_started;
        let exec_s = (self.now - exec_started).max(0.0);
        let avg_used = if exec_s > 0.0 {
            active.cpu_seconds_done / exec_s
        } else {
            0.0
        };
        let rec = InvocationRecord {
            id: inv_id,
            func: p.req.func,
            input: p.req.input.clone(),
            worker: worker_id,
            vcpus: p.vcpus,
            mem_mb: p.mem_mb,
            requested_vcpus: p.decision.vcpus,
            requested_mem_mb: p.decision.mem_mb,
            arrival: p.req.arrival,
            cold_start_s: p.cold_start_s,
            had_cold_start: p.had_cold_start,
            overhead_s: p.decision.overhead_s,
            queue_s: p.queue_s,
            exec_s,
            e2e_s: (self.now - p.req.arrival).max(0.0),
            end: self.now,
            slo_s: p.req.slo_s,
            verdict,
            avg_vcpus_used: avg_used,
            peak_vcpus_used: active.peak_vcpus,
            mem_used_gb: active.mem_used_gb.min(p.mem_mb as f64 / 1024.0),
        };
        self.trace_event(TraceEventKind::End { inv: inv_id, worker: worker_id, verdict });
        self.policy.on_complete(self.now, &rec, &self.cluster);
        self.records.push(rec);
    }

    /// One idle transition: consult the keep-alive policy, stamp the TTL
    /// deadline and any pre-warm intent on the container, and schedule
    /// the epoch-tagged `Evict`. Both idle paths — background-ready and
    /// release-after-completion — funnel through here (previously two
    /// duplicated `Evict` push blocks). The pre-warm is *not* scheduled
    /// here: it materializes only when the expiry actually evicts the
    /// container (`evict_container`), so a reuse during the grace
    /// window cancels the pending pre-warm along with the stale
    /// eviction — no stale-pre-warm race exists by construction.
    /// `may_prewarm` gates the intent: only containers that actually
    /// served work get a replacement, so an unused pre-warm's own idle
    /// transition cannot chain further pre-warms after demand stops.
    ///
    /// This is the *only* place allowed to construct `EventKind::Evict`
    /// (lint rule D009): the idle-epoch staleness guard is sound exactly
    /// because every eviction deadline is stamped here.
    fn schedule_idle_evict(
        &mut self,
        worker: usize,
        container: u64,
        idle_epoch: u64,
        may_prewarm: bool,
    ) {
        let func = self.cluster.workers[worker].containers[&container].func;
        let d = self.ka.on_idle(self.now, func);
        let ttl_s = d.ttl_s.max(0.0);
        let deadline = self.now + ttl_s;
        let prewarm_at = if may_prewarm {
            d.prewarm_at.map(|at| at.max(deadline))
        } else {
            None
        };
        {
            let c = self.cluster.workers[worker]
                .containers
                .get_mut(&container)
                .expect("idle container exists");
            debug_assert!(c.is_warm_idle() && c.idle_epoch == idle_epoch);
            c.evict_deadline = deadline;
            c.prewarm_at = prewarm_at;
        }
        self.trace_event(TraceEventKind::ContainerIdle {
            worker,
            container,
            ttl_s,
            prewarm: prewarm_at.is_some(),
        });
        self.push(deadline, EventKind::Evict { worker, container, idle_epoch });
    }

    /// A hybrid-histogram pre-warm fires: launch a background container
    /// of the evicted size if the worker has queue-aware capacity, else
    /// shed it (pre-warming must never jump ahead of parked demand —
    /// the same rule as policy-requested background launches).
    fn on_prewarm(&mut self, worker: usize, func: usize, vcpus: u32, mem_mb: u32) {
        if self.cluster.workers[worker].has_capacity(vcpus, mem_mb) {
            let cid = self.launch_container(worker, func, vcpus, mem_mb, None);
            self.cluster.workers[worker]
                .containers
                .get_mut(&cid)
                .expect("just launched")
                .prewarmed = true;
            self.prewarm_launches += 1;
            self.trace_event(TraceEventKind::PrewarmFired { worker, func, vcpus, mem_mb });
        } else {
            self.background_shed += 1;
            self.trace_event(TraceEventKind::PrewarmShed { worker });
        }
    }

    /// Tear down an idle container through the keep-alive lifecycle:
    /// account its idle period, log the eviction, remove it everywhere
    /// (warm indexes + any reservation via `Cluster::remove_container`),
    /// and fire the pre-warm the policy attached to this idle period —
    /// only on TTL expiry: a pressure eviction yielded its capacity to
    /// queued demand, so compensating warmth would immediately be shed.
    /// Only `Idle` containers are ever eviction targets —
    /// `Starting`/`Busy` hold work.
    fn evict_container(&mut self, worker: usize, cid: u64, reason: EvictReason) {
        let (func, vcpus, mem_mb, idle_since, deadline, prewarm_at) = {
            let c = &self.cluster.workers[worker].containers[&cid];
            debug_assert!(c.is_warm_idle(), "keep-alive eviction of a non-idle container");
            (c.func, c.vcpus, c.mem_mb, c.idle_since, c.evict_deadline, c.prewarm_at)
        };
        self.idle_container_s += (self.now - idle_since).max(0.0);
        if reason == EvictReason::Pressure {
            self.pressure_evictions += 1;
        }
        self.evictions.push(EvictionRecord {
            at: self.now,
            worker,
            container: cid,
            func,
            reason,
            deadline,
            idle_since,
        });
        self.trace_event(TraceEventKind::ContainerEvict { worker, container: cid, reason });
        self.cluster.remove_container(worker, cid);
        if let (EvictReason::Expired, Some(at)) = (reason, prewarm_at) {
            self.push(at.max(self.now), EventKind::PreWarm { worker, func, vcpus, mem_mb });
        }
    }

    fn on_evict(&mut self, worker: usize, container: u64, idle_epoch: u64) {
        // The idle-epoch staleness guard: expiry only fires when the
        // container is still in the *same* idle period the event was
        // scheduled for — a warm reuse in between bumped the epoch, and
        // the new idle period scheduled its own eviction.
        let expired = match self.cluster.workers[worker].containers.get(&container) {
            None => false,
            Some(c) => c.is_warm_idle() && c.idle_epoch == idle_epoch,
        };
        if expired {
            self.evict_container(worker, container, EvictReason::Expired);
            // Under reservation-holding keep-alive this expiry frees real
            // capacity; otherwise the drain keeps the "pop on every
            // capacity release" contract literal (complete, evict,
            // teardown).
            self.drain_admission(worker);
        }
    }

    /// Crash rerouting: the first up worker after the dead one (wrapping
    /// scan — deterministic) that can admit the ask right now; otherwise
    /// the first up worker at all, where the work parks on the admission
    /// queue. `None` only when the entire cluster is down.
    fn reroute_target(&self, from: usize, vcpus: u32, mem_mb: u32) -> Option<usize> {
        let n = self.cluster.len();
        let mut fallback = None;
        for step in 1..n {
            let w = (from + step) % n;
            if self.cluster.workers[w].down {
                continue;
            }
            if self.cluster.workers[w].can_admit(vcpus, mem_mb) {
                return Some(w);
            }
            if fallback.is_none() {
                fallback = Some(w);
            }
        }
        fallback
    }

    /// Re-point a crash-displaced invocation at `new_worker` and push it
    /// through the ordinary admission path as a cold start (its old warm
    /// hit and background intent died with the worker); with nowhere to
    /// go it dies `Failed`.
    fn requeue_or_fail(&mut self, inv_id: u64, target: Option<usize>) {
        match target {
            Some(new_worker) => {
                let p = self.pending.get_mut(&inv_id).expect("displaced invocation pending");
                p.decision.worker = new_worker;
                p.decision.container = ContainerChoice::Cold;
                p.decision.background = None;
                self.requeued_on_crash += 1;
                self.try_admit(inv_id);
            }
            None => self.fail_unbound(inv_id, Verdict::Failed),
        }
    }

    /// Fault injection (DESIGN.md §Faults): the worker dies. Everything on
    /// it is lost — in-flight invocations get `Failed` terminal records,
    /// queued and cold-start-waiting work re-enters the admission path on
    /// another worker (or fails with the whole cluster down), the warm
    /// pool and every reservation are torn down, and the policy is told
    /// last so learners can drop per-worker state.
    fn on_worker_crash(&mut self, worker_id: usize) {
        // The plan never crashes a down worker (cycles are disjoint); the
        // guard keeps a malformed schedule from corrupting state.
        debug_assert!(!self.cluster.workers[worker_id].down, "crash while already down");
        if self.cluster.workers[worker_id].down {
            return;
        }
        // Down first: every capacity predicate now answers false, so the
        // requeue probes below and the drains triggered by completions
        // steer around this worker.
        self.cluster.workers[worker_id].down = true;
        self.worker_crashes += 1;
        self.trace_event(TraceEventKind::WorkerCrash { worker: worker_id });
        self.cluster.workers[worker_id].advance(self.now);

        // 1. In-flight invocations die with a clean `Failed` record, in
        //    ascending id order (BTreeMap iteration). `complete` tears
        //    down each busy container and feeds the policy; its trailing
        //    queue drain no-ops on the down worker.
        let active: Vec<u64> = self.cluster.workers[worker_id].active.keys().copied().collect();
        for id in active {
            self.complete(id, Verdict::Failed);
        }

        // 2. Queued admissions reroute in FIFO order, keeping their
        //    walltime clocks and accrued queue time.
        while let Some(q) = self.cluster.workers[worker_id].pop_admission() {
            let p = self.pending.get_mut(&q.inv_id).expect("queued invocation pending");
            if let Some(since) = p.queued_since.take() {
                p.queue_s += self.now - since;
            }
            let target = self.reroute_target(worker_id, q.vcpus, q.mem_mb);
            self.requeue_or_fail(q.inv_id, target);
        }

        // 3. The remaining containers are `Starting` (busy ones died in
        //    step 1) or idle. Cold starts in flight are lost: their ready
        //    events are voided via `crashed_starting` and their waiters
        //    reroute like queued work, in ascending invocation id. Idle
        //    periods close out in the idle-time ledger first.
        let mut starting: Vec<u64> = Vec::new();
        let mut trailing_idle = 0.0;
        for (cid, c) in &self.cluster.workers[worker_id].containers {
            if c.is_warm_idle() {
                trailing_idle += (self.now - c.idle_since).max(0.0);
            } else {
                starting.push(*cid);
            }
        }
        self.idle_container_s += trailing_idle;
        let mut lost_waiters: Vec<u64> = Vec::new();
        for &cid in &starting {
            self.crashed_starting.insert(cid);
            if let Some(inv) = self.waiting_on_container.remove(&cid) {
                // A waiter may have timed out mid-cold-start already (its
                // record is written); only live ones reroute.
                if self.pending.contains_key(&inv) {
                    lost_waiters.push(inv);
                }
            }
        }
        let doomed: Vec<u64> =
            self.cluster.workers[worker_id].containers.keys().copied().collect();
        for cid in doomed {
            self.cluster.remove_container(worker_id, cid);
        }
        lost_waiters.sort_unstable();
        for inv in lost_waiters {
            let (vcpus, mem_mb) = {
                let p = &self.pending[&inv];
                (p.decision.vcpus, p.decision.mem_mb)
            };
            let target = self.reroute_target(worker_id, vcpus, mem_mb);
            self.requeue_or_fail(inv, target);
        }

        // 4. The policy hears about it last, with the post-crash cluster,
        //    so learners can forget what this worker's runs taught them.
        self.policy.on_worker_crash(self.now, worker_id, &self.cluster);
    }

    /// The crashed worker returns, empty: cold warm pool, zero
    /// reservations. Work routed at it while down parked on its admission
    /// queue and drains now.
    fn on_worker_restart(&mut self, worker_id: usize) {
        debug_assert!(self.cluster.workers[worker_id].down, "restart of a live worker");
        if !self.cluster.workers[worker_id].down {
            return;
        }
        self.cluster.workers[worker_id].down = false;
        self.trace_event(TraceEventKind::WorkerRestart { worker: worker_id });
        // No active work existed while down; this just moves the
        // processor-sharing clock past the outage.
        self.cluster.workers[worker_id].advance(self.now);
        self.drain_admission(worker_id);
    }
}

/// Time after exec start at which a footprint of `mem_gb` crosses an
/// `alloc_gb` container limit, or None when it fits. The boundary is
/// inclusive: a footprint exactly equal to the allocation runs to
/// completion (cgroup limits kill on *exceeding* the limit).
pub fn oom_crossing_s(mem_gb: f64, alloc_gb: f64, ideal_exec_s: f64) -> Option<f64> {
    if mem_gb <= alloc_gb {
        return None;
    }
    let frac = (alloc_gb / mem_gb).clamp(0.05, 0.95);
    Some(ideal_exec_s * frac)
}

/// Convenience: run a request list under a policy on a config.
pub fn simulate<P: Policy>(cfg: SimConfig, policy: &mut P, requests: Vec<Request>) -> SimResult {
    Engine::new(cfg, policy, requests).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::{InputKind, InputSpec};
    use crate::functions::catalog::index_of;

    /// Fixed-size policy: every invocation gets (vcpus, mem) cold on
    /// worker round-robin; no warm reuse logic (engine handles pools).
    struct FixedPolicy {
        vcpus: u32,
        mem_mb: u32,
        next: usize,
        reuse_warm: bool,
    }

    impl Policy for FixedPolicy {
        fn name(&self) -> String {
            "fixed".into()
        }

        fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
            let container = if self.reuse_warm {
                match cluster.find_warm_exact(req.func, self.vcpus, self.mem_mb) {
                    Some((w, cid)) => {
                        return Decision {
                            worker: w,
                            vcpus: self.vcpus,
                            mem_mb: self.mem_mb,
                            container: ContainerChoice::Warm(cid),
                            background: None,
                            overhead_s: 0.0,
                        }
                    }
                    None => ContainerChoice::Cold,
                }
            } else {
                ContainerChoice::Cold
            };
            let w = self.next % cluster.len();
            self.next += 1;
            Decision {
                worker: w,
                vcpus: self.vcpus,
                mem_mb: self.mem_mb,
                container,
                background: None,
                overhead_s: 0.0,
            }
        }
    }

    fn qr_request(id: u64, at: f64) -> Request {
        let mut input = InputSpec::new(InputKind::Payload);
        input.length = 100.0;
        input.size_bytes = 100.0;
        Request { id, func: index_of("qr").unwrap(), input, arrival: at, slo_s: 1.0 }
    }

    fn compress_request(id: u64, at: f64, mb: f64) -> Request {
        let mut input = InputSpec::new(InputKind::File);
        input.id = id | 1;
        input.size_bytes = mb * 1024.0 * 1024.0;
        Request { id, func: index_of("compress").unwrap(), input, arrival: at, slo_s: 60.0 }
    }

    #[test]
    fn single_invocation_completes() {
        let mut p = FixedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: false };
        let res = simulate(SimConfig::small(), &mut p, vec![qr_request(1, 0.0)]);
        assert_eq!(res.records.len(), 1);
        let r = &res.records[0];
        assert_eq!(r.verdict, Verdict::Completed);
        assert!(r.had_cold_start);
        assert!(r.cold_start_s > 0.0);
        assert!(r.exec_s > 0.05 && r.exec_s < 2.0, "exec {}", r.exec_s);
        assert!(r.e2e_s >= r.exec_s + r.cold_start_s - 1e-9);
    }

    #[test]
    fn warm_reuse_avoids_cold_start() {
        let mut p = FixedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: true };
        let reqs = vec![qr_request(1, 0.0), qr_request(2, 30.0)];
        let res = simulate(SimConfig::small(), &mut p, reqs);
        let rs = res.sorted_records();
        assert!(rs[0].had_cold_start);
        assert!(!rs[1].had_cold_start, "second run must hit the warm pool");
        assert_eq!(rs[1].cold_start_s, 0.0);
    }

    #[test]
    fn keep_alive_eviction_forces_new_cold_start() {
        let mut cfg = SimConfig::small();
        cfg.keep_alive_s = 5.0;
        let mut p = FixedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: true };
        let reqs = vec![qr_request(1, 0.0), qr_request(2, 60.0)];
        let res = simulate(cfg, &mut p, reqs);
        let rs = res.sorted_records();
        assert!(rs[1].had_cold_start, "container evicted after keep-alive");
        // the eviction log witnesses both TTL expiries, exactly at their
        // policy deadlines, with no pressure evictions under `fixed`
        assert_eq!(res.evictions.len(), 2);
        for e in &res.evictions {
            assert_eq!(e.reason, EvictReason::Expired);
            assert!((e.at - e.deadline).abs() < 1e-9, "expiry at its deadline");
            assert!((e.at - e.idle_since - 5.0).abs() < 1e-9, "5 s idle TTL");
        }
        assert_eq!(res.pressure_evictions, 0);
        assert_eq!(res.ready_miss, 0);
    }

    #[test]
    fn stale_evict_event_spares_reused_container() {
        // The idle-epoch staleness guard: a warm reuse between an Evict
        // being scheduled and firing bumps the idle epoch, so the stale
        // event must NOT evict the (re-idled) container — only the
        // eviction scheduled for the *current* idle period may.
        let mut cfg = SimConfig::small();
        cfg.keep_alive_s = 5.0;
        let mut p = FixedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: true };
        // req 2 reuses the container before req 1's eviction deadline
        // (completion + 5 s ≥ 5 s); req 3 lands within 5 s of req 2's
        // completion but *after* req 1's stale deadline, so it only
        // stays warm if the stale eviction was skipped.
        let reqs = vec![qr_request(1, 0.0), qr_request(2, 4.0), qr_request(3, 8.0)];
        let res = simulate(cfg, &mut p, reqs);
        let rs = res.sorted_records();
        assert!(!rs[1].had_cold_start, "req 2 reuses before the deadline");
        assert!(
            !rs[2].had_cold_start,
            "stale evict event must spare the reused container for req 3"
        );
        // exactly one real eviction in the end: the final idle period's
        assert_eq!(res.evictions.len(), 1);
        assert_eq!(res.evictions[0].reason, EvictReason::Expired);
        assert!((res.evictions[0].at - res.evictions[0].deadline).abs() < 1e-9);
        res.cluster.assert_warm_consistent();
    }

    #[test]
    fn oom_kill_when_memory_too_small() {
        // sentiment with batch 3000 needs > 3 GB
        let mut input = InputSpec::new(InputKind::Payload);
        input.length = 3000.0;
        let req = Request {
            id: 1,
            func: index_of("sentiment").unwrap(),
            input,
            arrival: 0.0,
            slo_s: 30.0,
        };
        let mut p = FixedPolicy { vcpus: 2, mem_mb: 512, next: 0, reuse_warm: false };
        let res = simulate(SimConfig::small(), &mut p, vec![req]);
        assert_eq!(res.records[0].verdict, Verdict::OomKilled);
        assert!(res.records[0].slo_violated());
    }

    #[test]
    fn timeout_fires_for_starved_allocation() {
        // large compress on 1 vCPU (~175 s) exceeds a 100 s walltime limit
        let mut cfg = SimConfig::small();
        cfg.timeout_s = 100.0;
        let mut p = FixedPolicy { vcpus: 1, mem_mb: 4096, next: 0, reuse_warm: false };
        let res = simulate(cfg, &mut p, vec![compress_request(1, 0.0, 2000.0)]);
        let r = &res.records[0];
        assert_eq!(r.verdict, Verdict::TimedOut);
        // The limit is walltime from *arrival*: e2e pins to the deadline,
        // and the cold start ate part of the execution budget.
        assert!((r.e2e_s - 100.0).abs() < 1e-6, "e2e {} must hit the deadline", r.e2e_s);
        assert!(r.exec_s <= 100.0 - r.cold_start_s + 1e-6);
        assert!(r.exec_s >= 85.0, "exec {} should still run most of the window", r.exec_s);
    }

    #[test]
    fn timeout_counts_decision_overhead_and_teardown_blocks_warm_reuse() {
        struct SlowDecision {
            next: usize,
        }
        impl Policy for SlowDecision {
            fn name(&self) -> String {
                "slow-decision".into()
            }
            fn on_request(&mut self, _now: SimTime, req: &Request, cluster: &Cluster) -> Decision {
                // route warm when possible so a donated container would show
                let (worker, container) = match cluster.find_warm_exact(req.func, 1, 4096) {
                    Some((w, cid)) => (w, ContainerChoice::Warm(cid)),
                    None => {
                        let w = self.next % cluster.len();
                        self.next += 1;
                        (w, ContainerChoice::Cold)
                    }
                };
                Decision {
                    worker,
                    vcpus: 1,
                    mem_mb: 4096,
                    container,
                    background: None,
                    overhead_s: 30.0, // pathological decision latency
                }
            }
        }
        let mut cfg = SimConfig::small();
        cfg.timeout_s = 100.0;
        let reqs = vec![compress_request(1, 0.0, 2000.0), compress_request(2, 150.0, 2000.0)];
        let res = simulate(cfg, &mut SlowDecision { next: 0 }, reqs);
        let rs = res.sorted_records();
        // 30 s decision overhead + cold start count against the 100 s
        // budget: the run is cut at arrival + 100 s, not exec + 100 s.
        assert_eq!(rs[0].verdict, Verdict::TimedOut);
        assert!((rs[0].e2e_s - 100.0).abs() < 1e-6);
        assert!(rs[0].exec_s < 70.0, "exec {} capped by overhead + cold start", rs[0].exec_s);
        // the timed-out container was torn down, not parked warm
        assert!(rs[1].had_cold_start, "timed-out run must not donate a warm container");
        res.cluster.assert_warm_consistent();
    }

    #[test]
    fn oom_boundary_footprint_equal_to_allocation_survives() {
        // `oom_crossing_s` is the exact predicate `bind_and_start` uses to
        // decide whether an OomKill event exists at all, so pinning it
        // pins the engine: the boundary is inclusive — a footprint equal
        // to the allocation schedules no kill.
        assert_eq!(oom_crossing_s(4.0, 4.0, 10.0), None, "exact fit must not OOM");
        assert_eq!(oom_crossing_s(3.99, 4.0, 10.0), None);
        assert_eq!(oom_crossing_s(0.5, 0.5, 3.0), None, "boundary holds at any size");
        let t = oom_crossing_s(4.0 + 1e-9, 4.0, 10.0).expect("above the limit OOMs");
        assert!(t > 0.0 && t <= 10.0 * 0.95 + 1e-12);
        // engine sanity on the fitting side: a footprint under the
        // allocation runs to completion, never OomKilled.
        let mut p = FixedPolicy { vcpus: 2, mem_mb: 4096, next: 0, reuse_warm: false };
        let res = simulate(SimConfig::small(), &mut p, vec![qr_request(1, 0.0)]);
        assert_eq!(res.records[0].verdict, Verdict::Completed);
    }

    #[test]
    fn more_vcpus_speed_up_parallel_function() {
        let run = |vcpus: u32| {
            let mut p = FixedPolicy { vcpus, mem_mb: 4096, next: 0, reuse_warm: false };
            let res = simulate(SimConfig::small(), &mut p, vec![compress_request(1, 0.0, 1024.0)]);
            res.records[0].exec_s
        };
        let t2 = run(2);
        let t16 = run(16);
        assert!(t16 < 0.5 * t2, "16 vCPUs must be much faster: {t2} vs {t16}");
    }

    #[test]
    fn contention_stretches_execution() {
        // Many simultaneous compress jobs (2 GB inputs parallelize to ~31
        // vCPUs each) on one worker exceed 96 physical cores and slow each
        // other down. The admission limit is raised above the aggregate
        // ask (6 x 32 = 192) so all six *run* concurrently — this test
        // pins the processor-sharing model, not admission control (which
        // would otherwise serialize them; see the admission tests).
        let cfg =
            || SimConfig { workers: 1, sched_vcpu_limit: 200.0, ..SimConfig::default() };
        let solo = {
            let mut p = FixedPolicy { vcpus: 32, mem_mb: 4096, next: 0, reuse_warm: false };
            let res = simulate(cfg(), &mut p, vec![compress_request(1, 0.0, 2000.0)]);
            res.records[0].exec_s
        };
        let crowded = {
            let mut p = FixedPolicy { vcpus: 32, mem_mb: 4096, next: 0, reuse_warm: false };
            let reqs: Vec<Request> =
                (0..6).map(|i| compress_request(i + 1, 0.0, 2000.0)).collect();
            let res = simulate(cfg(), &mut p, reqs);
            res.records.iter().map(|r| r.exec_s).fold(0.0f64, f64::max)
        };
        assert!(
            crowded > 1.3 * solo,
            "6x~31 vCPUs on 96 cores must contend: solo {solo} crowded {crowded}"
        );
    }

    #[test]
    fn utilization_bounded_by_allocation() {
        let mut p = FixedPolicy { vcpus: 8, mem_mb: 4096, next: 0, reuse_warm: false };
        let res = simulate(SimConfig::small(), &mut p, vec![compress_request(1, 0.0, 256.0)]);
        let r = &res.records[0];
        assert!(r.avg_vcpus_used <= r.vcpus as f64 + 1e-9);
        assert!(r.peak_vcpus_used <= r.vcpus as f64 + 1e-9);
        assert!(r.avg_vcpus_used > 0.5, "compress should keep cores busy");
    }

    #[test]
    fn single_threaded_never_uses_more_than_one_core() {
        let mut p = FixedPolicy { vcpus: 12, mem_mb: 1024, next: 0, reuse_warm: false };
        let res = simulate(SimConfig::small(), &mut p, vec![qr_request(1, 0.0)]);
        let r = &res.records[0];
        assert!(r.peak_vcpus_used <= 1.0 + 1e-9);
        assert!(r.avg_vcpus_used <= 1.0 + 1e-9);
    }

    #[test]
    fn background_launch_creates_idle_container() {
        struct BgPolicy;
        impl Policy for BgPolicy {
            fn name(&self) -> String {
                "bg".into()
            }
            fn on_request(&mut self, _now: SimTime, _req: &Request, _cl: &Cluster) -> Decision {
                Decision {
                    worker: 0,
                    vcpus: 2,
                    mem_mb: 512,
                    container: ContainerChoice::Cold,
                    background: Some(super::super::BackgroundLaunch {
                        worker: 1,
                        vcpus: 4,
                        mem_mb: 1024,
                    }),
                    overhead_s: 0.0,
                }
            }
        }
        let mut p = BgPolicy;
        let res = simulate(SimConfig::small(), &mut p, vec![qr_request(1, 0.0)]);
        assert_eq!(res.background_launches, 1);
        assert_eq!(res.containers_created, 2, "1 cold + 1 background");
        // the background launch landed on worker 1 with the right size
        // (it is keep-alive-evicted before the event queue drains, so we
        // check the launch log rather than the final pool)
        let bg: Vec<_> = res.launches.iter().filter(|l| l.background).collect();
        assert_eq!(bg.len(), 1);
        assert_eq!(bg[0].worker, 1);
        assert_eq!(bg[0].vcpus, 4);
        assert_eq!(bg[0].mem_mb, 1024);
        let qr = index_of("qr").unwrap();
        assert_eq!(res.unique_container_sizes(qr), 2);
    }

    #[test]
    fn admission_queue_is_fifo_and_never_overcommits() {
        // 12 identical invocations hit one worker whose limit fits two
        // 8-vCPU containers: the engine must serialize admission through
        // the FIFO queue instead of oversubscribing (which the per-event
        // debug asserts would catch immediately).
        let cfg = SimConfig { workers: 1, sched_vcpu_limit: 16.0, ..SimConfig::default() };
        let mut p = FixedPolicy { vcpus: 8, mem_mb: 512, next: 0, reuse_warm: false };
        let reqs: Vec<Request> = (0..12).map(|i| qr_request(i + 1, 0.0)).collect();
        let res = simulate(cfg, &mut p, reqs);
        assert_eq!(res.records.len(), 12);
        assert!(res.records.iter().all(|r| r.verdict == Verdict::Completed));
        // only the first two fit immediately; everyone else queued
        let queued: Vec<&InvocationRecord> =
            res.sorted_records().into_iter().filter(|r| r.queue_s > 0.0).collect();
        assert_eq!(queued.len(), 10, "10 of 12 must wait for admission");
        // FIFO: identical same-time requests leave the queue in id order,
        // so queue waits are non-decreasing in id
        let mut by_id: Vec<&InvocationRecord> = res.records.iter().collect();
        by_id.sort_by_key(|r| r.id);
        for pair in by_id.windows(2) {
            assert!(
                pair[1].queue_s >= pair[0].queue_s - 1e-12,
                "FIFO violated: id {} waited {} but id {} waited {}",
                pair[0].id,
                pair[0].queue_s,
                pair[1].id,
                pair[1].queue_s
            );
        }
        // the reservation peak is the release-build invariant witness
        assert!(res.cluster.peak_allocated_vcpus() <= 16.0);
        res.cluster.assert_admission_consistent();
        res.cluster.assert_warm_consistent();
    }

    #[test]
    fn request_dies_in_admission_queue_with_timeout_record() {
        // Worker fits one 8-vCPU container; two long jobs arrive at once
        // with a 5 s walltime limit. The second never binds — it must die
        // *in queue* with a TimedOut record (this used to panic on
        // `p.container.expect("bound container")`).
        let cfg = SimConfig {
            workers: 1,
            sched_vcpu_limit: 8.0,
            timeout_s: 5.0,
            ..SimConfig::default()
        };
        let mut p = FixedPolicy { vcpus: 8, mem_mb: 4096, next: 0, reuse_warm: false };
        let reqs = vec![compress_request(1, 0.0, 2000.0), compress_request(2, 0.0, 2000.0)];
        let res = simulate(cfg, &mut p, reqs);
        assert_eq!(res.records.len(), 2, "both requests must produce records");
        let rs = res.sorted_records();
        let r2 = rs.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(r2.verdict, Verdict::TimedOut);
        assert_eq!(r2.exec_s, 0.0, "never executed");
        assert!(r2.queue_s > 0.0, "died waiting for admission: {}", r2.queue_s);
        assert!((r2.e2e_s - 5.0).abs() < 1e-6, "walltime counted from arrival");
        res.cluster.assert_admission_consistent();
    }

    #[test]
    fn stale_warm_fallback_goes_through_admission() {
        // A decision's warm container can vanish before BeginExec; the
        // cold fallback must re-check admission instead of allocating
        // unconditionally on the (full) decided worker.
        struct StaleWarm {
            calls: usize,
        }
        impl Policy for StaleWarm {
            fn name(&self) -> String {
                "stale-warm".into()
            }
            fn on_request(&mut self, _now: SimTime, _req: &Request, _cl: &Cluster) -> Decision {
                self.calls += 1;
                Decision {
                    worker: 0,
                    vcpus: 16,
                    mem_mb: 2048,
                    // second request claims a warm container that never
                    // existed — the engine must fall back *through* the
                    // admission path
                    container: if self.calls == 1 {
                        ContainerChoice::Cold
                    } else {
                        ContainerChoice::Warm(999)
                    },
                    background: None,
                    overhead_s: 0.0,
                }
            }
        }
        let cfg = SimConfig { workers: 1, sched_vcpu_limit: 16.0, ..SimConfig::default() };
        let mut p = StaleWarm { calls: 0 };
        let reqs = vec![qr_request(1, 0.0), qr_request(2, 0.1)];
        let res = simulate(cfg, &mut p, reqs);
        let rs = res.sorted_records();
        assert_eq!(rs[1].verdict, Verdict::Completed);
        assert!(rs[1].had_cold_start, "stale warm hit falls back to cold");
        assert!(
            rs[1].queue_s > 0.0,
            "fallback must wait for capacity, not bypass it: queue_s {}",
            rs[1].queue_s
        );
        assert!(res.cluster.peak_allocated_vcpus() <= 16.0, "no overcommit via fallback");
        res.cluster.assert_admission_consistent();
    }

    #[test]
    fn background_launch_waits_for_its_decision() {
        // The pre-warm rides the decision that requested it: with 5 s of
        // decision overhead, the launch fires at BeginExec (t=5), never
        // at arrival (t=0).
        struct SlowBg;
        impl Policy for SlowBg {
            fn name(&self) -> String {
                "slow-bg".into()
            }
            fn on_request(&mut self, _now: SimTime, _req: &Request, _cl: &Cluster) -> Decision {
                Decision {
                    worker: 0,
                    vcpus: 2,
                    mem_mb: 512,
                    container: ContainerChoice::Cold,
                    background: Some(super::super::BackgroundLaunch {
                        worker: 1,
                        vcpus: 4,
                        mem_mb: 1024,
                    }),
                    overhead_s: 5.0,
                }
            }
        }
        let res = simulate(SimConfig::small(), &mut SlowBg, vec![qr_request(1, 0.0)]);
        assert_eq!(res.background_launches, 1);
        let bg: Vec<_> = res.launches.iter().filter(|l| l.background).collect();
        assert_eq!(bg.len(), 1);
        assert!(
            (bg[0].at - 5.0).abs() < 1e-9,
            "pre-warm at t={} must follow its decision (t=5), not precede it",
            bg[0].at
        );
    }

    #[test]
    fn background_launch_shed_when_target_cannot_admit() {
        // The foreground reservation leaves 10 free vCPUs; a 16-vCPU
        // pre-warm on the same worker must be shed (never queued, never
        // admitted over the limit).
        struct GreedyBg;
        impl Policy for GreedyBg {
            fn name(&self) -> String {
                "greedy-bg".into()
            }
            fn on_request(&mut self, _now: SimTime, _req: &Request, _cl: &Cluster) -> Decision {
                Decision {
                    worker: 0,
                    vcpus: 80,
                    mem_mb: 1024,
                    container: ContainerChoice::Cold,
                    background: Some(super::super::BackgroundLaunch {
                        worker: 0,
                        vcpus: 16,
                        mem_mb: 1024,
                    }),
                    overhead_s: 0.0,
                }
            }
        }
        let cfg = SimConfig { workers: 1, ..SimConfig::default() };
        let res = simulate(cfg, &mut GreedyBg, vec![qr_request(1, 0.0)]);
        assert_eq!(res.background_shed, 1, "inadmissible pre-warm is shed");
        assert_eq!(res.background_launches, 0);
        assert!(res.cluster.peak_allocated_vcpus() <= 90.0);
        res.cluster.assert_admission_consistent();
    }

    #[test]
    fn event_ordering_is_total_even_with_nan() {
        let e = |at: f64, seq: u64| Event { at, seq, kind: EventKind::BeginExec(0) };
        let mut heap = BinaryHeap::new();
        heap.push(e(2.0, 1));
        heap.push(e(f64::NAN.copysign(1.0), 2));
        heap.push(e(1.0, 3));
        heap.push(e(3.0, 4));
        heap.push(e(f64::NAN.copysign(-1.0), 5));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop()).map(|ev| ev.seq).collect();
        // finite timestamps ascend; NaN timestamps take deterministic
        // sign-dependent positions (negative NaN before -inf, positive
        // NaN after +inf) instead of collapsing to Equal mid-heap
        assert_eq!(order, vec![5, 3, 1, 4, 2]);
    }

    #[test]
    fn event_ties_break_fifo_by_seq() {
        let e = |at: f64, seq: u64| Event { at, seq, kind: EventKind::BeginExec(0) };
        let mut heap = BinaryHeap::new();
        heap.push(e(1.0, 9));
        heap.push(e(1.0, 2));
        heap.push(e(1.0, 5));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop()).map(|ev| ev.seq).collect();
        assert_eq!(order, vec![2, 5, 9], "same-time events pop in push order");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut p = FixedPolicy { vcpus: 4, mem_mb: 2048, next: 0, reuse_warm: true };
            let reqs: Vec<Request> =
                (0..20).map(|i| compress_request(i + 1, i as f64 * 0.5, 128.0)).collect();
            let res = simulate(SimConfig::small(), &mut p, reqs);
            res.sorted_records()
                .iter()
                .map(|r| (r.exec_s * 1e9) as u64)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn all_requests_produce_records() {
        let mut p = FixedPolicy { vcpus: 4, mem_mb: 2048, next: 0, reuse_warm: true };
        let reqs: Vec<Request> = (0..50)
            .map(|i| {
                if i % 2 == 0 {
                    qr_request(i + 1, i as f64 * 0.1)
                } else {
                    compress_request(i + 1, i as f64 * 0.1, 100.0)
                }
            })
            .collect();
        let res = simulate(SimConfig::small(), &mut p, reqs);
        assert_eq!(res.records.len(), 50);
    }
}
