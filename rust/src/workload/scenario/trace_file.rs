//! Trace replay: per-minute invocation counts from a CSV in the Azure
//! Functions production-trace schema (Shahrad et al.),
//! `HashOwner,HashApp,HashFunction,Trigger,1,2,...,N` — one row per
//! function, one numeric column per minute of the day. All rows are
//! summed into a cluster-wide per-minute profile, the profile is rescaled
//! so the replay window averages the requested RPS (residue-preserving
//! rounding, `azure::round_counts`), and windows longer than the trace
//! tile it. A 10-minute sample in this schema is checked in at
//! `rust/data/azure_sample.csv` (embedded at compile time, so `trace-file`
//! works regardless of the working directory).

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::util::rng::Rng;
use crate::workload::azure;

use super::Scenario;

/// Parsed-profile cache keyed by path: sweep cells rebuild their scenario
/// per (cell, replicate) for determinism, and a real Azure day trace is
/// hundreds of MB — re-reading it once per cell would dominate the sweep.
/// Profiles are immutable once parsed, so one read per process suffices.
fn path_cache() -> &'static Mutex<BTreeMap<String, Vec<u64>>> {
    static CACHE: OnceLock<Mutex<BTreeMap<String, Vec<u64>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// The checked-in sample trace (Azure Functions schema, 10 minutes,
/// 8 function rows with a minute-5/6 burst).
pub const SAMPLE_TRACE_CSV: &str = include_str!("../../../data/azure_sample.csv");

/// Replay of real per-minute invocation counts, rescaled to a target RPS.
#[derive(Debug, Clone)]
pub struct TraceFile {
    /// Cluster-wide invocations per trace minute (all rows summed).
    per_minute: Vec<u64>,
}

impl TraceFile {
    /// The embedded sample trace (what `--scenario trace-file` replays).
    pub fn sample() -> Result<Self> {
        Self::from_csv(SAMPLE_TRACE_CSV).context("embedded sample trace")
    }

    /// Load a CSV from disk (the `trace-file:<path>` registry form),
    /// memoized per path for the life of the process.
    pub fn from_path(path: &str) -> Result<Self> {
        if let Some(per_minute) = path_cache().lock().expect("trace cache").get(path) {
            return Ok(TraceFile { per_minute: per_minute.clone() });
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace file '{path}'"))?;
        let parsed =
            Self::from_csv(&text).with_context(|| format!("parsing trace file '{path}'"))?;
        path_cache()
            .lock()
            .expect("trace cache")
            .insert(path.to_string(), parsed.per_minute.clone());
        Ok(parsed)
    }

    /// Parse the Azure Functions trace schema: minute columns are the
    /// header fields that parse as integers; every other column
    /// (hashes, trigger) is ignored. Rows sum into one profile.
    pub fn from_csv(text: &str) -> Result<Self> {
        // enumerate before filtering so error messages cite real file lines
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let (_, header) = lines.next().ok_or_else(|| anyhow::anyhow!("empty trace CSV"))?;
        let minute_cols: Vec<usize> = header
            .split(',')
            .enumerate()
            .filter(|(_, h)| h.trim().parse::<u64>().is_ok())
            .map(|(i, _)| i)
            .collect();
        anyhow::ensure!(
            !minute_cols.is_empty(),
            "trace CSV header has no per-minute columns (expected Azure schema \
             'HashOwner,HashApp,HashFunction,Trigger,1,2,...')"
        );
        let mut per_minute = vec![0u64; minute_cols.len()];
        let mut rows = 0usize;
        for (lineno, line) in lines {
            let fields: Vec<&str> = line.split(',').collect();
            for (slot, &col) in minute_cols.iter().enumerate() {
                let field = fields.get(col).map(|f| f.trim()).unwrap_or("");
                let count: u64 = field.parse().with_context(|| {
                    format!("line {}: bad count '{field}' in minute column {col}", lineno + 1)
                })?;
                per_minute[slot] += count;
            }
            rows += 1;
        }
        anyhow::ensure!(rows > 0, "trace CSV has a header but no function rows");
        anyhow::ensure!(
            per_minute.iter().sum::<u64>() > 0,
            "trace CSV carries zero invocations"
        );
        Ok(TraceFile { per_minute })
    }

    /// The parsed cluster-wide per-minute profile (before rescaling).
    pub fn per_minute(&self) -> &[u64] {
        &self.per_minute
    }
}

impl Scenario for TraceFile {
    fn name(&self) -> &'static str {
        "trace-file"
    }

    fn arrival_times(&self, rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        let minutes = (duration_s / 60.0).ceil().max(1.0) as usize;
        // tile the trace across the window, then rescale to the target RPS
        // (rescale handles a window landing entirely on zero-count minutes
        // by falling back to a uniform profile — no 0/0)
        let mut raw: Vec<f64> = (0..minutes)
            .map(|m| self.per_minute[m % self.per_minute.len()] as f64)
            .collect();
        azure::rescale_to_rps(&mut raw, rps);
        azure::profile_starts(&raw, duration_s, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-computed column sums of `rust/data/azure_sample.csv`.
    pub const SAMPLE_PER_MINUTE: [u64; 10] = [33, 41, 28, 36, 95, 102, 30, 25, 38, 31];

    #[test]
    fn sample_parses_to_known_profile() {
        let t = TraceFile::sample().unwrap();
        assert_eq!(t.per_minute(), SAMPLE_PER_MINUTE);
    }

    #[test]
    fn replay_rescales_to_target_rps() {
        let t = TraceFile::sample().unwrap();
        for rps in [0.5, 4.0, 20.0] {
            let times = t.arrival_times(rps, 600.0, &mut Rng::new(1));
            let rate = times.len() as f64 / 600.0;
            assert!((rate - rps).abs() < 0.05 * rps + 0.01, "rps {rps}: rate {rate}");
        }
    }

    #[test]
    fn replay_preserves_trace_shape() {
        let t = TraceFile::sample().unwrap();
        let times = t.arrival_times(4.0, 600.0, &mut Rng::new(2));
        // minute 6 carries 102/459 of the mass; minute 8 carries 25/459
        let burst = times.iter().filter(|x| (300.0..360.0).contains(*x)).count();
        let calm = times.iter().filter(|x| (420.0..480.0).contains(*x)).count();
        assert!(
            burst as f64 > 3.0 * calm as f64,
            "trace burst must survive rescaling: {burst} vs {calm}"
        );
    }

    #[test]
    fn windows_longer_than_the_trace_tile_it() {
        let t = TraceFile::sample().unwrap();
        // 20-minute window over a 10-minute trace: both copies of minute 6
        let times = t.arrival_times(2.0, 1200.0, &mut Rng::new(3));
        let first = times.iter().filter(|x| (300.0..360.0).contains(*x)).count();
        let second = times.iter().filter(|x| (900.0..960.0).contains(*x)).count();
        assert!(first > 0 && second > 0, "burst must repeat: {first}, {second}");
    }

    #[test]
    fn zero_count_window_falls_back_to_uniform() {
        // minute 1 carries zero invocations trace-wide; a 60 s window
        // tiles only that minute and must still deliver the target rate
        // (shape is unrecoverable, so the profile degrades to uniform)
        let t = TraceFile::from_csv("HashOwner,Trigger,1,2\nabc,http,0,5\n").unwrap();
        let times = t.arrival_times(2.0, 60.0, &mut Rng::new(4));
        assert_eq!(times.len(), 120, "uniform fallback at the target rate");
        assert!(times.iter().all(|x| (0.0..=60.0).contains(x)));
    }

    #[test]
    fn parse_errors_cite_real_file_lines() {
        // the bad count sits on file line 4; the blank line 2 must not
        // shift the reported position
        let text = "HashOwner,Trigger,1,2\n\nabc,http,1,2\ndef,http,3,oops\n";
        let err = TraceFile::from_csv(text).unwrap_err();
        assert!(format!("{err:#}").contains("line 4"), "{err:#}");
    }

    #[test]
    fn malformed_csvs_rejected() {
        assert!(TraceFile::from_csv("").is_err());
        assert!(TraceFile::from_csv("HashOwner,HashApp,Trigger\n").is_err(), "no minute cols");
        assert!(
            TraceFile::from_csv("HashOwner,Trigger,1,2\n").is_err(),
            "header only, no rows"
        );
        assert!(
            TraceFile::from_csv("HashOwner,Trigger,1,2\nabc,http,0,0\n").is_err(),
            "all-zero trace"
        );
        assert!(
            TraceFile::from_csv("HashOwner,Trigger,1,2\nabc,http,3,oops\n").is_err(),
            "non-numeric count"
        );
    }
}
