//! Trace replay: per-minute invocation counts from a CSV in the Azure
//! Functions production-trace schema (Shahrad et al.),
//! `HashOwner,HashApp,HashFunction,Trigger,1,2,...,N` — one row per
//! function, one numeric column per minute of the day.
//!
//! The real 2019/2021 datasets carry millions of function-minutes, so the
//! ingest is **streaming and bounded-memory** (DESIGN.md §Trace ingest):
//!
//! * a chunked line reader ([`for_each_line`]) feeds the parser complete
//!   lines from fixed-size reads — the file is never materialized whole
//!   (the old `read_to_string` path is gone);
//! * per-function profiles are compact `u32` slabs, **hour-sharded**
//!   (only hours with activity allocate a 60-minute slab), so a replay
//!   window touches only the shards it overlaps;
//! * only the **top-K** functions by total invocations are retained as
//!   individual profiles ([`TOP_K`]); everything else is folded into one
//!   aggregate tail profile at eviction time, bounding peak resident
//!   profiles at `K + 1` regardless of row count (tracked in
//!   [`Ingest::peak_resident`], asserted by the 50k-row test below).
//!
//! The cluster-wide per-minute profile (all rows summed — identical to
//! the pre-streaming parser's output) is rescaled so the replay window
//! averages the requested RPS (residue-preserving rounding,
//! `azure::round_counts`), and windows longer than the trace tile it.
//! Function popularity is **trace-derived**: invocations map onto catalog
//! slots with weights from the ranked retained totals (head-heavy, like
//! the real dataset) instead of the synthetic uniform/zipf picks. A
//! 10-minute sample in this schema is checked in at
//! `rust/data/azure_sample.csv` (embedded at compile time, so
//! `trace-file` works regardless of the working directory).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use anyhow::{ensure, Context, Result};

use crate::functions::catalog::CATALOG;
use crate::util::rng::Rng;
use crate::workload::azure;

use super::Scenario;

/// How many individual function profiles the ingest retains; everything
/// below the cutoff is folded into the aggregate tail. 64 covers the
/// head that carries almost all invocations in the production trace
/// (popularity is heavily Zipf-skewed) while keeping peak resident
/// memory at `TOP_K + 1` slabs regardless of dataset size.
pub const TOP_K: usize = 64;

/// Minutes per profile shard (one hour — the replay windows experiments
/// use are minutes-to-hours, so an hour is the natural extraction unit).
pub const SHARD_MINUTES: usize = 60;

/// Bytes per read of the chunked line reader.
const CHUNK_BYTES: usize = 64 * 1024;

/// Parsed-ingest cache keyed by path: sweep cells rebuild their scenario
/// per (cell, replicate) for determinism, and a real Azure day trace is
/// hundreds of MB — re-reading it once per cell would dominate the sweep.
/// Ingests are immutable once parsed (shared via `Arc`), so one read per
/// process suffices.
fn path_cache() -> &'static Mutex<BTreeMap<String, Arc<Ingest>>> {
    static CACHE: OnceLock<Mutex<BTreeMap<String, Arc<Ingest>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Lock the path cache, recovering from poison: a panicking sweep thread
/// must not cascade failures into unrelated cells. The map is only ever
/// read or inserted into under the lock — never left mid-edit — so the
/// inner value is always consistent and safe to take back.
fn lock_cache() -> MutexGuard<'static, BTreeMap<String, Arc<Ingest>>> {
    match path_cache().lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// The checked-in sample trace (Azure Functions schema, 10 minutes,
/// 8 function rows with a minute-5/6 burst).
pub const SAMPLE_TRACE_CSV: &str = include_str!("../../../data/azure_sample.csv");

/// Per-minute counts for one retained (top-K) function, hour-sharded:
/// only hours with nonzero activity allocate a slab, and counts are
/// `u32` — the per-function-per-minute range of the dataset (the
/// cluster-wide sums stay `u64`).
#[derive(Debug, Clone)]
pub struct FnProfile {
    /// Stable identity from the row's HashFunction column (or a
    /// synthesized `row-N` when the schema carries no id columns).
    pub name: String,
    /// Total invocations across the whole trace.
    pub total: u64,
    /// First row index this function appeared at (eviction tie-break).
    first_row: usize,
    /// hour index -> 60-minute count slab.
    shards: BTreeMap<usize, Vec<u32>>,
}

impl FnProfile {
    fn new(name: String, first_row: usize) -> Self {
        FnProfile { name, total: 0, first_row, shards: BTreeMap::new() }
    }

    fn add(&mut self, minute: usize, count: u32) {
        let (hour, offset) = (minute / SHARD_MINUTES, minute % SHARD_MINUTES);
        let slab = self.shards.entry(hour).or_insert_with(|| vec![0u32; SHARD_MINUTES]);
        slab[offset] += count;
        self.total += count as u64;
    }

    /// Invocations in one trace minute (0 where no shard exists).
    pub fn count_at(&self, minute: usize) -> u64 {
        let (hour, offset) = (minute / SHARD_MINUTES, minute % SHARD_MINUTES);
        self.shards.get(&hour).map_or(0, |slab| slab[offset] as u64)
    }

    /// Invocations inside `[start_minute, start_minute + minutes)` —
    /// touches only the shards the window overlaps.
    pub fn window_total(&self, start_minute: usize, minutes: usize) -> u64 {
        let end = start_minute + minutes;
        let first_hour = start_minute / SHARD_MINUTES;
        let last_hour = end.div_ceil(SHARD_MINUTES);
        self.shards
            .range(first_hour..last_hour)
            .map(|(hour, slab)| {
                slab.iter()
                    .enumerate()
                    .filter(|(offset, _)| {
                        let m = hour * SHARD_MINUTES + offset;
                        m >= start_minute && m < end
                    })
                    .map(|(_, c)| *c as u64)
                    .sum::<u64>()
            })
            .sum()
    }

    /// How many hour shards this profile allocated.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

/// The bounded-memory result of streaming one trace CSV: cluster-wide
/// per-minute sums, the retained top-K per-function profiles, and the
/// aggregate tail everything else was folded into.
#[derive(Debug, Default)]
pub struct Ingest {
    /// Number of per-minute columns in the schema.
    pub minutes: usize,
    /// Cluster-wide invocations per trace minute (every row summed —
    /// byte-identical to the pre-streaming parser's profile).
    pub per_minute: Vec<u64>,
    /// Retained functions, ranked by (total desc, first-seen asc).
    pub top: Vec<FnProfile>,
    /// Per-minute sums of all rows *not* retained in `top`.
    pub tail_per_minute: Vec<u64>,
    /// Total function rows ingested.
    pub rows: usize,
    /// Rows folded into the tail (evicted or zero-mass).
    pub tail_rows: usize,
    /// Max individual profiles resident at any point during ingest —
    /// the bounded-memory contract: never exceeds `TOP_K + 1`.
    pub peak_resident: usize,
}

impl Ingest {
    /// Stream-parse the Azure Functions trace schema from any byte
    /// source: minute columns are the header fields that parse as
    /// integers; every other column (hashes, trigger) is ignored except
    /// the HashFunction-position column, which names the profile.
    fn read<R: std::io::Read>(src: R) -> Result<Ingest> {
        // (header field count, minute column indexes, name column)
        let mut header: Option<(usize, Vec<usize>, Option<usize>)> = None;
        let mut ingest = Ingest::default();
        for_each_line(src, |lineno, line| {
            if line.trim().is_empty() {
                return Ok(());
            }
            if header.is_none() {
                let fields: Vec<&str> = line.split(',').collect();
                let minute_cols: Vec<usize> = fields
                    .iter()
                    .enumerate()
                    .filter(|(_, h)| h.trim().parse::<u64>().is_ok())
                    .map(|(i, _)| i)
                    .collect();
                ensure!(
                    !minute_cols.is_empty(),
                    "trace CSV header has no per-minute columns (expected Azure schema \
                     'HashOwner,HashApp,HashFunction,Trigger,1,2,...')"
                );
                // HashFunction is the third id column in the Azure
                // schema; fall back to the last id column in reduced
                // test schemas.
                let id_cols: Vec<usize> =
                    (0..fields.len()).filter(|i| !minute_cols.contains(i)).collect();
                let name_col = id_cols.get(2).or(id_cols.last()).copied();
                ingest.minutes = minute_cols.len();
                ingest.per_minute = vec![0; minute_cols.len()];
                ingest.tail_per_minute = vec![0; minute_cols.len()];
                header = Some((fields.len(), minute_cols, name_col));
                return Ok(());
            }
            let (header_len, minute_cols, name_col) = header.as_ref().unwrap();
            ingest.row(lineno, line, *header_len, minute_cols, *name_col)
        })?;
        ensure!(header.is_some(), "empty trace CSV");
        ensure!(ingest.rows > 0, "trace CSV has a header but no function rows");
        ensure!(ingest.per_minute.iter().sum::<u64>() > 0, "trace CSV carries zero invocations");
        ingest.top.sort_by(|a, b| b.total.cmp(&a.total).then(a.first_row.cmp(&b.first_row)));
        Ok(ingest)
    }

    fn row(
        &mut self,
        lineno: usize,
        line: &str,
        header_len: usize,
        minute_cols: &[usize],
        name_col: Option<usize>,
    ) -> Result<()> {
        let fields: Vec<&str> = line.split(',').collect();
        ensure!(
            fields.len() >= header_len,
            "line {}: row has {} fields, header has {}",
            lineno + 1,
            fields.len(),
            header_len
        );
        let name = name_col
            .map(|c| fields[c].trim())
            .filter(|n| !n.is_empty())
            .map(str::to_string)
            .unwrap_or_else(|| format!("row-{}", lineno + 1));
        let mut profile = FnProfile::new(name, self.rows);
        for (slot, &col) in minute_cols.iter().enumerate() {
            let field = fields[col].trim();
            let count: u64 = field.parse().with_context(|| {
                format!("line {}: bad count '{field}' in minute column {col}", lineno + 1)
            })?;
            if count == 0 {
                continue;
            }
            let compact = u32::try_from(count).map_err(|_| {
                anyhow::anyhow!(
                    "line {}: count {count} in minute column {col} exceeds the u32 \
                     profile-slab range",
                    lineno + 1
                )
            })?;
            self.per_minute[slot] += count;
            profile.add(slot, compact);
        }
        self.rows += 1;
        self.retain(profile);
        Ok(())
    }

    /// Keep at most [`TOP_K`] individual profiles: when the pool
    /// overflows, fold the smallest-total profile (ties: latest first
    /// appearance) into the aggregate tail and drop its slabs.
    fn retain(&mut self, profile: FnProfile) {
        if profile.total == 0 {
            // zero-mass rows carry no popularity or shape signal
            self.tail_rows += 1;
            return;
        }
        self.top.push(profile);
        self.peak_resident = self.peak_resident.max(self.top.len());
        if self.top.len() > TOP_K {
            let mut evict = 0;
            for i in 1..self.top.len() {
                let (a, e) = (&self.top[i], &self.top[evict]);
                if (a.total, std::cmp::Reverse(a.first_row))
                    < (e.total, std::cmp::Reverse(e.first_row))
                {
                    evict = i;
                }
            }
            let folded = self.top.swap_remove(evict);
            self.tail_rows += 1;
            for (hour, slab) in &folded.shards {
                for (offset, count) in slab.iter().enumerate() {
                    if *count > 0 {
                        self.tail_per_minute[hour * SHARD_MINUTES + offset] += *count as u64;
                    }
                }
            }
        }
    }

    /// Total invocations folded into the aggregate tail.
    pub fn tail_total(&self) -> u64 {
        self.tail_per_minute.iter().sum()
    }
}

/// Chunked line reader: fixed-size reads, complete lines handed to `f`
/// with their 0-based file line number (blank lines included, so error
/// messages can cite real file positions). Memory is O(chunk + longest
/// line) regardless of source size.
fn for_each_line<R: std::io::Read>(
    mut src: R,
    mut f: impl FnMut(usize, &str) -> Result<()>,
) -> Result<()> {
    fn trim_cr(line: &[u8]) -> &[u8] {
        line.strip_suffix(b"\r").unwrap_or(line)
    }
    let mut chunk = vec![0u8; CHUNK_BYTES];
    let mut carry: Vec<u8> = Vec::new();
    let mut lineno = 0usize;
    loop {
        let n = src.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        let mut rest = &chunk[..n];
        while let Some(pos) = rest.iter().position(|b| *b == b'\n') {
            let (head, tail) = rest.split_at(pos);
            if carry.is_empty() {
                f(lineno, std::str::from_utf8(trim_cr(head))?)?;
            } else {
                carry.extend_from_slice(head);
                f(lineno, std::str::from_utf8(trim_cr(&carry))?)?;
                carry.clear();
            }
            lineno += 1;
            rest = &tail[1..];
        }
        carry.extend_from_slice(rest);
    }
    if !carry.is_empty() {
        f(lineno, std::str::from_utf8(trim_cr(&carry))?)?;
    }
    Ok(())
}

/// Replay of real per-minute invocation counts, rescaled to a target RPS,
/// with trace-derived function popularity. Cheap to clone: the parsed
/// ingest is shared behind an `Arc`.
#[derive(Debug, Clone)]
pub struct TraceFile {
    ingest: Arc<Ingest>,
    /// Popularity weights over catalog slots, derived once from the
    /// ranked retained-function totals plus the aggregate tail
    /// (`pick_function` runs per invocation and must not re-derive them).
    weights: Vec<f64>,
}

/// Map the ranked trace-function totals onto `n` catalog slots: rank `r`
/// contributes to slot `r % n` (head functions land on the catalog head,
/// mirroring the `ZipfSkew` convention), and the aggregate tail spreads
/// uniformly — so replayed popularity follows the dataset's skew instead
/// of a synthetic exponent.
fn popularity_weights(ingest: &Ingest, n: usize) -> Vec<f64> {
    let mut weights = vec![0.0; n];
    for (rank, profile) in ingest.top.iter().enumerate() {
        weights[rank % n] += profile.total as f64;
    }
    let tail = ingest.tail_total();
    if tail > 0 {
        let spread = tail as f64 / n as f64;
        for w in weights.iter_mut() {
            *w += spread;
        }
    }
    weights
}

impl TraceFile {
    /// The embedded sample trace (what `--scenario trace-file` replays).
    pub fn sample() -> Result<Self> {
        Self::from_csv(SAMPLE_TRACE_CSV).context("embedded sample trace")
    }

    /// Load a CSV from disk (the `trace-file:<path>` registry form),
    /// memoized per path for the life of the process. The file is
    /// streamed through the chunked reader — never read whole.
    pub fn from_path(path: &str) -> Result<Self> {
        if let Some(ingest) = lock_cache().get(path) {
            return Ok(Self::from_ingest(Arc::clone(ingest)));
        }
        let file =
            std::fs::File::open(path).with_context(|| format!("reading trace file '{path}'"))?;
        let ingest = Ingest::read(file)
            .with_context(|| format!("parsing trace file '{path}'"))
            .map(Arc::new)?;
        lock_cache().insert(path.to_string(), Arc::clone(&ingest));
        Ok(Self::from_ingest(ingest))
    }

    /// Parse an in-memory CSV (embedded sample, tests) through the same
    /// streaming parser the disk path uses.
    pub fn from_csv(text: &str) -> Result<Self> {
        Self::from_reader(text.as_bytes())
    }

    /// Stream-parse any byte source.
    pub fn from_reader<R: std::io::Read>(src: R) -> Result<Self> {
        Ok(Self::from_ingest(Arc::new(Ingest::read(src)?)))
    }

    fn from_ingest(ingest: Arc<Ingest>) -> Self {
        let weights = popularity_weights(&ingest, CATALOG.len());
        TraceFile { ingest, weights }
    }

    /// The parsed cluster-wide per-minute profile (before rescaling).
    pub fn per_minute(&self) -> &[u64] {
        &self.ingest.per_minute
    }

    /// The full ingest: retained profiles, tail, resident-memory stats
    /// (consumed by `experiment replay`'s characterization report).
    pub fn ingest(&self) -> &Ingest {
        &self.ingest
    }
}

impl Scenario for TraceFile {
    fn name(&self) -> &'static str {
        "trace-file"
    }

    fn arrival_times(&self, rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        let minutes = (duration_s / 60.0).ceil().max(1.0) as usize;
        // tile the trace across the window, then rescale to the target RPS
        // (rescale handles a window landing entirely on zero-count minutes
        // by falling back to a uniform profile — no 0/0)
        let mut raw: Vec<f64> = (0..minutes)
            .map(|m| self.ingest.per_minute[m % self.ingest.per_minute.len()] as f64)
            .collect();
        azure::rescale_to_rps(&mut raw, rps);
        azure::profile_starts(&raw, duration_s, rng)
    }

    /// Trace-derived popularity: one categorical draw over the ranked
    /// dataset weights per invocation. This deliberately replaced the
    /// PR 2 uniform pick (one `below` draw) — a documented stream shift
    /// for `trace-file` scenarios only (CHANGES.md, PR 10).
    fn pick_function(&self, funcs: &[usize], rng: &mut Rng) -> usize {
        if funcs.len() <= self.weights.len() {
            funcs[rng.categorical(&self.weights[..funcs.len()])]
        } else {
            funcs[rng.categorical(&popularity_weights(&self.ingest, funcs.len()))]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-computed column sums of `rust/data/azure_sample.csv`.
    pub const SAMPLE_PER_MINUTE: [u64; 10] = [33, 41, 28, 36, 95, 102, 30, 25, 38, 31];

    #[test]
    fn sample_parses_to_known_profile() {
        let t = TraceFile::sample().unwrap();
        assert_eq!(t.per_minute(), SAMPLE_PER_MINUTE);
        // 8 rows, all retained (under the top-K cutoff), no tail
        assert_eq!(t.ingest().rows, 8);
        assert_eq!(t.ingest().top.len(), 8);
        assert_eq!(t.ingest().tail_total(), 0);
        // ranked by total, names from the HashFunction column
        let totals: Vec<u64> = t.ingest().top.iter().map(|p| p.total).collect();
        assert!(totals.windows(2).all(|w| w[0] >= w[1]), "{totals:?}");
        assert!(t.ingest().top[0].name.starts_with("func-"), "{}", t.ingest().top[0].name);
    }

    #[test]
    fn replay_rescales_to_target_rps() {
        let t = TraceFile::sample().unwrap();
        for rps in [0.5, 4.0, 20.0] {
            let times = t.arrival_times(rps, 600.0, &mut Rng::new(1));
            let rate = times.len() as f64 / 600.0;
            assert!((rate - rps).abs() < 0.05 * rps + 0.01, "rps {rps}: rate {rate}");
        }
    }

    #[test]
    fn replay_preserves_trace_shape() {
        let t = TraceFile::sample().unwrap();
        let times = t.arrival_times(4.0, 600.0, &mut Rng::new(2));
        // minute 6 carries 102/459 of the mass; minute 8 carries 25/459
        let burst = times.iter().filter(|x| (300.0..360.0).contains(*x)).count();
        let calm = times.iter().filter(|x| (420.0..480.0).contains(*x)).count();
        assert!(
            burst as f64 > 3.0 * calm as f64,
            "trace burst must survive rescaling: {burst} vs {calm}"
        );
    }

    #[test]
    fn windows_longer_than_the_trace_tile_it() {
        let t = TraceFile::sample().unwrap();
        // 20-minute window over a 10-minute trace: both copies of minute 6
        let times = t.arrival_times(2.0, 1200.0, &mut Rng::new(3));
        let first = times.iter().filter(|x| (300.0..360.0).contains(*x)).count();
        let second = times.iter().filter(|x| (900.0..960.0).contains(*x)).count();
        assert!(first > 0 && second > 0, "burst must repeat: {first}, {second}");
    }

    #[test]
    fn zero_count_window_falls_back_to_uniform() {
        // minute 1 carries zero invocations trace-wide; a 60 s window
        // tiles only that minute and must still deliver the target rate
        // (shape is unrecoverable, so the profile degrades to uniform)
        let t = TraceFile::from_csv("HashOwner,Trigger,1,2\nabc,http,0,5\n").unwrap();
        let times = t.arrival_times(2.0, 60.0, &mut Rng::new(4));
        assert_eq!(times.len(), 120, "uniform fallback at the target rate");
        assert!(times.iter().all(|x| (0.0..=60.0).contains(x)));
    }

    #[test]
    fn parse_errors_cite_real_file_lines() {
        // the bad count sits on file line 4; the blank line 2 must not
        // shift the reported position
        let text = "HashOwner,Trigger,1,2\n\nabc,http,1,2\ndef,http,3,oops\n";
        let err = TraceFile::from_csv(text).unwrap_err();
        assert!(format!("{err:#}").contains("line 4"), "{err:#}");
    }

    #[test]
    fn short_rows_report_field_counts() {
        // a truncated row must fail with the field-count diagnosis, not
        // the misleading `bad count ''` the old parser produced
        let err = TraceFile::from_csv("HashOwner,Trigger,1,2\nabc,http,3\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2: row has 3 fields, header has 4"), "{msg}");
    }

    #[test]
    fn oversized_counts_rejected_with_context() {
        let text = "HashOwner,Trigger,1,2\nabc,http,1,5000000000\n";
        let err = TraceFile::from_csv(text).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("u32"), "{msg}");
    }

    #[test]
    fn malformed_csvs_rejected() {
        assert!(TraceFile::from_csv("").is_err());
        assert!(TraceFile::from_csv("HashOwner,HashApp,Trigger\n").is_err(), "no minute cols");
        assert!(
            TraceFile::from_csv("HashOwner,Trigger,1,2\n").is_err(),
            "header only, no rows"
        );
        assert!(
            TraceFile::from_csv("HashOwner,Trigger,1,2\nabc,http,0,0\n").is_err(),
            "all-zero trace"
        );
        assert!(
            TraceFile::from_csv("HashOwner,Trigger,1,2\nabc,http,3,oops\n").is_err(),
            "non-numeric count"
        );
    }

    #[test]
    fn path_cache_recovers_from_poison() {
        // one panicking sweep thread must not cascade the memo into
        // panics for every later cell (the old `.expect("trace cache")`)
        let path = std::env::temp_dir().join("shabari_poison_regression.csv");
        std::fs::write(&path, "HashOwner,HashApp,HashFunction,Trigger,1,2\na,b,f1,http,3,4\n")
            .unwrap();
        let poison = std::thread::spawn(|| {
            let _guard = lock_cache();
            panic!("poison the trace cache on purpose");
        })
        .join();
        assert!(poison.is_err(), "the poisoning thread must have panicked");
        let t = TraceFile::from_path(path.to_str().unwrap()).unwrap();
        assert_eq!(t.per_minute(), [3, 4]);
        // and the memo still serves
        let again = TraceFile::from_path(path.to_str().unwrap()).unwrap();
        assert_eq!(again.per_minute(), [3, 4]);
        std::fs::remove_file(&path).ok();
    }

    /// Synthesize a trace CSV: `rows` functions over `minutes` columns,
    /// function `i` active in minute `i % minutes` with count `weight(i)`.
    fn synth_csv(rows: usize, minutes: usize, weight: impl Fn(usize) -> u64) -> String {
        let mut csv = String::from("HashOwner,HashApp,HashFunction,Trigger");
        for m in 1..=minutes {
            csv.push_str(&format!(",{m}"));
        }
        csv.push('\n');
        for i in 0..rows {
            csv.push_str(&format!("o{i},a{i},f{i},http"));
            for m in 0..minutes {
                let c = if m == i % minutes { weight(i) } else { 0 };
                csv.push_str(&format!(",{c}"));
            }
            csv.push('\n');
        }
        csv
    }

    #[test]
    fn topk_eviction_folds_the_smallest_into_the_tail() {
        // TOP_K + 2 functions with distinct totals 1..=K+2: the two
        // smallest must be folded into the tail, everything conserved
        let rows = TOP_K + 2;
        let csv = synth_csv(rows, 4, |i| (i + 1) as u64);
        let t = TraceFile::from_csv(&csv).unwrap();
        let ingest = t.ingest();
        assert_eq!(ingest.rows, rows);
        assert_eq!(ingest.top.len(), TOP_K);
        assert_eq!(ingest.tail_rows, 2);
        assert_eq!(ingest.tail_total(), 1 + 2, "totals 1 and 2 evicted");
        assert_eq!(ingest.peak_resident, TOP_K + 1);
        assert_eq!(ingest.top[0].total, rows as u64, "head retained and ranked first");
        // conservation: cluster profile == retained + tail, per minute
        for m in 0..ingest.minutes {
            let retained: u64 = ingest.top.iter().map(|p| p.count_at(m)).sum();
            assert_eq!(ingest.per_minute[m], retained + ingest.tail_per_minute[m], "minute {m}");
        }
    }

    #[test]
    fn bounded_memory_on_a_50k_row_trace() {
        // the acceptance bound: peak resident profiles never exceed
        // TOP_K + 1 no matter how many rows stream through
        let rows = 50_000;
        let csv = synth_csv(rows, 20, |i| (i % 97 + 1) as u64);
        let t = TraceFile::from_reader(csv.as_bytes()).unwrap();
        let ingest = t.ingest();
        assert_eq!(ingest.rows, rows);
        assert!(
            ingest.peak_resident <= TOP_K + 1,
            "peak resident {} exceeds the top-K bound",
            ingest.peak_resident
        );
        assert_eq!(ingest.top.len(), TOP_K);
        assert_eq!(ingest.tail_rows, rows - TOP_K);
        let expect: u64 = (0..rows).map(|i| (i % 97 + 1) as u64).sum();
        assert_eq!(ingest.per_minute.iter().sum::<u64>(), expect, "no mass lost to eviction");
    }

    #[test]
    fn hour_shards_slice_windows_exactly() {
        // one function active across three hour shards; boundary minutes
        // 59/60 and 119/120 must land in the right shard and window
        let minutes = 125;
        let mut csv = String::from("HashOwner,HashApp,HashFunction,Trigger");
        for m in 1..=minutes {
            csv.push_str(&format!(",{m}"));
        }
        csv.push('\n');
        csv.push_str("o,a,f,http");
        for m in 0..minutes {
            let c = match m {
                0 | 59 | 60 | 119 | 120 | 124 => m + 1,
                _ => 0,
            };
            csv.push_str(&format!(",{c}"));
        }
        csv.push('\n');
        let t = TraceFile::from_csv(&csv).unwrap();
        let p = &t.ingest().top[0];
        assert_eq!(p.shard_count(), 3, "hours 0, 1, 2");
        for m in [0usize, 59, 60, 119, 120, 124] {
            assert_eq!(p.count_at(m), (m + 1) as u64, "minute {m}");
        }
        assert_eq!(p.count_at(1), 0);
        assert_eq!(p.window_total(59, 2), 60 + 61, "window straddling the hour boundary");
        assert_eq!(p.window_total(0, 60), 1 + 60, "first hour only");
        assert_eq!(p.window_total(120, 5), 121 + 125, "partial last shard");
        assert_eq!(p.window_total(0, minutes), p.total);
    }

    #[test]
    fn popularity_follows_the_trace_ranking() {
        let t = TraceFile::sample().unwrap();
        let funcs: Vec<usize> = (0..CATALOG.len()).collect();
        let mut rng = Rng::new(9);
        let mut counts = vec![0usize; CATALOG.len()];
        for _ in 0..20_000 {
            counts[t.pick_function(&funcs, &mut rng)] += 1;
        }
        // the sample has 8 ranked functions and no tail: the catalog head
        // must dominate and slots past the ranked mass stay silent
        assert!(counts[0] > counts[7], "head above the last ranked slot: {counts:?}");
        assert_eq!(counts[CATALOG.len() - 1], 0, "no tail mass -> silent slot: {counts:?}");
        assert!(counts[0] > 2 * counts[CATALOG.len() - 1].max(1), "{counts:?}");
    }
}
