//! Analytic arrival/popularity shapes: diurnal, flash-crowd, Zipf skew.
//!
//! All three reuse the §7.1 per-minute recipe (`azure::minute_starts`:
//! integer counts per minute, uniform start times within the minute) but
//! replace the *intensity profile* — deterministic given the scenario
//! parameters, so the only randomness is the within-minute placement and
//! the function/input picks.

use crate::util::rng::Rng;
use crate::workload::azure;

use super::Scenario;

/// Sinusoidal day/night rate: one full diurnal cycle compressed into the
/// trace window, starting at the nightly trough, peaking mid-window. The
/// window-average rate is the requested RPS (profile normalized before
/// residue rounding), but instantaneous rate swings between
/// `(1 - amplitude)` and `(1 + amplitude)` times the mean — the regime
/// where static provisioning over- and under-shoots in turn.
#[derive(Debug, Clone)]
pub struct Diurnal {
    /// Peak-to-mean swing, 0..1 (default 0.6: nights at 0.4x, peaks at 1.6x).
    pub amplitude: f64,
}

impl Default for Diurnal {
    fn default() -> Self {
        Diurnal { amplitude: 0.6 }
    }
}

impl Scenario for Diurnal {
    fn name(&self) -> &'static str {
        "diurnal"
    }

    fn arrival_times(&self, rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        let minutes = (duration_s / 60.0).ceil().max(1.0) as usize;
        let period = duration_s.max(60.0);
        let two_pi = 2.0 * std::f64::consts::PI;
        let mut raw: Vec<f64> = (0..minutes)
            .map(|m| {
                let mid = (m as f64 + 0.5) * 60.0;
                // phase -π/2: the window opens at the trough
                let mult = 1.0 + self.amplitude * (two_pi * mid / period - two_pi / 4.0).sin();
                mult.max(0.0)
            })
            .collect();
        // normalize the discrete profile so the window mean is exactly rps
        azure::rescale_to_rps(&mut raw, rps);
        azure::profile_starts(&raw, duration_s, rng)
    }
}

/// Step burst: baseline RPS everywhere except a burst window where the
/// rate jumps to `k ×` base — Fifer's worst-case regime for
/// underutilization and cold-start pileups. The burst is *additional*
/// load (the window mean exceeds the nominal RPS by design).
#[derive(Debug, Clone)]
pub struct FlashCrowd {
    /// Burst rate multiplier (default 4x).
    pub k: f64,
    /// Burst onset as a fraction of the window (default 0.4).
    pub onset_frac: f64,
    /// Burst width as a fraction of the window (default 0.15).
    pub width_frac: f64,
}

impl Default for FlashCrowd {
    fn default() -> Self {
        FlashCrowd { k: 4.0, onset_frac: 0.4, width_frac: 0.15 }
    }
}

impl FlashCrowd {
    /// Fraction of `[lo, hi)` covered by the burst interval.
    fn overlap(&self, lo: f64, hi: f64, duration_s: f64) -> f64 {
        let b_lo = self.onset_frac * duration_s;
        let b_hi = (self.onset_frac + self.width_frac).min(1.0) * duration_s;
        let covered = (hi.min(b_hi) - lo.max(b_lo)).max(0.0);
        covered / (hi - lo).max(1e-9)
    }
}

impl Scenario for FlashCrowd {
    fn name(&self) -> &'static str {
        "flash-crowd"
    }

    fn arrival_times(&self, rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        let minutes = (duration_s / 60.0).ceil().max(1.0) as usize;
        let raw: Vec<f64> = (0..minutes)
            .map(|m| {
                let lo = m as f64 * 60.0;
                let hi = lo + 60.0;
                let burst_frac = self.overlap(lo, hi.min(duration_s), duration_s);
                rps * 60.0 * (1.0 + (self.k - 1.0) * burst_frac)
            })
            .collect();
        // no rescale: the burst is additional load on top of the base rate
        azure::profile_starts(&raw, duration_s, rng)
    }
}

/// Azure-synthetic arrivals with **Zipf** function popularity in catalog
/// order: function at rank `i` is hit with weight `1 / (i+1)^s`. Head
/// functions accumulate observations (and converged models) quickly while
/// tail functions starve below the allocator's per-function confidence
/// gates — the skew regime the uniform mix never exercises.
#[derive(Debug, Clone)]
pub struct ZipfSkew {
    exponent: f64,
    /// Weights for the full catalog, precomputed once — `pick_function`
    /// runs per invocation and must not re-derive `n` powf calls each time.
    catalog_weights: Vec<f64>,
}

impl Default for ZipfSkew {
    fn default() -> Self {
        ZipfSkew::new(1.1)
    }
}

impl ZipfSkew {
    /// Zipf popularity with the given exponent (default 1.1; larger =
    /// more skew).
    pub fn new(exponent: f64) -> Self {
        let catalog_weights = zipf_weights(crate::functions::catalog::CATALOG.len(), exponent);
        ZipfSkew { exponent, catalog_weights }
    }

    /// Unnormalized popularity weights for `n` ranks.
    pub fn weights(&self, n: usize) -> Vec<f64> {
        zipf_weights(n, self.exponent)
    }
}

/// `1 / rank^s` for ranks `1..=n`.
fn zipf_weights(n: usize, exponent: f64) -> Vec<f64> {
    (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(exponent)).collect()
}

impl Scenario for ZipfSkew {
    fn name(&self) -> &'static str {
        "zipf-skew"
    }

    fn arrival_times(&self, rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        azure::arrival_times(rps, duration_s, rng)
    }

    fn pick_function(&self, funcs: &[usize], rng: &mut Rng) -> usize {
        // `zipf_weights(n)` is a prefix of `zipf_weights(m)` for n <= m,
        // so subset traces just slice the precomputed catalog weights
        if funcs.len() <= self.catalog_weights.len() {
            funcs[rng.categorical(&self.catalog_weights[..funcs.len()])]
        } else {
            funcs[rng.categorical(&self.weights(funcs.len()))]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_rate_averages_to_target_and_swings() {
        let d = Diurnal::default();
        let t = d.arrival_times(4.0, 600.0, &mut Rng::new(3));
        let rate = t.len() as f64 / 600.0;
        assert!((rate - 4.0).abs() < 0.2, "rate {rate}");
        // first minute (trough) must be much quieter than minute 5 (peak)
        let first = t.iter().filter(|x| **x < 60.0).count();
        let peak = t.iter().filter(|x| (240.0..300.0).contains(*x)).count();
        assert!(
            peak as f64 > 1.5 * first as f64,
            "peak minute {peak} vs trough minute {first}"
        );
    }

    #[test]
    fn flash_crowd_bursts_where_configured() {
        let f = FlashCrowd::default();
        let t = f.arrival_times(4.0, 600.0, &mut Rng::new(7));
        // burst covers [240, 330): minute 5 (300..360) is 50% burst, minutes
        // 4 (240..300) fully inside. Compare a burst minute to a calm one.
        let calm = t.iter().filter(|x| **x < 60.0).count();
        let burst = t.iter().filter(|x| (240.0..300.0).contains(*x)).count();
        assert!(
            burst as f64 > 2.5 * calm as f64,
            "burst minute {burst} vs calm minute {calm}"
        );
        // total exceeds the base-rate window: the burst is additional load
        assert!(t.len() as f64 > 4.0 * 600.0);
    }

    #[test]
    fn flash_crowd_overlap_fractions() {
        let f = FlashCrowd { k: 4.0, onset_frac: 0.4, width_frac: 0.15 };
        // burst = [240, 330) of a 600 s window
        assert!((f.overlap(240.0, 300.0, 600.0) - 1.0).abs() < 1e-12);
        assert!((f.overlap(300.0, 360.0, 600.0) - 0.5).abs() < 1e-12);
        assert_eq!(f.overlap(0.0, 60.0, 600.0), 0.0);
    }

    #[test]
    fn zipf_weights_decay_at_requested_exponent() {
        let z = ZipfSkew::new(1.1);
        let w = z.weights(12);
        assert_eq!(w.len(), 12);
        assert!(w.windows(2).all(|p| p[0] > p[1]), "strictly decreasing");
        // w[0]/w[k] = (k+1)^s exactly
        assert!((w[0] / w[11] - 12f64.powf(1.1)).abs() < 1e-9);
    }

    #[test]
    fn zipf_pick_skews_toward_head() {
        let z = ZipfSkew::default();
        let funcs: Vec<usize> = (0..12).collect();
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 12];
        let n = 20_000;
        for _ in 0..n {
            counts[z.pick_function(&funcs, &mut rng)] += 1;
        }
        let w = z.weights(12);
        let total_w: f64 = w.iter().sum();
        // head fraction within 10% relative of the theoretical mass
        let head = counts[0] as f64 / n as f64;
        let expect = w[0] / total_w;
        assert!((head - expect).abs() < 0.1 * expect, "head {head} vs expected {expect}");
        assert!(counts[0] > 5 * counts[11], "head must dominate tail: {counts:?}");
    }
}
