//! Pluggable workload scenarios (DESIGN.md §Scenarios).
//!
//! Every experiment in the original evaluation drives the cluster with a
//! single synthetic arrival process (`workload::azure`) and a uniform
//! function mix — one shape, eight figures. Robustness claims need more:
//! variance conclusions flip across workload shapes (Wen et al.) and
//! underutilization is worst under bursty, skewed traffic (Fifer). The
//! [`Scenario`] trait abstracts *how load arrives* along three axes:
//!
//! 1. the **arrival process** — per-minute intensity profile over the
//!    trace window ([`Scenario::arrival_times`]);
//! 2. **per-function popularity** — which catalog function each
//!    invocation hits ([`Scenario::pick_function`], uniform by default);
//! 3. the **per-invocation input pick** — which pool entry the invocation
//!    carries ([`Scenario::pick_input`], uniform by default).
//!
//! Registered implementations ([`SCENARIOS`], [`by_name`]):
//!
//! | name | process |
//! |---|---|
//! | `azure-synthetic` | today's lognormal × Pareto-burst profile ([`AzureSynthetic`]) |
//! | `diurnal` | sinusoidal day/night rate compressed into the window ([`shapes::Diurnal`]) |
//! | `flash-crowd` | step burst to k× base rate, configurable onset/width ([`shapes::FlashCrowd`]) |
//! | `zipf-skew` | Azure arrivals + Zipf function popularity ([`shapes::ZipfSkew`]) |
//! | `trace-file` | CSV replay of per-minute counts in the Azure Functions trace schema, rescaled to the target RPS ([`trace_file::TraceFile`]) |
//!
//! Determinism contract: a scenario must derive all randomness from the
//! `Rng` it is handed, consuming draws in a stable order — the sweep
//! harness replays the same `(seed, scenario)` pair on any thread and
//! expects byte-identical traces. `AzureSynthetic` consumes the *exact*
//! draw sequence of the direct `azure::arrival_times` + uniform-sampling
//! recipe that `Workload::trace_over` used before the trait existed, so
//! the refactor itself introduces zero drift — pinned by
//! `rust/tests/test_scenarios.rs` against the inlined recipe. (Absolute
//! outputs did shift once in this change, deliberately: `round_counts`
//! replaced per-minute `round()`, fixing dropped invocations at low
//! rates.)

pub mod shapes;
pub mod trace_file;

use anyhow::Result;

use crate::util::rng::Rng;

use super::azure;

/// One workload shape: arrival process + function popularity + input pick.
///
/// Implementations must be pure functions of their configuration and the
/// supplied `Rng` (no interior mutability, no ambient state) so one
/// instance can serve every cell of a parallel sweep.
pub trait Scenario {
    /// Registry name (also used in sweep-cell ids, so keep it stable).
    fn name(&self) -> &'static str;

    /// Invocation start times over `[0, duration_s]` at an average of
    /// `rps` (scenarios modelling overload, e.g. flash crowds, may exceed
    /// it). Must be sorted and bounded by the window.
    fn arrival_times(&self, rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64>;

    /// Which function the next invocation hits. Default: uniform over
    /// `funcs` — byte-compatible with the pre-trait uniform mix.
    fn pick_function(&self, funcs: &[usize], rng: &mut Rng) -> usize {
        funcs[rng.below(funcs.len())]
    }

    /// Which input-pool entry the invocation carries (`0..pool_len`).
    /// Default: uniform — the paper's sampling.
    fn pick_input(&self, pool_len: usize, rng: &mut Rng) -> usize {
        rng.below(pool_len)
    }
}

/// Today's Azure-like synthetic process (lognormal minute profile with
/// Pareto bursts, uniform function/input mix) behind the trait. This is
/// the default scenario everywhere; it consumes the same RNG draws in the
/// same order as calling `azure::arrival_times` + uniform picks directly,
/// so the trait indirection costs no reproducibility.
#[derive(Debug, Clone, Default)]
pub struct AzureSynthetic;

impl Scenario for AzureSynthetic {
    fn name(&self) -> &'static str {
        "azure-synthetic"
    }

    fn arrival_times(&self, rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
        azure::arrival_times(rps, duration_s, rng)
    }
}

/// All registered scenario names, in robustness-matrix column order.
pub const SCENARIOS: &[&str] =
    &["azure-synthetic", "diurnal", "flash-crowd", "zipf-skew", "trace-file"];

/// Build a scenario by registry name with its default parameters.
///
/// `trace-file` replays the checked-in sample trace
/// (`rust/data/azure_sample.csv`, embedded at compile time);
/// `trace-file:<path>` replays a CSV from disk instead.
pub fn by_name(name: &str) -> Result<Box<dyn Scenario>> {
    if let Some(path) = name.strip_prefix("trace-file:") {
        return Ok(Box::new(trace_file::TraceFile::from_path(path)?));
    }
    Ok(match name {
        "azure-synthetic" => Box::new(AzureSynthetic),
        "diurnal" => Box::new(shapes::Diurnal::default()),
        "flash-crowd" => Box::new(shapes::FlashCrowd::default()),
        "zipf-skew" => Box::new(shapes::ZipfSkew::default()),
        "trace-file" => Box::new(trace_file::TraceFile::sample()?),
        other => anyhow::bail!(
            "unknown scenario '{other}' (known: {SCENARIOS:?}, or 'trace-file:<path>')"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_every_name() {
        for name in SCENARIOS {
            let s = by_name(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(s.name(), *name);
        }
    }

    #[test]
    fn unknown_scenario_rejected() {
        assert!(by_name("full-moon").is_err());
        assert!(by_name("trace-file:/no/such/file.csv").is_err());
    }

    #[test]
    fn azure_synthetic_delegates_to_the_legacy_process() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let via_trait = AzureSynthetic.arrival_times(3.0, 300.0, &mut a);
        let direct = azure::arrival_times(3.0, 300.0, &mut b);
        assert_eq!(via_trait, direct);
        // and the RNGs end in the same state (identical draw counts)
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn default_picks_are_uniform_and_deterministic() {
        let funcs: Vec<usize> = (0..12).collect();
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let s = AzureSynthetic;
        for _ in 0..64 {
            assert_eq!(s.pick_function(&funcs, &mut a), s.pick_function(&funcs, &mut b));
            assert_eq!(s.pick_input(20, &mut a), s.pick_input(20, &mut b));
        }
        // uniform pick matches the raw Rng recipe the pre-trait code used
        let mut c = Rng::new(5);
        let mut d = Rng::new(5);
        for _ in 0..64 {
            assert_eq!(s.pick_function(&funcs, &mut c), *d.choose(&funcs));
            assert_eq!(s.pick_input(20, &mut c), d.below(20));
        }
    }

    // NOTE: the cross-scenario arrival contract (sorted / bounded /
    // deterministic / near-target-rate, property-checked across seeds)
    // lives in `rust/tests/test_scenarios.rs` — one copy, not two.
}
