//! Azure-trace-like arrival synthesis (§7.1 methodology).
//!
//! The paper samples a random ten-minute window of the Azure Functions
//! production trace (Shahrad et al.), generates random start times for
//! each invocation within its minute, then subsamples per minute to hit
//! the target RPS. We reproduce the same *process* over a synthetic
//! per-minute profile with Azure-like burstiness (heavy-tailed per-minute
//! counts: most minutes near the mean, occasional 2-3x bursts).

use crate::util::rng::Rng;

/// Per-minute invocation counts with Azure-like burstiness, scaled so the
/// whole window averages `rps`.
pub fn per_minute_counts(rps: f64, minutes: usize, rng: &mut Rng) -> Vec<u64> {
    // lognormal minute-to-minute variation plus a Pareto burst component
    // (the production trace shows frequent 2-4x minute-scale bursts).
    let mut raw: Vec<f64> = (0..minutes)
        .map(|_| {
            let base = rng.lognormal(0.0, 0.40);
            let burst = if rng.chance(0.08) { rng.pareto(1.0, 2.2) } else { 1.0 };
            base * burst
        })
        .collect();
    let mean: f64 = raw.iter().sum::<f64>() / minutes as f64;
    let target_per_min = rps * 60.0;
    for r in raw.iter_mut() {
        *r = (*r / mean) * target_per_min;
    }
    raw.into_iter().map(|r| r.round().max(0.0) as u64).collect()
}

/// Invocation start times over a `duration_s` window at `rps`:
/// per-minute counts from the burstiness profile, uniform-random start
/// times within each minute (exactly the paper's §7.1 recipe). Sorted.
pub fn arrival_times(rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
    let minutes = (duration_s / 60.0).ceil() as usize;
    let counts = per_minute_counts(rps, minutes.max(1), rng);
    let mut times = Vec::new();
    for (m, count) in counts.iter().enumerate() {
        let lo = m as f64 * 60.0;
        for _ in 0..*count {
            let t = lo + rng.f64() * 60.0;
            if t <= duration_s {
                times.push(t);
            }
        }
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_average_to_rps() {
        let mut rng = Rng::new(1);
        let counts = per_minute_counts(4.0, 10, &mut rng);
        let total: u64 = counts.iter().sum();
        let rate = total as f64 / 600.0;
        assert!((rate - 4.0).abs() < 0.5, "rate {rate}");
    }

    #[test]
    fn bursty_not_constant() {
        let mut rng = Rng::new(2);
        let counts = per_minute_counts(6.0, 30, &mut rng);
        let min = *counts.iter().min().unwrap() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max > 1.5 * min, "expected burstiness: {counts:?}");
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        let mut rng = Rng::new(3);
        let t = arrival_times(3.0, 600.0, &mut rng);
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        assert!(t.iter().all(|x| (0.0..=600.0).contains(x)));
        assert!(!t.is_empty());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = arrival_times(3.0, 300.0, &mut Rng::new(9));
        let b = arrival_times(3.0, 300.0, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
