//! Azure-trace-like arrival synthesis (§7.1 methodology).
//!
//! The paper samples a random ten-minute window of the Azure Functions
//! production trace (Shahrad et al.), generates random start times for
//! each invocation within its minute, then subsamples per minute to hit
//! the target RPS. We reproduce the same *process* over a synthetic
//! per-minute profile with Azure-like burstiness (heavy-tailed per-minute
//! counts: most minutes near the mean, occasional 2-3x bursts).
//!
//! This module is the *process*; `workload::scenario` wraps it (and four
//! alternative processes) behind the [`Scenario`](super::scenario::Scenario)
//! trait so every experiment can run under any arrival shape.

use crate::util::rng::Rng;

/// Round non-negative real per-minute intensities to integer counts whose
/// total equals `round(sum)` exactly (largest-remainder method): floor
/// every entry, then hand the rounding residue to the largest fractional
/// parts (ties broken by index, so the result is deterministic).
///
/// Naive per-entry `round()` can drop *every* invocation at very low
/// `rps × minutes` (all entries below 0.5 round to an all-zero window) or
/// drift by several counts over long windows; this guarantees the window
/// carries the expected total ±1 regardless of how the mass is spread.
pub fn round_counts(raw: &[f64]) -> Vec<u64> {
    let total: f64 = raw.iter().map(|r| r.max(0.0)).sum();
    let target = total.round() as u64;
    let mut counts: Vec<u64> = raw.iter().map(|r| r.max(0.0).floor() as u64).collect();
    let floor_sum: u64 = counts.iter().sum();
    let mut residue = target.saturating_sub(floor_sum);
    if residue > 0 {
        let mut by_frac: Vec<(usize, f64)> = raw
            .iter()
            .map(|r| {
                let r = r.max(0.0);
                r - r.floor()
            })
            .enumerate()
            .collect();
        by_frac.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        for (i, _) in by_frac {
            if residue == 0 {
                break;
            }
            counts[i] += 1;
            residue -= 1;
        }
    }
    counts
}

/// Rescale a per-minute intensity profile in place so the window mean is
/// exactly `rps` (sum = `rps * 60 * len`). An all-zero profile cannot
/// preserve its shape, so it falls back to a uniform profile at the
/// target rate instead of dividing by zero (a trace-replay window can
/// land entirely on zero-count minutes).
pub fn rescale_to_rps(raw: &mut [f64], rps: f64) {
    if raw.is_empty() {
        return;
    }
    let target = rps * 60.0 * raw.len() as f64;
    let sum: f64 = raw.iter().map(|r| r.max(0.0)).sum();
    if sum <= 0.0 {
        let uniform = target / raw.len() as f64;
        raw.fill(uniform);
    } else {
        for r in raw.iter_mut() {
            *r = r.max(0.0) * target / sum;
        }
    }
}

/// Arrivals from a raw per-minute intensity profile: residue-preserving
/// rounding ([`round_counts`]) then uniform within-minute placement
/// ([`minute_starts`]) — the shared tail of every per-minute scenario.
pub fn profile_starts(raw: &[f64], duration_s: f64, rng: &mut Rng) -> Vec<f64> {
    minute_starts(&round_counts(raw), duration_s, rng)
}

/// Per-minute invocation counts with Azure-like burstiness, scaled so the
/// whole window averages `rps`. The total over the window is exactly
/// `round(rps * 60 * minutes)` (see [`round_counts`]).
pub fn per_minute_counts(rps: f64, minutes: usize, rng: &mut Rng) -> Vec<u64> {
    // lognormal minute-to-minute variation plus a Pareto burst component
    // (the production trace shows frequent 2-4x minute-scale bursts).
    let mut raw: Vec<f64> = (0..minutes)
        .map(|_| {
            let base = rng.lognormal(0.0, 0.40);
            let burst = if rng.chance(0.08) { rng.pareto(1.0, 2.2) } else { 1.0 };
            base * burst
        })
        .collect();
    rescale_to_rps(&mut raw, rps);
    round_counts(&raw)
}

/// Invocation start times over a `duration_s` window at `rps`:
/// per-minute counts from the burstiness profile, uniform-random start
/// times within each minute (exactly the paper's §7.1 recipe). Sorted.
pub fn arrival_times(rps: f64, duration_s: f64, rng: &mut Rng) -> Vec<f64> {
    let minutes = (duration_s / 60.0).ceil() as usize;
    let counts = per_minute_counts(rps, minutes.max(1), rng);
    minute_starts(&counts, duration_s, rng)
}

/// Shared tail of every per-minute arrival process: uniform-random start
/// times within each minute, sorted (NaN-safe).
///
/// The final minute of a non-multiple-of-60 window is *partial* (width
/// `duration_s - lo < 60`). Earlier versions drew over the full minute and
/// silently dropped draws landing past `duration_s`, which made the window
/// total a coin flip (binomial thinning of the last minute) instead of the
/// deterministic `round(...)` contract the rest of the pipeline pins.
/// Instead, the partial minute's mass is rescaled to its covered fraction
/// (`round(count * w / 60)` arrivals, uniform over `[lo, lo + w)`), so the
/// delivered total is an exact function of the counts and the density at
/// the window edge matches the rest of the minute. Multiple-of-60 windows
/// take the `w == 60` path and consume the byte-identical draw sequence
/// they always did.
pub fn minute_starts(counts: &[u64], duration_s: f64, rng: &mut Rng) -> Vec<f64> {
    let mut times = Vec::new();
    for (m, count) in counts.iter().enumerate() {
        let lo = m as f64 * 60.0;
        let w = (duration_s - lo).min(60.0);
        if w <= 0.0 {
            continue;
        }
        let k = if w >= 60.0 { *count } else { ((*count as f64) * w / 60.0).round() as u64 };
        for _ in 0..k {
            times.push(lo + rng.f64() * w);
        }
    }
    times.sort_by(f64::total_cmp);
    times
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_average_to_rps() {
        let mut rng = Rng::new(1);
        let counts = per_minute_counts(4.0, 10, &mut rng);
        let total: u64 = counts.iter().sum();
        let rate = total as f64 / 600.0;
        assert!((rate - 4.0).abs() < 0.5, "rate {rate}");
    }

    #[test]
    fn counts_total_exact() {
        // largest-remainder rounding pins the window total, not just the mean
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let counts = per_minute_counts(3.7, 10, &mut rng);
            let total: u64 = counts.iter().sum();
            assert_eq!(total, (3.7f64 * 60.0 * 10.0).round() as u64, "seed {seed}");
        }
    }

    #[test]
    fn low_rate_window_not_all_zero() {
        // rps * 60 * minutes = 1.8 expected invocations; naive rounding of
        // per-minute values (~0.6 each, often < 0.5 after burst scaling)
        // could zero the whole window. The residue guarantee forbids that.
        for seed in 0..50 {
            let mut rng = Rng::new(seed);
            let counts = per_minute_counts(0.01, 3, &mut rng);
            let total: u64 = counts.iter().sum();
            assert!((1..=2).contains(&total), "seed {seed}: total {total} not within expected ±1");
        }
    }

    #[test]
    fn round_counts_preserves_total_and_handles_edges() {
        assert_eq!(round_counts(&[]), Vec::<u64>::new());
        assert_eq!(round_counts(&[0.0, 0.0]), vec![0, 0]);
        // 0.4 + 0.4 + 0.4 = 1.2 -> one invocation, on the first (tie) minute
        assert_eq!(round_counts(&[0.4, 0.4, 0.4]), vec![1, 0, 0]);
        // residue goes to the largest fractional part
        assert_eq!(round_counts(&[1.2, 0.7, 2.1]), vec![1, 1, 2]);
        // negatives clamp to zero instead of corrupting the total
        assert_eq!(round_counts(&[-3.0, 2.5, 0.5]), vec![0, 3, 0]);
        let raw = [10.3, 0.9, 5.55, 7.77, 0.01];
        let total: u64 = round_counts(&raw).iter().sum();
        assert_eq!(total, raw.iter().sum::<f64>().round() as u64);
    }

    #[test]
    fn rescale_hits_target_and_survives_zero_profiles() {
        let mut raw = vec![1.0, 3.0, 2.0];
        rescale_to_rps(&mut raw, 2.0);
        assert!((raw.iter().sum::<f64>() - 2.0 * 60.0 * 3.0).abs() < 1e-9);
        assert!(raw[1] > raw[0], "shape preserved");
        // all-zero window: uniform fallback instead of 0/0 = NaN
        let mut zeros = vec![0.0, 0.0];
        rescale_to_rps(&mut zeros, 1.0);
        assert!(zeros.iter().all(|r| (*r - 60.0).abs() < 1e-9), "{zeros:?}");
        let mut empty: Vec<f64> = vec![];
        rescale_to_rps(&mut empty, 1.0);
        assert!(empty.is_empty());
    }

    #[test]
    fn bursty_not_constant() {
        let mut rng = Rng::new(2);
        let counts = per_minute_counts(6.0, 30, &mut rng);
        let min = *counts.iter().min().unwrap() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max > 1.5 * min, "expected burstiness: {counts:?}");
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        let mut rng = Rng::new(3);
        let t = arrival_times(3.0, 600.0, &mut rng);
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        assert!(t.iter().all(|x| (0.0..=600.0).contains(x)));
        assert!(!t.is_empty());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = arrival_times(3.0, 300.0, &mut Rng::new(9));
        let b = arrival_times(3.0, 300.0, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn partial_minute_mass_is_rescaled_not_truncated() {
        // one full minute + a 30 s partial minute: the partial minute
        // carries round(10 * 30/60) = 5 arrivals, uniform over [60, 90).
        let t = minute_starts(&[10, 10], 90.0, &mut Rng::new(1));
        assert_eq!(t.len(), 15);
        let tail: Vec<f64> = t.iter().copied().filter(|x| *x >= 60.0).collect();
        assert_eq!(tail.len(), 5);
        assert!(tail.iter().all(|x| (60.0..90.0).contains(x)), "{tail:?}");
        // minutes past the window contribute nothing (and draw nothing)
        let clipped = minute_starts(&[5, 5, 5], 60.0, &mut Rng::new(1));
        assert_eq!(clipped.len(), 5);
        assert!(clipped.iter().all(|x| (0.0..60.0).contains(x)));
    }

    #[test]
    fn partial_minute_windows_deliver_an_exact_total() {
        // the delivered total must be a deterministic function of the
        // counts — not a binomial thinning of the final minute.
        for &(rps, dur) in &[(4.0, 90.0), (6.0, 330.0)] {
            let minutes = (dur / 60.0_f64).ceil() as usize;
            let counts = per_minute_counts(rps, minutes, &mut Rng::new(5));
            let w = dur - (minutes as f64 - 1.0) * 60.0;
            let expect: u64 = counts[..minutes - 1].iter().sum::<u64>()
                + ((counts[minutes - 1] as f64) * w / 60.0).round() as u64;
            let t = arrival_times(rps, dur, &mut Rng::new(5));
            assert_eq!(t.len() as u64, expect, "rps {rps} dur {dur}");
            assert!(t.iter().all(|x| (0.0..=dur).contains(x)));
            let rate = t.len() as f64 / dur;
            assert!((rate - rps).abs() < 0.35 * rps, "rps {rps} dur {dur}: delivered {rate}");
        }
    }

    #[test]
    fn multiple_of_60_windows_keep_the_legacy_draw_stream() {
        // the partial-minute fix must not shift full-minute windows: they
        // consume byte-identical draws to the pre-fix recipe.
        let counts = [3u64, 0, 7, 2];
        let new = minute_starts(&counts, 240.0, &mut Rng::new(11));
        let mut rng = Rng::new(11);
        let mut old = Vec::new();
        for (m, count) in counts.iter().enumerate() {
            let lo = m as f64 * 60.0;
            for _ in 0..*count {
                let t = lo + rng.f64() * 60.0;
                if t <= 240.0 {
                    old.push(t);
                }
            }
        }
        old.sort_by(f64::total_cmp);
        assert_eq!(new, old);
    }
}
