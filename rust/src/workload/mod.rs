//! Workload generation: Azure-like invocation traces (§7.1), pluggable
//! arrival/popularity scenarios (DESIGN.md §Scenarios), and the
//! per-function/input SLO assignment the evaluation uses.

pub mod azure;
pub mod scenario;
pub mod slo;

use crate::featurizer::InputSpec;
use crate::functions::catalog::CATALOG;
use crate::functions::inputs;
use crate::simulator::Request;
use crate::util::rng::Rng;

/// Salt for the suite-construction stream (input pools + SLO derivation),
/// decorrelated from the engine/policy streams off the same seed.
const SALT_WORKLOAD: u64 = 0x3017_AB1E;

/// Salt for trace generation (arrival times, function/input picks). Public
/// because the scenario byte-pin test replays the legacy recipe with the
/// identical stream.
pub const SALT_TRACE: u64 = 0x7A3C_E000;

/// The benchmark suite: every function's input pool plus per-input SLOs.
#[derive(Debug)]
pub struct Workload {
    /// Input pools, indexed by catalog function index.
    pub pools: Vec<Vec<InputSpec>>,
    /// SLOs aligned with `pools` (seconds).
    pub slos: Vec<Vec<f64>>,
    pub slo_multiplier: f64,
}

impl Workload {
    /// Build the full Table-1 suite with SLOs at `multiplier` x the
    /// median isolated runtime (1.4x in the paper's evaluation).
    pub fn build(seed: u64, multiplier: f64) -> Self {
        let mut rng = Rng::new(seed ^ SALT_WORKLOAD);
        let mut pools = Vec::with_capacity(CATALOG.len());
        let mut slos = Vec::with_capacity(CATALOG.len());
        for spec in CATALOG {
            let pool = inputs::pool(spec, &mut rng);
            let s: Vec<f64> = pool
                .iter()
                .map(|input| slo::derive_slo(spec, input, multiplier, &mut rng))
                .collect();
            pools.push(pool);
            slos.push(s);
        }
        Workload { pools, slos, slo_multiplier: multiplier }
    }

    /// A subset workload over named functions (smaller experiments).
    pub fn subset(&self, names: &[&str]) -> Vec<usize> {
        names
            .iter()
            .map(|n| crate::functions::catalog::index_of(n).expect("unknown function"))
            .collect()
    }

    /// Generate a request trace at `rps` over `duration_s` seconds using
    /// the Azure-like arrival process, sampling (function, input)
    /// uniformly as the paper does.
    pub fn trace(&self, rps: f64, duration_s: f64, seed: u64) -> Vec<Request> {
        self.trace_over(&(0..CATALOG.len()).collect::<Vec<_>>(), rps, duration_s, seed)
    }

    /// Trace restricted to a set of function indices.
    pub fn trace_over(
        &self,
        funcs: &[usize],
        rps: f64,
        duration_s: f64,
        seed: u64,
    ) -> Vec<Request> {
        // `AzureSynthetic` + the trait's default picks consume the exact
        // RNG draw sequence of the direct `azure::arrival_times` + uniform
        // sampling recipe, so routing through the trait adds zero drift
        // (pinned by `tests/test_scenarios.rs` against the inlined recipe).
        self.trace_scenario(&scenario::AzureSynthetic, funcs, rps, duration_s, seed)
    }

    /// Trace over the full catalog under any [`scenario::Scenario`].
    pub fn trace_with(
        &self,
        scenario: &dyn scenario::Scenario,
        rps: f64,
        duration_s: f64,
        seed: u64,
    ) -> Vec<Request> {
        self.trace_scenario(
            scenario,
            &(0..CATALOG.len()).collect::<Vec<_>>(),
            rps,
            duration_s,
            seed,
        )
    }

    /// The one trace generator every path shares: the scenario supplies
    /// the arrival process and the (function, input) sampling; this
    /// attaches pool inputs and SLOs. One `Rng` (salted exactly like the
    /// historical generator) is threaded through arrivals and picks in a
    /// fixed order, so traces are deterministic per (seed, scenario).
    pub fn trace_scenario(
        &self,
        scenario: &dyn scenario::Scenario,
        funcs: &[usize],
        rps: f64,
        duration_s: f64,
        seed: u64,
    ) -> Vec<Request> {
        let mut rng = Rng::new(seed ^ SALT_TRACE);
        let starts = scenario.arrival_times(rps, duration_s, &mut rng);
        starts
            .into_iter()
            .enumerate()
            .map(|(i, at)| {
                let func = scenario.pick_function(funcs, &mut rng);
                let input_idx = scenario.pick_input(self.pools[func].len(), &mut rng);
                Request {
                    id: i as u64 + 1,
                    func,
                    input: self.pools[func][input_idx].clone(),
                    arrival: at,
                    slo_s: self.slos[func][input_idx],
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_builds_slos_for_every_input() {
        let w = Workload::build(1, 1.4);
        assert_eq!(w.pools.len(), CATALOG.len());
        for (pool, slos) in w.pools.iter().zip(&w.slos) {
            assert_eq!(pool.len(), slos.len());
            assert!(slos.iter().all(|s| *s > 0.0));
        }
    }

    #[test]
    fn trace_rate_approximately_target() {
        let w = Workload::build(1, 1.4);
        let t = w.trace(4.0, 600.0, 7);
        let rate = t.len() as f64 / 600.0;
        assert!((rate - 4.0).abs() < 0.8, "rate {rate}");
        // sorted by arrival? engine sorts anyway; check span
        assert!(t.iter().all(|r| (0.0..=600.0).contains(&r.arrival)));
    }

    #[test]
    fn trace_mixes_functions() {
        let w = Workload::build(1, 1.4);
        let t = w.trace(5.0, 600.0, 7);
        let funcs: std::collections::BTreeSet<usize> = t.iter().map(|r| r.func).collect();
        assert!(funcs.len() >= 10, "uniform sampling must cover most functions");
    }

    #[test]
    fn trace_deterministic() {
        let w = Workload::build(1, 1.4);
        let a = w.trace(3.0, 120.0, 9);
        let b = w.trace(3.0, 120.0, 9);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival == y.arrival && x.func == y.func));
    }

    #[test]
    fn trace_with_scenario_changes_the_mix() {
        let w = Workload::build(1, 1.4);
        let zipf = scenario::shapes::ZipfSkew::default();
        let t = w.trace_with(&zipf, 5.0, 600.0, 7);
        let mut counts = vec![0usize; CATALOG.len()];
        for r in &t {
            counts[r.func] += 1;
        }
        assert!(
            counts[0] > 3 * counts[CATALOG.len() - 1].max(1),
            "zipf mix must skew to the head: {counts:?}"
        );
    }

    #[test]
    fn subset_restricts_functions() {
        let w = Workload::build(1, 1.4);
        let fs = w.subset(&["qr", "compress"]);
        let t = w.trace_over(&fs, 4.0, 300.0, 7);
        assert!(t.iter().all(|r| fs.contains(&r.func)));
    }
}
