//! SLO derivation (§7.1): for every function/input pair, run the function
//! in isolation on every vCPU count 1..32, take the median execution time
//! across those runs, and set the SLO to `multiplier ×` that median
//! (1.4× in the paper — much tighter than Cypress's max+20%).

use crate::featurizer::InputSpec;
use crate::functions::FunctionSpec;
use crate::util::rng::Rng;
use crate::util::stats;

/// vCPU counts profiled for the SLO (paper: 1..32).
pub const PROFILE_VCPUS: std::ops::RangeInclusive<u32> = 1..=32;
/// Repetitions per vCPU count.
pub const RUNS_PER_COUNT: usize = 3;

/// The 1.4x evaluation default.
pub const DEFAULT_MULTIPLIER: f64 = 1.4;

/// Derive the SLO for one function/input pair.
pub fn derive_slo(spec: &FunctionSpec, input: &InputSpec, multiplier: f64, rng: &mut Rng) -> f64 {
    let mut times = Vec::with_capacity(32 * RUNS_PER_COUNT);
    for vcpus in PROFILE_VCPUS {
        for _ in 0..RUNS_PER_COUNT {
            let d = spec.noisy_demand(input, rng);
            times.push(d.ideal_exec_s(vcpus as f64, 10.0));
        }
    }
    stats::median(&times) * multiplier
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::catalog::by_name;
    use crate::functions::inputs;

    #[test]
    fn single_threaded_slo_near_fixed_runtime() {
        // For a single-threaded function every vCPU count gives the same
        // time, so the SLO ~ multiplier x that time and 1 vCPU meets it.
        let spec = by_name("qr").unwrap();
        let mut rng = Rng::new(1);
        let pool = inputs::pool(spec, &mut rng);
        let input = &pool[5];
        let slo = derive_slo(spec, input, 1.4, &mut rng);
        let t1 = (spec.demand)(input).ideal_exec_s(1.0, 10.0);
        assert!(slo > t1, "slo {slo} vs t1 {t1}");
        assert!(slo < 1.8 * t1, "slo should be ~1.4x the flat runtime");
    }

    #[test]
    fn multi_threaded_slo_requires_mid_allocation() {
        // The median over 1..32 vCPUs sits at a mid allocation, so small
        // allocations violate and large ones meet comfortably.
        let spec = by_name("compress").unwrap();
        let mut rng = Rng::new(2);
        let pool = inputs::pool(spec, &mut rng);
        let input = pool.last().unwrap(); // 2 GB
        let slo = derive_slo(spec, input, 1.4, &mut rng);
        let d = (spec.demand)(input);
        assert!(
            d.ideal_exec_s(2.0, 10.0) > slo,
            "2 vCPUs must miss the SLO for the largest input"
        );
        assert!(
            d.ideal_exec_s(32.0, 10.0) < slo,
            "32 vCPUs must meet the SLO comfortably"
        );
    }

    #[test]
    fn multiplier_scales_slo() {
        let spec = by_name("encrypt").unwrap();
        let mut rng = Rng::new(3);
        let pool = inputs::pool(spec, &mut rng);
        let s12 = derive_slo(spec, &pool[3], 1.2, &mut Rng::new(4));
        let s18 = derive_slo(spec, &pool[3], 1.8, &mut Rng::new(4));
        assert!((s18 / s12 - 1.5).abs() < 1e-9);
    }
}
