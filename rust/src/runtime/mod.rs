//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! This is the only place where the crate talks to XLA. The interchange
//! format is HLO *text* (not serialized `HloModuleProto`): jax >= 0.5 emits
//! protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
//! the text parser reassigns ids and round-trips cleanly.
//!
//! The coordinator keeps one [`XlaEngine`] per process. Model weights are
//! kept as host `Vec<f32>` owned by the learner (they are small:
//! `C x F = 48 x 16` f32 per model) and uploaded per call; see
//! EXPERIMENTS.md §Perf for the measured cost and the batching strategy.
//!
//! The engine compiles only with the non-default `xla` cargo feature;
//! without it this module still exports the shape constants shared with
//! the Python layers, and `learner::xla::Backend::Native` is the only
//! usable backend (DESIGN.md §1).

#[cfg(feature = "xla")]
use std::collections::BTreeMap;
#[cfg(feature = "xla")]
use std::path::{Path, PathBuf};

#[cfg(feature = "xla")]
use anyhow::{anyhow, bail, Context, Result};

/// Shape constants shared with `python/compile/model.py`. `aot.py` bakes the
/// same values into the artifacts; [`XlaEngine::load_dir`] cross-checks them
/// against `artifacts/manifest.json`.
pub const NUM_CLASSES: usize = 48;
/// Feature-vector dimension (padded; see `featurizer::FeatureVector`).
pub const FEAT_DIM: usize = 16;
/// Batch size of the batched predictor artifact.
pub const BATCH: usize = 64;

/// Names of the artifacts the engine expects under `artifacts/`.
pub const ARTIFACTS: &[&str] = &["csmc_predict", "csmc_update", "csmc_predict_batch"];

/// A loaded, compiled HLO executable plus metadata.
#[cfg(feature = "xla")]
struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
    /// Number of parameters the HLO module expects (sanity checking).
    arity: usize,
}

/// Engine owning the PJRT CPU client and the compiled executables.
#[cfg(feature = "xla")]
pub struct XlaEngine {
    client: xla::PjRtClient,
    exes: BTreeMap<String, LoadedExe>,
    dir: PathBuf,
}

/// Manual `Debug`: the PJRT client is an opaque FFI handle; the artifact
/// directory and loaded executable names describe the engine.
#[cfg(feature = "xla")]
impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("dir", &self.dir)
            .field("exes", &self.exes.keys().collect::<Vec<_>>())
            .finish_non_exhaustive()
    }
}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Create an engine backed by the PJRT CPU client, loading all standard
    /// artifacts from `dir` (typically `artifacts/`).
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let mut engine = Self { client, exes: BTreeMap::new(), dir: dir.clone() };
        for name in ARTIFACTS {
            let path = dir.join(format!("{name}.hlo.txt"));
            engine
                .load_hlo(name, &path)
                .with_context(|| format!("loading artifact {}", path.display()))?;
        }
        engine.check_manifest()?;
        Ok(engine)
    }

    /// Create an engine with no artifacts loaded (tests load ad-hoc HLO).
    pub fn empty() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, exes: BTreeMap::new(), dir: PathBuf::from("artifacts") })
    }

    /// Load and compile one HLO-text file under `name`.
    pub fn load_hlo(&mut self, name: &str, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let arity = count_parameters(&text);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        self.exes.insert(name.to_string(), LoadedExe { exe, arity });
        Ok(())
    }

    /// Whether an executable with this name has been loaded.
    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute `name` with pre-built literals (hot path: callers cache
    /// and mutate their input literals in place to avoid per-call
    /// allocation — see EXPERIMENTS.md §Perf).
    pub fn execute_lits(&self, name: &str, lits: &[&xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let loaded = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not loaded"))?;
        if loaded.arity != 0 && loaded.arity != lits.len() {
            bail!("executable '{name}' expects {} parameters, got {}", loaded.arity, lits.len());
        }
        let result = loaded
            .exe
            .execute::<&xla::Literal>(lits)
            .map_err(|e| anyhow!("execute '{name}': {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of '{name}': {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untuple result of '{name}': {e}"))?;
        let mut vecs = Vec::with_capacity(parts.len());
        for p in parts {
            vecs.push(p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?);
        }
        Ok(vecs)
    }

    /// Build a reusable literal of the given shape (for `execute_lits`).
    pub fn make_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))
    }

    /// Execute `name` with f32 tensor inputs `(data, dims)`; returns the
    /// flattened f32 contents of each tuple element of the result.
    ///
    /// All our L2 graphs are lowered with `return_tuple=True`, so the single
    /// output literal is always a tuple.
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let loaded = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not loaded"))?;
        if loaded.arity != 0 && loaded.arity != inputs.len() {
            bail!(
                "executable '{name}' expects {} parameters, got {}",
                loaded.arity,
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let n: i64 = dims.iter().product();
            if n as usize != data.len() {
                bail!("input shape {:?} does not match data len {}", dims, data.len());
            }
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))?;
            lits.push(lit);
        }
        let result = loaded
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute '{name}': {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of '{name}': {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untuple result of '{name}': {e}"))?;
        let mut vecs = Vec::with_capacity(parts.len());
        for p in parts {
            vecs.push(p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?);
        }
        Ok(vecs)
    }

    /// Cross-check artifact shapes against `manifest.json` written by aot.py.
    fn check_manifest(&self) -> Result<()> {
        let path = self.dir.join("manifest.json");
        let Ok(text) = std::fs::read_to_string(&path) else {
            return Ok(()); // manifest optional (older artifact dirs)
        };
        for (key, want) in
            [("num_classes", NUM_CLASSES), ("feat_dim", FEAT_DIM), ("batch", BATCH)]
        {
            if let Some(got) = json_usize(&text, key) {
                if got != want {
                    bail!(
                        "artifact manifest {key}={got} does not match crate constant {want} — \
                         re-run `make artifacts`"
                    );
                }
            }
        }
        Ok(())
    }

    /// Platform description, for logging.
    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }
}

/// Count `parameter(i)` declarations in the entry computation of HLO text.
/// Cheap sanity check so arity mismatches fail with a clear message instead
/// of an opaque XLA error.
#[cfg(any(feature = "xla", test))]
fn count_parameters(hlo: &str) -> usize {
    let mut entry = false;
    let mut count = 0usize;
    for line in hlo.lines() {
        let t = line.trim_start();
        if t.starts_with("ENTRY ") {
            entry = true;
            continue;
        }
        if entry {
            if t.starts_with('}') {
                break;
            }
            if t.contains("= parameter(") || (t.contains(" parameter(") && t.contains('=')) {
                count += 1;
            }
        }
    }
    count
}

/// Extract `"key": <int>` from a flat JSON object without a JSON dependency.
#[cfg(any(feature = "xla", test))]
fn json_usize(text: &str, key: &str) -> Option<usize> {
    let pat = format!("\"{key}\"");
    let at = text.find(&pat)?;
    let rest = &text[at + pat.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_counting() {
        let hlo = r#"
HloModule m

ENTRY main {
  p0 = f32[48,16]{1,0} parameter(0)
  p1 = f32[16]{0} parameter(1)
  ROOT t = (f32[48]{0}) tuple(p0)
}
"#;
        assert_eq!(count_parameters(hlo), 2);
    }

    #[test]
    fn manifest_parse() {
        let t = r#"{ "num_classes": 48, "feat_dim": 16, "batch": 64 }"#;
        assert_eq!(json_usize(t, "num_classes"), Some(48));
        assert_eq!(json_usize(t, "feat_dim"), Some(16));
        assert_eq!(json_usize(t, "missing"), None);
    }
}
