//! Input Featurizer (paper §4.3.1, Appendix A / Table 2).
//!
//! Extracts descriptive, performance-relevant features from each function
//! input: *not* content understanding, just the metadata that drives
//! execution time and resource utilization (size, resolution, rows/cols,
//! duration, ...). Features land in a fixed-dimension padded
//! [`FeatureVector`] (F = 16, shared with the AOT artifacts).
//!
//! Featurization runs in the background when an object is persisted to the
//! datastore; it is on the critical path only for storage-triggered
//! invocations (§7.6, Figure 14). [`FeatureCache`] models exactly that —
//! the in-memory metadata store on the allocator node.

pub mod extract;

use std::collections::BTreeMap;

use crate::runtime::FEAT_DIM;

/// Input types studied in the paper (Tables 1 & 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InputKind {
    Image,
    Matrix,
    Video,
    Csv,
    JsonDoc,
    Audio,
    /// Inline payloads (strings, urls, numeric parameters) — featurized
    /// from the invocation payload itself, zero extraction cost (§7.6).
    Payload,
    /// Opaque binary file (compress): only size is known without reading.
    File,
}

impl InputKind {
    pub fn name(&self) -> &'static str {
        match self {
            InputKind::Image => "image",
            InputKind::Matrix => "matrix",
            InputKind::Video => "video",
            InputKind::Csv => "csv",
            InputKind::JsonDoc => "json",
            InputKind::Audio => "audio",
            InputKind::Payload => "payload",
            InputKind::File => "file",
        }
    }

    /// All kinds, in a stable order (used by the per-input-type model
    /// formulation of Figure 6).
    pub fn all() -> &'static [InputKind] {
        &[
            InputKind::Image,
            InputKind::Matrix,
            InputKind::Video,
            InputKind::Csv,
            InputKind::JsonDoc,
            InputKind::Audio,
            InputKind::Payload,
            InputKind::File,
        ]
    }

    pub fn index(&self) -> usize {
        Self::all().iter().position(|k| k == self).unwrap()
    }
}

/// A synthetic input object. Stands in for the real blobs the paper's
/// datastore holds; carries the metadata the real featurizer would read
/// with ffprobe/imagemagick/file-opens (DESIGN.md §2 substitution table).
#[derive(Debug, Clone)]
pub struct InputSpec {
    /// Object id in the datastore (feature-cache key). 0 = inline payload.
    pub id: u64,
    pub kind: InputKind,
    pub size_bytes: f64,
    /// matrix/csv: rows, cols; matrix: density.
    pub rows: f64,
    pub cols: f64,
    pub density: f64,
    /// image/video: pixel dimensions; image: channels + dpi.
    pub width: f64,
    pub height: f64,
    pub channels: f64,
    pub dpi: f64,
    /// video/audio: duration, bitrate; video: fps + encoding enum;
    /// audio: sample rate + FLAC flag.
    pub duration_s: f64,
    pub bitrate: f64,
    pub fps: f64,
    pub encoding: f64,
    pub sample_rate: f64,
    pub flac: bool,
    /// payload: logical length (string len, url len, batch count).
    pub length: f64,
    /// Whether the object lives in the datastore (background featurization)
    /// or arrives inline with the invocation.
    pub in_datastore: bool,
}

impl InputSpec {
    /// An empty spec of a given kind; builders in `functions::inputs` fill
    /// in the relevant fields.
    pub fn new(kind: InputKind) -> Self {
        InputSpec {
            id: 0,
            kind,
            size_bytes: 0.0,
            rows: 0.0,
            cols: 0.0,
            density: 1.0,
            width: 0.0,
            height: 0.0,
            channels: 3.0,
            dpi: 72.0,
            duration_s: 0.0,
            bitrate: 0.0,
            fps: 30.0,
            encoding: 0.0,
            sample_rate: 44_100.0,
            flac: false,
            length: 0.0,
            in_datastore: true,
        }
    }

    pub fn size_mb(&self) -> f64 {
        self.size_bytes / (1024.0 * 1024.0)
    }
}

/// Fixed-dimension padded feature vector fed to the CSMC learner.
///
/// Layout: `[bias, kind-specific features (Table 2)..., 0-padding, slo]`.
/// The SLO occupies the last slot for vCPU models and is zeroed for memory
/// models (§4.3.2: memory allocation does not affect performance, so the
/// SLO is not a memory feature).
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureVector(pub [f32; FEAT_DIM]);

impl FeatureVector {
    pub const SLO_SLOT: usize = FEAT_DIM - 1;

    pub fn zeros() -> Self {
        FeatureVector([0.0; FEAT_DIM])
    }

    /// Build from raw features (bias is added automatically at slot 0).
    pub fn from_features(feats: &[f32]) -> Self {
        assert!(
            feats.len() <= FEAT_DIM - 2,
            "too many features: {} > {}",
            feats.len(),
            FEAT_DIM - 2
        );
        let mut v = [0.0f32; FEAT_DIM];
        v[0] = 1.0; // bias
        v[1..1 + feats.len()].copy_from_slice(feats);
        FeatureVector(v)
    }

    /// Attach a (log-scaled, normalized) SLO to the reserved slot.
    pub fn with_slo(mut self, slo_s: f64) -> Self {
        self.0[Self::SLO_SLOT] = ((slo_s.max(1e-3)).ln() / extract::LOG_NORM) as f32;
        self
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }
}

/// Result of featurization: the vector plus the extraction latency that
/// the invocation pays *if* the features were not already cached (§7.6).
#[derive(Debug, Clone)]
pub struct Featurized {
    pub vector: FeatureVector,
    /// Seconds of extraction work (file-open types are slow, metadata-only
    /// types are fast, payload types are free).
    pub extract_latency_s: f64,
}

/// Extract Table-2 features for an input. Dispatches on the input kind.
pub fn featurize(input: &InputSpec) -> Featurized {
    let (feats, latency) = match input.kind {
        InputKind::Image => extract::image(input),
        InputKind::Matrix => extract::matrix(input),
        InputKind::Video => extract::video(input),
        InputKind::Csv => extract::csv(input),
        InputKind::JsonDoc => extract::json_doc(input),
        InputKind::Audio => extract::audio(input),
        InputKind::Payload => extract::payload(input),
        InputKind::File => extract::file(input),
    };
    Featurized { vector: FeatureVector::from_features(&feats), extract_latency_s: latency }
}

/// The in-memory metadata store holding featurized objects. Objects
/// persisted to the datastore are featurized in the background; a cache
/// hit means zero critical-path extraction latency.
#[derive(Debug, Default)]
pub struct FeatureCache {
    cache: BTreeMap<u64, FeatureVector>,
    pub hits: u64,
    pub misses: u64,
}

impl FeatureCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Called when an object is persisted (background, off critical path).
    pub fn persist(&mut self, input: &InputSpec) {
        if input.id != 0 {
            self.cache.insert(input.id, featurize(input).vector);
        }
    }

    /// Featurize on the invocation path. Returns the vector and the
    /// critical-path latency actually paid:
    /// * cache hit → 0
    /// * datastore object, storage-triggered (not yet persisted) → full
    ///   extraction latency
    /// * inline payload → payload conversion cost (~0)
    pub fn featurize_invocation(&mut self, input: &InputSpec) -> (FeatureVector, f64) {
        if input.id != 0 {
            if let Some(v) = self.cache.get(&input.id) {
                self.hits += 1;
                return (v.clone(), 0.0);
            }
        }
        self.misses += 1;
        let f = featurize(input);
        if input.id != 0 {
            self.cache.insert(input.id, f.vector.clone());
        }
        (f.vector, f.extract_latency_s)
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image_spec() -> InputSpec {
        let mut s = InputSpec::new(InputKind::Image);
        s.id = 42;
        s.size_bytes = 1024.0 * 1024.0;
        s.width = 1920.0;
        s.height = 1080.0;
        s
    }

    #[test]
    fn feature_vector_layout() {
        let v = FeatureVector::from_features(&[2.0, 3.0]);
        assert_eq!(v.0[0], 1.0, "bias");
        assert_eq!(v.0[1], 2.0);
        assert_eq!(v.0[2], 3.0);
        assert_eq!(v.0[3], 0.0, "padding");
        assert_eq!(v.0[FeatureVector::SLO_SLOT], 0.0);
    }

    #[test]
    fn slo_slot_set() {
        let v = FeatureVector::from_features(&[1.0]).with_slo(2.0);
        let expect = (2.0f64.ln() / extract::LOG_NORM) as f32;
        assert!((v.0[FeatureVector::SLO_SLOT] - expect).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "too many features")]
    fn overfull_features_panic() {
        FeatureVector::from_features(&[0.0; FEAT_DIM]);
    }

    #[test]
    fn featurize_all_kinds_produces_nonzero() {
        for kind in InputKind::all() {
            let mut s = InputSpec::new(*kind);
            s.size_bytes = 1e6;
            s.width = 640.0;
            s.height = 480.0;
            s.rows = 100.0;
            s.cols = 100.0;
            s.duration_s = 10.0;
            s.bitrate = 1e6;
            s.length = 500.0;
            let f = featurize(&s);
            let nonzero = f.vector.0.iter().filter(|x| **x != 0.0).count();
            assert!(nonzero >= 2, "{kind:?} produced a near-empty vector");
            assert!(f.extract_latency_s >= 0.0);
        }
    }

    #[test]
    fn cache_hit_is_free() {
        let mut cache = FeatureCache::new();
        let spec = image_spec();
        cache.persist(&spec);
        let (_, lat) = cache.featurize_invocation(&spec);
        assert_eq!(lat, 0.0);
        assert_eq!(cache.hits, 1);
    }

    #[test]
    fn storage_trigger_pays_extraction() {
        let mut cache = FeatureCache::new();
        let spec = image_spec();
        let (_, lat) = cache.featurize_invocation(&spec);
        assert!(lat >= 0.0);
        assert_eq!(cache.misses, 1);
        // second invocation on the same object hits
        let (_, lat2) = cache.featurize_invocation(&spec);
        assert_eq!(lat2, 0.0);
    }

    #[test]
    fn inline_payloads_not_cached() {
        let mut cache = FeatureCache::new();
        let mut s = InputSpec::new(InputKind::Payload);
        s.length = 100.0;
        s.id = 0;
        cache.featurize_invocation(&s);
        assert!(cache.is_empty());
    }
}
