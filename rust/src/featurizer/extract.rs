//! Per-input-type feature extraction (Table 2) + extraction-cost model
//! (§7.6, Figure 14).
//!
//! Feature values are log-scaled where they span orders of magnitude so
//! the linear CSMC regressors see well-conditioned inputs.
//!
//! Extraction-cost model, calibrated to Figure 14:
//! * `matrix`, `csv`, `json` — the featurizer must *open and scan* the
//!   file (row/col counts): 20–35 ms, growing mildly with size.
//! * `image`, `video`, `audio` — metadata read without decoding the
//!   payload (imagemagick/ffprobe header reads): ~0.1–2 ms.
//! * `payload` — the invocation payload *is* the feature: ~0 (linpack).
//! * `file` (opaque) — size comes from the object store listing: ~0.05 ms.

use super::InputSpec;

/// Log-scale + normalize to ~[0, 2]: raw `ln` values reach ~31 for
/// multi-GB sizes, which would make the CSOAA LMS step `lr * |x|^2`
/// unstable (needs < 2). Dividing by 16 keeps every feature O(1).
pub const LOG_NORM: f64 = 16.0;

#[inline]
fn log1p(x: f64) -> f32 {
    ((x.max(0.0)).ln_1p() / LOG_NORM) as f32
}

/// image: width, height, channels, x-dpi, y-dpi, filesize (Table 2).
pub fn image(s: &InputSpec) -> (Vec<f32>, f64) {
    let feats = vec![
        log1p(s.width),
        log1p(s.height),
        s.channels as f32,
        log1p(s.dpi),
        log1p(s.dpi),
        log1p(s.size_bytes),
        // raw-scale pixels: memory footprint is linear in the bitmap size,
        // which a log-only basis cannot express for a linear model
        (s.width * s.height / 2.0e6) as f32,
    ];
    // header metadata read; no decode
    (feats, 0.000_13)
}

/// matrix: rows, cols, density. Requires opening the file (§7.6).
pub fn matrix(s: &InputSpec) -> (Vec<f32>, f64) {
    let feats = vec![
        log1p(s.rows),
        log1p(s.cols),
        s.density as f32,
        log1p(s.size_bytes),
        // raw-scale elements: footprint is linear in rows*cols
        (s.rows * s.cols / 6.4e7) as f32,
    ];
    // 20–35 ms depending on size (file open + header scan)
    let latency = 0.020 + 0.015 * (s.size_mb() / 100.0).min(1.0);
    (feats, latency)
}

/// video: width, height, duration, bitrate, fps, encoding (Table 2).
pub fn video(s: &InputSpec) -> (Vec<f32>, f64) {
    let feats = vec![
        log1p(s.width),
        log1p(s.height),
        log1p(s.duration_s),
        log1p(s.bitrate),
        log1p(s.fps),
        s.encoding as f32,
        log1p(s.size_bytes),
        // raw-scale frame pixels (frame-buffer memory is linear in these)
        (s.width * s.height / 2.0e6) as f32,
    ];
    // ffprobe header read
    (feats, 0.000_8)
}

/// csv: rows, cols, filesize. Requires file scan.
pub fn csv(s: &InputSpec) -> (Vec<f32>, f64) {
    let feats = vec![
        log1p(s.rows),
        log1p(s.cols),
        log1p(s.size_bytes),
        (s.size_mb() / 200.0) as f32, // raw-scale size
    ];
    let latency = 0.018 + 0.017 * (s.size_mb() / 100.0).min(1.0);
    (feats, latency)
}

/// json: length of outermost object, filesize.
pub fn json_doc(s: &InputSpec) -> (Vec<f32>, f64) {
    let feats = vec![
        log1p(s.length),
        log1p(s.size_bytes),
        (s.size_mb() / 100.0) as f32, // raw-scale size
    ];
    let latency = 0.010 + 0.010 * (s.size_mb() / 50.0).min(1.0);
    (feats, latency)
}

/// audio: channels, sample rate, duration, bitrate, FLAC flag.
pub fn audio(s: &InputSpec) -> (Vec<f32>, f64) {
    let feats = vec![
        s.channels as f32,
        log1p(s.sample_rate),
        log1p(s.duration_s),
        log1p(s.bitrate),
        if s.flac { 1.0 } else { 0.0 },
        log1p(s.size_bytes),
        (s.duration_s / 900.0) as f32, // raw-scale duration
    ];
    (feats, 0.000_6)
}

/// payload: the invocation payload is the feature vector (linpack, qr,
/// encrypt, sentiment): logical length + raw size. Free.
pub fn payload(s: &InputSpec) -> (Vec<f32>, f64) {
    (
        vec![
            log1p(s.length),
            log1p(s.size_bytes),
            (s.length / 1.0e3) as f32, // raw-scale length (batch sizes etc.)
        ],
        0.0,
    )
}

/// opaque file: size only (compress).
pub fn file(s: &InputSpec) -> (Vec<f32>, f64) {
    (
        vec![log1p(s.size_bytes), (s.size_bytes / 2.0e9) as f32],
        0.000_05,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurizer::InputKind;

    #[test]
    fn matrix_slower_than_image() {
        let mut m = InputSpec::new(InputKind::Matrix);
        m.size_bytes = 50e6;
        m.rows = 4000.0;
        m.cols = 4000.0;
        let mut i = InputSpec::new(InputKind::Image);
        i.size_bytes = 1e6;
        i.width = 800.0;
        i.height = 600.0;
        let (_, lm) = matrix(&m);
        let (_, li) = image(&i);
        assert!(lm > 10.0 * li, "matrix {lm} vs image {li}");
        assert!((0.020..=0.035).contains(&lm), "fig14 range: {lm}");
    }

    #[test]
    fn payload_is_free() {
        let mut p = InputSpec::new(InputKind::Payload);
        p.length = 1000.0;
        let (_, lat) = payload(&p);
        assert_eq!(lat, 0.0);
    }

    #[test]
    fn log_scaling_monotone() {
        let mut a = InputSpec::new(InputKind::File);
        a.size_bytes = 64e6;
        let mut b = a.clone();
        b.size_bytes = 2e9;
        let (fa, _) = file(&a);
        let (fb, _) = file(&b);
        assert!(fb[0] > fa[0]);
    }

    #[test]
    fn video_encodes_resolution() {
        let mut v = InputSpec::new(InputKind::Video);
        v.width = 1280.0;
        v.height = 720.0;
        v.duration_s = 30.0;
        v.bitrate = 2e6;
        let (f, _) = video(&v);
        assert!(f[0] > 0.0 && f[1] > 0.0);
        let mut lo = v.clone();
        lo.width = 320.0;
        lo.height = 240.0;
        let (flo, _) = video(&lo);
        assert!(f[0] > flo[0] && f[1] > flo[1]);
    }

    #[test]
    fn audio_flac_flag() {
        let mut a = InputSpec::new(InputKind::Audio);
        a.flac = true;
        a.duration_s = 12.0;
        let (f, _) = audio(&a);
        assert_eq!(f[4], 1.0);
    }
}
