//! Tiny flag parser: `--key value`, `--key=value`, `--flag` booleans and
//! positional arguments. Sufficient for the `shabari` subcommands; no
//! third-party CLI crate is available in the offline build.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: positionals in order plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse a flat argv slice. `bool_flags` lists flags that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    out.options.insert(stripped.to_string(), "true".to_string());
                } else {
                    let Some(v) = argv.get(i + 1) else {
                        bail!("flag --{stripped} expects a value");
                    };
                    out.options.insert(stripped.to_string(), v.clone());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value() {
        let a = Args::parse(&sv(&["--rps", "4", "fig8"]), &[]).unwrap();
        assert_eq!(a.get("rps"), Some("4"));
        assert_eq!(a.positional, vec!["fig8"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(&sv(&["--seed=7"]), &[]).unwrap();
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn bool_flags_take_no_value() {
        let a = Args::parse(&sv(&["--native", "fig8"]), &["native"]).unwrap();
        assert!(a.get_bool("native"));
        assert_eq!(a.positional, vec!["fig8"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--rps"]), &[]).is_err());
    }

    #[test]
    fn typed_getters_validate() {
        let a = Args::parse(&sv(&["--rps", "abc"]), &[]).unwrap();
        assert!(a.get_usize("rps", 2).is_err());
        assert_eq!(a.get_usize("other", 2).unwrap(), 2);
    }
}
