//! `shabari report` — human-readable digest of a JSONL lifecycle trace
//! (DESIGN.md §Observability): the per-invocation latency breakdown
//! (decision / queue / cold-start / exec percentiles over the whole run)
//! and the cluster utilization timeline (busy vs allocated-idle vCPUs,
//! queue depth, warm pool per sampling interval).

use anyhow::{anyhow, Context, Result};

use crate::metrics::spans::{breakdown, LatencyBreakdown};
use crate::simulator::trace::TraceLog;
use crate::util::table::{fnum, Table};

use super::args::Args;

/// Cap on printed timeline rows: long runs are strided down (first
/// sample of each stride), never truncated at the front or back.
const MAX_TIMELINE_ROWS: usize = 48;

pub fn cmd_report(a: &Args) -> Result<()> {
    let path = a
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: shabari report <trace.jsonl>"))?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let log = TraceLog::from_jsonl(&text).with_context(|| format!("parsing trace {path}"))?;
    print!("{}", render_report(&log));
    Ok(())
}

/// The full report as a string (testable without capturing stdout).
pub fn render_report(log: &TraceLog) -> String {
    let mut out = String::new();
    out.push_str(&render_header(log));
    let spans = log.spans();
    let b = breakdown(&spans);
    out.push_str(&render_breakdown(&b));
    out.push_str(&render_timeline(log));
    out
}

fn render_header(log: &TraceLog) -> String {
    let mut s = String::from("trace:");
    for (k, v) in &log.meta {
        s.push_str(&format!(" {k}={v}"));
    }
    s.push_str(&format!(
        "\n       {} events, {} timeline samples @ {}s interval\n",
        log.events.len(),
        log.samples.len(),
        log.cfg.sample_interval_s
    ));
    s
}

fn render_breakdown(b: &LatencyBreakdown) -> String {
    let mut t = Table::new(
        &format!("latency breakdown — {} invocations (seconds)", b.invocations),
        &["component", "count", "mean", "p50", "p90", "p99", "max"],
    );
    for (label, h) in b.components() {
        t.row(vec![
            label.to_string(),
            h.count().to_string(),
            fnum(h.mean(), 3),
            fnum(h.percentile(50.0), 3),
            fnum(h.percentile(90.0), 3),
            fnum(h.percentile(99.0), 3),
            fnum(h.max(), 3),
        ]);
    }
    t.note(
        "percentiles are log2-bucket upper bounds (within 2x); \
         decision+queue+cold-start+exec telescopes to e2e per invocation",
    );
    let mut s = t.render();
    let verdicts: Vec<String> =
        b.verdicts.iter().map(|(k, v)| format!("{k} {v}")).collect();
    s.push_str(&format!(
        "verdicts: {}  (max component-sum error {:.1e}s)\n",
        verdicts.join(", "),
        b.max_sum_error_s
    ));
    s
}

fn render_timeline(log: &TraceLog) -> String {
    if log.samples.is_empty() {
        return String::from("(no timeline samples — run longer than the sample interval)\n");
    }
    let stride = log.samples.len().div_ceil(MAX_TIMELINE_ROWS);
    let mut t = Table::new(
        &format!(
            "cluster timeline — {} workers, every {}s{}",
            log.worker_count(),
            log.cfg.sample_interval_s,
            if stride > 1 { format!(" (showing every {stride}th sample)") } else { String::new() }
        ),
        &["t (s)", "busy vCPU", "alloc vCPU", "limit", "util", "idle", "queue", "warm", "down"],
    );
    for sample in log.samples.iter().step_by(stride) {
        let busy: f64 = sample.workers.iter().map(|w| w.busy_vcpus).sum();
        let alloc: f64 = sample.workers.iter().map(|w| w.allocated_vcpus).sum();
        let limit: f64 = sample.workers.iter().map(|w| w.vcpu_limit).sum();
        let queue: usize = sample.workers.iter().map(|w| w.queue_depth).sum();
        let warm: usize = sample.workers.iter().map(|w| w.warm_pool).sum();
        let down = sample.workers.iter().filter(|w| w.down).count();
        let util = if limit > 0.0 { 100.0 * busy / limit } else { 0.0 };
        // idle fraction = capacity neither running an invocation nor
        // held by a reservation, the "where every vCPU goes" column
        let idle = if limit > 0.0 { 100.0 * (limit - alloc).max(0.0) / limit } else { 0.0 };
        t.row(vec![
            fnum(sample.at, 0),
            fnum(busy, 1),
            fnum(alloc, 1),
            fnum(limit, 0),
            format!("{util:.0}%"),
            format!("{idle:.0}%"),
            queue.to_string(),
            warm.to_string(),
            down.to_string(),
        ]);
    }
    t.note(
        "util = busy/limit; idle = unreserved capacity; alloc-busy is \
         reserved-but-idle (cold starts in flight + warm slack)",
    );
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::{self, Ctx, TraceOut};

    fn traced_log() -> TraceLog {
        // run a real small simulation with tracing on and report on it
        let ctx = Ctx {
            duration_s: 60.0,
            trace: Some(TraceOut { interval_s: 10.0, ..Default::default() }),
            ..Default::default()
        };
        let workload = ctx.workload();
        let cfg = common::sim_config(&ctx);
        let (res, _) =
            common::run_one("static-medium", &ctx, &workload, 2.0, &cfg).unwrap();
        res.trace.expect("tracing was enabled")
    }

    #[test]
    fn report_renders_breakdown_and_timeline() {
        let log = traced_log();
        let s = render_report(&log);
        assert!(s.contains("latency breakdown"), "{s}");
        assert!(s.contains("cold-start"), "{s}");
        assert!(s.contains("e2e"), "{s}");
        assert!(s.contains("cluster timeline"), "{s}");
        assert!(s.contains("verdicts: "), "{s}");
        // 60 s at a 10 s interval: several timeline rows made it in
        assert!(log.samples.len() >= 5, "{} samples", log.samples.len());
    }

    #[test]
    fn report_round_trips_through_jsonl() {
        let log = traced_log();
        let reparsed = TraceLog::from_jsonl(&log.to_jsonl()).unwrap();
        assert_eq!(render_report(&log), render_report(&reparsed));
    }

    #[test]
    fn empty_trace_reports_gracefully() {
        let log = TraceLog::new(Default::default(), Default::default());
        let s = render_report(&log);
        assert!(s.contains("0 invocations"), "{s}");
        assert!(s.contains("no timeline samples"), "{s}");
    }
}
