//! Hand-rolled CLI (no `clap` available offline).
//!
//! Subcommands:
//!   run         — run one policy over a trace, print metrics
//!   experiment  — regenerate a paper figure/table (fig1..fig14, table1-3)
//!   report      — latency breakdown + utilization timeline of a trace
//!   profile     — isolated profiling of one function (SLO derivation)
//!   selfcheck   — artifacts load + XLA/native learner parity
//!   lint        — two-pass determinism linter (rules D001–D010, CI gate)
//!   list        — known policies and experiments

pub mod args;
pub mod report;

use anyhow::{bail, ensure, Result};

use crate::experiments::common::TraceOut;
use crate::experiments::sweep;
use crate::experiments::{self, Ctx};
use crate::learner::xla::Backend;

/// Entrypoint called by `main.rs`. Returns the process exit code.
pub fn main() -> i32 {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

const BOOL_FLAGS: &[&str] = &["xla", "native", "verbose", "json", "list-rules"];

fn ctx_from(a: &args::Args) -> Result<Ctx> {
    let backend = if a.get_bool("xla") { Backend::Xla } else { Backend::Native };
    let seeds = a.get_usize("seeds", 5)?.max(1);
    let jobs = match a.get_usize("jobs", 0)? {
        0 => sweep::default_jobs(), // 0 = auto: all available cores
        n => n,
    };
    let scenario = a.get_or("scenario", "azure-synthetic");
    // fail fast on typos (trace-file paths are checked here too)
    crate::workload::scenario::by_name(&scenario)?;
    // same fail-fast contract for the keep-alive policy
    let keepalive = crate::simulator::keepalive::parse(&a.get_or("keepalive", "fixed"))?;
    // ... and for the fault profile (default: an immortal, uniform cluster)
    let faults = crate::simulator::faults::parse(&a.get_or("faults", "none"))?;
    // ... and for the cluster scaler (default: a fixed-size pool whose
    // streams are byte-identical to every pre-scaler run)
    let scaler = crate::simulator::scaler::parse(&a.get_or("scaler", "none"))?;
    // lifecycle tracing (DESIGN.md §Observability): either exporter flag
    // switches the engine's trace sink on; absent both, tracing stays
    // dormant and every stream is byte-identical to an untraced run
    let trace_jsonl = a.get("trace").map(str::to_string);
    let trace_chrome = a.get("trace-chrome").map(str::to_string);
    let trace = if trace_jsonl.is_some() || trace_chrome.is_some() {
        let interval_s = a.get_f64("trace-interval", 10.0)?;
        ensure!(
            interval_s > 0.0,
            "--trace-interval expects a positive number of seconds, got {interval_s}"
        );
        Some(TraceOut { jsonl: trace_jsonl, chrome: trace_chrome, interval_s, exact: false })
    } else {
        None
    };
    Ok(Ctx {
        seed: a.get_u64("seed", 42)?,
        backend,
        duration_s: a.get_f64("duration", 600.0)?,
        slo_multiplier: a.get_f64("slo-multiplier", 1.4)?,
        artifacts_dir: a.get_or("artifacts", "artifacts"),
        seeds,
        jobs,
        scenario,
        scale_workers: a.get_usize("scale-workers", 64)?.max(1),
        scale_rps: a.get_f64("scale-rps", 24.0)?,
        overload_workers: a.get_usize("overload-workers", 4)?.max(1),
        keepalive,
        keepalive_workers: a.get_usize("keepalive-workers", 4)?.max(1),
        faults,
        adversity_workers: a.get_usize("adversity-workers", 4)?.max(1),
        scaler,
        trace,
    })
}

fn run(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let rest = if argv.is_empty() { &[] } else { &argv[1..] };
    let a = args::Args::parse(rest, BOOL_FLAGS)?;
    if a.get_bool("verbose") {
        crate::util::log::set_level(crate::util::log::Level::Debug);
    }
    // --log-level names the level exactly and wins over --verbose
    if let Some(name) = a.get("log-level") {
        match crate::util::log::parse_level(name) {
            Some(l) => crate::util::log::set_level(l),
            None => bail!("--log-level expects error|warn|info|debug|trace, got '{name}'"),
        }
    }
    match cmd {
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        "list" => {
            println!("policies:    {}", experiments::common::POLICIES.join(", "));
            println!("experiments: {} (or 'all')", experiments::EXPERIMENTS.join(", "));
            println!(
                "scenarios:   {} (or trace-file:<path>)",
                crate::workload::scenario::SCENARIOS.join(", ")
            );
            println!(
                "keep-alive:  {} (each optionally ':<secs>')",
                crate::simulator::keepalive::KEEPALIVES.join(", ")
            );
            println!(
                "faults:      {} (crash/chaos take ':<downtime_s>', \
                 stragglers ':<factor>')",
                crate::simulator::faults::FAULTS.join(", ")
            );
            println!(
                "scalers:     {} (fifer takes ':<headroom>' in (0,1])",
                crate::simulator::scaler::SCALERS.join(", ")
            );
            Ok(())
        }
        "run" => cmd_run(&a),
        "report" => report::cmd_report(&a),
        "experiment" => {
            let ctx = ctx_from(&a)?;
            let id = a
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("usage: shabari experiment <id> [flags]"))?;
            experiments::run(id, &ctx)
        }
        "profile" => cmd_profile(&a),
        "selfcheck" => cmd_selfcheck(&a),
        "lint" => cmd_lint(&a),
        other => bail!("unknown subcommand '{other}' (see `shabari help`)"),
    }
}

fn cmd_run(a: &args::Args) -> Result<()> {
    let mut ctx = ctx_from(a)?;
    if let Some(t) = ctx.trace.as_mut() {
        // a single run is one cell: write to the requested paths verbatim
        // (grids keep exact=false and get per-cell suffixed names)
        t.exact = true;
    }
    let policy = a.get_or("policy", "shabari");
    let rps = a.get_f64("rps", 4.0)?;
    // lint:allow(D002): host-side sweep timing for the operator report only
    let t0 = std::time::Instant::now();
    // One sweep cell replicated across --seeds, executed on --jobs threads.
    let cells = [sweep::Cell::new(&policy, rps)];
    let outcomes = sweep::run_cells(&cells, ctx.seed, ctx.seeds, ctx.jobs, |cell, seed| {
        experiments::common::run_cell(&cell.policy, &ctx, cell.rps, seed)
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let out = &outcomes[0];
    let m = out.mean_metrics();
    let viol = out.stat(|m| m.slo_violation_pct);
    let mut t = crate::util::table::Table::new(
        &format!(
            "run: {policy} @ {rps} rps, {}s {} trace, keepalive {}, {} seed(s) x {} job(s)",
            ctx.duration_s,
            ctx.scenario,
            ctx.keepalive.label(),
            ctx.seeds,
            ctx.jobs
        ),
        &["metric", "value (cross-seed mean)"],
    );
    t.row(vec!["invocations".into(), m.invocations.to_string()]);
    t.row(vec!["SLO violations".into(), format!("{:.1}%", m.slo_violation_pct)]);
    t.row(vec![
        "SLO violations p50/p99 over seeds".into(),
        format!("{:.1}% / {:.1}%", viol.p50, viol.p99),
    ]);
    t.row(vec![
        "SLO violations 95% CI".into(),
        format!("[{:.1}%, {:.1}%]", viol.ci95.0, viol.ci95.1),
    ]);
    t.row(vec!["wasted vCPUs p50/p95".into(), format!("{:.1} / {:.1}", m.wasted_vcpus.p50, m.wasted_vcpus.p95)]);
    t.row(vec!["wasted mem GB p50/p95".into(), format!("{:.2} / {:.2}", m.wasted_mem_gb.p50, m.wasted_mem_gb.p95)]);
    t.row(vec!["vCPU util p50".into(), format!("{:.0}%", 100.0 * m.vcpu_utilization.p50)]);
    t.row(vec!["mem util p50".into(), format!("{:.0}%", 100.0 * m.mem_utilization.p50)]);
    t.row(vec!["cold starts".into(), format!("{:.1}%", m.cold_start_pct)]);
    t.row(vec![
        "admission queued / wait p99".into(),
        format!("{:.1}% / {:.2}s", m.queued_pct, m.queue_wait.p99),
    ]);
    t.row(vec!["OOM / timeout".into(), format!("{:.1}% / {:.1}%", m.oom_pct, m.timeout_pct)]);
    if m.worker_crashes > 0 || m.failed_pct > 0.0 {
        t.row(vec![
            "failed / crashes / requeued".into(),
            format!("{:.1}% / {} / {}", m.failed_pct, m.worker_crashes, m.requeued_on_crash),
        ]);
    }
    t.row(vec!["mean e2e latency".into(), format!("{:.2}s", m.mean_e2e_s)]);
    t.row(vec!["throughput".into(), format!("{:.2}/s", m.throughput)]);
    t.row(vec!["containers created".into(), m.containers_created.to_string()]);
    t.row(vec!["background launches".into(), m.background_launches.to_string()]);
    t.row(vec![
        "evictions (ttl / pressure)".into(),
        format!("{} / {}", m.evictions, m.pressure_evictions),
    ]);
    t.row(vec![
        "idle container-s / prewarm hits".into(),
        format!("{:.0} / {}", m.idle_container_s, m.prewarm_hits),
    ]);
    t.row(vec![
        "sweep wall time".into(),
        format!(
            "{wall:.2}s ({:.0} inv/s)",
            (m.invocations * ctx.seeds) as f64 / wall.max(1e-9)
        ),
    ]);
    t.print();
    if let Some(tr) = &ctx.trace {
        if let Some(p) = &tr.jsonl {
            println!("(wrote lifecycle trace {p}; inspect with `shabari report {p}`)");
        }
        if let Some(p) = &tr.chrome {
            println!("(wrote Chrome trace {p}; load in Perfetto or chrome://tracing)");
        }
    }
    Ok(())
}

/// `shabari lint [--root <dir>] [--json] [--only D006,D007]
/// [--list-rules]`: the two-pass determinism linter (DESIGN.md §Static
/// analysis). Exit code is the CI gate: non-zero on any violation a
/// `lint:allow(DXXX): <reason>` escape does not cover.
fn cmd_lint(a: &args::Args) -> Result<()> {
    if a.get_bool("list-rules") {
        print!("{}", crate::analysis::report::render_rule_list());
        return Ok(());
    }
    let only = match a.get("only") {
        Some(list) => {
            let known: std::collections::BTreeSet<String> = crate::analysis::rules::rule_metas()
                .iter()
                .map(|m| m.id.to_string())
                .collect();
            let mut set = std::collections::BTreeSet::new();
            for id in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                ensure!(
                    known.contains(id),
                    "--only: unknown rule '{id}' (see `shabari lint --list-rules`)"
                );
                set.insert(id.to_string());
            }
            ensure!(!set.is_empty(), "--only expects a comma list of rule ids");
            Some(set)
        }
        None => None,
    };
    let root = a.get_or("root", ".");
    let out = crate::analysis::lint_tree_only(std::path::Path::new(&root), only.as_ref())?;
    if a.get_bool("json") {
        println!("{}", crate::analysis::report::to_json(&out).to_pretty());
    } else {
        print!("{}", crate::analysis::report::render(&out));
    }
    if !out.is_clean() {
        bail!("{} determinism violation(s), see report above", out.violations.len());
    }
    Ok(())
}

fn cmd_profile(a: &args::Args) -> Result<()> {
    let ctx = ctx_from(a)?;
    let fname = a.get_or("function", "compress");
    let fi = crate::functions::catalog::index_of(&fname)
        .ok_or_else(|| anyhow::anyhow!("unknown function '{fname}'"))?;
    let spec = &crate::functions::catalog::CATALOG[fi];
    let mut rng = crate::util::rng::Rng::new(ctx.seed);
    let pool = crate::functions::inputs::pool(spec, &mut rng);
    let mut t = crate::util::table::Table::new(
        &format!("profile: {fname} (isolated, median of 5)"),
        &["size (MB)", "t@1", "t@4", "t@16", "t@32", "mem (GB)", "SLO@1.4x"],
    );
    for input in &pool {
        let mut row = vec![crate::util::table::fnum(input.size_mb(), 2)];
        for k in [1u32, 4, 16, 32] {
            let t = crate::baselines::profiling::isolated_exec_s(fi, input, k, 5, &mut rng);
            row.push(format!("{t:.2}"));
        }
        let d = (spec.demand)(input);
        row.push(format!("{:.2}", d.mem_gb));
        let slo = crate::workload::slo::derive_slo(spec, input, ctx.slo_multiplier, &mut rng);
        row.push(format!("{slo:.2}"));
        t.row(row);
    }
    t.print();
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_selfcheck(_a: &args::Args) -> Result<()> {
    bail!(
        "selfcheck exercises the XLA/PJRT learner; rebuild with \
         `cargo run --features xla -- selfcheck` (and run `make artifacts`)"
    )
}

#[cfg(feature = "xla")]
fn cmd_selfcheck(a: &args::Args) -> Result<()> {
    let ctx = ctx_from(a)?;
    println!("checking artifacts in '{}' ...", ctx.artifacts_dir);
    let engine = crate::runtime::XlaEngine::load_dir(&ctx.artifacts_dir)?;
    println!("  platform: {}", engine.platform());
    for name in crate::runtime::ARTIFACTS {
        anyhow::ensure!(engine.has(name), "missing executable {name}");
        println!("  loaded {name}");
    }
    // XLA vs native parity on a quick update sequence
    use crate::learner::{cost_vector, CsmcModel};
    let engine = std::rc::Rc::new(engine);
    let mut xla = crate::learner::xla::XlaCsmc::new(engine, 0.3);
    let mut native = crate::learner::native::NativeCsmc::new(0.3);
    let mut rng = crate::util::rng::Rng::new(ctx.seed);
    for _ in 0..30 {
        let mut x = [0f32; crate::runtime::FEAT_DIM];
        for v in x.iter_mut() {
            *v = rng.range_f64(-1.0, 1.0) as f32;
        }
        x[0] = 1.0;
        let costs = cost_vector(rng.below(crate::runtime::NUM_CLASSES), 2.0);
        xla.update(&x, &costs);
        native.update(&x, &costs);
        anyhow::ensure!(
            xla.predict(&x) == native.predict(&x),
            "XLA/native prediction mismatch"
        );
    }
    println!("  XLA/native parity: OK (30 update steps)");
    println!("selfcheck OK");
    Ok(())
}

fn print_help() {
    println!(
        "shabari — delayed, input-aware serverless resource management\n\
         (reproduction of Sinha et al., 2024; rust + JAX + Pallas via XLA/PJRT)\n\
         \n\
         USAGE: shabari <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS:\n\
           run          run a policy over a trace\n\
                          --policy <name>   (default shabari; see `list`)\n\
                          --rps <f>         (default 4)\n\
           experiment   regenerate a paper figure/table\n\
                          <id>              fig1..fig14, table1-3, scenarios,\n\
                                            scale, overload, keepalive,\n\
                                            adversity, replay, or 'all'\n\
                          --scale-workers <n>  scale-grid cluster size (default 64)\n\
                          --scale-rps <f>      scale-grid request rate (default 24)\n\
                          --overload-workers <n>  overload-sweep cluster size\n\
                                            (default 4; the rps axis crosses\n\
                                            saturation and proves the admission\n\
                                            invariant, dumping out/overload.json)\n\
                          --keepalive-workers <n>  keepalive-matrix cluster size\n\
                                            (default 4; policy x keep-alive x\n\
                                            scenario grid, dumps out/keepalive.json)\n\
                          --adversity-workers <n>  adversity-matrix cluster size\n\
                                            (default 4; policy x keep-alive x\n\
                                            fault-profile grid with per-replicate\n\
                                            invariant checks, dumps\n\
                                            out/adversity.json)\n\
                                            ('replay' takes no size flag: the\n\
                                            policy x scaler grid replays the\n\
                                            --scenario trace, or the embedded\n\
                                            sample, dumping out/replay.json)\n\
           report       digest a JSONL lifecycle trace: latency breakdown\n\
                        (decision/queue/cold-start/exec percentiles) +\n\
                        cluster utilization timeline\n\
                          <path>            trace written by --trace\n\
           profile      isolated profiling runs (SLO derivation)\n\
                          --function <name>\n\
           selfcheck    verify artifacts + XLA/native learner parity\n\
           lint         two-pass determinism linter: token rules D001..D005\n\
                        + cross-file rules D006..D010 over\n\
                        rust/{{src,tests,benches}} and examples/ (non-zero\n\
                        exit on any violation without a\n\
                        `lint:allow(DXXX): <reason>`)\n\
                          --root <dir>      repo or crate root (default .)\n\
                          --json            machine-readable report\n\
                          --only <ids>      comma list of rules to run\n\
                          --list-rules      print the rule registry\n\
           list         known policies and experiment ids\n\
           help         this message\n\
         \n\
         COMMON FLAGS:\n\
           --seed <u64>            deterministic base seed (default 42)\n\
           --seeds <n>             replicates per sweep cell; each replicate\n\
                                   re-seeds workload + policy + cluster as\n\
                                   base ^ hash(cell, replicate) (default 5)\n\
           --jobs <n>              sweep worker threads (default 0 = all cores)\n\
           --duration <s>          trace length (default 600)\n\
           --scenario <name>       workload shape: azure-synthetic (default),\n\
                                   diurnal, flash-crowd, zipf-skew, trace-file,\n\
                                   or trace-file:<csv-path> (Azure trace schema)\n\
           --keepalive <name>      warm-container keep-alive policy: fixed\n\
                                   (default; legacy 600 s TTL), fixed:<secs>,\n\
                                   histogram (per-function idle histograms +\n\
                                   pre-warm), or pressure (idle containers\n\
                                   yield to queued demand, LRU eviction);\n\
                                   each accepts ':<secs>' as TTL override\n\
           --faults <name>         fault profile: none (default), crash or\n\
                                   crash:<downtime_s> (seed-derived worker\n\
                                   crash/restart cycles), stragglers or\n\
                                   stragglers:<factor> (slow workers),\n\
                                   hetero (mixed worker classes), chaos or\n\
                                   chaos:<downtime_s> (all three at once)\n\
           --scaler <name>         cluster scaler: none (default; fixed pool,\n\
                                   byte-identical to pre-scaler streams) or\n\
                                   fifer / fifer:<headroom> (reactive whole-\n\
                                   worker scaling on queue depth + utilization,\n\
                                   headroom in (0,1], default 0.7)\n\
           --trace <path>          record every lifecycle event + utilization\n\
                                   sample to a JSONL trace (off = byte-identical\n\
                                   to an untraced run; sweeps trace replicate 0\n\
                                   of each cell into per-cell suffixed files)\n\
           --trace-chrome <path>   also export Chrome trace-event JSON\n\
                                   (Perfetto / chrome://tracing; workers are\n\
                                   tracks, invocations are spans)\n\
           --trace-interval <s>    utilization sampling interval (default 10)\n\
           --log-level <name>      stderr log level: error|warn|info|debug|trace\n\
                                   (wins over --verbose and SHABARI_LOG)\n\
           --slo-multiplier <f>    SLO = f x median isolated time (default 1.4)\n\
           --xla                   use the AOT XLA learner (production path;\n\
                                   needs a `--features xla` build)\n\
           --artifacts <dir>       artifact directory (default artifacts/)"
    );
}
