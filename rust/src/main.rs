//! `shabari` CLI — leader entrypoint.
//!
//! Subcommands (see `shabari help`):
//!   run         — run a trace through a chosen allocator + scheduler
//!   experiment  — regenerate a paper figure/table (fig1..fig14, table3)
//!   profile     — isolated profiling runs used to derive SLOs
//!   selfcheck   — verify artifacts load and the XLA learner matches native

fn main() {
    let code = shabari::cli::main();
    std::process::exit(code);
}
