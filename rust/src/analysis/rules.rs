//! The determinism rules. D001–D005 are token-stream pattern matchers
//! behind the [`Rule`] trait (pass two, per file); D006–D010 are
//! cross-file contract checks behind the [`CrateRule`] trait, querying
//! the [`CrateIndex`] built in pass one. Path scoping decides where a
//! rule applies, and `#[cfg(test)]` regions are exempt from the
//! runtime-only rules (tests may freely compare floats or unwrap pops —
//! they *check* determinism rather than produce it).
//!
//! The rules deliberately work without type information: they encode the
//! repo's naming conventions (`Rng::new`, `SALT_*`, `pop_admission`,
//! `TraceEventKind`) rather than resolved semantics, trading
//! false-negative room for a dependency-free pass that runs in
//! milliseconds. Divergences from a type-aware linter are documented per
//! rule in DESIGN.md §Static analysis.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{Token, TokenKind};
use super::parse::ItemKind;
use super::symbols::{enum_mentions, CrateIndex, DirectiveVerb, FileIndex};
use super::RelatedSite;

/// A rule hit before `lint:allow` filtering.
#[derive(Debug, Clone)]
pub struct RawViolation {
    pub rule: &'static str,
    pub path: String,
    pub line: u32,
    pub message: String,
    /// Second location for cross-file diagnostics (the conflicting
    /// definition, the aggregation fn, the sanctioned funnel).
    pub related: Option<RelatedSite>,
}

impl RawViolation {
    fn at(rule: &'static str, path: &str, line: u32, message: String) -> RawViolation {
        RawViolation { rule, path: path.to_string(), line, message, related: None }
    }

    fn with_related(mut self, path: &str, line: u32, note: &str) -> RawViolation {
        self.related = Some(RelatedSite { path: path.to_string(), line, note: note.to_string() });
        self
    }
}

/// Which analyzer pass a rule runs in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    /// Per-file token-stream scan.
    Token,
    /// Whole-crate symbol-index query.
    Crate,
}

impl Pass {
    pub fn label(self) -> &'static str {
        match self {
            Pass::Token => "token",
            Pass::Crate => "crate",
        }
    }
}

/// Registry metadata: id, contract, file scope, pass. `--list-rules`,
/// the JSON schema, and the docs all render from this table.
#[derive(Debug, Clone, Copy)]
pub struct RuleMeta {
    pub id: &'static str,
    pub summary: &'static str,
    pub scope: &'static str,
    pub pass: Pass,
}

/// Every rule, in id order.
pub fn rule_metas() -> Vec<RuleMeta> {
    vec![
        RuleMeta {
            id: "D001",
            summary: "no HashMap/HashSet in simulator/coordinator/learner/metrics paths",
            scope: "src/{simulator,coordinator,learner,metrics}/ (non-test)",
            pass: Pass::Token,
        },
        RuleMeta {
            id: "D002",
            summary: "no Instant::now/SystemTime::now outside util::bench and benches/",
            scope: "everywhere but util/bench.rs and benches/ (non-test)",
            pass: Pass::Token,
        },
        RuleMeta {
            id: "D003",
            summary: "RNG forks go through util::rng with named SALT_* constants",
            scope: "everywhere, tests included",
            pass: Pass::Token,
        },
        RuleMeta {
            id: "D004",
            summary: "float ordering via total_cmp; no partial_cmp, no exact f64 ==",
            scope: "partial_cmp everywhere; float == in determinism dirs (non-test)",
            pass: Pass::Token,
        },
        RuleMeta {
            id: "D005",
            summary: "no unwrap/expect on event-heap or admission-queue pops in simulator/",
            scope: "src/simulator/ (non-test)",
            pass: Pass::Token,
        },
        RuleMeta {
            id: "D006",
            summary: "every SALT_* const unique by name and value; every Rng fork salt resolves",
            scope: "crate-wide (src, tests, benches, examples)",
            pass: Pass::Crate,
        },
        RuleMeta {
            id: "D007",
            summary: "every numeric RunMetrics field aggregated in mean_of or lint:reducer-annotated",
            scope: "src/metrics/mod.rs (RunMetrics vs mean_of)",
            pass: Pass::Crate,
        },
        RuleMeta {
            id: "D008",
            summary: "every TraceEventKind variant constructed in simulator/ and handled in spans/exporters",
            scope: "src/simulator/ (trace.rs taxonomy vs engine + exporters)",
            pass: Pass::Crate,
        },
        RuleMeta {
            id: "D009",
            summary: "EventKind::Evict is only constructed inside schedule_idle_evict",
            scope: "src/simulator/ (non-test)",
            pass: Pass::Crate,
        },
        RuleMeta {
            id: "D010",
            summary: "no Rng clones; no two Rng::new forks sharing one salt symbol",
            scope: "crate-wide, tests included",
            pass: Pass::Crate,
        },
    ]
}

/// One token-pass rule: an id (`D00x`), a path scope, and a token-stream
/// check. Summaries live in [`rule_metas`].
pub trait Rule {
    fn id(&self) -> &'static str;
    /// Whether the rule scans `path` at all (normalized, `/`-separated).
    fn applies(&self, path: &str) -> bool;
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>);
}

/// One crate-pass rule: sees the whole [`CrateIndex`] at once and can
/// cite two locations per violation.
pub trait CrateRule {
    fn id(&self) -> &'static str;
    fn check(&self, idx: &CrateIndex, out: &mut Vec<RawViolation>);
}

/// Paths whose iteration/compare order feeds event order or SGD order.
const DETERMINISM_DIRS: &[&str] =
    &["src/simulator/", "src/coordinator/", "src/learner/", "src/metrics/"];

fn in_determinism_dirs(path: &str) -> bool {
    DETERMINISM_DIRS.iter().any(|d| path.contains(d))
}

/// `util::bench` and the bench harness are the sanctioned wall-clock
/// consumers (they measure the host, not the simulation).
fn is_bench_path(path: &str) -> bool {
    path.contains("/benches/") || path.starts_with("benches/") || path.ends_with("util/bench.rs")
}

fn is_text(toks: &[Token], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.text == text)
}

/// D001: hash-ordered collections in determinism-scoped paths.
#[derive(Debug)]
pub struct HashOrder;

impl Rule for HashOrder {
    fn id(&self) -> &'static str {
        "D001"
    }
    fn applies(&self, path: &str) -> bool {
        in_determinism_dirs(path)
    }
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for t in toks {
            if t.in_test || t.kind != TokenKind::Ident {
                continue;
            }
            if t.text == "HashMap" || t.text == "HashSet" {
                out.push(RawViolation::at(
                    self.id(),
                    path,
                    t.line,
                    format!(
                        "{} in a determinism-scoped path: iteration order is \
                         hash-seeded; use BTreeMap/BTreeSet or sort before iterating",
                        t.text
                    ),
                ));
            }
        }
    }
}

/// D002: wall-clock reads outside `util::bench`/benches.
#[derive(Debug)]
pub struct WallClock;

impl Rule for WallClock {
    fn id(&self) -> &'static str {
        "D002"
    }
    fn applies(&self, path: &str) -> bool {
        !is_bench_path(path)
    }
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokenKind::Ident {
                continue;
            }
            if (t.text == "Instant" || t.text == "SystemTime")
                && is_text(toks, i + 1, "::")
                && is_text(toks, i + 2, "now")
            {
                out.push(RawViolation::at(
                    self.id(),
                    path,
                    t.line,
                    format!(
                        "wall-clock read ({}::now) outside util::bench/benches: \
                         simulated time must come from the event clock",
                        t.text
                    ),
                ));
            }
        }
    }
}

/// D003: process-varying randomness and inline (unnamed) RNG salts.
#[derive(Debug)]
pub struct UnsaltedRng;

/// Identifiers that smuggle per-process entropy into a run.
const RANDOM_SOURCES: &[&str] = &["DefaultHasher", "RandomState", "thread_rng", "from_entropy"];

impl Rule for UnsaltedRng {
    fn id(&self) -> &'static str {
        "D003"
    }
    fn applies(&self, _path: &str) -> bool {
        true
    }
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokenKind::Ident {
                continue;
            }
            if RANDOM_SOURCES.contains(&t.text.as_str()) {
                out.push(RawViolation::at(
                    self.id(),
                    path,
                    t.line,
                    format!(
                        "{} is process-varying randomness; all RNG must flow \
                         from util::rng with an explicit seed",
                        t.text
                    ),
                ));
            }
            // `Rng::new( ... <int literal> ^ ... )`: inline salts defeat
            // grep-ability; the convention is `seed ^ SALT_X` with the
            // constant named at module scope (PR 6).
            if t.text == "Rng"
                && is_text(toks, i + 1, "::")
                && is_text(toks, i + 2, "new")
                && is_text(toks, i + 3, "(")
            {
                let mut j = i + 4;
                let mut pdepth = 1i32;
                while j < toks.len() && pdepth > 0 {
                    match toks[j].text.as_str() {
                        "(" => pdepth += 1,
                        ")" => pdepth -= 1,
                        "^" => {
                            let prev_lit = toks[j - 1].kind == TokenKind::Int;
                            let next_lit =
                                toks.get(j + 1).is_some_and(|t| t.kind == TokenKind::Int);
                            if prev_lit || next_lit {
                                out.push(RawViolation::at(
                                    self.id(),
                                    path,
                                    toks[j].line,
                                    "inline RNG salt: hoist the literal to a named \
                                     SALT_* constant (seed ^ SALT_X convention)"
                                        .to_string(),
                                ));
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            }
        }
    }
}

/// D004: float ordering/equality must be total.
#[derive(Debug)]
pub struct FloatOrder;

impl Rule for FloatOrder {
    fn id(&self) -> &'static str {
        "D004"
    }
    fn applies(&self, _path: &str) -> bool {
        true
    }
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        let det = in_determinism_dirs(path);
        for (i, t) in toks.iter().enumerate() {
            // partial_cmp is flagged everywhere, tests included: a test
            // that sorts through a partial order can mask the exact
            // nondeterminism the battery exists to catch.
            if t.kind == TokenKind::Ident && t.text == "partial_cmp" {
                out.push(RawViolation::at(
                    self.id(),
                    path,
                    t.line,
                    "partial_cmp is not a total order over floats; \
                     use f64::total_cmp"
                        .to_string(),
                ));
            }
            if det
                && !t.in_test
                && t.kind == TokenKind::Punct
                && (t.text == "==" || t.text == "!=")
            {
                let prev_f = i > 0 && toks[i - 1].kind == TokenKind::Float;
                let next_f = toks.get(i + 1).is_some_and(|n| n.kind == TokenKind::Float);
                if prev_f || next_f {
                    out.push(RawViolation::at(
                        self.id(),
                        path,
                        t.line,
                        "exact float equality in a determinism-scoped path; \
                         use total_cmp or justify the exact compare"
                            .to_string(),
                    ));
                }
            }
        }
    }
}

/// D005: fallible pops on event/admission queues in the simulator.
#[derive(Debug)]
pub struct FalliblePop;

const POP_NAMES: &[&str] = &["pop", "pop_front", "pop_first", "pop_last", "pop_admission"];

impl Rule for FalliblePop {
    fn id(&self) -> &'static str {
        "D005"
    }
    fn applies(&self, path: &str) -> bool {
        path.contains("src/simulator/")
    }
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokenKind::Ident {
                continue;
            }
            if POP_NAMES.contains(&t.text.as_str())
                && i > 0
                && toks[i - 1].text == "."
                && is_text(toks, i + 1, "(")
                && is_text(toks, i + 2, ")")
                && is_text(toks, i + 3, ".")
                && toks.get(i + 4)
                    .is_some_and(|n| n.text == "unwrap" || n.text == "expect")
            {
                out.push(RawViolation::at(
                    self.id(),
                    path,
                    t.line,
                    format!(
                        "{}().{}() on an event/admission queue: handle empty \
                         explicitly (while let / if let)",
                        t.text,
                        toks[i + 4].text
                    ),
                ));
            }
        }
    }
}

/// D006: the crate-wide salt registry. Every `SALT_*` const must be
/// defined exactly once, all literal values must be pairwise distinct,
/// and every `Rng::new(seed ^ SALT_X)` operand must resolve to one of
/// the definitions.
#[derive(Debug)]
pub struct SaltRegistry;

impl CrateRule for SaltRegistry {
    fn id(&self) -> &'static str {
        "D006"
    }
    fn check(&self, idx: &CrateIndex, out: &mut Vec<RawViolation>) {
        let defs = idx.consts_with_prefix("SALT_");
        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, d) in defs.iter().enumerate() {
            by_name.entry(&d.name).or_default().push(i);
        }
        for (name, sites) in &by_name {
            for &i in &sites[1..] {
                let first = &defs[sites[0]];
                out.push(
                    RawViolation::at(
                        self.id(),
                        &defs[i].path,
                        defs[i].line,
                        format!("{name} is defined more than once; salts must be crate-unique"),
                    )
                    .with_related(&first.path, first.line, "first definition"),
                );
            }
        }
        // value collisions across *distinct* names (same-name duplicates
        // were already reported above): key on each name's first def.
        let mut by_value: BTreeMap<u128, Vec<usize>> = BTreeMap::new();
        for sites in by_name.values() {
            let d = &defs[sites[0]];
            if let Some(v) = d.value {
                by_value.entry(v).or_default().push(sites[0]);
            }
        }
        for (value, sites) in &by_value {
            let mut sites = sites.clone();
            sites.sort_by(|&a, &b| (&defs[a].path, defs[a].line).cmp(&(&defs[b].path, defs[b].line)));
            for &i in &sites[1..] {
                let first = &defs[sites[0]];
                out.push(
                    RawViolation::at(
                        self.id(),
                        &defs[i].path,
                        defs[i].line,
                        format!(
                            "{} has the same value (0x{value:X}) as {}; colliding salts \
                             collapse two RNG streams into one",
                            defs[i].name, first.name
                        ),
                    )
                    .with_related(&first.path, first.line, "colliding definition"),
                );
            }
        }
        // unresolved fork operands
        let names: BTreeSet<&str> = by_name.keys().copied().collect();
        for f in &idx.files {
            for u in &f.salt_uses {
                if !names.contains(u.name.as_str()) {
                    out.push(RawViolation::at(
                        self.id(),
                        &f.path,
                        u.line,
                        format!(
                            "Rng fork xors {}, which is not defined anywhere in the \
                             crate; define the SALT_* const at module scope",
                            u.name
                        ),
                    ));
                }
            }
        }
    }
}

/// The file D007 anchors on. The rule is silent when the anchor is not in
/// the linted set (single-file fixtures), and hard-fails when the anchor
/// exists but the struct/fn moved (that is how renames surface).
const METRICS_ANCHOR: &str = "src/metrics/mod.rs";

/// Field types that participate in cross-seed aggregation.
const NUMERIC_TYPES: &[&str] =
    &["f32", "f64", "u8", "u16", "u32", "u64", "usize", "i32", "i64", "Summary"];

/// D007: metrics-aggregation coverage. Every numeric `RunMetrics` field
/// must appear in `mean_of`, or carry a `lint:reducer(D007, field): why`
/// annotation naming its non-mean reducer.
#[derive(Debug)]
pub struct MetricsCoverage;

impl CrateRule for MetricsCoverage {
    fn id(&self) -> &'static str {
        "D007"
    }
    fn check(&self, idx: &CrateIndex, out: &mut Vec<RawViolation>) {
        let Some(f) = idx.file_ending(METRICS_ANCHOR) else { return };
        let Some(s) = f.find_type(ItemKind::Struct, "RunMetrics") else {
            out.push(RawViolation::at(
                self.id(),
                &f.path,
                1,
                "RunMetrics struct not found: the aggregation-coverage anchor moved; \
                 update analysis::rules::MetricsCoverage"
                    .to_string(),
            ));
            return;
        };
        let Some(m) = f.find_fn(Some("RunMetrics"), "mean_of") else {
            out.push(RawViolation::at(
                self.id(),
                &f.path,
                s.line,
                "RunMetrics::mean_of not found: the aggregation-coverage anchor moved; \
                 update analysis::rules::MetricsCoverage"
                    .to_string(),
            ));
            return;
        };
        let mut reducer_fields: BTreeSet<&str> = BTreeSet::new();
        for d in &f.directives {
            if d.verb != DirectiveVerb::Reducer || d.rule != "D007" {
                continue;
            }
            for n in &d.names {
                if s.fields.iter().any(|fl| fl.name == *n) {
                    reducer_fields.insert(n);
                } else {
                    out.push(
                        RawViolation::at(
                            self.id(),
                            &f.path,
                            d.line,
                            format!("lint:reducer names {n}, which is not a RunMetrics field"),
                        )
                        .with_related(&f.path, s.line, "RunMetrics definition"),
                    );
                }
            }
        }
        for field in &s.fields {
            if !NUMERIC_TYPES.contains(&field.ty.as_str()) {
                continue;
            }
            if f.body_has_ident(m, &field.name) || reducer_fields.contains(field.name.as_str()) {
                continue;
            }
            out.push(
                RawViolation::at(
                    self.id(),
                    &f.path,
                    field.line,
                    format!(
                        "RunMetrics.{} is never aggregated in mean_of and carries no \
                         lint:reducer annotation: cross-seed summaries silently drop it",
                        field.name
                    ),
                )
                .with_related(&f.path, m.line, "mean_of aggregates fields here"),
            );
        }
    }
}

/// The file D008 anchors on.
const TRACE_ANCHOR: &str = "src/simulator/trace.rs";

/// D008: trace-taxonomy coverage. Every `TraceEventKind` variant must be
/// constructed somewhere in `src/simulator/` (outside the anchor) and
/// handled — or `lint:covers`-annotated — in span assembly and both
/// exporters.
#[derive(Debug)]
pub struct TraceCoverage;

/// (impl type, fn name, role) of the three consumers every variant must
/// reach.
const TRACE_HANDLERS: &[(Option<&str>, &str, &str)] = &[
    (None, "assemble_spans", "span assembly"),
    (Some("TraceEvent"), "to_json", "JSONL exporter"),
    (Some("TraceLog"), "to_chrome", "Chrome exporter"),
];

impl CrateRule for TraceCoverage {
    fn id(&self) -> &'static str {
        "D008"
    }
    fn check(&self, idx: &CrateIndex, out: &mut Vec<RawViolation>) {
        let Some(f) = idx.file_ending(TRACE_ANCHOR) else { return };
        let Some(e) = f.find_type(ItemKind::Enum, "TraceEventKind") else {
            out.push(RawViolation::at(
                self.id(),
                &f.path,
                1,
                "TraceEventKind enum not found: the trace-taxonomy anchor moved; \
                 update analysis::rules::TraceCoverage"
                    .to_string(),
            ));
            return;
        };
        let variant_names: BTreeSet<&str> = e.variants.iter().map(|v| v.name.as_str()).collect();
        // directive hygiene: a covers list naming a non-variant is how
        // renames surface
        for d in &f.directives {
            if d.verb != DirectiveVerb::Covers || d.rule != "D008" {
                continue;
            }
            for n in &d.names {
                if !variant_names.contains(n.as_str()) {
                    out.push(
                        RawViolation::at(
                            self.id(),
                            &f.path,
                            d.line,
                            format!("lint:covers names {n}, which is not a TraceEventKind variant"),
                        )
                        .with_related(&f.path, e.line, "TraceEventKind definition"),
                    );
                }
            }
        }
        for &(impl_ty, fn_name, role) in TRACE_HANDLERS {
            let Some(fun) = f.find_fn(impl_ty, fn_name) else {
                out.push(RawViolation::at(
                    self.id(),
                    &f.path,
                    e.line,
                    format!(
                        "{fn_name} ({role}) not found in trace.rs: the taxonomy-coverage \
                         anchor moved; update analysis::rules::TRACE_HANDLERS"
                    ),
                ));
                continue;
            };
            let lines = f.body_lines(fun);
            let covered: BTreeSet<&str> = f
                .directives
                .iter()
                .filter(|d| {
                    d.verb == DirectiveVerb::Covers
                        && d.rule == "D008"
                        && lines.is_some_and(|(lo, hi)| d.line >= lo && d.line <= hi)
                })
                .flat_map(|d| d.names.iter().map(|n| n.as_str()))
                .collect();
            for v in &e.variants {
                if f.body_has_ident(fun, &v.name) || covered.contains(v.name.as_str()) {
                    continue;
                }
                out.push(
                    RawViolation::at(
                        self.id(),
                        &f.path,
                        v.line,
                        format!(
                            "TraceEventKind::{} is not handled in {fn_name} ({role}); \
                             add an arm or a lint:covers annotation on its wildcard",
                            v.name
                        ),
                    )
                    .with_related(&f.path, fun.line, "handler that must cover it"),
                );
            }
        }
        // construction check: only meaningful when at least one other
        // simulator file is in the linted set (the single-file fixtures
        // would otherwise report every variant as orphaned)
        let others: Vec<&FileIndex> = idx
            .files
            .iter()
            .filter(|o| o.path.contains("src/simulator/") && o.path != f.path)
            .collect();
        if others.is_empty() {
            return;
        }
        for v in &e.variants {
            let constructed = others.iter().any(|o| {
                enum_mentions(&o.toks, "TraceEventKind", &v.name)
                    .iter()
                    .any(|m| !m.is_pattern && !m.in_test)
            });
            if !constructed {
                out.push(RawViolation::at(
                    self.id(),
                    &f.path,
                    v.line,
                    format!(
                        "TraceEventKind::{} is never constructed in src/simulator/: \
                         dead taxonomy entries hide coverage gaps",
                        v.name
                    ),
                ));
            }
        }
    }
}

/// D009: the single-funnel eviction contract (PR 5). `EventKind::Evict`
/// carries an idle-epoch guard that only `schedule_idle_evict` maintains;
/// constructing it anywhere else bypasses the staleness check.
#[derive(Debug)]
pub struct EvictFunnel;

const EVICT_FUNNEL_FN: &str = "schedule_idle_evict";

impl CrateRule for EvictFunnel {
    fn id(&self) -> &'static str {
        "D009"
    }
    fn check(&self, idx: &CrateIndex, out: &mut Vec<RawViolation>) {
        // locate the funnel (any impl context, any simulator file)
        let funnel = idx.files.iter().find_map(|f| {
            if !f.path.contains("src/simulator/") {
                return None;
            }
            f.find_fn_named(EVICT_FUNNEL_FN).map(|it| (f, it))
        });
        for f in &idx.files {
            if !f.path.contains("src/simulator/") {
                continue;
            }
            for m in enum_mentions(&f.toks, "EventKind", "Evict") {
                if m.is_pattern || m.in_test {
                    continue;
                }
                let inside = funnel.is_some_and(|(ff, it)| {
                    ff.path == f.path
                        && ff.body_lines(it).is_some_and(|(lo, hi)| m.line >= lo && m.line <= hi)
                });
                if inside {
                    continue;
                }
                let mut v = RawViolation::at(
                    self.id(),
                    &f.path,
                    m.line,
                    format!(
                        "EventKind::Evict constructed outside {EVICT_FUNNEL_FN}: the \
                         idle-epoch staleness guard only holds on the single funnel"
                    ),
                );
                if let Some((ff, it)) = funnel {
                    v = v.with_related(&ff.path, it.line, "the sanctioned push site");
                }
                out.push(v);
            }
        }
    }
}

/// D010: RNG-stream hygiene. Cloning an `Rng` duplicates its stream
/// (draws stop being unique), and two `Rng::new` forks sharing one salt
/// symbol are the same stream under two names.
#[derive(Debug)]
pub struct RngHygiene;

impl CrateRule for RngHygiene {
    fn id(&self) -> &'static str {
        "D010"
    }
    fn check(&self, idx: &CrateIndex, out: &mut Vec<RawViolation>) {
        // (a) `<rng-named ident>.clone()` — type-unaware by design: the
        // naming convention is the contract
        for f in &idx.files {
            for (i, t) in f.toks.iter().enumerate() {
                if t.kind == TokenKind::Ident
                    && t.text.to_ascii_lowercase().contains("rng")
                    && is_text(&f.toks, i + 1, ".")
                    && is_text(&f.toks, i + 2, "clone")
                    && is_text(&f.toks, i + 3, "(")
                {
                    out.push(RawViolation::at(
                        self.id(),
                        &f.path,
                        t.line,
                        format!(
                            "{}.clone() duplicates an RNG stream; fork a new salted \
                             stream instead (Rng::new(seed ^ SALT_X) or .fork())",
                            t.text
                        ),
                    ));
                }
            }
        }
        // (b) one salt symbol feeding two forks
        let mut uses: BTreeMap<&str, Vec<(&str, u32)>> = BTreeMap::new();
        for f in &idx.files {
            for u in &f.salt_uses {
                uses.entry(&u.name).or_default().push((&f.path, u.line));
            }
        }
        for (name, sites) in &uses {
            for &(path, line) in &sites[1..] {
                let (fp, fl) = sites[0];
                out.push(
                    RawViolation::at(
                        self.id(),
                        path,
                        line,
                        format!(
                            "{name} already salts another Rng::new fork; two forks \
                             sharing a salt are one stream under two names"
                        ),
                    )
                    .with_related(fp, fl, "first fork with this salt"),
                );
            }
        }
    }
}

/// The token-pass registry, in rule-id order.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(HashOrder),
        Box::new(WallClock),
        Box::new(UnsaltedRng),
        Box::new(FloatOrder),
        Box::new(FalliblePop),
    ]
}

/// The crate-pass registry, in rule-id order.
pub fn crate_rules() -> Vec<Box<dyn CrateRule>> {
    vec![
        Box::new(SaltRegistry),
        Box::new(MetricsCoverage),
        Box::new(TraceCoverage),
        Box::new(EvictFunnel),
        Box::new(RngHygiene),
    ]
}

/// Run every applicable token rule over one file's token stream.
pub fn check_file(path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
    for rule in all_rules() {
        if rule.applies(path) {
            rule.check(path, toks, out);
        }
    }
}
