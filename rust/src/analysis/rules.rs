//! The determinism rules (D001–D005). Each rule is a small token-stream
//! pattern matcher behind the [`Rule`] trait; path scoping decides where a
//! rule applies, and `#[cfg(test)]` regions are exempt from the
//! runtime-only rules (tests may freely compare floats or unwrap pops —
//! they *check* determinism rather than produce it).
//!
//! The rules deliberately work without type information: they encode the
//! repo's naming conventions (`Rng::new`, `SALT_*`, `pop_admission`)
//! rather than resolved semantics, trading false-negative room for a
//! dependency-free pass that runs in milliseconds. Divergences from a
//! type-aware linter are documented per rule in DESIGN.md §Static
//! analysis.

use super::lexer::{Token, TokenKind};

/// A rule hit before `lint:allow` filtering.
#[derive(Debug, Clone)]
pub struct RawViolation {
    pub rule: &'static str,
    pub line: u32,
    pub message: String,
}

/// One determinism rule: an id (`D00x`), a one-line summary for the
/// report, and a token-stream check.
pub trait Rule {
    fn id(&self) -> &'static str;
    fn summary(&self) -> &'static str;
    /// Whether the rule scans `path` at all (normalized, `/`-separated).
    fn applies(&self, path: &str) -> bool;
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>);
}

/// Paths whose iteration/compare order feeds event order or SGD order.
const DETERMINISM_DIRS: &[&str] =
    &["src/simulator/", "src/coordinator/", "src/learner/", "src/metrics/"];

fn in_determinism_dirs(path: &str) -> bool {
    DETERMINISM_DIRS.iter().any(|d| path.contains(d))
}

/// `util::bench` and the bench harness are the sanctioned wall-clock
/// consumers (they measure the host, not the simulation).
fn is_bench_path(path: &str) -> bool {
    path.contains("/benches/") || path.starts_with("benches/") || path.ends_with("util/bench.rs")
}

fn is_text(toks: &[Token], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.text == text)
}

/// D001: hash-ordered collections in determinism-scoped paths.
#[derive(Debug)]
pub struct HashOrder;

impl Rule for HashOrder {
    fn id(&self) -> &'static str {
        "D001"
    }
    fn summary(&self) -> &'static str {
        "no HashMap/HashSet in simulator/coordinator/learner/metrics paths"
    }
    fn applies(&self, path: &str) -> bool {
        in_determinism_dirs(path)
    }
    fn check(&self, _path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for t in toks {
            if t.in_test || t.kind != TokenKind::Ident {
                continue;
            }
            if t.text == "HashMap" || t.text == "HashSet" {
                out.push(RawViolation {
                    rule: self.id(),
                    line: t.line,
                    message: format!(
                        "{} in a determinism-scoped path: iteration order is \
                         hash-seeded; use BTreeMap/BTreeSet or sort before iterating",
                        t.text
                    ),
                });
            }
        }
    }
}

/// D002: wall-clock reads outside `util::bench`/benches.
#[derive(Debug)]
pub struct WallClock;

impl Rule for WallClock {
    fn id(&self) -> &'static str {
        "D002"
    }
    fn summary(&self) -> &'static str {
        "no Instant::now/SystemTime::now outside util::bench and benches/"
    }
    fn applies(&self, path: &str) -> bool {
        !is_bench_path(path)
    }
    fn check(&self, _path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokenKind::Ident {
                continue;
            }
            if (t.text == "Instant" || t.text == "SystemTime")
                && is_text(toks, i + 1, "::")
                && is_text(toks, i + 2, "now")
            {
                out.push(RawViolation {
                    rule: self.id(),
                    line: t.line,
                    message: format!(
                        "wall-clock read ({}::now) outside util::bench/benches: \
                         simulated time must come from the event clock",
                        t.text
                    ),
                });
            }
        }
    }
}

/// D003: process-varying randomness and inline (unnamed) RNG salts.
#[derive(Debug)]
pub struct UnsaltedRng;

/// Identifiers that smuggle per-process entropy into a run.
const RANDOM_SOURCES: &[&str] = &["DefaultHasher", "RandomState", "thread_rng", "from_entropy"];

impl Rule for UnsaltedRng {
    fn id(&self) -> &'static str {
        "D003"
    }
    fn summary(&self) -> &'static str {
        "RNG forks go through util::rng with named SALT_* constants"
    }
    fn applies(&self, _path: &str) -> bool {
        true
    }
    fn check(&self, _path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokenKind::Ident {
                continue;
            }
            if RANDOM_SOURCES.contains(&t.text.as_str()) {
                out.push(RawViolation {
                    rule: self.id(),
                    line: t.line,
                    message: format!(
                        "{} is process-varying randomness; all RNG must flow \
                         from util::rng with an explicit seed",
                        t.text
                    ),
                });
            }
            // `Rng::new( ... <int literal> ^ ... )`: inline salts defeat
            // grep-ability; the convention is `seed ^ SALT_X` with the
            // constant named at module scope (PR 6).
            if t.text == "Rng"
                && is_text(toks, i + 1, "::")
                && is_text(toks, i + 2, "new")
                && is_text(toks, i + 3, "(")
            {
                let mut j = i + 4;
                let mut pdepth = 1i32;
                while j < toks.len() && pdepth > 0 {
                    match toks[j].text.as_str() {
                        "(" => pdepth += 1,
                        ")" => pdepth -= 1,
                        "^" => {
                            let prev_lit = toks[j - 1].kind == TokenKind::Int;
                            let next_lit =
                                toks.get(j + 1).is_some_and(|t| t.kind == TokenKind::Int);
                            if prev_lit || next_lit {
                                out.push(RawViolation {
                                    rule: self.id(),
                                    line: toks[j].line,
                                    message: "inline RNG salt: hoist the literal to a named \
                                              SALT_* constant (seed ^ SALT_X convention)"
                                        .to_string(),
                                });
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            }
        }
    }
}

/// D004: float ordering/equality must be total.
#[derive(Debug)]
pub struct FloatOrder;

impl Rule for FloatOrder {
    fn id(&self) -> &'static str {
        "D004"
    }
    fn summary(&self) -> &'static str {
        "float ordering via total_cmp; no partial_cmp, no exact f64 =="
    }
    fn applies(&self, _path: &str) -> bool {
        true
    }
    fn check(&self, path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        let det = in_determinism_dirs(path);
        for (i, t) in toks.iter().enumerate() {
            // partial_cmp is flagged everywhere, tests included: a test
            // that sorts through a partial order can mask the exact
            // nondeterminism the battery exists to catch.
            if t.kind == TokenKind::Ident && t.text == "partial_cmp" {
                out.push(RawViolation {
                    rule: self.id(),
                    line: t.line,
                    message: "partial_cmp is not a total order over floats; \
                              use f64::total_cmp"
                        .to_string(),
                });
            }
            if det
                && !t.in_test
                && t.kind == TokenKind::Punct
                && (t.text == "==" || t.text == "!=")
            {
                let prev_f = i > 0 && toks[i - 1].kind == TokenKind::Float;
                let next_f = toks.get(i + 1).is_some_and(|n| n.kind == TokenKind::Float);
                if prev_f || next_f {
                    out.push(RawViolation {
                        rule: self.id(),
                        line: t.line,
                        message: "exact float equality in a determinism-scoped path; \
                                  use total_cmp or justify the exact compare"
                            .to_string(),
                    });
                }
            }
        }
    }
}

/// D005: fallible pops on event/admission queues in the simulator.
#[derive(Debug)]
pub struct FalliblePop;

const POP_NAMES: &[&str] = &["pop", "pop_front", "pop_first", "pop_last", "pop_admission"];

impl Rule for FalliblePop {
    fn id(&self) -> &'static str {
        "D005"
    }
    fn summary(&self) -> &'static str {
        "no unwrap/expect on event-heap or admission-queue pops in simulator/"
    }
    fn applies(&self, path: &str) -> bool {
        path.contains("src/simulator/")
    }
    fn check(&self, _path: &str, toks: &[Token], out: &mut Vec<RawViolation>) {
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokenKind::Ident {
                continue;
            }
            if POP_NAMES.contains(&t.text.as_str())
                && i > 0
                && toks[i - 1].text == "."
                && is_text(toks, i + 1, "(")
                && is_text(toks, i + 2, ")")
                && is_text(toks, i + 3, ".")
                && toks.get(i + 4)
                    .is_some_and(|n| n.text == "unwrap" || n.text == "expect")
            {
                out.push(RawViolation {
                    rule: self.id(),
                    line: t.line,
                    message: format!(
                        "{}().{}() on an event/admission queue: handle empty \
                         explicitly (while let / if let)",
                        t.text,
                        toks[i + 4].text
                    ),
                });
            }
        }
    }
}

/// The registry, in rule-id order. The report and the docs iterate this.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(HashOrder),
        Box::new(WallClock),
        Box::new(UnsaltedRng),
        Box::new(FloatOrder),
        Box::new(FalliblePop),
    ]
}

/// Run every applicable rule over one file's token stream.
pub fn check_file(path: &str, toks: &[Token]) -> Vec<RawViolation> {
    let mut out = Vec::new();
    for rule in all_rules() {
        if rule.applies(path) {
            rule.check(path, toks, &mut out);
        }
    }
    // stable report order: by line, then rule id
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}
