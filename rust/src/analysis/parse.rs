//! An item-tree parser over the [`lexer`](super::lexer) token stream —
//! pass one of the two-pass analyzer (DESIGN.md §Static analysis v2).
//!
//! It recovers exactly the structure the cross-file rules (D006–D010)
//! query: modules, structs with fields, enums with variants, `const`/
//! `static` definitions with literal values, fn/impl signatures, and
//! brace-matched body token ranges. Everything else (`use`, `type`,
//! macros, trait declarations) is skipped with balanced-delimiter
//! recovery, so unknown syntax degrades to "no items", never to a
//! desynchronized tree.
//!
//! Items guarded by `#[cfg(test)]` / `#[test]` are dropped at parse time:
//! the crate-wide symbol index describes shipped code only, mirroring the
//! token rules' test-region exemption.

use super::lexer::{Token, TokenKind};

/// Item classes the cross-file rules discriminate on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ItemKind {
    Mod,
    Struct,
    Enum,
    Const,
    Static,
    Fn,
    Impl,
}

/// One named field of a struct.
#[derive(Debug, Clone)]
pub struct Field {
    pub name: String,
    /// Type text with tokens joined (`Vec<Episode>`, `Option<f64>`).
    pub ty: String,
    pub line: u32,
}

/// One enum variant (payload shape is not retained — the rules only need
/// the name and the definition site).
#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub line: u32,
}

/// One parsed item. `span` is the token index range `[start, end)` of the
/// whole item; `body` is the range strictly inside its braces, if any.
#[derive(Debug, Clone)]
pub struct Item {
    pub kind: ItemKind,
    /// Item name; for impls, the self-type name (`Engine` for
    /// `impl<'p, P: Policy> Engine<'p, P>`).
    pub name: String,
    pub line: u32,
    pub span: (usize, usize),
    pub body: Option<(usize, usize)>,
    /// Nested items: mod contents, impl methods/consts.
    pub children: Vec<Item>,
    /// Struct fields (named structs only; tuple/unit structs have none).
    pub fields: Vec<Field>,
    /// Enum variants.
    pub variants: Vec<Variant>,
    /// `const`/`static` initializer, when it is a single integer literal
    /// (the D006 salt-registry value check).
    pub const_value: Option<u128>,
    /// Header text: `fn name ( .. ) -> ..` / `impl Trait for Type`.
    pub signature: String,
}

impl Item {
    fn new(kind: ItemKind, name: String, line: u32) -> Self {
        Item {
            kind,
            name,
            line,
            span: (0, 0),
            body: None,
            children: Vec::new(),
            fields: Vec::new(),
            variants: Vec::new(),
            const_value: None,
            signature: String::new(),
        }
    }
}

/// Parse the item tree of one file. Test-guarded items are dropped; the
/// token stream must already be [`mark_test_regions`](super::lexer)-ed by
/// the caller only for consistency — the parser re-detects the guarding
/// attributes itself so it also works on a raw stream.
pub fn parse_items(toks: &[Token]) -> Vec<Item> {
    let mut p = Parser { t: toks, i: 0 };
    p.items(toks.len())
}

/// Parse a `u64`-ish integer literal (`0x5C4E_D011`, `1_000u64`, `0b101`).
pub fn int_literal_value(text: &str) -> Option<u128> {
    let t = text.replace('_', "");
    let (radix, digits) = match t.get(..2) {
        Some("0x") => (16, &t[2..]),
        Some("0o") => (8, &t[2..]),
        Some("0b") => (2, &t[2..]),
        _ => (10, t.as_str()),
    };
    // strip a trailing type suffix; careful with hex, where the suffix and
    // the digits share the alphabet (`0xFFu64`): take the longest valid
    // digit prefix, then require the rest to be a known suffix.
    let valid = |c: char| c.is_digit(radix);
    let split = digits.find(|c| !valid(c)).unwrap_or(digits.len());
    let (num, suffix) = digits.split_at(split);
    const SUFFIXES: &[&str] =
        &["", "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize"];
    if num.is_empty() || !SUFFIXES.contains(&suffix) {
        return None;
    }
    u128::from_str_radix(num, radix).ok()
}

/// One arm of a `match` expression.
#[derive(Debug, Clone)]
pub struct MatchArm {
    /// Token index range `[start, end)` of the pattern (before `=>`).
    pub head: (usize, usize),
    pub line: u32,
    /// The pattern is a bare `_` (optionally guarded).
    pub is_wildcard: bool,
}

/// Token indices of every `match` keyword inside `range` (outer-to-inner
/// source order). Pair with [`match_arms_at`].
pub fn find_matches(toks: &[Token], range: (usize, usize)) -> Vec<usize> {
    (range.0..range.1.min(toks.len()))
        .filter(|&i| toks[i].kind == TokenKind::Ident && toks[i].text == "match")
        .collect()
}

/// Extract the arms of the `match` whose keyword sits at `match_idx`. The
/// scrutinee runs to the first `{` at balanced depth (struct literals
/// need parens in scrutinee position, so that brace is the match body).
pub fn match_arms_at(toks: &[Token], match_idx: usize) -> Vec<MatchArm> {
    let n = toks.len();
    // find the body-opening brace
    let mut i = match_idx + 1;
    let (mut par, mut brk) = (0i32, 0i32);
    while i < n {
        match toks[i].text.as_str() {
            "(" => par += 1,
            ")" => par -= 1,
            "[" => brk += 1,
            "]" => brk -= 1,
            "{" if par == 0 && brk == 0 => break,
            _ => {}
        }
        i += 1;
    }
    if i >= n {
        return Vec::new();
    }
    let mut arms = Vec::new();
    let mut j = i + 1;
    while j < n && toks[j].text != "}" {
        let head_start = j;
        let line = toks[j].line;
        // pattern runs to `=>` at balanced depth
        let (mut p, mut bk, mut br) = (0i32, 0i32, 0i32);
        while j < n {
            match toks[j].text.as_str() {
                "(" => p += 1,
                ")" => p -= 1,
                "[" => bk += 1,
                "]" => bk -= 1,
                "{" => br += 1,
                "}" => {
                    if br == 0 {
                        // ran into the match-closing brace: malformed arm
                        return arms;
                    }
                    br -= 1;
                }
                "=>" if p == 0 && bk == 0 && br == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if j >= n {
            return arms;
        }
        let head = (head_start, j);
        let is_wildcard = toks[head_start].text == "_"
            && (j == head_start + 1 || toks[head_start + 1].text == "if");
        arms.push(MatchArm { head, line, is_wildcard });
        j += 1; // past `=>`
        // arm body: a braced block, or an expression up to `,` / `}`
        if j < n && toks[j].text == "{" {
            j = skip_balanced(toks, j, "{", "}");
        } else {
            let (mut p, mut bk, mut br) = (0i32, 0i32, 0i32);
            while j < n {
                match toks[j].text.as_str() {
                    "(" => p += 1,
                    ")" => p -= 1,
                    "[" => bk += 1,
                    "]" => bk -= 1,
                    "{" => br += 1,
                    "}" => {
                        if br == 0 {
                            break; // match-closing brace ends the last arm
                        }
                        br -= 1;
                    }
                    "," if p == 0 && bk == 0 && br == 0 => {
                        j += 1;
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            continue;
        }
        if j < n && toks[j].text == "," {
            j += 1;
        }
    }
    arms
}

/// Skip past a balanced `open ... close` group starting at `i` (which must
/// hold `open`); returns the index just past the matching close.
fn skip_balanced(toks: &[Token], i: usize, open: &str, close: &str) -> usize {
    let n = toks.len();
    let mut depth = 0i32;
    let mut j = i;
    while j < n {
        if toks[j].text == open {
            depth += 1;
        } else if toks[j].text == close {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    n
}

struct Parser<'a> {
    t: &'a [Token],
    i: usize,
}

impl<'a> Parser<'a> {
    fn text(&self, i: usize) -> &str {
        self.t.get(i).map(|t| t.text.as_str()).unwrap_or("")
    }

    fn line(&self, i: usize) -> u32 {
        self.t.get(i).map(|t| t.line).unwrap_or(0)
    }

    /// Parse items until `end` (exclusive token index).
    fn items(&mut self, end: usize) -> Vec<Item> {
        let mut items = Vec::new();
        let mut drop_next = false; // `#[cfg(test)]` / `#[test]` latch
        while self.i < end {
            // inner attributes (`#![..]`) decorate the enclosing scope
            if self.text(self.i) == "#" && self.text(self.i + 1) == "!" && self.text(self.i + 2) == "[" {
                self.i = skip_balanced(self.t, self.i + 2, "[", "]").min(end);
                continue;
            }
            // attributes: scan for test guards, then skip
            if self.text(self.i) == "#" && self.text(self.i + 1) == "[" {
                let after = skip_balanced(self.t, self.i + 1, "[", "]").min(end);
                if attr_is_test(&self.t[self.i + 2..after.saturating_sub(1)]) {
                    drop_next = true;
                }
                self.i = after;
                continue;
            }
            // visibility: `pub`, `pub(crate)`, `pub(in ..)`
            if self.text(self.i) == "pub" {
                self.i += 1;
                if self.text(self.i) == "(" {
                    self.i = skip_balanced(self.t, self.i, "(", ")").min(end);
                }
                continue;
            }
            let parsed = match self.text(self.i) {
                "mod" => self.item_mod(end),
                "struct" => self.item_struct(end),
                "enum" => self.item_enum(end),
                "const" | "static" => self.item_const(end),
                "fn" => self.item_fn(end),
                "impl" => self.item_impl(end),
                "unsafe" | "async" | "extern" => {
                    // qualifier: fold into whatever item follows
                    self.i += 1;
                    continue;
                }
                _ => {
                    self.skip_item(end);
                    None
                }
            };
            if let Some(item) = parsed {
                if drop_next {
                    drop_next = false;
                } else {
                    items.push(item);
                }
            } else {
                drop_next = false;
            }
        }
        items
    }

    /// `mod name { items }` or `mod name;`
    fn item_mod(&mut self, end: usize) -> Option<Item> {
        let start = self.i;
        let line = self.line(self.i);
        self.i += 1;
        let name = self.ident()?;
        let mut item = Item::new(ItemKind::Mod, name, line);
        if self.text(self.i) == "{" {
            let close = skip_balanced(self.t, self.i, "{", "}").min(end);
            item.body = Some((self.i + 1, close.saturating_sub(1)));
            self.i += 1;
            item.children = self.items(close.saturating_sub(1));
            self.i = close;
        } else if self.text(self.i) == ";" {
            self.i += 1;
        }
        item.span = (start, self.i);
        Some(item)
    }

    /// `struct Name<..> { fields }` / tuple / unit structs.
    fn item_struct(&mut self, end: usize) -> Option<Item> {
        let start = self.i;
        let line = self.line(self.i);
        self.i += 1;
        let name = self.ident()?;
        let mut item = Item::new(ItemKind::Struct, name, line);
        self.skip_generics();
        self.skip_where("{;(");
        match self.text(self.i) {
            "{" => {
                let close = skip_balanced(self.t, self.i, "{", "}").min(end);
                item.body = Some((self.i + 1, close.saturating_sub(1)));
                item.fields = self.fields(self.i + 1, close.saturating_sub(1));
                self.i = close;
            }
            "(" => {
                self.i = skip_balanced(self.t, self.i, "(", ")").min(end);
                self.skip_where(";");
                if self.text(self.i) == ";" {
                    self.i += 1;
                }
            }
            ";" => self.i += 1,
            _ => {}
        }
        item.span = (start, self.i);
        Some(item)
    }

    /// Named fields between `open..close` token indices.
    fn fields(&mut self, open: usize, close: usize) -> Vec<Field> {
        let mut out = Vec::new();
        let mut j = open;
        while j < close {
            // skip attributes and visibility on the field
            if self.text(j) == "#" && self.text(j + 1) == "[" {
                j = skip_balanced(self.t, j + 1, "[", "]").min(close);
                continue;
            }
            if self.text(j) == "pub" {
                j += 1;
                if self.text(j) == "(" {
                    j = skip_balanced(self.t, j, "(", ")").min(close);
                }
                continue;
            }
            let Some(t) = self.t.get(j) else { break };
            if t.kind != TokenKind::Ident {
                j += 1;
                continue;
            }
            let name = t.text.clone();
            let line = t.line;
            if self.text(j + 1) != ":" {
                j += 1;
                continue;
            }
            // type runs to `,` at balanced depth (or the closing brace)
            let mut k = j + 2;
            let (mut p, mut bk, mut ang) = (0i32, 0i32, 0i32);
            let mut ty = String::new();
            while k < close {
                match self.text(k) {
                    "(" => p += 1,
                    ")" => p -= 1,
                    "[" => bk += 1,
                    "]" => bk -= 1,
                    "<" => ang += 1,
                    ">" => ang -= 1,
                    "," if p == 0 && bk == 0 && ang == 0 => break,
                    _ => {}
                }
                ty.push_str(self.text(k));
                k += 1;
            }
            out.push(Field { name, ty, line });
            j = k + 1;
        }
        out
    }

    /// `enum Name<..> { Variant, Variant(..), Variant { .. }, .. }`
    fn item_enum(&mut self, end: usize) -> Option<Item> {
        let start = self.i;
        let line = self.line(self.i);
        self.i += 1;
        let name = self.ident()?;
        let mut item = Item::new(ItemKind::Enum, name, line);
        self.skip_generics();
        self.skip_where("{");
        if self.text(self.i) == "{" {
            let close = skip_balanced(self.t, self.i, "{", "}").min(end);
            item.body = Some((self.i + 1, close.saturating_sub(1)));
            let mut j = self.i + 1;
            let inner_end = close.saturating_sub(1);
            while j < inner_end {
                if self.text(j) == "#" && self.text(j + 1) == "[" {
                    j = skip_balanced(self.t, j + 1, "[", "]").min(inner_end);
                    continue;
                }
                let Some(t) = self.t.get(j) else { break };
                if t.kind == TokenKind::Ident {
                    item.variants.push(Variant { name: t.text.clone(), line: t.line });
                    j += 1;
                    // payload / discriminant, then the separating comma
                    match self.text(j) {
                        "{" => j = skip_balanced(self.t, j, "{", "}").min(inner_end),
                        "(" => j = skip_balanced(self.t, j, "(", ")").min(inner_end),
                        _ => {}
                    }
                    while j < inner_end && self.text(j) != "," {
                        j += 1;
                    }
                }
                j += 1;
            }
            self.i = close;
        }
        item.span = (start, self.i);
        Some(item)
    }

    /// `const NAME: Ty = expr;` / `static NAME: Ty = expr;`
    fn item_const(&mut self, end: usize) -> Option<Item> {
        let start = self.i;
        let line = self.line(self.i);
        let kind = if self.text(self.i) == "static" { ItemKind::Static } else { ItemKind::Const };
        self.i += 1;
        if self.text(self.i) == "mut" {
            self.i += 1;
        }
        let name = self.ident()?;
        let mut item = Item::new(kind, name, line);
        // skip the type annotation up to `=` (or `;` for extern decls)
        while self.i < end && self.text(self.i) != "=" && self.text(self.i) != ";" {
            match self.text(self.i) {
                "(" => self.i = skip_balanced(self.t, self.i, "(", ")").min(end),
                "[" => self.i = skip_balanced(self.t, self.i, "[", "]").min(end),
                "{" => self.i = skip_balanced(self.t, self.i, "{", "}").min(end),
                _ => self.i += 1,
            }
        }
        if self.text(self.i) == "=" {
            self.i += 1;
            let expr_start = self.i;
            let (mut p, mut bk, mut br) = (0i32, 0i32, 0i32);
            while self.i < end {
                match self.text(self.i) {
                    "(" => p += 1,
                    ")" => p -= 1,
                    "[" => bk += 1,
                    "]" => bk -= 1,
                    "{" => br += 1,
                    "}" => br -= 1,
                    ";" if p == 0 && bk == 0 && br == 0 => break,
                    _ => {}
                }
                self.i += 1;
            }
            if self.i == expr_start + 1 && self.t[expr_start].kind == TokenKind::Int {
                item.const_value = int_literal_value(&self.t[expr_start].text);
            }
        }
        if self.text(self.i) == ";" {
            self.i += 1;
        }
        item.span = (start, self.i);
        Some(item)
    }

    /// `fn name(..) -> .. { body }` (or `;` for trait-style decls).
    fn item_fn(&mut self, end: usize) -> Option<Item> {
        let start = self.i;
        let line = self.line(self.i);
        self.i += 1;
        let name = self.ident()?;
        let mut item = Item::new(ItemKind::Fn, name, line);
        // signature runs to the body `{` or a `;` at balanced depth; `<`
        // is tracked so `where P: Fn(usize) -> bool {` cannot fool it
        let sig_start = start;
        let (mut p, mut bk) = (0i32, 0i32);
        while self.i < end {
            match self.text(self.i) {
                "(" => p += 1,
                ")" => p -= 1,
                "[" => bk += 1,
                "]" => bk -= 1,
                "{" if p == 0 && bk == 0 => break,
                ";" if p == 0 && bk == 0 => break,
                _ => {}
            }
            self.i += 1;
        }
        item.signature = self.t[sig_start..self.i.min(end)]
            .iter()
            .map(|t| t.text.as_str())
            .collect::<Vec<_>>()
            .join(" ");
        if self.text(self.i) == "{" {
            let close = skip_balanced(self.t, self.i, "{", "}").min(end);
            item.body = Some((self.i + 1, close.saturating_sub(1)));
            self.i = close;
        } else if self.text(self.i) == ";" {
            self.i += 1;
        }
        item.span = (start, self.i);
        Some(item)
    }

    /// `impl<..> Type { .. }` / `impl<..> Trait for Type { .. }`
    fn item_impl(&mut self, end: usize) -> Option<Item> {
        let start = self.i;
        let line = self.line(self.i);
        self.i += 1;
        self.skip_generics();
        // header runs to the body `{` at balanced depth
        let head_start = self.i;
        let (mut p, mut bk, mut ang) = (0i32, 0i32, 0i32);
        while self.i < end {
            match self.text(self.i) {
                "(" => p += 1,
                ")" => p -= 1,
                "[" => bk += 1,
                "]" => bk -= 1,
                "<" => ang += 1,
                ">" => ang -= 1,
                "{" if p == 0 && bk == 0 && ang <= 0 => break,
                _ => {}
            }
            self.i += 1;
        }
        let head = &self.t[head_start..self.i.min(end)];
        let name = impl_type_name(head);
        let mut item = Item::new(ItemKind::Impl, name, line);
        item.signature = std::iter::once("impl")
            .chain(head.iter().map(|t| t.text.as_str()))
            .collect::<Vec<_>>()
            .join(" ");
        if self.text(self.i) == "{" {
            let close = skip_balanced(self.t, self.i, "{", "}").min(end);
            item.body = Some((self.i + 1, close.saturating_sub(1)));
            self.i += 1;
            item.children = self.items(close.saturating_sub(1));
            self.i = close;
        }
        item.span = (start, self.i);
        Some(item)
    }

    fn ident(&mut self) -> Option<String> {
        let t = self.t.get(self.i)?;
        if t.kind != TokenKind::Ident {
            return None;
        }
        self.i += 1;
        Some(t.text.clone())
    }

    /// Skip `<..>` generics by angle depth (the lexer emits single `<` and
    /// `>`, so nested generics never fuse into `>>`).
    fn skip_generics(&mut self) {
        if self.text(self.i) != "<" {
            return;
        }
        let mut depth = 0i32;
        while self.i < self.t.len() {
            match self.text(self.i) {
                "<" => depth += 1,
                ">" => {
                    depth -= 1;
                    if depth == 0 {
                        self.i += 1;
                        return;
                    }
                }
                _ => {}
            }
            self.i += 1;
        }
    }

    /// Skip a `where` clause up to any of the `stop` characters.
    fn skip_where(&mut self, stop: &str) {
        if self.text(self.i) != "where" {
            return;
        }
        let (mut p, mut bk, mut ang) = (0i32, 0i32, 0i32);
        while self.i < self.t.len() {
            let t = self.text(self.i);
            match t {
                "(" => p += 1,
                ")" => p -= 1,
                "[" => bk += 1,
                "]" => bk -= 1,
                "<" => ang += 1,
                ">" => ang -= 1,
                _ => {}
            }
            if p == 0 && bk == 0 && ang <= 0 && t.len() == 1 && stop.contains(t) {
                return;
            }
            self.i += 1;
        }
    }

    /// Generic recovery: consume one unrecognized item — through a `;` at
    /// balanced depth, or a balanced `{..}` block, whichever comes first.
    fn skip_item(&mut self, end: usize) {
        let (mut p, mut bk) = (0i32, 0i32);
        while self.i < end {
            match self.text(self.i) {
                "(" => p += 1,
                ")" => p -= 1,
                "[" => bk += 1,
                "]" => bk -= 1,
                "{" if p == 0 && bk == 0 => {
                    self.i = skip_balanced(self.t, self.i, "{", "}").min(end);
                    return;
                }
                ";" if p == 0 && bk == 0 => {
                    self.i += 1;
                    return;
                }
                _ => {}
            }
            self.i += 1;
        }
    }
}

/// Is this attribute body (`cfg ( test )`, `test`, `tokio :: test`) a
/// test guard? Mirrors `mark_test_regions`' detection.
fn attr_is_test(body: &[Token]) -> bool {
    let mut first_ident: Option<&str> = None;
    let mut has_test = false;
    for t in body {
        if t.kind == TokenKind::Ident {
            if first_ident.is_none() {
                first_ident = Some(&t.text);
            } else if t.text == "test" {
                has_test = true;
            }
        }
    }
    matches!(first_ident, Some("test")) || (matches!(first_ident, Some("cfg")) && has_test)
}

/// The self-type name of an impl header (tokens between `impl<..>` and the
/// body brace): the last identifier at angle depth 0 of the type path
/// after `for` (or of the whole head when there is no trait).
fn impl_type_name(head: &[Token]) -> String {
    let mut ang = 0i32;
    let type_part: &[Token] = head
        .iter()
        .position(|t| {
            let at_depth0 = ang == 0;
            match t.text.as_str() {
                "<" => ang += 1,
                ">" => ang -= 1,
                _ => {}
            }
            at_depth0 && t.text == "for"
        })
        .map(|p| &head[p + 1..])
        .unwrap_or(head);
    let mut ang = 0i32;
    let mut name = String::new();
    for t in type_part {
        match t.text.as_str() {
            "<" => ang += 1,
            ">" => ang -= 1,
            "where" if ang == 0 => break,
            _ => {
                if ang == 0 && t.kind == TokenKind::Ident {
                    name = t.text.clone();
                }
            }
        }
    }
    name
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn parse(src: &str) -> Vec<Item> {
        let (toks, _) = lex(src);
        parse_items(&toks)
    }

    #[test]
    fn nested_mods_build_a_tree() {
        let items = parse("mod outer { mod inner { fn leaf() {} } fn mid() {} } fn top() {}");
        assert_eq!(items.len(), 2);
        let outer = &items[0];
        assert_eq!((outer.kind, outer.name.as_str()), (ItemKind::Mod, "outer"));
        let inner = &outer.children[0];
        assert_eq!((inner.kind, inner.name.as_str()), (ItemKind::Mod, "inner"));
        assert_eq!(inner.children[0].name, "leaf");
        assert_eq!(outer.children[1].name, "mid");
        assert_eq!(items[1].name, "top");
    }

    #[test]
    fn generic_struct_fields_and_types() {
        let items = parse(
            "pub struct Window<T: Clone> where T: Default {\n\
             \x20   pub items: Vec<(T, f64)>,\n\
             \x20   cap: usize,\n\
             }",
        );
        assert_eq!(items.len(), 1);
        let s = &items[0];
        assert_eq!((s.kind, s.name.as_str()), (ItemKind::Struct, "Window"));
        let fields: Vec<(&str, &str)> =
            s.fields.iter().map(|f| (f.name.as_str(), f.ty.as_str())).collect();
        assert_eq!(fields, vec![("items", "Vec<(T,f64)>"), ("cap", "usize")]);
    }

    #[test]
    fn tuple_and_unit_structs_parse() {
        let items = parse("struct Wrap(pub f64);\nstruct Marker;\nfn after() {}");
        assert_eq!(items.len(), 3);
        assert_eq!(items[0].name, "Wrap");
        assert!(items[0].fields.is_empty());
        assert_eq!(items[1].name, "Marker");
        assert_eq!(items[2].name, "after");
    }

    #[test]
    fn enum_variants_with_payloads() {
        let items = parse(
            "enum Kind { Plain, Tuple(u64, f64), Struct { a: usize, b: Vec<u8> }, Last = 3 }",
        );
        let e = &items[0];
        assert_eq!(e.kind, ItemKind::Enum);
        let names: Vec<&str> = e.variants.iter().map(|v| v.name.as_str()).collect();
        assert_eq!(names, vec!["Plain", "Tuple", "Struct", "Last"]);
    }

    #[test]
    fn const_literal_values_parse() {
        let items = parse(
            "const SALT_A: u64 = 0x5C4E_D011;\nconst B: u64 = 1_000u64;\nconst C: u64 = 1 + 2;",
        );
        assert_eq!(items[0].const_value, Some(0x5C4E_D011));
        assert_eq!(items[1].const_value, Some(1000));
        assert_eq!(items[2].const_value, None); // expressions are opaque
    }

    #[test]
    fn cfg_test_items_are_dropped() {
        let items = parse(
            "fn live() {}\n#[cfg(test)]\nmod tests { fn helper() {} }\n#[test]\nfn t() {}\nfn after() {}",
        );
        let names: Vec<&str> = items.iter().map(|i| i.name.as_str()).collect();
        assert_eq!(names, vec!["live", "after"]);
    }

    #[test]
    fn impl_methods_and_type_names() {
        let items = parse(
            "impl<'p, P: Policy> Engine<'p, P> { fn step(&mut self) {} }\n\
             impl std::fmt::Debug for Span { fn fmt(&self) {} }",
        );
        assert_eq!(items[0].name, "Engine");
        assert_eq!(items[0].children[0].name, "step");
        assert_eq!(items[1].name, "Span");
        assert!(items[1].signature.contains("Debug for Span"));
    }

    #[test]
    fn match_arm_extraction() {
        let src = "fn f(k: Kind) -> u32 { match k { Kind::A { x, .. } => x, Kind::B(v) => { v + 1 } _ if x > 0 => 2, _ => 0, } }";
        let (toks, _) = lex(src);
        let items = parse_items(&toks);
        let body = items[0].body.unwrap();
        let matches = find_matches(&toks, body);
        assert_eq!(matches.len(), 1);
        let arms = match_arms_at(&toks, matches[0]);
        assert_eq!(arms.len(), 4);
        let head_text = |a: &MatchArm| {
            toks[a.head.0..a.head.1].iter().map(|t| t.text.clone()).collect::<Vec<_>>().join("")
        };
        assert_eq!(head_text(&arms[0]), "Kind::A{x,..}");
        assert_eq!(head_text(&arms[1]), "Kind::B(v)");
        assert!(arms[2].is_wildcard); // guarded `_ if ..`
        assert!(arms[3].is_wildcard);
        assert!(!arms[0].is_wildcard);
    }

    #[test]
    fn fn_bodies_are_brace_matched() {
        let (toks, _) = lex("fn a() { if x { y(); } }\nfn b() {}");
        let items = parse_items(&toks);
        let (s, e) = items[0].body.unwrap();
        let body_text: Vec<&str> = toks[s..e].iter().map(|t| t.text.as_str()).collect();
        assert_eq!(body_text, vec!["if", "x", "{", "y", "(", ")", ";", "}"]);
        assert!(items[1].body.unwrap().0 > e);
    }
}
