//! The crate-wide symbol index — pass one's output, pass two's input
//! (DESIGN.md §Static analysis v2).
//!
//! [`CrateIndex`] holds one [`FileIndex`] per linted file: its token
//! stream, its item tree, its coverage directives, and the `Rng::new`
//! fork sites extracted from it. Cross-file rules (D006–D010) query the
//! index instead of re-scanning text, which is what lets a salt collision
//! cite *both* definition sites.
//!
//! Two in-source directives ride on line comments (doc comments stay
//! inert, exactly like `lint:allow`):
//!
//! * `lint:covers(D008, VariantA, VariantB): reason` — declares that a
//!   wildcard arm inside the enclosing fn deliberately absorbs the listed
//!   `TraceEventKind` variants;
//! * `lint:reducer(D007, field_a, field_b): reason` — declares that a
//!   `RunMetrics` field is aggregated by a non-mean reducer (max/min) and
//!   is exempt from the `mean_of` coverage check.
//!
//! Like `lint:allow`, the reason is mandatory; a directive naming an
//! unknown field/variant is itself a violation (it is how renames get
//! caught).

use super::lexer::{Comment, Token, TokenKind};
use super::parse::{parse_items, Item, ItemKind};

/// Directive verbs (the word after `lint:`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DirectiveVerb {
    Covers,
    Reducer,
}

/// One parsed coverage directive.
#[derive(Debug, Clone)]
pub struct Directive {
    pub verb: DirectiveVerb,
    /// The rule id inside the parens (`D007`/`D008`).
    pub rule: String,
    /// The field/variant names after the rule id.
    pub names: Vec<String>,
    pub line: u32,
    pub reason: String,
}

/// One `Rng::new(.. ^ SALT_X ..)` fork site.
#[derive(Debug, Clone)]
pub struct SaltUse {
    pub name: String,
    pub line: u32,
    pub in_test: bool,
}

/// Everything pass one learned about a single file.
#[derive(Debug)]
pub struct FileIndex {
    /// Repo-relative path with `/` separators (rule scoping keys on it).
    pub path: String,
    pub toks: Vec<Token>,
    pub items: Vec<Item>,
    pub directives: Vec<Directive>,
    pub salt_uses: Vec<SaltUse>,
}

/// A `const`/`static` definition site, flattened out of the item tree.
#[derive(Debug, Clone)]
pub struct ConstDef {
    pub name: String,
    pub path: String,
    pub line: u32,
    pub value: Option<u128>,
}

/// The whole crate, as pass two sees it. Files stay in walk order
/// (sorted by path), so every query is deterministic.
#[derive(Debug, Default)]
pub struct CrateIndex {
    pub files: Vec<FileIndex>,
}

impl FileIndex {
    /// Index one file: lex must already have happened (the caller owns
    /// the token stream so allows can be parsed from the same pass).
    pub fn build(path: &str, toks: Vec<Token>, comments: &[Comment]) -> FileIndex {
        let items = parse_items(&toks);
        let directives = parse_directives(comments);
        let salt_uses = extract_salt_uses(&toks);
        FileIndex { path: path.to_string(), toks, items, directives, salt_uses }
    }

    /// Depth-first search for a fn item; `impl_type` narrows to methods
    /// of that impl (`None` matches free fns and fns in plain mods).
    pub fn find_fn(&self, impl_type: Option<&str>, name: &str) -> Option<&Item> {
        fn walk<'a>(
            items: &'a [Item],
            in_impl: Option<&str>,
            impl_type: Option<&str>,
            name: &str,
        ) -> Option<&'a Item> {
            for it in items {
                match it.kind {
                    ItemKind::Fn if it.name == name && in_impl == impl_type => return Some(it),
                    ItemKind::Impl => {
                        if let Some(f) = walk(&it.children, Some(&it.name), impl_type, name) {
                            return Some(f);
                        }
                    }
                    ItemKind::Mod => {
                        if let Some(f) = walk(&it.children, None, impl_type, name) {
                            return Some(f);
                        }
                    }
                    _ => {}
                }
            }
            None
        }
        walk(&self.items, None, impl_type, name)
    }

    /// Depth-first search for a fn by name in *any* impl/mod context
    /// (the D009 funnel is a method, but the rule should survive it
    /// moving to a free fn).
    pub fn find_fn_named(&self, name: &str) -> Option<&Item> {
        fn walk<'a>(items: &'a [Item], name: &str) -> Option<&'a Item> {
            for it in items {
                if it.kind == ItemKind::Fn && it.name == name {
                    return Some(it);
                }
                if let Some(f) = walk(&it.children, name) {
                    return Some(f);
                }
            }
            None
        }
        walk(&self.items, name)
    }

    /// Depth-first search for a struct/enum by name.
    pub fn find_type(&self, kind: ItemKind, name: &str) -> Option<&Item> {
        fn walk<'a>(items: &'a [Item], kind: ItemKind, name: &str) -> Option<&'a Item> {
            for it in items {
                if it.kind == kind && it.name == name {
                    return Some(it);
                }
                if let Some(f) = walk(&it.children, kind, name) {
                    return Some(f);
                }
            }
            None
        }
        walk(&self.items, kind, name)
    }

    /// The source line range `[first, last]` of an item's body tokens.
    pub fn body_lines(&self, item: &Item) -> Option<(u32, u32)> {
        let (s, e) = item.body?;
        if s >= e || e > self.toks.len() {
            return None;
        }
        Some((self.toks[s].line, self.toks[e - 1].line))
    }

    /// Does `ident` appear as an identifier token inside the item's body?
    pub fn body_has_ident(&self, item: &Item, ident: &str) -> bool {
        let Some((s, e)) = item.body else { return false };
        self.toks[s..e.min(self.toks.len())]
            .iter()
            .any(|t| t.kind == TokenKind::Ident && t.text == ident)
    }
}

impl CrateIndex {
    pub fn build(files: Vec<FileIndex>) -> CrateIndex {
        CrateIndex { files }
    }

    /// The unique file whose path ends with `suffix` (anchor lookup).
    pub fn file_ending(&self, suffix: &str) -> Option<&FileIndex> {
        self.files.iter().find(|f| f.path.ends_with(suffix))
    }

    /// Every `const`/`static` whose name starts with `prefix`, flattened
    /// across files, in (path, line) order.
    pub fn consts_with_prefix(&self, prefix: &str) -> Vec<ConstDef> {
        let mut out = Vec::new();
        for f in &self.files {
            fn walk(items: &[Item], prefix: &str, path: &str, out: &mut Vec<ConstDef>) {
                for it in items {
                    if matches!(it.kind, ItemKind::Const | ItemKind::Static)
                        && it.name.starts_with(prefix)
                    {
                        out.push(ConstDef {
                            name: it.name.clone(),
                            path: path.to_string(),
                            line: it.line,
                            value: it.const_value,
                        });
                    }
                    walk(&it.children, prefix, path, out);
                }
            }
            walk(&f.items, prefix, &f.path, &mut out);
        }
        out
    }
}

/// Parse `lint:covers(..)` / `lint:reducer(..)` directives out of line
/// comments. Doc comments never carry directives (documentation about the
/// syntax stays inert), matching the `lint:allow` convention.
pub fn parse_directives(comments: &[Comment]) -> Vec<Directive> {
    let mut out = Vec::new();
    for c in comments {
        if c.text.starts_with("///") || c.text.starts_with("//!") {
            continue;
        }
        for (needle, verb) in
            [("lint:covers(", DirectiveVerb::Covers), ("lint:reducer(", DirectiveVerb::Reducer)]
        {
            let Some(at) = c.text.find(needle) else { continue };
            let rest = &c.text[at + needle.len()..];
            let Some(close) = rest.find(')') else { continue };
            let mut parts = rest[..close].split(',').map(str::trim);
            let rule = parts.next().unwrap_or("").to_string();
            let names: Vec<String> =
                parts.filter(|s| !s.is_empty()).map(str::to_string).collect();
            let after = rest[close + 1..].trim_start();
            let reason = after.strip_prefix(':').map(str::trim).unwrap_or("").to_string();
            out.push(Directive { verb, rule, names, line: c.line, reason });
        }
    }
    out
}

/// Extract `Rng::new( expr )` fork sites whose xor operands resolve to a
/// bare `SALT_*`-suffixed path (`seed ^ SALT_X`, `s ^ crate::m::SALT_X`).
/// Call operands (`seed ^ fnv1a(..)`) are derived salts, not symbols, and
/// are deliberately skipped — D003 already polices literal operands.
fn extract_salt_uses(toks: &[Token]) -> Vec<SaltUse> {
    let mut out = Vec::new();
    let text = |i: usize| toks.get(i).map(|t| t.text.as_str()).unwrap_or("");
    for i in 0..toks.len() {
        if !(toks[i].kind == TokenKind::Ident
            && toks[i].text == "Rng"
            && text(i + 1) == "::"
            && text(i + 2) == "new"
            && text(i + 3) == "(")
        {
            continue;
        }
        let mut j = i + 4;
        let mut depth = 1i32;
        while j < toks.len() && depth > 0 {
            match text(j) {
                "(" => depth += 1,
                ")" => depth -= 1,
                "^" => {
                    for side in [salt_operand_after(toks, j), salt_operand_before(toks, j)] {
                        if let Some((name, line, in_test)) = side {
                            out.push(SaltUse { name, line, in_test });
                        }
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }
    out
}

/// Resolve the operand after `^` at index `x`: walk the `A::B::SALT_X`
/// path forward; a trailing `(` makes it a call (skipped).
fn salt_operand_after(toks: &[Token], x: usize) -> Option<(String, u32, bool)> {
    let mut j = x + 1;
    let mut last: Option<&Token> = None;
    loop {
        let t = toks.get(j)?;
        if t.kind != TokenKind::Ident {
            return None;
        }
        last = Some(t);
        j += 1;
        match toks.get(j).map(|t| t.text.as_str()) {
            Some("::") => j += 1,
            Some("(") => return None, // call, not a symbol
            _ => break,
        }
    }
    let t = last?;
    t.text.starts_with("SALT_").then(|| (t.text.clone(), t.line, t.in_test))
}

/// Resolve the operand before `^` at index `x`: the ident just left of
/// the operator (call results end in `)` and are skipped).
fn salt_operand_before(toks: &[Token], x: usize) -> Option<(String, u32, bool)> {
    let t = toks.get(x.checked_sub(1)?)?;
    if t.kind != TokenKind::Ident || !t.text.starts_with("SALT_") {
        return None;
    }
    Some((t.text.clone(), t.line, t.in_test))
}

/// One `Enum::Variant` mention, classified as pattern (match arm /
/// binding position) or construction.
#[derive(Debug, Clone)]
pub struct Mention {
    pub line: u32,
    pub is_pattern: bool,
    pub in_test: bool,
}

/// Find `enum_name :: variant` mentions in `toks`. Classification is
/// positional: after the path (and its brace/paren payload group, if
/// any), `=>`, `|`, `if`, or `=` mark a pattern; anything else is a
/// construction. Type-unaware by design — see DESIGN.md divergences.
pub fn enum_mentions(toks: &[Token], enum_name: &str, variant: &str) -> Vec<Mention> {
    let text = |i: usize| toks.get(i).map(|t| t.text.as_str()).unwrap_or("");
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !(toks[i].kind == TokenKind::Ident
            && toks[i].text == enum_name
            && text(i + 1) == "::"
            && text(i + 2) == variant)
        {
            continue;
        }
        let mut j = i + 3;
        match text(j) {
            "{" => j = skip_group(toks, j, "{", "}"),
            "(" => j = skip_group(toks, j, "(", ")"),
            _ => {}
        }
        let is_pattern = matches!(text(j), "=>" | "|" | "if" | "=");
        out.push(Mention { line: toks[i].line, is_pattern, in_test: toks[i].in_test });
    }
    out
}

fn skip_group(toks: &[Token], i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < toks.len() {
        if toks[j].text == open {
            depth += 1;
        } else if toks[j].text == close {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::{lex, mark_test_regions};

    fn index(path: &str, src: &str) -> FileIndex {
        let (mut toks, comments) = lex(src);
        mark_test_regions(&mut toks);
        FileIndex::build(path, toks, &comments)
    }

    #[test]
    fn salt_uses_resolve_paths_and_skip_calls() {
        let f = index(
            "src/a.rs",
            "fn f(seed: u64) {\n\
             \x20   let a = Rng::new(seed ^ SALT_A);\n\
             \x20   let b = Rng::new(crate::util::SALT_B ^ seed);\n\
             \x20   let c = Rng::new(seed ^ fnv1a(b\"tag\"));\n\
             }",
        );
        let names: Vec<&str> = f.salt_uses.iter().map(|u| u.name.as_str()).collect();
        assert_eq!(names, vec!["SALT_A", "SALT_B"]);
    }

    #[test]
    fn directives_parse_names_and_reasons() {
        let (_, comments) =
            lex("// lint:covers(D008, A, B): wildcard arm\n// lint:reducer(D007, peak): max\n/// lint:covers(D008, Doc): inert\n");
        let ds = parse_directives(&comments);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].verb, DirectiveVerb::Covers);
        assert_eq!(ds[0].rule, "D008");
        assert_eq!(ds[0].names, vec!["A", "B"]);
        assert_eq!(ds[0].reason, "wildcard arm");
        assert_eq!(ds[1].verb, DirectiveVerb::Reducer);
    }

    #[test]
    fn enum_mentions_split_ctor_from_pattern() {
        let src = "fn f(e: Kind) { match e { Kind::A { x } => x, _ => 0 }; \
                   push(Kind::A { x: 1 }); if let Kind::A { x } = e {} }";
        let (toks, _) = lex(src);
        let m = enum_mentions(&toks, "Kind", "A");
        assert_eq!(m.len(), 3);
        assert!(m[0].is_pattern); // match arm
        assert!(!m[1].is_pattern); // construction
        assert!(m[2].is_pattern); // if-let binding
    }

    #[test]
    fn const_prefix_query_spans_files() {
        let a = index("src/a.rs", "pub const SALT_A: u64 = 0x1; const OTHER: u64 = 9;");
        let b = index("src/b.rs", "mod inner { pub const SALT_B: u64 = 0x2; }");
        let idx = CrateIndex::build(vec![a, b]);
        let defs = idx.consts_with_prefix("SALT_");
        let names: Vec<&str> = defs.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, vec!["SALT_A", "SALT_B"]);
        assert_eq!(defs[0].value, Some(1));
    }

    #[test]
    fn find_fn_disambiguates_impl_types() {
        let f = index(
            "src/a.rs",
            "impl Foo { fn go(&self) -> u32 { 1 } }\nimpl Bar { fn go(&self) -> u32 { 2 } }\nfn go() {}",
        );
        let foo = f.find_fn(Some("Foo"), "go").unwrap();
        let bar = f.find_fn(Some("Bar"), "go").unwrap();
        let free = f.find_fn(None, "go").unwrap();
        assert!(foo.line < bar.line && bar.line < free.line);
        assert!(!f.body_has_ident(foo, "1")); // 1 is an Int, not an Ident
    }
}
