//! Rendering for lint outcomes: the human-readable report (violations +
//! the `lint:allow` summary table), the `--list-rules` registry table,
//! and the machine-readable `--json` document CI artifacts can consume.

use crate::util::json::Json;
use crate::util::table::Table;

use super::rules::rule_metas;
use super::{AllowedSite, LintOutcome, Violation};

/// Render the human-readable report. Violations first (grep-friendly
/// `path:line [rule] message` lines, cross-file hits followed by their
/// `related:` site), then the allow summary table, then a one-line
/// verdict.
pub fn render(out: &LintOutcome) -> String {
    let mut s = String::new();
    if !out.violations.is_empty() {
        s.push_str("determinism violations:\n");
        for v in &out.violations {
            s.push_str(&format!("  {}:{} [{}] {}\n", v.path, v.line, v.rule, v.message));
            if let Some(r) = &v.related {
                s.push_str(&format!("      related: {}:{} ({})\n", r.path, r.line, r.note));
            }
        }
        s.push('\n');
    }
    if !out.allowed.is_empty() {
        s.push_str(&allow_table("lint:allow escapes in effect", &out.allowed).render());
        s.push('\n');
    }
    if !out.unused_allows.is_empty() {
        s.push_str(&allow_table("unused lint:allow escapes (stale?)", &out.unused_allows).render());
        s.push('\n');
    }
    s.push_str(&format!(
        "lint: {} file(s), {} violation(s), {} allowed, {} unused allow(s)\n",
        out.files,
        out.violations.len(),
        out.allowed.len(),
        out.unused_allows.len()
    ));
    s
}

/// Render the `--list-rules` registry: id, pass, scope, contract.
pub fn render_rule_list() -> String {
    let mut t = Table::new("lint rules", &["id", "pass", "scope", "contract"]);
    for m in rule_metas() {
        t.row(vec![
            m.id.to_string(),
            m.pass.label().to_string(),
            m.scope.to_string(),
            m.summary.to_string(),
        ]);
    }
    t.render()
}

fn allow_table(title: &str, sites: &[AllowedSite]) -> Table {
    let mut t = Table::new(title, &["rule", "site", "reason"]);
    for a in sites {
        t.row(vec![a.rule.clone(), format!("{}:{}", a.path, a.line), a.reason.clone()]);
    }
    t
}

/// The `--json` document: rules, violations, allows, counters. Object
/// keys are BTreeMap-ordered and files were walked sorted, so the dump is
/// byte-stable across runs.
pub fn to_json(out: &LintOutcome) -> Json {
    let rules = rule_metas()
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("id", Json::Str(m.id.to_string())),
                ("summary", Json::Str(m.summary.to_string())),
                ("scope", Json::Str(m.scope.to_string())),
                ("pass", Json::Str(m.pass.label().to_string())),
            ])
        })
        .collect();
    let violations = out.violations.iter().map(violation_json).collect();
    Json::obj(vec![
        ("rules", Json::Arr(rules)),
        ("violations", Json::Arr(violations)),
        ("allowed", Json::Arr(allow_json(&out.allowed))),
        ("unused_allows", Json::Arr(allow_json(&out.unused_allows))),
        ("files", Json::Num(out.files as f64)),
        ("clean", Json::Bool(out.is_clean())),
    ])
}

fn violation_json(v: &Violation) -> Json {
    let mut fields = vec![
        ("rule", Json::Str(v.rule.clone())),
        ("path", Json::Str(v.path.clone())),
        ("line", Json::Num(f64::from(v.line))),
        ("message", Json::Str(v.message.clone())),
    ];
    if let Some(r) = &v.related {
        fields.push((
            "related",
            Json::obj(vec![
                ("path", Json::Str(r.path.clone())),
                ("line", Json::Num(f64::from(r.line))),
                ("note", Json::Str(r.note.clone())),
            ]),
        ));
    }
    Json::obj(fields)
}

fn allow_json(sites: &[AllowedSite]) -> Vec<Json> {
    sites
        .iter()
        .map(|a| {
            Json::obj(vec![
                ("rule", Json::Str(a.rule.clone())),
                ("path", Json::Str(a.path.clone())),
                ("line", Json::Num(f64::from(a.line))),
                ("reason", Json::Str(a.reason.clone())),
            ])
        })
        .collect()
}
