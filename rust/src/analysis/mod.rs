//! `shabari-lint`: a dependency-free static-analysis pass enforcing the
//! repo's determinism contracts (DESIGN.md §Static analysis).
//!
//! Every result in this repo rests on byte-identical replay — the online
//! learner's SGD order, the cross-seed sweep CIs, and the jobs-/shard-
//! invariance pins all assume event order and RNG draws are exactly
//! reproducible. Those contracts were enforced only at runtime (byte-pin
//! tests); this pass checks them at CI time, before a refactor can
//! reintroduce hash-order or wall-clock nondeterminism.
//!
//! The analyzer runs **two passes**. Pass one lexes every file, marks
//! test regions, parses the item tree ([`parse`]) and builds a crate-wide
//! symbol index ([`symbols`]). Pass two runs the per-file token rules
//! (**D001–D005**: hash order, wall clock, unsalted RNG, float order,
//! fallible pops) and the cross-file contract rules (**D006–D010**: the
//! salt registry, metrics-aggregation coverage, trace-taxonomy coverage,
//! the Evict funnel, RNG-stream hygiene) over the index. Cross-file
//! violations cite both sites — the offending line *and* the conflicting
//! definition.
//!
//! Escape hatch: `// lint:allow(DXXX): <reason>`. Trailing on a line it
//! covers that line; standalone it covers the next code line. The reason
//! is mandatory — an allow without one is itself a violation — and every
//! used escape is reported in the summary table, so the audit trail stays
//! visible. Unused allows are reported but do not fail the build. Two
//! structured cousins feed the coverage rules: `lint:covers(D008, ..)`
//! and `lint:reducer(D007, ..)` (see [`symbols`]).
//!
//! Entry points: [`lint_source`] (one in-memory file — the fixture
//! tests), [`lint_sources`] (a set of in-memory files — the cross-file
//! fixtures), [`lint_tree`] (walk `src`/`tests`/`benches`/`examples`
//! under a root). The `lint` CLI subcommand wraps [`lint_tree`] with
//! `--json`, `--only`, `--list-rules` and a non-zero exit on violations,
//! which is what CI gates on.

pub mod lexer;
pub mod parse;
pub mod report;
pub mod rules;
pub mod symbols;

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use lexer::{lex, mark_test_regions, Comment, Token};
use symbols::{CrateIndex, DirectiveVerb, FileIndex};

/// The second location of a cross-file diagnostic: where the conflicting
/// definition / aggregation fn / sanctioned funnel lives.
#[derive(Debug, Clone)]
pub struct RelatedSite {
    pub path: String,
    pub line: u32,
    pub note: String,
}

/// A confirmed violation (no matching `lint:allow`).
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub message: String,
    pub related: Option<RelatedSite>,
}

/// A `lint:allow` escape, with the line it covers and its reason.
#[derive(Debug, Clone)]
pub struct AllowedSite {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub reason: String,
}

/// Everything one lint pass produced.
#[derive(Debug, Clone, Default)]
pub struct LintOutcome {
    pub violations: Vec<Violation>,
    /// Escapes that suppressed a real rule hit (the summary table).
    pub allowed: Vec<AllowedSite>,
    /// Escapes that matched nothing (reported, not fatal: the linter errs
    /// toward keeping stale-but-documented escapes visible rather than
    /// breaking the build over them).
    pub unused_allows: Vec<AllowedSite>,
    pub files: usize,
}

impl LintOutcome {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// One parsed `lint:allow` escape before matching.
#[derive(Debug)]
struct Allow {
    rule: String,
    line: u32,
    covered: u32,
    reason: String,
    used: bool,
}

/// Parse `lint:allow(DXXX): reason` escapes out of the plain `//` line
/// comments — several rules at once via a comma list. A trailing comment
/// covers its own line; a standalone comment covers the next line that
/// holds any token. Doc comments (`///`, `//!`) never carry escapes, so
/// documentation *about* the escape syntax stays inert.
fn parse_allows(toks: &[Token], comments: &[Comment]) -> Vec<Allow> {
    let mut allows = Vec::new();
    for c in comments {
        if c.text.starts_with("///") || c.text.starts_with("//!") {
            continue;
        }
        let Some(at) = c.text.find("lint:allow(") else {
            continue;
        };
        let rest = &c.text[at + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else {
            continue;
        };
        let ids = &rest[..close];
        let after = rest[close + 1..].trim_start();
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("").to_string();
        let covered = if c.trailing {
            c.line
        } else {
            toks.iter()
                .map(|t| t.line)
                .find(|&l| l > c.line)
                .unwrap_or(0)
        };
        for id in ids.split(',') {
            let id = id.trim();
            if id.is_empty() {
                continue;
            }
            allows.push(Allow {
                rule: id.to_string(),
                line: c.line,
                covered,
                reason: reason.clone(),
                used: false,
            });
        }
    }
    allows
}

/// Lint one file's source text. `path` drives rule scoping and should be
/// repo-relative with `/` separators (`src/simulator/engine.rs`).
pub fn lint_source(path: &str, src: &str) -> LintOutcome {
    lint_sources(&[(path, src)])
}

/// Lint a set of in-memory files as one crate (both passes).
pub fn lint_sources(files: &[(&str, &str)]) -> LintOutcome {
    lint_sources_only(files, None)
}

/// [`lint_sources`] restricted to a rule subset (`--only D006,D007`).
/// Meta-hygiene (reasonless allows/directives, unknown directive rules)
/// always applies: a rule filter must not hide a malformed escape.
pub fn lint_sources_only(files: &[(&str, &str)], only: Option<&BTreeSet<String>>) -> LintOutcome {
    let mut sorted: Vec<(&str, &str)> = files.to_vec();
    sorted.sort_by_key(|&(p, _)| p);

    // pass one: lex, mark test regions, parse allows + item tree, index
    let mut allows_by_path: BTreeMap<String, Vec<Allow>> = BTreeMap::new();
    let mut indexed = Vec::with_capacity(sorted.len());
    for (path, src) in &sorted {
        let (mut toks, comments) = lex(src);
        mark_test_regions(&mut toks);
        allows_by_path.insert(path.to_string(), parse_allows(&toks, &comments));
        indexed.push(FileIndex::build(path, toks, &comments));
    }
    let idx = CrateIndex::build(indexed);

    // pass two: token rules per file, then crate rules over the index
    let mut raw = Vec::new();
    for f in &idx.files {
        rules::check_file(&f.path, &f.toks, &mut raw);
    }
    for rule in rules::crate_rules() {
        rule.check(&idx, &mut raw);
    }
    if let Some(only) = only {
        raw.retain(|v| only.contains(v.rule));
    }
    raw.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));

    // match escapes, collect meta-hygiene violations
    let mut out = LintOutcome { files: idx.files.len(), ..LintOutcome::default() };
    for v in raw {
        let hit = allows_by_path.get_mut(&v.path).and_then(|allows| {
            allows
                .iter_mut()
                .find(|a| a.rule == v.rule && (a.covered == v.line || a.line == v.line))
        });
        match hit {
            Some(a) => {
                a.used = true;
                out.allowed.push(AllowedSite {
                    rule: v.rule.to_string(),
                    path: v.path.clone(),
                    line: v.line,
                    reason: a.reason.clone(),
                });
            }
            None => out.violations.push(Violation {
                rule: v.rule.to_string(),
                path: v.path,
                line: v.line,
                message: v.message,
                related: v.related,
            }),
        }
    }
    for (path, allows) in &allows_by_path {
        for a in allows {
            if a.reason.is_empty() {
                out.violations.push(Violation {
                    rule: a.rule.clone(),
                    path: path.clone(),
                    line: a.line,
                    message: "lint:allow without a reason: every escape must say why \
                              the site is safe"
                        .to_string(),
                    related: None,
                });
            } else if !a.used {
                out.unused_allows.push(AllowedSite {
                    rule: a.rule.clone(),
                    path: path.clone(),
                    line: a.line,
                    reason: a.reason.clone(),
                });
            }
        }
    }
    for f in &idx.files {
        directive_hygiene(f, &mut out.violations);
    }
    out.violations
        .sort_by(|a, b| (&a.path, a.line, &a.rule).cmp(&(&b.path, b.line, &b.rule)));
    out
}

/// Structured-directive hygiene, always on: a `lint:covers`/`lint:reducer`
/// with no reason or bound to the wrong rule is itself a violation (a
/// silently ignored annotation would be worse than none).
fn directive_hygiene(f: &FileIndex, out: &mut Vec<Violation>) {
    for d in &f.directives {
        let (verb, expected) = match d.verb {
            DirectiveVerb::Covers => ("lint:covers", "D008"),
            DirectiveVerb::Reducer => ("lint:reducer", "D007"),
        };
        if d.rule != expected {
            out.push(Violation {
                rule: expected.to_string(),
                path: f.path.clone(),
                line: d.line,
                message: format!(
                    "{verb} only annotates {expected} (got {}); the directive is ignored \
                     as written",
                    if d.rule.is_empty() { "nothing" } else { &d.rule }
                ),
                related: None,
            });
        }
        if d.reason.is_empty() {
            out.push(Violation {
                rule: expected.to_string(),
                path: f.path.clone(),
                line: d.line,
                message: format!(
                    "{verb} without a reason: every annotation must say why the \
                     divergence is deliberate"
                ),
                related: None,
            });
        }
    }
}

/// The scanned subtrees, relative to the crate dir (`rust/`).
const SCAN_DIRS: &[&str] = &["src", "tests", "benches"];

/// Resolve the crate dir under `root`: accepts both the repo root (which
/// holds `rust/src`) and the crate dir itself (`cargo test` runs with cwd
/// = `rust/`).
fn crate_dir(root: &Path) -> Result<PathBuf> {
    let nested = root.join("rust");
    if nested.join("src").is_dir() {
        return Ok(nested);
    }
    if root.join("src").is_dir() {
        return Ok(root.to_path_buf());
    }
    anyhow::bail!(
        "no Rust tree under {}: expected rust/src or src",
        root.display()
    )
}

/// Recursively collect `.rs` files, sorted for a deterministic report.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Every `.rs` file the tree walk lints, as (repo-relative label, path)
/// pairs sorted by label: `src`, `tests`, `benches` under the crate dir,
/// plus the workspace `examples/` tree (which sits next to the crate dir
/// when the linter runs from the repo root, or one level up when `cargo
/// test` runs with cwd = `rust/`).
pub fn tree_files(root: &Path) -> Result<Vec<(String, PathBuf)>> {
    let crate_root = crate_dir(root)?;
    let mut out = Vec::new();
    let mut add = |label: &str, dir: &Path, out: &mut Vec<(String, PathBuf)>| -> Result<()> {
        let mut files = Vec::new();
        collect_rs(dir, &mut files)?;
        for p in files {
            let rel = p
                .strip_prefix(dir)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            out.push((format!("{label}/{rel}"), p));
        }
        Ok(())
    };
    for sub in SCAN_DIRS {
        let dir = crate_root.join(sub);
        if dir.is_dir() {
            add(sub, &dir, &mut out)?;
        }
    }
    for cand in [crate_root.join("examples"), crate_root.join("..").join("examples")] {
        if cand.is_dir() {
            add("examples", &cand, &mut out)?;
            break;
        }
    }
    out.sort();
    Ok(out)
}

/// Lint the whole tree under `root` (repo root or crate dir) as one
/// crate: both passes over every file [`tree_files`] returns.
pub fn lint_tree(root: &Path) -> Result<LintOutcome> {
    lint_tree_only(root, None)
}

/// [`lint_tree`] restricted to a rule subset (`--only`).
pub fn lint_tree_only(root: &Path, only: Option<&BTreeSet<String>>) -> Result<LintOutcome> {
    let files = tree_files(root)?;
    let mut srcs = Vec::with_capacity(files.len());
    for (rel, p) in &files {
        let src = fs::read_to_string(p).with_context(|| format!("reading {}", p.display()))?;
        srcs.push((rel.clone(), src));
    }
    let refs: Vec<(&str, &str)> = srcs.iter().map(|(p, s)| (p.as_str(), s.as_str())).collect();
    Ok(lint_sources_only(&refs, only))
}
