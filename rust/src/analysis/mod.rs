//! `shabari-lint`: a dependency-free static-analysis pass enforcing the
//! repo's determinism contracts (DESIGN.md §Static analysis).
//!
//! Every result in this repo rests on byte-identical replay — the online
//! learner's SGD order, the cross-seed sweep CIs, and the jobs-/shard-
//! invariance pins all assume event order and RNG draws are exactly
//! reproducible. Those contracts were enforced only at runtime (byte-pin
//! tests); this pass checks them at CI time, before a refactor can
//! reintroduce hash-order or wall-clock nondeterminism:
//!
//! * **D001** no `HashMap`/`HashSet` in `simulator/`, `coordinator/`,
//!   `learner/`, `metrics/` paths;
//! * **D002** no wall-clock reads outside `util::bench`/benches;
//! * **D003** RNG forks through `util::rng` with named `SALT_*` salts;
//! * **D004** float ordering via `total_cmp`, never `partial_cmp`/`f64 ==`;
//! * **D005** no `unwrap/expect` on event/admission-queue pops in
//!   `simulator/`.
//!
//! Escape hatch: `// lint:allow(DXXX): <reason>`. Trailing on a line it
//! covers that line; standalone it covers the next code line. The reason
//! is mandatory — an allow without one is itself a violation — and every
//! used escape is reported in the summary table, so the audit trail stays
//! visible. Unused allows are reported but do not fail the build.
//!
//! Entry points: [`lint_source`] (one in-memory file — the fixture tests),
//! [`lint_tree`] (walk `src`/`tests`/`benches` under a root). The `lint`
//! CLI subcommand wraps [`lint_tree`] with `--json` and a non-zero exit
//! on violations, which is what CI gates on.

pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use lexer::{lex, mark_test_regions, Comment, Token};
use rules::check_file;

/// A confirmed violation (no matching `lint:allow`).
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub message: String,
}

/// A `lint:allow` escape, with the line it covers and its reason.
#[derive(Debug, Clone)]
pub struct AllowedSite {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub reason: String,
}

/// Everything one lint pass produced.
#[derive(Debug, Clone, Default)]
pub struct LintOutcome {
    pub violations: Vec<Violation>,
    /// Escapes that suppressed a real rule hit (the summary table).
    pub allowed: Vec<AllowedSite>,
    /// Escapes that matched nothing (reported, not fatal: the linter errs
    /// toward keeping stale-but-documented escapes visible rather than
    /// breaking the build over them).
    pub unused_allows: Vec<AllowedSite>,
    pub files: usize,
}

impl LintOutcome {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    fn merge(&mut self, other: LintOutcome) {
        self.violations.extend(other.violations);
        self.allowed.extend(other.allowed);
        self.unused_allows.extend(other.unused_allows);
        self.files += other.files;
    }
}

/// One parsed `lint:allow` escape before matching.
#[derive(Debug)]
struct Allow {
    rule: String,
    line: u32,
    covered: u32,
    reason: String,
    used: bool,
}

/// Parse `lint:allow(DXXX): reason` escapes out of the plain `//` line
/// comments — several rules at once via a comma list. A trailing comment
/// covers its own line; a standalone comment covers the next line that
/// holds any token. Doc comments (`///`, `//!`) never carry escapes, so
/// documentation *about* the escape syntax stays inert.
fn parse_allows(toks: &[Token], comments: &[Comment]) -> Vec<Allow> {
    let mut allows = Vec::new();
    for c in comments {
        if c.text.starts_with("///") || c.text.starts_with("//!") {
            continue;
        }
        let Some(at) = c.text.find("lint:allow(") else {
            continue;
        };
        let rest = &c.text[at + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else {
            continue;
        };
        let ids = &rest[..close];
        let after = rest[close + 1..].trim_start();
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("").to_string();
        let covered = if c.trailing {
            c.line
        } else {
            toks.iter()
                .map(|t| t.line)
                .find(|&l| l > c.line)
                .unwrap_or(0)
        };
        for id in ids.split(',') {
            let id = id.trim();
            if id.is_empty() {
                continue;
            }
            allows.push(Allow {
                rule: id.to_string(),
                line: c.line,
                covered,
                reason: reason.clone(),
                used: false,
            });
        }
    }
    allows
}

/// Lint one file's source text. `path` drives rule scoping and should be
/// repo-relative with `/` separators (`rust/src/simulator/engine.rs`).
pub fn lint_source(path: &str, src: &str) -> LintOutcome {
    let (mut toks, comments) = lex(src);
    mark_test_regions(&mut toks);
    let mut allows = parse_allows(&toks, &comments);
    let raw = check_file(path, &toks);

    let mut out = LintOutcome { files: 1, ..LintOutcome::default() };
    for v in raw {
        let hit = allows
            .iter_mut()
            .find(|a| a.rule == v.rule && (a.covered == v.line || a.line == v.line));
        match hit {
            Some(a) => {
                a.used = true;
                out.allowed.push(AllowedSite {
                    rule: v.rule.to_string(),
                    path: path.to_string(),
                    line: v.line,
                    reason: a.reason.clone(),
                });
            }
            None => out.violations.push(Violation {
                rule: v.rule.to_string(),
                path: path.to_string(),
                line: v.line,
                message: v.message,
            }),
        }
    }
    for a in &allows {
        if a.reason.is_empty() {
            out.violations.push(Violation {
                rule: a.rule.clone(),
                path: path.to_string(),
                line: a.line,
                message: "lint:allow without a reason: every escape must say why \
                          the site is safe"
                    .to_string(),
            });
        } else if !a.used {
            out.unused_allows.push(AllowedSite {
                rule: a.rule.clone(),
                path: path.to_string(),
                line: a.line,
                reason: a.reason.clone(),
            });
        }
    }
    out
}

/// The scanned subtrees, relative to the crate dir (`rust/`).
const SCAN_DIRS: &[&str] = &["src", "tests", "benches"];

/// Resolve the crate dir under `root`: accepts both the repo root (which
/// holds `rust/src`) and the crate dir itself (`cargo test` runs with cwd
/// = `rust/`).
fn crate_dir(root: &Path) -> Result<std::path::PathBuf> {
    let nested = root.join("rust");
    if nested.join("src").is_dir() {
        return Ok(nested);
    }
    if root.join("src").is_dir() {
        return Ok(root.to_path_buf());
    }
    anyhow::bail!(
        "no Rust tree under {}: expected rust/src or src",
        root.display()
    )
}

/// Recursively collect `.rs` files, sorted for a deterministic report.
fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the whole tree under `root` (repo root or crate dir): every `.rs`
/// file in `src`, `tests`, and `benches`.
pub fn lint_tree(root: &Path) -> Result<LintOutcome> {
    let crate_root = crate_dir(root)?;
    let mut files = Vec::new();
    for sub in SCAN_DIRS {
        let dir = crate_root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut out = LintOutcome::default();
    for f in &files {
        let src = fs::read_to_string(f).with_context(|| format!("reading {}", f.display()))?;
        // rule scoping keys on the path relative to the crate dir
        let rel = f
            .strip_prefix(&crate_root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        out.merge(lint_source(&rel, &src));
    }
    Ok(out)
}
