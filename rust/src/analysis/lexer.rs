//! A minimal hand-rolled Rust lexer — just enough token structure for the
//! determinism rules (DESIGN.md §Static analysis). No third-party parser
//! exists in the offline build, and the rules only need identifiers,
//! literals, a handful of compound operators (`==`, `!=`, `::`, `=>`,
//! `->`) and comment/test-region boundaries; full grammar fidelity is
//! explicitly a non-goal. The item-tree parser (`analysis::parse`) builds
//! on exactly this token stream.
//!
//! What it does get right, because the rules depend on it:
//!
//! * strings (plain, raw `r#".."#`, byte) and char literals never leak
//!   identifier tokens, so fixture text inside test strings cannot
//!   self-trigger rules;
//! * char literals vs lifetimes (`'a'` vs `'a`) are disambiguated, so
//!   generic code does not desynchronize the stream;
//! * nested block comments and line comments are captured (line comments
//!   carry the `lint:allow` escapes);
//! * int vs float literals are distinguished (`1..2` stays integral,
//!   `1.0`/`1e3`/`1f64` are floats) — rule D004 keys on float operands;
//! * `#[cfg(test)]` / `#[test]` regions are marked token-by-token so
//!   test-only code is exempt from the runtime-determinism rules.

/// Token classes the rules discriminate on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    Ident,
    Int,
    Float,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One lexed token. `text` is the source slice for idents/numbers/puncts;
/// string and char literal bodies are not retained (rules never read them).
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
    /// Inside a `#[cfg(test)]`/`#[test]`-guarded block (set by
    /// [`mark_test_regions`], false straight out of [`lex`]).
    pub in_test: bool,
}

/// A line comment (`//...`). Block comments are skipped entirely: the
/// `lint:allow` escape syntax is line-comment only.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    /// Code tokens precede the comment on its own line (a trailing
    /// comment covers that line; a standalone one covers the next).
    pub trailing: bool,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: unterminated constructs run to EOF, which
/// is good enough for a linter over code that must already compile.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut last_tok_line = 0u32;

    let mut push = |toks: &mut Vec<Token>, kind: TokenKind, text: String, ln: u32| {
        toks.push(Token { kind, text, line: ln, in_test: false });
    };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            comments.push(Comment {
                line,
                text: b[start..i].iter().collect(),
                trailing: last_tok_line == line,
            });
            continue;
        }
        // block comment (nested)
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // raw strings (r"..", r#".."#, br#".."#), raw idents (r#ident)
        if c == 'r' || c == 'b' {
            let mut j = i;
            if b[j] == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 1;
            }
            if b[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && b[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == '"' {
                    k += 1;
                    let start_line = line;
                    while k < n {
                        if b[k] == '\n' {
                            line += 1;
                            k += 1;
                        } else if b[k] == '"' {
                            let tail = b[k + 1..].iter().take_while(|&&h| h == '#').count();
                            if tail >= hashes {
                                k += 1 + hashes;
                                break;
                            }
                            k += 1;
                        } else {
                            k += 1;
                        }
                    }
                    push(&mut toks, TokenKind::Str, "<raw>".into(), start_line);
                    last_tok_line = start_line;
                    i = k;
                    continue;
                }
                if hashes == 1 && j == i && k < n && is_ident_start(b[k]) {
                    let mut m = k;
                    while m < n && is_ident_char(b[m]) {
                        m += 1;
                    }
                    push(&mut toks, TokenKind::Ident, b[k..m].iter().collect(), line);
                    last_tok_line = line;
                    i = m;
                    continue;
                }
            }
            // b"..." / b'.' fall through as plain string/char below
            if c == 'b' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '\'') {
                i += 1;
                // re-dispatch on the quote character
                let q = b[i];
                if q == '"' {
                    i = scan_string(&b, i, &mut line);
                    push(&mut toks, TokenKind::Str, "<str>".into(), line);
                    last_tok_line = line;
                    continue;
                }
                let (next, text) = scan_char_or_lifetime(&b, i);
                push(&mut toks, TokenKind::Char, text, line);
                last_tok_line = line;
                i = next;
                continue;
            }
        }
        if c == '"' {
            let start_line = line;
            i = scan_string(&b, i, &mut line);
            push(&mut toks, TokenKind::Str, "<str>".into(), start_line);
            last_tok_line = start_line;
            continue;
        }
        if c == '\'' {
            // char literal vs lifetime
            if i + 1 < n && is_ident_char(b[i + 1]) && b[i + 1] != '\\' {
                let mut j = i + 1;
                while j < n && is_ident_char(b[j]) {
                    j += 1;
                }
                if j < n && b[j] == '\'' {
                    push(&mut toks, TokenKind::Char, b[i..=j].iter().collect(), line);
                    last_tok_line = line;
                    i = j + 1;
                } else {
                    push(&mut toks, TokenKind::Lifetime, b[i..j].iter().collect(), line);
                    last_tok_line = line;
                    i = j;
                }
                continue;
            }
            let (next, text) = scan_char_or_lifetime(&b, i);
            push(&mut toks, TokenKind::Char, text, line);
            last_tok_line = line;
            i = next;
            continue;
        }
        if c.is_ascii_digit() {
            let (next, kind, text) = scan_number(&b, i);
            push(&mut toks, kind, text, line);
            last_tok_line = line;
            i = next;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_char(b[j]) {
                j += 1;
            }
            push(&mut toks, TokenKind::Ident, b[i..j].iter().collect(), line);
            last_tok_line = line;
            i = j;
            continue;
        }
        // punctuation: combine the operators the rules and the item-tree
        // parser key on (`=>` delimits match arms, `->` return types)
        if i + 1 < n {
            let two: String = b[i..i + 2].iter().collect();
            if two == "==" || two == "!=" || two == "::" || two == "=>" || two == "->" {
                push(&mut toks, TokenKind::Punct, two, line);
                last_tok_line = line;
                i += 2;
                continue;
            }
        }
        push(&mut toks, TokenKind::Punct, c.to_string(), line);
        last_tok_line = line;
        i += 1;
    }
    (toks, comments)
}

/// Scan a `"`-delimited string starting at the opening quote; returns the
/// index past the closing quote. Tracks embedded newlines.
fn scan_string(b: &[char], start: usize, line: &mut u32) -> usize {
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        match b[j] {
            '\\' => j += 2,
            '\n' => {
                *line += 1;
                j += 1;
            }
            '"' => return j + 1,
            _ => j += 1,
        }
    }
    j
}

/// Scan a char literal starting at `'` (escape or punctuation body);
/// returns (index past closing quote, literal text).
fn scan_char_or_lifetime(b: &[char], start: usize) -> (usize, String) {
    let n = b.len();
    let mut j = start + 1;
    if j < n && b[j] == '\\' {
        j += 2; // skip escape head; \u{..} bodies fall into the scan below
    }
    while j < n && b[j] != '\'' {
        j += 1;
    }
    let end = (j + 1).min(n);
    (end, b[start..end].iter().collect())
}

/// Scan an integer or float literal; returns (next index, kind, text).
fn scan_number(b: &[char], start: usize) -> (usize, TokenKind, String) {
    let n = b.len();
    let mut j = start;
    let mut is_float = false;
    let radix_prefix = b[j] == '0' && j + 1 < n && matches!(b[j + 1], 'x' | 'o' | 'b');
    if radix_prefix {
        j += 2;
        while j < n && is_ident_char(b[j]) {
            j += 1;
        }
    } else {
        while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
            j += 1;
        }
        if j < n && b[j] == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
            is_float = true;
            j += 1;
            while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
                j += 1;
            }
        } else if j < n && b[j] == '.' {
            let after = b.get(j + 1);
            if after.is_none_or(|&a| !is_ident_start(a) && a != '.') {
                // trailing-dot float like `1.`
                is_float = true;
                j += 1;
            }
        }
        if j < n && (b[j] == 'e' || b[j] == 'E') {
            let mut k = j + 1;
            if k < n && (b[k] == '+' || b[k] == '-') {
                k += 1;
            }
            if k < n && b[k].is_ascii_digit() {
                is_float = true;
                j = k;
                while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
                    j += 1;
                }
            }
        }
        // type suffix (u64, f64, usize, ...) folds into the token
        let s = j;
        while j < n && is_ident_char(b[j]) {
            j += 1;
        }
        let suffix: String = b[s..j].iter().collect();
        if suffix == "f32" || suffix == "f64" {
            is_float = true;
        }
    }
    let kind = if is_float { TokenKind::Float } else { TokenKind::Int };
    (j, kind, b[start..j].iter().collect())
}

/// Mark tokens inside `#[cfg(test)]` / `#[test]`-guarded blocks. The
/// attribute latches `pending`; the next `{` opens the region (matched by
/// brace depth), while a `;` first cancels it (attribute on a `use` or
/// other braceless item). Regions do not nest observably: inside a test
/// region everything already counts as test code.
pub fn mark_test_regions(toks: &mut [Token]) {
    let n = toks.len();
    let mut depth = 0i32;
    let mut pending = false;
    let mut in_test = false;
    let mut test_depth = 0i32;
    let mut i = 0usize;
    while i < n {
        if !in_test && toks[i].text == "#" && i + 1 < n && toks[i + 1].text == "[" {
            // scan the bracket-balanced attribute
            let mut j = i + 1;
            let mut bdepth = 0i32;
            let mut first_ident: Option<String> = None;
            let mut has_test_ident = false;
            while j < n {
                match toks[j].text.as_str() {
                    "[" => bdepth += 1,
                    "]" => {
                        bdepth -= 1;
                        if bdepth == 0 {
                            break;
                        }
                    }
                    _ => {
                        if toks[j].kind == TokenKind::Ident {
                            if first_ident.is_none() {
                                first_ident = Some(toks[j].text.clone());
                            } else if toks[j].text == "test" {
                                has_test_ident = true;
                            }
                        }
                    }
                }
                j += 1;
            }
            match first_ident.as_deref() {
                Some("test") => pending = true,
                Some("cfg") if has_test_ident => pending = true,
                _ => {}
            }
            i = (j + 1).min(n);
            continue;
        }
        match toks[i].text.as_str() {
            "{" => {
                if pending {
                    in_test = true;
                    test_depth = depth;
                    pending = false;
                }
                depth += 1;
            }
            "}" => {
                depth -= 1;
                if in_test && depth == test_depth {
                    toks[i].in_test = true; // closing brace still in region
                    in_test = false;
                    i += 1;
                    continue;
                }
            }
            ";" => {
                if pending {
                    pending = false;
                }
            }
            _ => {}
        }
        toks[i].in_test = in_test;
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        let (toks, _) = lex(src);
        toks.iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text.clone())
            .collect()
    }

    #[test]
    fn strings_hide_identifier_text() {
        assert_eq!(idents(r##"let s = "HashMap::new()";"##), vec!["let", "s"]);
        let raw = "let s = r#\"Instant::now()\"#;";
        assert_eq!(idents(raw), vec!["let", "s"]);
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let (toks, _) = lex("fn f<'a>(x: &'a str) -> char { 'b' }");
        let kinds: Vec<(TokenKind, &str)> =
            toks.iter().map(|t| (t.kind, t.text.as_str())).collect();
        assert!(kinds.contains(&(TokenKind::Lifetime, "'a")));
        assert!(kinds.contains(&(TokenKind::Char, "'b'")));
    }

    #[test]
    fn numbers_classify_int_vs_float() {
        let src = "let a = 1..2; let b = 1.5; let c = 0xFF_AB; let d = 1e3; let e = 2f64;";
        let (toks, _) = lex(src);
        let nums: Vec<(TokenKind, &str)> = toks
            .iter()
            .filter(|t| matches!(t.kind, TokenKind::Int | TokenKind::Float))
            .map(|t| (t.kind, t.text.as_str()))
            .collect();
        assert_eq!(
            nums,
            vec![
                (TokenKind::Int, "1"),
                (TokenKind::Int, "2"),
                (TokenKind::Float, "1.5"),
                (TokenKind::Int, "0xFF_AB"),
                (TokenKind::Float, "1e3"),
                (TokenKind::Float, "2f64"),
            ]
        );
    }

    #[test]
    fn nested_block_comments_are_skipped() {
        assert_eq!(idents("/* a /* b */ c */ fn f() {}"), vec!["fn", "f"]);
    }

    #[test]
    fn trailing_vs_standalone_comments() {
        let (_, comments) = lex("let x = 1; // trailing\n// standalone\nlet y = 2;");
        assert_eq!(comments.len(), 2);
        assert!(comments[0].trailing);
        assert!(!comments[1].trailing);
    }

    #[test]
    fn compound_operators_are_single_tokens() {
        let (toks, _) = lex("a == b != c :: d => e -> f");
        let puncts: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokenKind::Punct)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(puncts, vec!["==", "!=", "::", "=>", "->"]);
    }

    #[test]
    fn cfg_test_region_marks_tokens() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn inner() {}\n}\nfn after() {}";
        let (mut toks, _) = lex(src);
        mark_test_regions(&mut toks);
        let flag = |name: &str| toks.iter().find(|t| t.text == name).unwrap().in_test;
        assert!(!flag("live"));
        assert!(flag("inner"));
        assert!(!flag("after"));
    }

    #[test]
    fn cfg_test_on_braceless_item_does_not_latch() {
        let src = "#[cfg(test)]\nuse std::fmt;\nfn live() { let x = 1; }";
        let (mut toks, _) = lex(src);
        mark_test_regions(&mut toks);
        assert!(toks.iter().all(|t| !t.in_test));
    }
}
