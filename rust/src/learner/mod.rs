//! The online cost-sensitive multi-class (CSMC / VW-CSOAA) learner.
//!
//! One linear regressor per class predicts the *cost* of choosing that
//! class; prediction = argmin of the per-class scores. The allocator keeps
//! one vCPU model and one memory model per function (§4.2's winning
//! formulation), each over the padded F=16 feature vector.
//!
//! Two interchangeable backends implement the same math:
//! * [`NativeCsmc`] — pure rust mirror (oracle for tests, fast path for
//!   large experiment sweeps);
//! * [`xla::XlaCsmc`] — the production path: executes the AOT-compiled
//!   Pallas/JAX HLO artifacts through PJRT (`runtime::XlaEngine`).
//!
//! Parity between the two is asserted by `rust/tests/test_parity.rs`.

pub mod native;
pub mod xla;

use crate::runtime::{FEAT_DIM, NUM_CLASSES};

/// Default CSOAA learning rate (mirrors python/compile/model.py).
pub const DEFAULT_LR: f32 = 0.05;

/// A cost-sensitive multi-class model: predict per-class costs for a
/// feature vector; update from an observed cost vector.
pub trait CsmcModel {
    /// Per-class predicted costs (length [`NUM_CLASSES`]).
    fn scores(&mut self, x: &[f32; FEAT_DIM]) -> [f32; NUM_CLASSES];

    /// One online update toward the observed `costs`.
    fn update(&mut self, x: &[f32; FEAT_DIM], costs: &[f32; NUM_CLASSES]);

    /// Number of updates absorbed so far (confidence gating input).
    fn updates(&self) -> u64;

    /// Predicted best class = argmin of scores.
    fn predict(&mut self, x: &[f32; FEAT_DIM]) -> usize {
        let s = self.scores(x);
        argmin(&s)
    }
}

/// Index of the minimum value (first on ties). Ranks through
/// `f32::total_cmp` so the allocator's class choice stays a total order
/// even if a cost score ever degenerates to NaN (a NaN ranks above every
/// real score instead of poisoning every comparison it touches).
pub fn argmin(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if v.total_cmp(&xs[best]) == std::cmp::Ordering::Less {
            best = i;
        }
    }
    best
}

/// Build a CSOAA cost vector with minimum cost 1 at `target` and linearly
/// growing costs away from it; classes *below* the target (underprediction
/// of resources) are penalized `under_penalty`× more steeply than classes
/// above (§4.3.1: "underpredictions being penalized further").
pub fn cost_vector(target: usize, under_penalty: f32) -> [f32; NUM_CLASSES] {
    let mut c = [0f32; NUM_CLASSES];
    for (i, ci) in c.iter_mut().enumerate() {
        let d = i as f32 - target as f32;
        *ci = if d >= 0.0 {
            1.0 + d
        } else {
            1.0 + under_penalty * (-d)
        };
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_basics() {
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), 1);
        assert_eq!(argmin(&[1.0, 1.0]), 0, "first wins ties");
        assert_eq!(argmin(&[5.0]), 0);
        // total_cmp ranks +NaN above every real score: a degenerate cost
        // never wins the class choice and never poisons the comparison
        assert_eq!(argmin(&[f32::NAN, 2.0, 3.0]), 1);
    }

    #[test]
    fn cost_vector_shape() {
        let c = cost_vector(10, 2.0);
        assert_eq!(c[10], 1.0, "target has minimum cost 1");
        assert_eq!(c[11], 2.0, "overprediction grows by 1/class");
        assert_eq!(c[12], 3.0);
        assert_eq!(c[9], 3.0, "underprediction grows 2x steeper");
        assert_eq!(c[8], 5.0);
        // argmin recovers the target
        assert_eq!(argmin(&c), 10);
    }

    #[test]
    fn cost_vector_edges() {
        let c0 = cost_vector(0, 2.0);
        assert_eq!(argmin(&c0), 0);
        let clast = cost_vector(NUM_CLASSES - 1, 2.0);
        assert_eq!(argmin(&clast), NUM_CLASSES - 1);
    }

    #[test]
    fn costs_all_at_least_one() {
        for t in [0, 7, 47] {
            let c = cost_vector(t, 3.0);
            assert!(c.iter().all(|v| *v >= 1.0));
        }
    }
}
