//! Pure-rust CSOAA backend — a bit-faithful mirror of the L1 Pallas
//! kernels (`python/compile/kernels/csmc.py`): per-class linear scoring
//! and the rank-1 SGD update, in the same operation order and f32
//! precision. Serves as the oracle for XLA parity tests and as the fast
//! path for large experiment sweeps (`--native`).

use super::CsmcModel;
use crate::runtime::{FEAT_DIM, NUM_CLASSES};

/// Weights `W[C][F]` + update counter.
#[derive(Debug, Clone)]
pub struct NativeCsmc {
    pub w: Vec<f32>, // row-major [NUM_CLASSES * FEAT_DIM]
    pub lr: f32,
    updates: u64,
}

/// Normalized-LMS step: `lr / max(1, |x|^2)` (shared by every backend so
/// the XLA and native paths stay bit-comparable).
pub fn effective_lr(lr: f32, x: &[f32]) -> f32 {
    let norm_sq: f32 = x.iter().map(|v| v * v).sum();
    lr / norm_sq.max(1.0)
}

impl NativeCsmc {
    pub fn new(lr: f32) -> Self {
        NativeCsmc { w: vec![0.0; NUM_CLASSES * FEAT_DIM], lr, updates: 0 }
    }

    /// Raw weight row for a class (tests/inspection).
    pub fn row(&self, class: usize) -> &[f32] {
        &self.w[class * FEAT_DIM..(class + 1) * FEAT_DIM]
    }
}

impl CsmcModel for NativeCsmc {
    fn scores(&mut self, x: &[f32; FEAT_DIM]) -> [f32; NUM_CLASSES] {
        let mut out = [0f32; NUM_CLASSES];
        for (i, o) in out.iter_mut().enumerate() {
            let row = &self.w[i * FEAT_DIM..(i + 1) * FEAT_DIM];
            // same left-to-right accumulation order as the jnp matvec
            let mut acc = 0f32;
            for j in 0..FEAT_DIM {
                acc += row[j] * x[j];
            }
            *o = acc;
        }
        out
    }

    fn update(&mut self, x: &[f32; FEAT_DIM], costs: &[f32; NUM_CLASSES]) {
        // W' = W - lr_eff * outer(W@x - costs, x), with the VW-style
        // normalized step lr_eff = lr / max(1, |x|^2) so convergence is
        // unconditionally stable for any feature scaling.
        let lr_eff = effective_lr(self.lr, x);
        let scores = self.scores(x);
        for i in 0..NUM_CLASSES {
            let err = scores[i] - costs[i];
            let g = lr_eff * err;
            let row = &mut self.w[i * FEAT_DIM..(i + 1) * FEAT_DIM];
            for j in 0..FEAT_DIM {
                row[j] -= g * x[j];
            }
        }
        self.updates += 1;
    }

    fn updates(&self) -> u64 {
        self.updates
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::{argmin, cost_vector};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_x(rng: &mut Rng) -> [f32; FEAT_DIM] {
        let mut x = [0f32; FEAT_DIM];
        for v in x.iter_mut() {
            *v = rng.range_f64(-1.0, 1.0) as f32;
        }
        x[0] = 1.0; // bias
        x
    }

    #[test]
    fn zero_weights_score_zero() {
        let mut m = NativeCsmc::new(0.05);
        let x = [0.5f32; FEAT_DIM];
        assert!(m.scores(&x).iter().all(|s| *s == 0.0));
    }

    #[test]
    fn learns_fixed_target() {
        // Repeatedly presenting cost vectors for class 12 must make the
        // model predict 12 for that input.
        let mut m = NativeCsmc::new(0.1);
        let mut rng = Rng::new(5);
        let x = rand_x(&mut rng);
        let costs = cost_vector(12, 2.0);
        for _ in 0..200 {
            m.update(&x, &costs);
        }
        assert_eq!(m.predict(&x), 12);
        assert_eq!(m.updates(), 200);
    }

    #[test]
    fn learns_input_dependent_targets() {
        // Two distinguishable inputs with different target classes.
        let mut m = NativeCsmc::new(0.08);
        let mut a = [0f32; FEAT_DIM];
        a[0] = 1.0;
        a[1] = 1.0;
        let mut b = [0f32; FEAT_DIM];
        b[0] = 1.0;
        b[2] = 1.0;
        let ca = cost_vector(4, 2.0);
        let cb = cost_vector(30, 2.0);
        for _ in 0..400 {
            m.update(&a, &ca);
            m.update(&b, &cb);
        }
        assert_eq!(m.predict(&a), 4);
        assert_eq!(m.predict(&b), 30);
    }

    #[test]
    fn update_moves_scores_toward_costs() {
        prop::check(11, 30, |rng| {
            let mut m = NativeCsmc::new(0.05);
            // random warm-up so weights are nonzero
            for _ in 0..5 {
                let x = rand_x(rng);
                let c = cost_vector(rng.below(NUM_CLASSES), 2.0);
                m.update(&x, &c);
            }
            let x = rand_x(rng);
            let costs = cost_vector(rng.below(NUM_CLASSES), 2.0);
            let before = m.scores(&x);
            m.update(&x, &costs);
            let after = m.scores(&x);
            let err = |s: &[f32; NUM_CLASSES]| -> f64 {
                s.iter()
                    .zip(costs.iter())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum()
            };
            assert!(
                err(&after) <= err(&before) + 1e-6,
                "SGD step must not increase squared error"
            );
        });
    }

    #[test]
    fn argmin_stable_under_scaling() {
        let mut m = NativeCsmc::new(0.1);
        let mut rng = Rng::new(77);
        let x = rand_x(&mut rng);
        for _ in 0..100 {
            m.update(&x, &cost_vector(20, 2.0));
        }
        let s = m.scores(&x);
        assert_eq!(argmin(&s), 20);
    }

    #[test]
    fn weights_finite_under_stress() {
        let mut m = NativeCsmc::new(0.05);
        let mut rng = Rng::new(13);
        for _ in 0..5000 {
            let x = rand_x(&mut rng);
            let c = cost_vector(rng.below(NUM_CLASSES), 3.0);
            m.update(&x, &c);
        }
        assert!(m.w.iter().all(|v| v.is_finite()));
    }
}

// ---------------------------------------------------------------------------
// Dynamic-shape variant (used by the Fig-6 formulation ablation, where the
// one-hot design needs a wide feature vector that exceeds the AOT F=16).
// ---------------------------------------------------------------------------

/// CSOAA with runtime-chosen (classes, features) dimensions. Same math as
/// [`NativeCsmc`] but Vec-based; exists only for design-exploration
/// experiments — the production path is the fixed-shape AOT artifact.
#[derive(Debug, Clone)]
pub struct DynCsmc {
    pub c: usize,
    pub f: usize,
    pub w: Vec<f32>,
    pub lr: f32,
    updates: u64,
}

impl DynCsmc {
    pub fn new(c: usize, f: usize, lr: f32) -> Self {
        DynCsmc { c, f, w: vec![0.0; c * f], lr, updates: 0 }
    }

    pub fn scores_dyn(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.f);
        let mut out = vec![0f32; self.c];
        for (i, o) in out.iter_mut().enumerate() {
            let row = &self.w[i * self.f..(i + 1) * self.f];
            let mut acc = 0f32;
            for j in 0..self.f {
                acc += row[j] * x[j];
            }
            *o = acc;
        }
        out
    }

    pub fn update_dyn(&mut self, x: &[f32], costs: &[f32]) {
        assert_eq!(costs.len(), self.c);
        let lr_eff = effective_lr(self.lr, x);
        let scores = self.scores_dyn(x);
        for i in 0..self.c {
            let g = lr_eff * (scores[i] - costs[i]);
            let row = &mut self.w[i * self.f..(i + 1) * self.f];
            for j in 0..self.f {
                row[j] -= g * x[j];
            }
        }
        self.updates += 1;
    }

    pub fn predict_dyn(&self, x: &[f32]) -> usize {
        super::argmin(&self.scores_dyn(x))
    }

    pub fn updates(&self) -> u64 {
        self.updates
    }
}

#[cfg(test)]
mod dyn_tests {
    use super::*;
    use crate::learner::cost_vector;

    #[test]
    fn dyn_matches_fixed_shape_math() {
        use crate::learner::CsmcModel;
        use crate::runtime::{FEAT_DIM, NUM_CLASSES};
        let mut fixed = NativeCsmc::new(0.07);
        let mut dynm = DynCsmc::new(NUM_CLASSES, FEAT_DIM, 0.07);
        let mut x = [0f32; FEAT_DIM];
        for (j, v) in x.iter_mut().enumerate() {
            *v = (j as f32 * 0.1).sin();
        }
        let costs = cost_vector(17, 2.0);
        for _ in 0..50 {
            fixed.update(&x, &costs);
            dynm.update_dyn(&x, &costs);
        }
        for (a, b) in fixed.w.iter().zip(dynm.w.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(fixed.predict(&x), dynm.predict_dyn(&x));
    }

    #[test]
    fn dyn_wide_learns() {
        let f = 16 * 12 + 1;
        let mut m = DynCsmc::new(48, f, 0.1);
        let mut x = vec![0f32; f];
        x[0] = 1.0;
        x[5 * 16 + 3] = 0.7; // "function 5" block
        let costs: Vec<f32> = cost_vector(9, 2.0).to_vec();
        for _ in 0..300 {
            m.update_dyn(&x, &costs);
        }
        assert_eq!(m.predict_dyn(&x), 9);
    }
}
