//! XLA/PJRT-backed CSOAA backend — the production path.
//!
//! Each model owns its weight buffer on the host (tiny: 48x16 f32) and
//! executes the AOT-compiled `csmc_predict` / `csmc_update` HLO through a
//! shared [`XlaEngine`]. The engine is reference-counted: the allocator
//! creates one per process and hands clones of the `Rc` to every
//! per-function model (the coordinator is single-threaded on the decision
//! path, matching the paper's single shim-layer process).

#[cfg(feature = "xla")]
use std::rc::Rc;

use anyhow::Result;

use super::CsmcModel;
#[cfg(feature = "xla")]
use crate::runtime::{XlaEngine, FEAT_DIM, NUM_CLASSES};

/// A CSOAA model whose math runs in XLA (requires the `xla` feature).
///
/// EXPERIMENTS.md §Perf: input literals (weights, features, costs, lr) are
/// cached and mutated in place via `copy_raw_from`, avoiding four literal
/// allocations per call on the request path.
#[cfg(feature = "xla")]
pub struct XlaCsmc {
    engine: Rc<XlaEngine>,
    w: Vec<f32>,
    lr: f32,
    updates: u64,
    w_lit: xla::Literal,
    w_dirty: bool,
    x_lit: xla::Literal,
    c_lit: xla::Literal,
    lr_lit: xla::Literal,
}

/// Manual `Debug`: `xla::Literal` buffers have no `Debug`; the scalar
/// hyperparameters and update count describe the model state.
#[cfg(feature = "xla")]
impl std::fmt::Debug for XlaCsmc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaCsmc")
            .field("lr", &self.lr)
            .field("updates", &self.updates)
            .finish_non_exhaustive()
    }
}

#[cfg(feature = "xla")]
impl XlaCsmc {
    pub fn new(engine: Rc<XlaEngine>, lr: f32) -> Self {
        let w = vec![0.0; NUM_CLASSES * FEAT_DIM];
        let w_lit = XlaEngine::make_literal(&w, &Self::dims_w()).expect("w literal");
        let x_lit = XlaEngine::make_literal(&[0.0; FEAT_DIM], &[FEAT_DIM as i64]).expect("x literal");
        let c_lit =
            XlaEngine::make_literal(&[0.0; NUM_CLASSES], &[NUM_CLASSES as i64]).expect("c literal");
        let lr_lit = XlaEngine::make_literal(&[0.0], &[]).expect("lr literal");
        XlaCsmc { engine, w, lr, updates: 0, w_lit, w_dirty: false, x_lit, c_lit, lr_lit }
    }

    fn sync_w(&mut self) {
        if self.w_dirty {
            self.w_lit.copy_raw_from(&self.w).expect("w copy");
            self.w_dirty = false;
        }
    }

    fn dims_w() -> [i64; 2] {
        [NUM_CLASSES as i64, FEAT_DIM as i64]
    }

    fn exec_scores(&mut self, x: &[f32; FEAT_DIM]) -> Result<Vec<f32>> {
        self.sync_w();
        self.x_lit.copy_raw_from(x)?;
        let out = self
            .engine
            .execute_lits("csmc_predict", &[&self.w_lit, &self.x_lit])?;
        Ok(out.into_iter().next().expect("tuple element"))
    }

    fn exec_update(&mut self, x: &[f32; FEAT_DIM], costs: &[f32; NUM_CLASSES]) -> Result<()> {
        // Same normalized-LMS step as the native mirror; the AOT kernel
        // takes lr as a runtime scalar, so no recompilation is needed.
        let lr_eff = super::native::effective_lr(self.lr, x);
        self.sync_w();
        self.x_lit.copy_raw_from(x)?;
        self.c_lit.copy_raw_from(costs)?;
        self.lr_lit.copy_raw_from(&[lr_eff])?;
        let out = self.engine.execute_lits(
            "csmc_update",
            &[&self.w_lit, &self.x_lit, &self.c_lit, &self.lr_lit],
        )?;
        self.w = out.into_iter().next().expect("tuple element");
        self.w_dirty = true;
        Ok(())
    }

    /// Batched scoring through `csmc_predict_batch` (bench/replay path).
    pub fn scores_batch(&mut self, xs: &[f32]) -> Result<Vec<f32>> {
        self.sync_w();
        let b = xs.len() / FEAT_DIM;
        let out = self.engine.execute_f32(
            "csmc_predict_batch",
            &[
                (&self.w, &Self::dims_w()),
                (xs, &[b as i64, FEAT_DIM as i64]),
            ],
        )?;
        Ok(out.into_iter().next().expect("tuple element"))
    }

    /// Direct weight access (parity tests).
    pub fn weights(&self) -> &[f32] {
        &self.w
    }
}

#[cfg(feature = "xla")]
impl CsmcModel for XlaCsmc {
    fn scores(&mut self, x: &[f32; FEAT_DIM]) -> [f32; NUM_CLASSES] {
        let v = self
            .exec_scores(x)
            .expect("XLA predict failed (artifacts missing? run `make artifacts`)");
        let mut out = [0f32; NUM_CLASSES];
        out.copy_from_slice(&v);
        out
    }

    fn update(&mut self, x: &[f32; FEAT_DIM], costs: &[f32; NUM_CLASSES]) {
        self.exec_update(x, costs)
            .expect("XLA update failed (artifacts missing? run `make artifacts`)");
        self.updates += 1;
    }

    fn updates(&self) -> u64 {
        self.updates
    }
}

/// Backend selector used by the allocator & CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT Pallas/JAX artifacts through PJRT (production path).
    Xla,
    /// Pure-rust mirror (oracle / fast sweeps).
    Native,
}

/// Factory for CSMC models of the chosen backend.
pub enum ModelFactory {
    #[cfg(feature = "xla")]
    Xla(Rc<XlaEngine>, f32),
    Native(f32),
}

/// Manual `Debug`: the Xla variant holds the shared PJRT engine, which
/// has no meaningful field view; the learning rate identifies the factory.
impl std::fmt::Debug for ModelFactory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            #[cfg(feature = "xla")]
            ModelFactory::Xla(_, lr) => f.debug_tuple("Xla").field(lr).finish(),
            ModelFactory::Native(lr) => f.debug_tuple("Native").field(lr).finish(),
        }
    }
}

impl ModelFactory {
    pub fn new(backend: Backend, artifacts_dir: &str, lr: f32) -> Result<Self> {
        match backend {
            #[cfg(feature = "xla")]
            Backend::Xla => {
                let engine = Rc::new(XlaEngine::load_dir(artifacts_dir)?);
                Ok(ModelFactory::Xla(engine, lr))
            }
            #[cfg(not(feature = "xla"))]
            Backend::Xla => anyhow::bail!(
                "learner backend 'xla' needs a build with `--features xla` \
                 (artifacts dir: {artifacts_dir}); this binary has only the \
                 native mirror"
            ),
            Backend::Native => Ok(ModelFactory::Native(lr)),
        }
    }

    pub fn make(&self) -> Box<dyn CsmcModel> {
        match self {
            #[cfg(feature = "xla")]
            ModelFactory::Xla(engine, lr) => Box::new(XlaCsmc::new(engine.clone(), *lr)),
            ModelFactory::Native(lr) => Box::new(super::native::NativeCsmc::new(*lr)),
        }
    }

    pub fn backend(&self) -> Backend {
        match self {
            #[cfg(feature = "xla")]
            ModelFactory::Xla(..) => Backend::Xla,
            ModelFactory::Native(..) => Backend::Native,
        }
    }
}
