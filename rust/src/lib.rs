//! Shabari: delayed decision-making for faster and efficient serverless functions.
//!
//! Reproduction of Sinha, Kaffes, Yadwadkar (2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (rust, this crate)** — the serverless coordinator: request
//!   router, input featurizer, online resource allocator, cold-start-aware
//!   scheduler, plus the entire cluster substrate (a discrete-event
//!   simulator standing in for the paper's 17-node OpenWhisk testbed).
//! * **Layer 2 (JAX, `python/compile/model.py`)** — the cost-sensitive
//!   multi-class learner's predict/update graphs, AOT-lowered to HLO text.
//! * **Layer 1 (Pallas, `python/compile/kernels/`)** — the per-class linear
//!   scoring / SGD-update kernels called from the L2 graphs.
//!
//! Python never runs on the request path: `rust/src/runtime` loads the HLO
//! artifacts through the PJRT C API (`xla` crate) once at startup.
//!
//! Reproducibility is a checked invariant, not a convention: the runtime
//! byte-pin tests replay whole runs, and `shabari lint` (the [`analysis`]
//! module, DESIGN.md §Static analysis) statically enforces the
//! determinism contracts (D001–D005) over this crate at CI time.

// No unsafe anywhere: the only FFI (PJRT) lives behind the vendored `xla`
// crate, and everything in this crate is safe simulation/learning code.
#![forbid(unsafe_code)]
// Determinism-adjacent hygiene, enforced crate-wide. `Debug` everywhere
// keeps dumps/assertions available on every public type; the unused-*
// lints keep dead generality from accreting.
#![deny(
    missing_debug_implementations,
    non_ascii_idents,
    unused_extern_crates,
    unused_lifetimes,
    unused_must_use
)]

pub mod analysis;
pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod experiments;
pub mod featurizer;
pub mod learner;
pub mod metrics;
pub mod functions;
pub mod util;
pub mod runtime;
pub mod simulator;
pub mod workload;
