//! Shabari: delayed decision-making for faster and efficient serverless functions.
//!
//! Reproduction of Sinha, Kaffes, Yadwadkar (2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (rust, this crate)** — the serverless coordinator: request
//!   router, input featurizer, online resource allocator, cold-start-aware
//!   scheduler, plus the entire cluster substrate (a discrete-event
//!   simulator standing in for the paper's 17-node OpenWhisk testbed).
//! * **Layer 2 (JAX, `python/compile/model.py`)** — the cost-sensitive
//!   multi-class learner's predict/update graphs, AOT-lowered to HLO text.
//! * **Layer 1 (Pallas, `python/compile/kernels/`)** — the per-class linear
//!   scoring / SGD-update kernels called from the L2 graphs.
//!
//! Python never runs on the request path: `rust/src/runtime` loads the HLO
//! artifacts through the PJRT C API (`xla` crate) once at startup.

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod experiments;
pub mod featurizer;
pub mod learner;
pub mod metrics;
pub mod functions;
pub mod util;
pub mod runtime;
pub mod simulator;
pub mod workload;
